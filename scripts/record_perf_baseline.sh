#!/usr/bin/env sh
# Record a measured perf baseline into BENCH_PERF.json.
#
# The committed file starts life as an empty seed record (no toolchain in
# the authoring container), which keeps the >25% ns/op regression gate in
# `benches/perf_hotpath.rs` disarmed. Running this script anywhere a Rust
# toolchain exists fills it with real numbers; committing the result arms
# the gate. Without a toolchain the script skips cleanly and changes
# nothing, so it is safe to wire into any environment.
#
# PIM_BENCH_FAST=1 is honored (CI uses it: smaller iteration counts, no
# wall-clock speedup assertions — still measures every named target).
set -eu
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "record_perf_baseline: no cargo on PATH; skipping (BENCH_PERF.json untouched)"
    exit 0
fi

echo "record_perf_baseline: running perf_hotpath${PIM_BENCH_FAST:+ (fast mode)}..."
cargo bench --bench perf_hotpath
echo "record_perf_baseline: BENCH_PERF.json updated — commit it to arm the regression gate"
