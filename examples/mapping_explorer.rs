//! Mapping explorer: walk Algorithm 1 over every layer of a network and
//! print the placement the paper's Fig 12 illustrates — MACs per subarray,
//! stacked pairs, waves, wasted columns, and the parallelism ↔ footprint
//! trade-off (§IV-B).
//!
//! Run: `cargo run --release --example mapping_explorer [network] [k]`

use pim_dram::dram::DramGeometry;
use pim_dram::mapping::{footprint, map_network, MapConfig};
use pim_dram::util::si;
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let k: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1);
    let net = nets::by_name(&name)?;

    for (label, geometry) in [
        ("paper-ideal", DramGeometry::paper_ideal()),
        ("real DDR3  ", DramGeometry::paper_default()),
    ] {
        let cfg = MapConfig::uniform(geometry.clone(), 8, k);
        let m = map_network(&net, &cfg)?;
        let mut t = Table::new(&[
            "layer", "mac", "macs", "k", "macs/sub", "sub(ideal)", "sub(used)",
            "waves", "stack", "util%",
        ])
        .aligns(&[
            Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
            Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
        ]);
        for l in &m.layers {
            t.row(&[
                l.name.clone(),
                l.mac_size.to_string(),
                l.macs_total.to_string(),
                l.k.to_string(),
                l.macs_per_subarray.to_string(),
                l.subarrays_ideal.to_string(),
                l.subarrays_used.to_string(),
                l.waves.to_string(),
                l.stacked_pairs.to_string(),
                format!("{:.1}", l.utilization * 100.0),
            ]);
        }
        println!(
            "== {} on {} (k={k}, {} banks of {} subarrays) ==",
            net.name,
            label,
            geometry.total_banks(),
            geometry.subarrays_per_bank
        );
        println!("{}", t.render());
        println!(
            "banks used: {} (+{} residual reserves)  fully resident: {}\n",
            m.layers.len(),
            m.residual_banks,
            m.fully_resident()
        );
    }

    // Footprint trade-off for the fattest layer (§IV-B discussion).
    let fat = net
        .layers
        .iter()
        .max_by_key(|l| l.num_macs() * l.mac_size())
        .unwrap();
    println!("== footprint vs parallelism for `{}` ==", fat.name);
    for kk in [1usize, 2, 4, 8, 16] {
        println!(
            "  k={kk:>2}: resident {}bit",
            si(footprint::resident_bits_at_k(fat, 8, kk) as f64)
        );
    }
    Ok(())
}
