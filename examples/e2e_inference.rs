//! End-to-end driver (the required full-system demo): serve batched
//! classification requests over the AOT artifacts through the L3
//! coordinator, report latency/throughput + accuracy, and put the same
//! workload through the PIM timing model for the DRAM-side cost.
//!
//! This proves all layers compose: Pallas kernel (L1) → jax graph (L2) →
//! HLO artifacts → PJRT runtime → coordinator batching (L3), with the
//! paper's architecture simulator pricing the identical computation.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference [N]`

use std::time::Instant;

use pim_dram::coordinator::{InferenceServer, ServerConfig};
use pim_dram::gpu::GpuModel;
use pim_dram::runtime::{
    artifacts_available, artifacts_dir, ArtifactManifest, DigitsDataset,
};
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::stats::Summary;
use pim_dram::workloads::nets;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    let n_requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(256);

    let dir = artifacts_dir();
    let manifest = ArtifactManifest::load(&dir)?;
    let ds = DigitsDataset::load(&dir, &manifest)?;
    println!(
        "artifacts: {} layers, batch {}, {}-bit quant, {} test images",
        manifest.layers.len(),
        manifest.batch,
        manifest.wa,
        ds.count
    );

    // ---- serve batched requests through the coordinator ----------------
    let server = InferenceServer::start(ServerConfig::default())?;
    println!("server up (batch={}), sending {n_requests} requests...", server.batch_size());

    let mut latencies = Summary::new();
    let mut correct = 0usize;
    let t0 = Instant::now();
    // Concurrent clients: 4 threads hammer the server so batches fill.
    let results: Vec<(bool, f64)> = std::thread::scope(|scope| {
        let server = &server;
        let ds = &ds;
        let mut handles = Vec::new();
        for t in 0..4 {
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                for i in (t..n_requests).step_by(4) {
                    let (img, lbl) = ds.batch(i, 1);
                    let resp = server.classify(img).expect("classify");
                    out.push((
                        resp.class == lbl[0] as usize,
                        resp.latency.as_secs_f64() * 1e6,
                    ));
                }
                out
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed();
    for (ok, lat_us) in results {
        correct += ok as usize;
        latencies.push(lat_us);
    }

    println!("\n== serving results ==");
    println!(
        "throughput: {:.1} img/s   wall: {:.1} ms for {n_requests} requests",
        n_requests as f64 / wall.as_secs_f64(),
        wall.as_secs_f64() * 1e3
    );
    println!(
        "latency: mean {:.0} µs, p50 {:.0} µs, p99 {:.0} µs",
        latencies.mean(),
        latencies.percentile(50.0),
        latencies.percentile(99.0)
    );
    println!(
        "accuracy: {:.1}% ({} / {n_requests}); python quant reference {:.1}%",
        100.0 * correct as f64 / n_requests as f64,
        correct,
        100.0 * manifest.quant_test_accuracy
    );
    println!("coordinator: {}", server.metrics().report());

    // ---- the same workload on the PIM timing model ----------------------
    println!("\n== PIM-DRAM timing model for the same network ==");
    let net = nets::pimnet();
    let gpu = GpuModel::titan_xp();
    for (label, cfg) in [
        ("paper-favorable", SimConfig::paper_favorable(manifest.wa)),
        ("conservative   ", SimConfig::conservative(manifest.wa)),
    ] {
        let r = simulate(&net, &cfg)?;
        println!(
            "  {label}: {:.1} µs/image steady-state ({:.0} img/s), \
             {} AAPs/image, DRAM energy {:.2} µJ, speedup vs ideal GPU {:.2}x",
            r.pipeline.cycle_ns / 1e3,
            r.replica_throughput_ips(),
            r.total_aaps,
            r.total_dram_energy_nj / 1e3,
            r.speedup_vs(&gpu, &net, 4)
        );
    }
    server.shutdown();
    Ok(())
}
