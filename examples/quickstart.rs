//! Quickstart: the PIM-DRAM stack in one file.
//!
//! 1. Multiply two operands *inside the DRAM subarray model* (the paper's
//!    §III primitive) and see its AAP cost.
//! 2. Run a matrix-vector product through the full bank pipeline
//!    (subarray multiply → adder tree → accumulator → zero-point fixup).
//! 3. If `make artifacts` has run: execute the same MVM through the
//!    AOT-compiled Pallas kernel via PJRT and check all three agree.
//! 4. Price AlexNet through the `api::Job` surface (Spec → Job → report)
//!    vs the Titan Xp roofline.
//! 5. Author a custom workload as a `pim::ir` operator graph (depthwise
//!    conv + residual add edge), lower it, and price it like a builtin.
//!
//! Run: `cargo run --release --example quickstart`

use pim_dram::api::{Job, Spec};
use pim_dram::arch::{adder_tree::AdderTree, bank_pim::BankPipeline};
use pim_dram::gpu::GpuModel;
use pim_dram::ir::{Graph, Shape};
use pim_dram::primitives::{self, PimSubarray};
use pim_dram::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. One in-DRAM multiplication, column-parallel ------------------
    println!("== 1. In-subarray multiply (§III-B) ==");
    let mut pim = PimSubarray::new(8, 4, 1);
    for (col, (a, w)) in [(23u64, 71u64), (255, 255), (0, 200), (128, 3)]
        .into_iter()
        .enumerate()
    {
        pim.write_pair(col, 0, a, w);
    }
    primitives::mul::in_dram_mul(&mut pim, 0);
    for col in 0..4 {
        println!("  column {col}: product = {}", pim.read_product(col));
    }
    println!(
        "  cost: {} AAPs (paper closed form for n=8: {})",
        pim.stats.total_aaps(),
        primitives::paper_mul_aaps(8)
    );

    // --- 2. Bank-pipeline MVM --------------------------------------------
    println!("\n== 2. Bank pipeline MVM (multiply → tree → accumulate) ==");
    let mut rng = Rng::new(7);
    let k = 16;
    let outs = 4;
    let x: Vec<u64> = (0..k).map(|_| rng.int_range(0, 255) as u64).collect();
    let w: Vec<Vec<i64>> = (0..k)
        .map(|_| (0..outs).map(|_| rng.int_range(-128, 127)).collect())
        .collect();
    let bp = BankPipeline::new(AdderTree::new(64), 8);
    let y = bp.mvm(&x, &w);
    let want: Vec<i64> = (0..outs)
        .map(|o| x.iter().zip(&w).map(|(&a, r)| a as i64 * r[o]).sum())
        .collect();
    println!("  PIM pipeline: {y:?}");
    println!("  reference   : {want:?}  (match: {})", y == want);
    assert_eq!(y, want);

    // --- 3. Cross-check against the AOT Pallas kernel via PJRT -----------
    pjrt_crosscheck(&bp, &mut rng)?;

    // --- 4. System-level timing vs GPU (Spec → Job → report) -------------
    println!("\n== 4. AlexNet on the timing simulator ==");
    let gpu = GpuModel::titan_xp();
    for (label, preset) in [
        ("paper-favorable", "paper_favorable"),
        ("conservative   ", "conservative"),
    ] {
        let job = Job::new(Spec::builtin("alexnet").with_preset(preset))?;
        let r = job.simulate_full()?;
        println!(
            "  {label}: {:.3} ms/image, speedup over ideal {}: {:.2}x",
            r.pipeline.cycle_ns / 1e6,
            gpu.name,
            r.speedup_vs(&gpu, job.network(), 4)
        );
    }

    // --- 5. A custom workload through the operator-graph IR --------------
    // Author a graph (residuals are ordinary add edges), lower it through
    // the `pim::ir` pass pipeline, and price it like any builtin.
    println!("\n== 5. Custom graph through pim::ir ==");
    let mut g = Graph::new("demo_block");
    let x = g.input("x", Shape::Map { h: 16, w: 16, c: 8 });
    let c1 = g.conv("c1", x, 8, 3, 1, 1);
    let c1r = g.relu("c1.relu", c1);
    let dw = g.depthwise("dw", c1r, 3, 1, 1);
    let dwr = g.relu("dw.relu", dw);
    let res = g.add("res", c1r, dwr);
    let pw = g.conv("pw", res, 16, 1, 1, 0);
    let gp = g.global_avg_pool("pw.gap", pw);
    g.linear("fc", gp, 10);
    let job = Job::new(Spec::inline_graph(g).with_preset("conservative"))?;
    let net = job.network();
    println!(
        "  lowered: {} bank stages + {} residual reserve(s)",
        net.layers.len(),
        net.residuals.len()
    );
    let rep = job.report()?;
    println!(
        "  {:.3} ms/image steady-state over {} replica(s)",
        rep.cycle_ns / 1e6,
        rep.replicas
    );
    Ok(())
}

/// Step 3 needs the PJRT runtime: compiled only with `--features pjrt`.
#[cfg(feature = "pjrt")]
fn pjrt_crosscheck(bp: &BankPipeline, rng: &mut Rng) -> anyhow::Result<()> {
    use pim_dram::runtime::{
        artifacts_available, artifacts_dir, ArtifactManifest, Runtime, Tensor,
    };
    if !artifacts_available() {
        println!("\n== 3. (skipped — run `make artifacts` for the PJRT check) ==");
        return Ok(());
    }
    println!("\n== 3. AOT Pallas kernel via PJRT ==");
    let dir = artifacts_dir();
    let manifest = ArtifactManifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    let module = rt.load_hlo_text(&dir.join(&manifest.mvm_hlo))?;
    let (m, kk, n) = manifest.mvm_shape;
    let xs: Vec<i32> =
        (0..m * kk).map(|_| rng.int_range(0, 255) as i32).collect();
    let ws: Vec<i32> =
        (0..kk * n).map(|_| rng.int_range(-128, 127) as i32).collect();
    let out = module.run1(&[
        Tensor::i32(xs.clone(), &[m, kk]),
        Tensor::i32(ws.clone(), &[kk, n]),
    ])?;
    let got = out.as_i32()?;
    // Compare first row against the DRAM-model pipeline.
    let x0: Vec<u64> = xs[..kk].iter().map(|&v| v as u64).collect();
    let wmat: Vec<Vec<i64>> = (0..kk)
        .map(|r| (0..n).map(|c| ws[r * n + c] as i64).collect())
        .collect();
    let sim = bp.mvm(&x0, &wmat);
    let agree = (0..n).all(|j| sim[j] == got[j] as i64);
    println!("  PJRT({m}×{kk}×{n}) row0 == DRAM-model row0: {agree}");
    assert!(agree);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_crosscheck(_bp: &BankPipeline, _rng: &mut Rng) -> anyhow::Result<()> {
    println!("\n== 3. (skipped — this build has no PJRT; use --features pjrt) ==");
    Ok(())
}
