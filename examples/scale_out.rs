//! Scale-out walkthrough: one network, many PIM devices.
//!
//! 1. Lower ResNet18 onto a 4-channel × 4-rank grid under each shard
//!    policy and print the device plans.
//! 2. Price the plans (plan → price → aggregate) and compare replication
//!    against layer-splitting.
//! 3. Serve a burst of synthetic requests from a pool of simulated
//!    devices — one worker per replica — and show the dispatch counts.
//!
//! Run: `cargo run --release --example scale_out [network]`

use pim_dram::coordinator::{MultiDeviceServer, Policy, PoolConfig, SimBackend};
use pim_dram::mapping::MapConfig;
use pim_dram::plan::{lower, ShardPolicy};
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let net = nets::by_name(&name)?;

    // ---- 1. lowering ----------------------------------------------------
    let cfg = SimConfig::conservative(8).with_grid(4, 4);
    let mc = MapConfig {
        geometry: cfg.geometry.clone(),
        n_bits: cfg.n_bits,
        ks: cfg.ks.clone(),
    };
    println!("== 1. lowering {} onto 4 channels × 4 ranks ==", net.name);
    for policy in [
        ShardPolicy::Replicate,
        ShardPolicy::LayerSplit,
        ShardPolicy::Hybrid { replicas: 2 },
    ] {
        let plan = lower(&net, &mc, policy)?;
        println!(
            "  {:<12} {} replica(s), {} device(s), {} hop(s)/image",
            plan.policy.to_string(),
            plan.replicas,
            plan.devices.len(),
            plan.hops_per_image()
        );
        for d in plan.chain(0) {
            let dev = &plan.devices[*d];
            println!(
                "      device {}: ch{} ranks {}..{}  layers {:>2}..{:<2} \
                 (+{} residuals)",
                dev.id,
                dev.channel,
                dev.ranks.start,
                dev.ranks.end,
                dev.shard.layers.start,
                dev.shard.layers.end,
                dev.shard.residuals.len()
            );
        }
    }

    // ---- 2. pricing ------------------------------------------------------
    println!("\n== 2. plan → price → aggregate ==");
    let mut t = Table::new(&["policy", "replicas", "img/s", "ms/img", "hops us/img"])
        .aligns(&[
            Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        ]);
    for policy in [
        ShardPolicy::Replicate,
        ShardPolicy::LayerSplit,
        ShardPolicy::Hybrid { replicas: 2 },
    ] {
        let r = simulate(&net, &cfg.clone().with_shard(policy))?;
        t.row(&[
            policy.to_string(),
            r.replicas().to_string(),
            format!("{:.1}", r.throughput_ips()),
            format!("{:.3}", r.latency_ns() / 1e6),
            if r.scale_out.hop_ns_total > 0.0 {
                format!("{:.1}", r.scale_out.hop_ns_total / 1e3)
            } else {
                "-".into()
            },
        ]);
    }
    println!("{}", t.render());

    // ---- 3. serving from the pool ---------------------------------------
    let r = simulate(&net, &cfg)?;
    let replicas = r.replicas();
    println!("== 3. serving from {replicas} simulated replica device(s) ==");
    let backend = SimBackend::from_sim(&r, &net, 8);
    let server = MultiDeviceServer::start(
        PoolConfig {
            devices: replicas,
            policy: Policy::RoundRobin,
            batch_window: std::time::Duration::from_millis(2),
        },
        move |_| Ok(backend.clone()),
    )?;
    let elems = server.image_elems();
    let requests = 64usize;
    std::thread::scope(|scope| {
        let server = &server;
        let handles: Vec<_> = (0..4usize)
            .map(|t| {
                scope.spawn(move || {
                    for i in (t..requests).step_by(4) {
                        let img = vec![(i % 251) as i32; elems];
                        server.classify(img).expect("classify");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    println!("coordinator: {}", server.metrics().report());
    println!(
        "model: {:.1} img/s aggregate ({} replicas × {:.1} img/s)",
        r.throughput_ips(),
        replicas,
        r.replica_throughput_ips()
    );
    server.shutdown();
    Ok(())
}
