//! Scale-out walkthrough: one network, many PIM devices — all through the
//! `api::Job` surface.
//!
//! 1. Lower ResNet18 onto a 4-channel × 4-rank grid under each shard
//!    policy and print the device plans (`Job::simulate_full().plan`).
//! 2. Price the plans (plan → price → aggregate) and compare replication
//!    against layer-splitting.
//! 3. Serve a burst of synthetic requests from a pool of simulated
//!    devices via `Job::serve` — one worker per replica — and show the
//!    dispatch counts.
//!
//! Run: `cargo run --release --example scale_out [network]`

use pim_dram::api::{Job, ServeSpec, Spec};
use pim_dram::plan::ShardPolicy;
use pim_dram::util::table::{Align, Table};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".into());
    let base = Spec::builtin(&name).with_preset("conservative").with_grid(4, 4);
    let policies = [
        ShardPolicy::Replicate,
        ShardPolicy::LayerSplit,
        ShardPolicy::Hybrid { replicas: 2 },
    ];

    // ---- 1. lowering ----------------------------------------------------
    println!("== 1. lowering {name} onto 4 channels × 4 ranks ==");
    let mut priced = Vec::new();
    for policy in policies {
        let job = Job::new(base.clone().with_shard(policy))?;
        let r = job.simulate_full()?;
        let plan = &r.plan;
        println!(
            "  {:<12} {} replica(s), {} device(s), {} hop(s)/image",
            plan.policy.to_string(),
            plan.replicas,
            plan.devices.len(),
            plan.hops_per_image()
        );
        for d in plan.chain(0) {
            let dev = &plan.devices[*d];
            println!(
                "      device {}: ch{} ranks {}..{}  layers {:>2}..{:<2} \
                 (+{} residuals)",
                dev.id,
                dev.channel,
                dev.ranks.start,
                dev.ranks.end,
                dev.shard.layers.start,
                dev.shard.layers.end,
                dev.shard.residuals.len()
            );
        }
        priced.push(r);
    }

    // ---- 2. pricing ------------------------------------------------------
    println!("\n== 2. plan → price → aggregate ==");
    let mut t = Table::new(&["policy", "replicas", "img/s", "ms/img", "hops us/img"])
        .aligns(&[
            Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        ]);
    for r in &priced {
        t.row(&[
            r.plan.policy.to_string(),
            r.replicas().to_string(),
            format!("{:.1}", r.throughput_ips()),
            format!("{:.3}", r.latency_ns() / 1e6),
            if r.scale_out.hop_ns_total > 0.0 {
                format!("{:.1}", r.scale_out.hop_ns_total / 1e3)
            } else {
                "-".into()
            },
        ]);
    }
    println!("{}", t.render());

    // ---- 3. serving from the pool ---------------------------------------
    let job = Job::new(base.with_serve(ServeSpec::default()))?;
    let handle = job.serve()?;
    let replicas = handle.report.replicas;
    println!("== 3. serving from {replicas} simulated replica device(s) ==");
    let server = &handle.server;
    let elems = server.image_elems();
    let requests = 64usize;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4usize)
            .map(|t| {
                scope.spawn(move || {
                    for i in (t..requests).step_by(4) {
                        let img = vec![(i % 251) as i32; elems];
                        server.classify(img).expect("classify");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    println!("coordinator: {}", server.metrics().report());
    println!(
        "model: {:.1} img/s aggregate ({} replicas × {:.1} img/s)",
        handle.report.throughput_ips(),
        replicas,
        handle.report.replica_throughput_ips()
    );
    handle.server.shutdown();
    Ok(())
}
