//! Design-space exploration: sweep the knobs the paper exposes
//! (parallelism k, operand precision, subarray capacity, adder width) and
//! print the throughput/footprint frontier for one network.
//!
//! The whole exploration runs through one incremental `SimSession`
//! (DESIGN.md §8): per sweep point only the lowering + aggregation
//! re-runs; per-layer mapping/pricing is cached by config fingerprint.
//!
//! Run: `cargo run --release --example design_space [network]`

use pim_dram::gpu::GpuModel;
use pim_dram::sim::{SimConfig, SimSession};
use pim_dram::util::si;
use pim_dram::util::table::{Align, Table};
use pim_dram::workloads::nets;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "alexnet".into());
    let net = nets::by_name(&name)?;
    let mut session = SimSession::new(&net);
    let gpu = GpuModel::titan_xp();
    let gpu_ms = gpu.network_time_s(&net, 4) * 1e3;
    println!(
        "network: {}  ({} layers, {} FLOP/image; ideal {} = {:.3} ms)\n",
        net.name,
        net.layers.len(),
        si(net.total_flops() as f64),
        gpu.name,
        gpu_ms
    );

    // ---- k × precision sweep (paper-favorable geometry) -----------------
    let mut t = Table::new(&["bits", "k", "ms/img", "img/s", "speedup", "resident"])
        .aligns(&[
            Align::Right, Align::Right, Align::Right, Align::Right,
            Align::Right, Align::Right,
        ]);
    for bits in [2usize, 4, 8, 16] {
        for k in [1usize, 2, 4, 8] {
            let cfg = SimConfig::paper_favorable(bits).with_ks(vec![k]);
            let r = match session.report(&cfg) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("bits={bits} k={k}: {e}");
                    continue;
                }
            };
            t.row(&[
                bits.to_string(),
                k.to_string(),
                format!("{:.3}", r.cycle_ns / 1e6),
                format!("{:.0}", r.replica_throughput_ips()),
                format!("{:.2}x", r.speedup_vs(&gpu, &net, 4)),
                r.fully_resident.to_string(),
            ]);
        }
    }
    println!("== parallelism × precision (paper-favorable) ==\n{}", t.render());

    // ---- capacity sweep: ideal → real DDR3 ------------------------------
    let mut t2 = Table::new(&["subarrays/bank", "tree/subarray", "ms/img", "speedup"])
        .aligns(&[Align::Right, Align::Right, Align::Right, Align::Right]);
    for (subs, tps) in [
        (1usize << 20, true),
        (4096, true),
        (256, true),
        (32, true),
        (32, false),
    ] {
        let mut cfg = SimConfig::paper_favorable(8);
        cfg.geometry.subarrays_per_bank = subs;
        cfg.tree_per_subarray = tps;
        let r = session.report(&cfg)?;
        t2.row(&[
            subs.to_string(),
            tps.to_string(),
            format!("{:.3}", r.cycle_ns / 1e6),
            format!("{:.2}x", r.speedup_vs(&gpu, &net, 4)),
        ]);
    }
    println!(
        "== capacity: paper-ideal → real DDR3 (8-bit, k=1) ==\n{}",
        t2.render()
    );
    println!(
        "(the last rows show why the paper's headline needs its implicit\n\
         capacity assumption — see DESIGN.md §7 and EXPERIMENTS.md)"
    );
    let (hits, misses) = session.cache_stats();
    println!(
        "session cache over the exploration: {hits} hits / {misses} misses \
         ({} artifacts)",
        session.cached_layers()
    );
    Ok(())
}
