//! Design-space exploration: sweep the knobs the paper exposes
//! (parallelism k, operand precision, subarray capacity, adder width) and
//! print the throughput/footprint frontier for one network.
//!
//! Every sweep point is an `api::Spec` variant priced through one
//! `api::Job` and its incremental session (DESIGN.md §8/§API): per point
//! only the lowering + aggregation re-runs when the pricing inputs are
//! unchanged; per-layer mapping/pricing is cached by config fingerprint.
//!
//! Run: `cargo run --release --example design_space [network]`

use pim_dram::api::{Job, Spec};
use pim_dram::gpu::GpuModel;
use pim_dram::util::si;
use pim_dram::util::table::{Align, Table};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "alexnet".into());
    let base = Spec::builtin(&name);
    let job = Job::new(base.clone())?;
    let net = job.network();
    let mut session = job.session();
    let gpu = GpuModel::titan_xp();
    let gpu_ms = gpu.network_time_s(net, 4) * 1e3;
    println!(
        "network: {}  ({} layers, {} FLOP/image; ideal {} = {:.3} ms)\n",
        net.name,
        net.layers.len(),
        si(net.total_flops() as f64),
        gpu.name,
        gpu_ms
    );

    // ---- k × precision sweep (paper-favorable geometry) -----------------
    let mut t = Table::new(&["bits", "k", "ms/img", "img/s", "speedup", "resident"])
        .aligns(&[
            Align::Right, Align::Right, Align::Right, Align::Right,
            Align::Right, Align::Right,
        ]);
    for bits in [2usize, 4, 8, 16] {
        for k in [1usize, 2, 4, 8] {
            let spec = base.clone().with_precision(bits).with_ks(vec![k]);
            let r = match job.report_variant(&mut session, &spec) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("bits={bits} k={k}: {e}");
                    continue;
                }
            };
            t.row(&[
                bits.to_string(),
                k.to_string(),
                format!("{:.3}", r.cycle_ns / 1e6),
                format!("{:.0}", r.replica_throughput_ips()),
                format!("{:.2}x", r.speedup_vs(&gpu, net, 4)),
                r.fully_resident.to_string(),
            ]);
        }
    }
    println!("== parallelism × precision (paper-favorable) ==\n{}", t.render());

    // ---- capacity sweep: ideal → real DDR3 ------------------------------
    let mut t2 = Table::new(&["subarrays/bank", "tree/subarray", "ms/img", "speedup"])
        .aligns(&[Align::Right, Align::Right, Align::Right, Align::Right]);
    for (subs, tps) in [
        (1usize << 20, true),
        (4096, true),
        (256, true),
        (32, true),
        (32, false),
    ] {
        let spec = base
            .clone()
            .with_subarrays_per_bank(subs)
            .with_tree_per_subarray(tps);
        let r = job.report_variant(&mut session, &spec)?;
        t2.row(&[
            subs.to_string(),
            tps.to_string(),
            format!("{:.3}", r.cycle_ns / 1e6),
            format!("{:.2}x", r.speedup_vs(&gpu, net, 4)),
        ]);
    }
    println!(
        "== capacity: paper-ideal → real DDR3 (8-bit, k=1) ==\n{}",
        t2.render()
    );
    println!(
        "(the last rows show why the paper's headline needs its implicit\n\
         capacity assumption — see DESIGN.md §7 and EXPERIMENTS.md)"
    );
    let (hits, misses) = session.cache_stats();
    println!(
        "session cache over the exploration: {hits} hits / {misses} misses \
         ({} artifacts)",
        session.cached_layers()
    );
    Ok(())
}
