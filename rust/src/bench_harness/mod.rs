//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Cargo bench targets in `benches/` are `harness = false` binaries that
//! use this module: warmup, repeated timed runs, robust summary stats, and
//! the shared `Table` renderer so every paper-figure bench prints uniform
//! rows. Wall-clock timing only — the DRAM/GPU numbers the benches report
//! come from the *simulators*, which are deterministic; the harness timing
//! is for the §Perf optimization pass of the simulator hot paths themselves.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub std: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// Items/second if `items_per_iter` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|items| items / self.mean.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) => format!("  {}/s", crate::util::si(t)),
            None => String::new(),
        };
        format!(
            "{:<40} {:>12?} ±{:>10?}  (median {:?}, {} iters){}",
            self.name, self.mean, self.std, self.median, self.iters, tp
        )
    }
}

/// Benchmark runner with warmup + adaptive iteration count.
pub struct Bencher {
    /// Target total measurement time per benchmark.
    pub target_time: Duration,
    /// Number of warmup invocations.
    pub warmup_iters: u64,
    /// Minimum timed iterations regardless of duration.
    pub min_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            target_time: Duration::from_millis(500),
            warmup_iters: 3,
            min_iters: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast settings for CI / smoke runs (`PIM_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("PIM_BENCH_FAST").is_ok() {
            Bencher {
                target_time: Duration::from_millis(50),
                warmup_iters: 1,
                min_iters: 3,
                results: Vec::new(),
            }
        } else {
            Self::default()
        }
    }

    /// Time `f`, which must do one full unit of work per call. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like `bench` but records a throughput denominator.
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &Measurement {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        // Estimate per-iter cost to size the run.
        let probe_start = Instant::now();
        black_box(f());
        let probe = probe_start.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.target_time.as_secs_f64() / probe.as_secs_f64()) as u64)
            .clamp(self.min_iters, 1_000_000);

        let mut samples = Summary::new();
        let mut min = Duration::MAX;
        let mut max = Duration::ZERO;
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            let dt = t0.elapsed();
            samples.push(dt.as_secs_f64());
            min = min.min(dt);
            max = max.max(dt);
        }
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(samples.mean()),
            median: Duration::from_secs_f64(samples.median()),
            std: Duration::from_secs_f64(samples.std()),
            min,
            max,
            items_per_iter: items,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Optimizer barrier (std::hint::black_box stabilized in 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run `points` independent sweep points across all cores with scoped
/// threads, preserving index order in the result. Work is handed out
/// dynamically through an atomic cursor, so uneven point costs still fill
/// every core; a panic inside `f` (a failed shape assertion) propagates
/// when the scope joins. One point or one core degrades to the plain
/// sequential loop.
pub fn par_sweep<T, F>(points: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(points);
    if workers <= 1 {
        return (0..points).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<T>>> =
        (0..points).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= points {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("sweep point not computed"))
        .collect()
}

/// Write the machine-readable per-target perf report (`BENCH_PERF.json`):
/// mean/median wall-clock ns per op for every measurement, derived
/// scalars (e.g. the fresh-vs-session sweep speedup), and the `baseline`
/// ns/op this run was diffed against (empty when no baseline existed).
/// The schema is stable so CI and trend tooling can diff runs.
///
/// The file is merged, not clobbered: targets, derived scalars and
/// baseline entries recorded by a *different* bench binary (names absent
/// from this run) are carried over, so `perf_hotpath` and
/// `saturation_sweep` share the one tracked report.
pub fn write_bench_json(
    path: &str,
    note: &str,
    results: &[Measurement],
    derived: &[(&str, f64)],
    baseline: &[(String, f64)],
) -> std::io::Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut target_rows: Vec<String> = results
        .iter()
        .map(|m| {
            format!(
                "    \"{}\": {{\"ns_per_op\": {:.1}, \"median_ns\": {:.1}, \
                 \"std_ns\": {:.1}, \"iters\": {}}}",
                esc(&m.name),
                m.mean.as_secs_f64() * 1e9,
                m.median.as_secs_f64() * 1e9,
                m.std.as_secs_f64() * 1e9,
                m.iters,
            )
        })
        .collect();
    let mut derived_rows: Vec<String> = derived
        .iter()
        .map(|(k, v)| format!("    \"{}\": {v:.3}", esc(k)))
        .collect();
    let mut baseline_rows: Vec<String> = baseline
        .iter()
        .map(|(k, v)| format!("    \"{}\": {v:.1}", esc(k)))
        .collect();
    if let Some(doc) = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| crate::util::json::Json::parse(&t).ok())
    {
        if let Some(obj) = doc.get("targets").and_then(|t| t.as_obj()) {
            for (name, t) in obj {
                if results.iter().any(|m| &m.name == name) {
                    continue;
                }
                let f = |k: &str| t.get(k).and_then(|v| v.as_f64());
                if let (Some(ns), Some(med), Some(std), Some(iters)) =
                    (f("ns_per_op"), f("median_ns"), f("std_ns"), f("iters"))
                {
                    target_rows.push(format!(
                        "    \"{}\": {{\"ns_per_op\": {ns:.1}, \"median_ns\": \
                         {med:.1}, \"std_ns\": {std:.1}, \"iters\": {}}}",
                        esc(name),
                        iters as u64,
                    ));
                }
            }
        }
        if let Some(obj) = doc.get("derived").and_then(|d| d.as_obj()) {
            for (name, v) in obj {
                if derived.iter().any(|(k, _)| *k == name.as_str()) {
                    continue;
                }
                if let Some(v) = v.as_f64() {
                    derived_rows.push(format!("    \"{}\": {v:.3}", esc(name)));
                }
            }
        }
        if let Some(obj) = doc.get("baseline").and_then(|b| b.as_obj()) {
            for (name, v) in obj {
                if baseline.iter().any(|(k, _)| k == name) {
                    continue;
                }
                if let Some(v) = v.as_f64() {
                    baseline_rows.push(format!("    \"{}\": {v:.1}", esc(name)));
                }
            }
        }
    }
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"pim-dram/bench-perf/v2\",\n");
    out.push_str(&format!(
        "  \"fast_mode\": {},\n",
        std::env::var("PIM_BENCH_FAST").is_ok()
    ));
    out.push_str(&format!("  \"note\": \"{}\",\n", esc(note)));
    out.push_str("  \"targets\": {\n");
    out.push_str(&target_rows.join(",\n"));
    if !target_rows.is_empty() {
        out.push('\n');
    }
    out.push_str("  },\n  \"derived\": {\n");
    out.push_str(&derived_rows.join(",\n"));
    if !derived_rows.is_empty() {
        out.push('\n');
    }
    out.push_str("  },\n  \"baseline\": {\n");
    out.push_str(&baseline_rows.join(",\n"));
    if !baseline_rows.is_empty() {
        out.push('\n');
    }
    out.push_str("  }\n}\n");
    std::fs::write(path, out)
}

/// Read the measured targets of a previous `BENCH_PERF.json` as
/// `(name, ns_per_op)` pairs, for the regression gate. Returns `None`
/// when the file is missing, unparseable, or records no targets (the
/// seed placeholder committed before any toolchain ran) — callers treat
/// all three as "no baseline, skip the diff".
pub fn read_baseline(path: &str) -> Option<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path).ok()?;
    let doc = crate::util::json::Json::parse(&text).ok()?;
    let targets = doc.get("targets")?.as_obj()?;
    let out: Vec<(String, f64)> = targets
        .iter()
        .filter_map(|(name, t)| {
            t.get("ns_per_op").and_then(|v| v.as_f64()).map(|ns| (name.clone(), ns))
        })
        .collect();
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Diff a fresh run against a baseline: any target whose mean ns/op grew
/// by more than `tolerance` (0.25 = +25%) is a regression. Targets
/// present on only one side are skipped — the suite is allowed to grow.
/// Returns `Err` with one line per regressed target.
pub fn check_regression(
    baseline: &[(String, f64)],
    results: &[Measurement],
    tolerance: f64,
) -> Result<(), String> {
    let mut bad = Vec::new();
    for m in results {
        let Some((_, base_ns)) = baseline.iter().find(|(name, _)| *name == m.name)
        else {
            continue;
        };
        let fresh_ns = m.mean.as_secs_f64() * 1e9;
        if *base_ns > 0.0 && fresh_ns > base_ns * (1.0 + tolerance) {
            bad.push(format!(
                "{}: {:.1} ns/op vs baseline {:.1} ns/op (+{:.0}%, limit +{:.0}%)",
                m.name,
                fresh_ns,
                base_ns,
                (fresh_ns / base_ns - 1.0) * 100.0,
                tolerance * 100.0
            ));
        }
    }
    if bad.is_empty() {
        Ok(())
    } else {
        Err(bad.join("\n"))
    }
}

/// Standard bench preamble: prints the figure/table banner.
pub fn banner(id: &str, caption: &str) {
    println!("\n=== {} — {} ===", id, caption);
    println!(
        "(simulated substrate; compare *shape* with the paper, not absolutes)\n"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_measurement() {
        let mut b = Bencher {
            target_time: Duration::from_millis(5),
            warmup_iters: 1,
            min_iters: 3,
            results: Vec::new(),
        };
        let m = b.bench("noop", || 1 + 1).clone();
        assert!(m.iters >= 3);
        assert!(m.mean > Duration::ZERO);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bencher {
            target_time: Duration::from_millis(2),
            warmup_iters: 0,
            min_iters: 3,
            results: Vec::new(),
        };
        let m = b.bench_items("items", 100.0, || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn mean_between_min_max() {
        let mut b = Bencher {
            target_time: Duration::from_millis(2),
            warmup_iters: 0,
            min_iters: 5,
            results: Vec::new(),
        };
        let m = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        }).clone();
        assert!(m.min <= m.mean && m.mean <= m.max);
    }

    #[test]
    fn par_sweep_preserves_order() {
        let out = par_sweep(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_sweep_degenerate_sizes() {
        assert!(par_sweep(0, |i| i).is_empty());
        assert_eq!(par_sweep(1, |i| i + 41), vec![41]);
    }

    fn measurement(name: &str, mean_ns: u64) -> Measurement {
        Measurement {
            name: name.into(),
            iters: 42,
            mean: Duration::from_nanos(mean_ns),
            median: Duration::from_nanos(mean_ns),
            std: Duration::from_nanos(mean_ns / 10),
            min: Duration::from_nanos(mean_ns / 2),
            max: Duration::from_nanos(mean_ns * 2),
            items_per_iter: None,
        }
    }

    #[test]
    fn bench_json_round_trips_through_parser() {
        let m = measurement("simulate(vgg16, \"quoted\")", 1500);
        let path = std::env::temp_dir().join("pim_dram_bench_perf_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        write_bench_json(
            path,
            "unit test",
            &[m],
            &[("sweep_speedup_x", 4.2)],
            &[("price_layer".to_string(), 900.0)],
        )
        .unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(doc.req_str("schema").unwrap(), "pim-dram/bench-perf/v2");
        let target = doc
            .get("targets")
            .unwrap()
            .get("simulate(vgg16, \"quoted\")")
            .unwrap();
        assert_eq!(target.req_f64("ns_per_op").unwrap(), 1500.0);
        assert_eq!(target.req_i64("iters").unwrap(), 42);
        assert!(
            (doc.get("derived").unwrap().req_f64("sweep_speedup_x").unwrap() - 4.2)
                .abs()
                < 1e-9
        );
        assert_eq!(
            doc.get("baseline").unwrap().req_f64("price_layer").unwrap(),
            900.0
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn read_baseline_skips_empty_placeholders() {
        let path = std::env::temp_dir().join("pim_dram_bench_baseline_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        // The committed seed placeholder has no targets → no baseline.
        write_bench_json(path, "seed", &[], &[], &[]).unwrap();
        assert!(read_baseline(path).is_none());
        // A missing file is also no baseline.
        assert!(read_baseline("/nonexistent/bench.json").is_none());
        // A real run round-trips.
        write_bench_json(path, "real", &[measurement("lower", 2000)], &[], &[])
            .unwrap();
        let base = read_baseline(path).unwrap();
        assert_eq!(base, vec![("lower".to_string(), 2000.0)]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bench_json_merges_other_binaries_targets() {
        let path = std::env::temp_dir().join("pim_dram_bench_merge_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        write_bench_json(
            path,
            "hotpath run",
            &[measurement("price_layer", 1000)],
            &[("sweep_speedup_x", 4.2)],
            &[("price_layer".to_string(), 900.0)],
        )
        .unwrap();
        // A different binary writes its own targets: both sets survive,
        // and a re-measured target takes the fresh numbers.
        write_bench_json(
            path,
            "saturation run",
            &[measurement("saturation_knee", 2000), measurement("price_layer", 1100)],
            &[("backlog_goodput_gain_x", 1.3)],
            &[],
        )
        .unwrap();
        let doc = crate::util::json::Json::parse(&std::fs::read_to_string(path).unwrap())
            .unwrap();
        let targets = doc.get("targets").unwrap();
        assert_eq!(
            targets.get("price_layer").unwrap().req_f64("ns_per_op").unwrap(),
            1100.0
        );
        assert_eq!(
            targets.get("saturation_knee").unwrap().req_f64("ns_per_op").unwrap(),
            2000.0
        );
        let derived = doc.get("derived").unwrap();
        assert!((derived.req_f64("sweep_speedup_x").unwrap() - 4.2).abs() < 1e-9);
        assert!(
            (derived.req_f64("backlog_goodput_gain_x").unwrap() - 1.3).abs() < 1e-9
        );
        // The earlier baseline entry is carried when the new run has none.
        assert_eq!(
            doc.get("baseline").unwrap().req_f64("price_layer").unwrap(),
            900.0
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn regression_gate_flags_only_real_slowdowns() {
        let base = vec![
            ("price_layer".to_string(), 1000.0),
            ("lower".to_string(), 1000.0),
            ("retired_target".to_string(), 1.0),
        ];
        // Within tolerance (+20%) and a brand-new target: pass.
        let ok = [measurement("price_layer", 1200), measurement("session_hit", 9999)];
        assert!(check_regression(&base, &ok, 0.25).is_ok());
        // +100% on a tracked target: fail, naming the target.
        let bad = [measurement("lower", 2000)];
        let err = check_regression(&base, &bad, 0.25).unwrap_err();
        assert!(err.contains("lower"), "{err}");
        assert!(err.contains("+100%"), "{err}");
    }
}
