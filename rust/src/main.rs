//! PIM-DRAM launcher: see `pim-dram help` (or `cli::usage()`).

use pim_dram::cli;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
