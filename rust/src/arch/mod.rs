//! PIM-DRAM bank peripheral architecture (§IV-A, DESIGN.md S7–S8): the
//! reconfigurable adder tree, shift-add accumulators, special function
//! units (ReLU / BatchNorm / Quantize / MaxPool) and the SRAM transpose
//! unit, each with a bit-exact functional model and a cycle model.
//!
//! Functional semantics are kept identical to the L1 Pallas kernels
//! (`python/compile/kernels/`), so the Rust pipeline, the HLO artifacts and
//! the jnp oracles all agree bit-for-bit.

pub mod accumulator;
pub mod adder_tree;
pub mod bank_pim;
pub mod sfu;
pub mod transpose;

pub use accumulator::Accumulator;
pub use adder_tree::AdderTree;
pub use bank_pim::BankPipeline;
pub use sfu::{fused_sfu, FixedPointScale, SfuChain};
pub use transpose::TransposeUnit;
