//! The reconfigurable adder tree (§IV-A.1).
//!
//! A binary tree whose first level has `inputs/2` two-input units; every
//! node either *adds* its operands or *forwards* one of them, which is what
//! lets one physical tree reduce several independent MACs per pass as long
//! as each MAC occupies a contiguous, power-of-two-aligned span of the row
//! buffer. The row buffer is as wide as the first level (§IV-A.1).
//!
//! In the PIM dataflow the tree consumes one *product bit-plane* per pass
//! (the §IV dataflow: "the adder tree keeps on adding results of the
//! products from 0th till the 2n-th bit"), so a full MAC needs 2n passes,
//! accumulated by [`super::Accumulator`].

use crate::util::{ceil_div, log2_ceil};

/// A reconfigurable adder tree with `inputs` row-buffer inputs (power of 2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdderTree {
    inputs: usize,
}

impl AdderTree {
    /// The paper's Table I component is a 4096-input tree.
    pub const PAPER_INPUTS: usize = 4096;

    pub fn new(inputs: usize) -> Self {
        assert!(inputs >= 2 && inputs.is_power_of_two(), "inputs={inputs}");
        AdderTree { inputs }
    }

    pub fn paper_default() -> Self {
        Self::new(Self::PAPER_INPUTS)
    }

    pub fn inputs(&self) -> usize {
        self.inputs
    }

    /// Number of tree levels (pipeline depth).
    pub fn levels(&self) -> u32 {
        log2_ceil(self.inputs)
    }

    /// Total two-input adder units in the tree (2^L - 1).
    pub fn units(&self) -> usize {
        self.inputs - 1
    }

    /// Segment width used for a MAC of `mac_size` inputs: the smallest
    /// power-of-two span that contains it (forwarding nodes pad the rest).
    pub fn segment_for(&self, mac_size: usize) -> usize {
        assert!(mac_size >= 1);
        mac_size.next_power_of_two().min(self.inputs)
    }

    /// How many MACs of `mac_size` inputs one pass can reduce.
    pub fn macs_per_pass(&self, mac_size: usize) -> usize {
        if mac_size > self.inputs {
            // MAC wider than the tree: needs multiple passes + external
            // accumulation; exactly one MAC is in flight.
            1
        } else {
            self.inputs / self.segment_for(mac_size)
        }
    }

    /// Passes needed to reduce `num_macs` MACs of `mac_size` inputs over
    /// one bit-plane.
    pub fn passes(&self, num_macs: usize, mac_size: usize) -> usize {
        if mac_size > self.inputs {
            // Each MAC takes ceil(mac_size/inputs) partial passes.
            num_macs * ceil_div(mac_size, self.inputs)
        } else {
            ceil_div(num_macs, self.macs_per_pass(mac_size))
        }
    }

    /// Cycle count to stream `passes` pipelined passes: fill + drain.
    pub fn cycles(&self, passes: usize) -> u64 {
        if passes == 0 {
            return 0;
        }
        self.levels() as u64 + passes as u64 - 1
    }

    /// Functional reduction: sum `values` in groups of `mac_size`,
    /// returning one sum per MAC — exactly what the add/forward
    /// configuration computes. (Independent of segment padding: forwarded
    /// lanes contribute zero.)
    pub fn reduce(&self, values: &[i64], mac_size: usize) -> Vec<i64> {
        assert!(mac_size >= 1);
        values.chunks(mac_size).map(|c| c.iter().sum()).collect()
    }

    /// Functional reduction of a product bit-plane (0/1 lanes): popcount
    /// per MAC group. `plane[i]` is product bit `b` of column `i`.
    pub fn reduce_plane(&self, plane: &[bool], mac_size: usize) -> Vec<i64> {
        assert!(mac_size >= 1);
        plane
            .chunks(mac_size)
            .map(|c| c.iter().filter(|&&b| b).count() as i64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;

    #[test]
    fn paper_tree_shape() {
        let t = AdderTree::paper_default();
        assert_eq!(t.inputs(), 4096);
        assert_eq!(t.levels(), 12);
        assert_eq!(t.units(), 4095);
    }

    #[test]
    #[should_panic(expected = "inputs=")]
    fn rejects_non_power_of_two() {
        AdderTree::new(48);
    }

    #[test]
    fn segmentation() {
        let t = AdderTree::new(16);
        assert_eq!(t.segment_for(3), 4);
        assert_eq!(t.segment_for(4), 4);
        assert_eq!(t.segment_for(5), 8);
        assert_eq!(t.macs_per_pass(3), 4);
        assert_eq!(t.macs_per_pass(16), 1);
        assert_eq!(t.macs_per_pass(17), 1); // wider than tree
    }

    #[test]
    fn passes_and_cycles() {
        let t = AdderTree::new(8);
        // 10 MACs of size 3 → 2 per pass... segment 4 → 2 MACs/pass → 5.
        assert_eq!(t.passes(10, 3), 5);
        // Wide MAC: 20 inputs over an 8-wide tree = 3 partial passes each.
        assert_eq!(t.passes(2, 20), 6);
        assert_eq!(t.cycles(5), 3 + 5 - 1);
        assert_eq!(t.cycles(0), 0);
    }

    #[test]
    fn reduce_groups() {
        let t = AdderTree::new(8);
        assert_eq!(t.reduce(&[1, 2, 3, 4, 5, 6], 3), vec![6, 15]);
        assert_eq!(t.reduce(&[1, 2, 3, 4, 5], 2), vec![3, 7, 5]);
    }

    #[test]
    fn reduce_plane_popcounts() {
        let t = AdderTree::new(8);
        let plane = [true, false, true, true, false, false];
        assert_eq!(t.reduce_plane(&plane, 3), vec![2, 1]);
    }

    #[test]
    fn reduce_matches_scalar_sum_property() {
        crate::testutil::check(30, |rng| {
            let t = AdderTree::new(1 << rng.int_range(1, 6) as usize);
            let len = rng.int_range(1, 200) as usize;
            let mac = rng.int_range(1, 32) as usize;
            let vals: Vec<i64> =
                (0..len).map(|_| rng.int_range(-1000, 1000)).collect();
            let got = t.reduce(&vals, mac);
            for (g, chunk) in got.iter().zip(vals.chunks(mac)) {
                prop_assert_eq!(*g, chunk.iter().sum::<i64>());
            }
            Ok(())
        });
    }
}
