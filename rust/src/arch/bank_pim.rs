//! The composed bank pipeline (§IV-A): subarray multiply → adder tree →
//! accumulators → SFU chain → transpose, as one functional + timed unit.
//!
//! [`BankPipeline::mvm`] runs a complete matrix-vector product through the
//! *actual* bit-level primitives — the same computation the AOT'd Pallas
//! kernel performs — and is the cross-validation point between the Rust
//! functional simulator and the PJRT artifacts (examples/quickstart.rs).
//!
//! Sign handling: the in-DRAM multiplier is unsigned, so signed weights are
//! stored with zero-point `z = 2^(n-1)` (asymmetric quantization) and the
//! coordinator applies `Σ a·w = Σ a·w_u − z·Σ a`; the activation-sum term
//! reuses the same MVM machinery with unit weights.

use super::accumulator::accumulate_planes;
use super::adder_tree::AdderTree;
use crate::dram::DramTiming;
use crate::primitives::{self, PimSubarray};

/// Per-phase cost of one layer pass through a bank (one multiply round).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BankCosts {
    /// In-subarray multiply time (all subarrays in parallel; stacked pairs
    /// are sequential).
    pub multiply_ns: f64,
    /// Adder-tree reduction cycles across all bit planes.
    pub tree_cycles: u64,
    /// Accumulator shift-add cycles.
    pub acc_cycles: u64,
    /// SFU chain cycles.
    pub sfu_cycles: u64,
    /// Transpose unit cycles.
    pub transpose_cycles: u64,
}

impl BankCosts {
    /// Total wall time in ns given the derated logic clock.
    pub fn total_ns(&self, logic_cycle_ns: f64) -> f64 {
        self.multiply_ns
            + (self.tree_cycles + self.acc_cycles + self.sfu_cycles
                + self.transpose_cycles) as f64
                * logic_cycle_ns
    }

    pub fn logic_cycles(&self) -> u64 {
        self.tree_cycles + self.acc_cycles + self.sfu_cycles + self.transpose_cycles
    }
}

/// A bank's compute pipeline configuration.
#[derive(Debug, Clone)]
pub struct BankPipeline {
    pub tree: AdderTree,
    /// Activation bit width.
    pub wa: usize,
    /// Weight bit width.
    pub ww: usize,
    /// Subarray multiply width: operands are stored n×n with
    /// n = max(wa, ww) (the §III-B primitive is symmetric).
    pub n: usize,
}

impl BankPipeline {
    pub fn new(tree: AdderTree, n: usize) -> Self {
        Self::asymmetric(tree, n, n)
    }

    /// Different activation/weight widths (Fig 17 sweeps these together,
    /// but the kernels support asymmetry).
    pub fn asymmetric(tree: AdderTree, wa: usize, ww: usize) -> Self {
        assert!((1..=16).contains(&wa) && (1..=16).contains(&ww));
        BankPipeline { tree, wa, ww, n: wa.max(ww) }
    }

    /// Functional MVM through the bit-level primitives:
    /// `y[o] = Σ_k x[k] · w[k][o]` with unsigned activations (< 2^wa) and
    /// signed weights (|w| < 2^(ww-1)). Returns raw accumulator values.
    pub fn mvm(&self, x: &[u64], w: &[Vec<i64>]) -> Vec<i64> {
        let k = x.len();
        assert_eq!(w.len(), k, "weight rows != activation length");
        let outputs = if k == 0 { 0 } else { w[0].len() };
        if outputs == 0 {
            return Vec::new();
        }
        let z = 1i64 << (self.ww - 1); // weight zero-point

        // One column per (output, k) product; MACs are contiguous spans of
        // k columns (§IV-B mapping rule), plus one trailing MAC of unit
        // weights for the zero-point correction term Σx.
        let cols = (outputs + 1) * k;
        let mut pim = PimSubarray::new(self.n, cols, 1);
        for o in 0..outputs {
            for (ki, &a) in x.iter().enumerate() {
                let wu = w[ki][o] + z;
                assert!(
                    (0..(1 << self.ww)).contains(&wu),
                    "weight {} out of ww={} range",
                    w[ki][o],
                    self.ww
                );
                assert!(
                    a < (1 << self.wa),
                    "activation {a} out of wa={} range",
                    self.wa
                );
                pim.write_pair(o * k + ki, 0, a, wu as u64);
            }
        }
        for (ki, &a) in x.iter().enumerate() {
            pim.write_pair(outputs * k + ki, 0, a, 1);
        }

        primitives::mul::in_dram_mul(&mut pim, 0);

        // Adder tree consumes the product bit-planes; accumulator shift-adds.
        let planes: Vec<Vec<i64>> = (0..2 * self.n)
            .map(|bit| {
                let row = pim.product_plane(bit);
                let lanes: Vec<bool> = (0..cols).map(|c| row.get(c)).collect();
                self.tree.reduce_plane(&lanes, k)
            })
            .collect();
        let sums = accumulate_planes(&planes);

        // Zero-point correction: y[o] = acc_u[o] − z·Σx.
        let sum_x = sums[outputs];
        (0..outputs).map(|o| sums[o] - z * sum_x).collect()
    }

    /// Cost of one multiply round in a bank:
    /// `subarrays` subarrays multiply in parallel (`stacked_pairs`
    /// sequential rounds each), then the shared tree drains every
    /// subarray's planes.
    pub fn round_cost(
        &self,
        timing: &DramTiming,
        cost_model: primitives::CostModel,
        subarrays: usize,
        stacked_pairs: usize,
        macs_per_subarray: usize,
        mac_size: usize,
        sfu_stages: u32,
    ) -> BankCosts {
        let mul_aaps = primitives::mul_aaps(cost_model, self.n as u64);
        let multiply_ns =
            stacked_pairs as f64 * mul_aaps as f64 * timing.aap_ns();

        let planes = 2 * self.n as u64;
        let passes_per_subarray = self.tree.passes(macs_per_subarray, mac_size);
        let total_passes = passes_per_subarray as u64
            * subarrays as u64
            * planes
            * stacked_pairs as u64;
        let tree_cycles = self.tree.cycles(total_passes as usize);

        let macs_total =
            (macs_per_subarray * subarrays * stacked_pairs) as u64;
        let acc_cycles = macs_total * planes; // one shift-add per plane/MAC
        let sfu_cycles = if macs_total == 0 {
            0
        } else {
            sfu_stages as u64 + macs_total - 1
        };
        let transpose_cycles = macs_total + self.n as u64;

        BankCosts {
            multiply_ns,
            tree_cycles,
            acc_cycles,
            sfu_cycles,
            transpose_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::primitives::CostModel;

    #[test]
    fn mvm_matches_direct_dot_product() {
        let bp = BankPipeline::new(AdderTree::new(64), 8);
        let x = vec![3u64, 0, 255, 17];
        let w = vec![
            vec![5i64, -128],
            vec![-3, 127],
            vec![100, -1],
            vec![0, 64],
        ];
        let got = bp.mvm(&x, &w);
        let want: Vec<i64> = (0..2)
            .map(|o| x.iter().zip(&w).map(|(&a, r)| a as i64 * r[o]).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn mvm_empty_output() {
        let bp = BankPipeline::new(AdderTree::new(8), 4);
        assert!(bp.mvm(&[], &[]).is_empty());
    }

    #[test]
    fn mvm_random_property() {
        crate::testutil::check(25, |rng| {
            let n = rng.int_range(2, 8) as usize;
            let k = rng.int_range(1, 8) as usize;
            let o = rng.int_range(1, 5) as usize;
            let bp = BankPipeline::new(AdderTree::new(64), n);
            let x: Vec<u64> =
                (0..k).map(|_| rng.int_range(0, (1 << n) - 1) as u64).collect();
            let w: Vec<Vec<i64>> = (0..k)
                .map(|_| {
                    (0..o)
                        .map(|_| {
                            rng.int_range(-(1 << (n - 1)), (1 << (n - 1)) - 1)
                        })
                        .collect()
                })
                .collect();
            let got = bp.mvm(&x, &w);
            for oi in 0..o {
                let want: i64 =
                    x.iter().zip(&w).map(|(&a, r)| a as i64 * r[oi]).sum();
                prop_assert_eq!(got[oi], want);
            }
            Ok(())
        });
    }

    #[test]
    fn round_cost_components() {
        let bp = BankPipeline::new(AdderTree::new(4096), 8);
        let t = DramTiming::ddr3_1600();
        let c = bp.round_cost(&t, CostModel::Paper, 4, 1, 256, 9, 4);
        // Multiply: one stacked pair → paper 8-bit count × 48.75 ns.
        let want_mul =
            crate::primitives::paper_mul_aaps(8) as f64 * t.aap_ns();
        assert!((c.multiply_ns - want_mul).abs() < 1e-9);
        assert!(c.tree_cycles > 0 && c.acc_cycles > 0);
        assert!(c.total_ns(2.43) > c.multiply_ns);
    }

    #[test]
    fn stacked_pairs_scale_multiply_time() {
        let bp = BankPipeline::new(AdderTree::new(1024), 8);
        let t = DramTiming::ddr3_1600();
        let c1 = bp.round_cost(&t, CostModel::Paper, 2, 1, 64, 16, 4);
        let c4 = bp.round_cost(&t, CostModel::Paper, 2, 4, 64, 16, 4);
        assert!((c4.multiply_ns / c1.multiply_ns - 4.0).abs() < 1e-9);
        assert!(c4.tree_cycles > c1.tree_cycles);
    }
}
