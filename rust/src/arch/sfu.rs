//! Special Function Units (§IV-A.3–5): ReLU, BatchNorm (folded affine),
//! Quantize and MaxPool, chained after the accumulators in each bank.
//!
//! Semantics are *bit-identical* to the L1 Pallas `fused_sfu` kernel
//! (python/compile/kernels/sfu.py): inference-time BatchNorm is constant,
//! so ReLU + BN + Quantize fold into one fixed-point affine requantization
//!
//!   y = clamp((max(acc + bias, 0) · mult + 2^(shift-1)) >> shift, lo, hi)
//!
//! with `mult`/`shift` the fixed-point encoding of the float scale.

/// Fixed-point scale used by the Quantize unit (matches
/// `quantize_fixedpoint_params` on the Python side: 16 fraction bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedPointScale {
    pub mult: i64,
    pub shift: u32,
}

impl FixedPointScale {
    pub const FRACTION_BITS: u32 = 16;

    /// Encode a float scale. Errors on negative or overflowing scales,
    /// mirroring the Python builder.
    pub fn encode(scale: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(scale >= 0.0, "requant scale must be >= 0, got {scale}");
        let mult = (scale * f64::from(1u32 << Self::FRACTION_BITS)).round() as i64;
        anyhow::ensure!(mult < (1 << 31), "scale {scale} too large for fixed point");
        Ok(FixedPointScale { mult, shift: Self::FRACTION_BITS })
    }

    pub fn apply(&self, v: i64) -> i64 {
        (v * self.mult + (1i64 << (self.shift - 1))) >> self.shift
    }
}

/// The fused ReLU → BN → Quantize datapath for one MAC value.
pub fn fused_sfu(
    acc: i64,
    bias: i64,
    scale: FixedPointScale,
    bits: u32,
    relu: bool,
) -> i32 {
    let mut v = acc + bias;
    if relu {
        v = v.max(0);
    }
    let rounded = scale.apply(v);
    let hi = (1i64 << bits) - 1;
    let lo = if relu { 0 } else { -(1i64 << (bits - 1)) };
    rounded.clamp(lo, hi) as i32
}

/// The pooling unit (§IV-A.5): a counter walks the window, a register
/// keeps the running max. 2×2/stride-2 over an (h, w) channel plane laid
/// out row-major.
pub fn maxpool2x2(plane: &[i32], h: usize, w: usize) -> Vec<i32> {
    assert_eq!(plane.len(), h * w, "plane shape mismatch");
    assert!(h % 2 == 0 && w % 2 == 0, "H={h}, W={w} must be even");
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![i32::MIN; oh * ow];
    for y in 0..h {
        for x in 0..w {
            let o = (y / 2) * ow + (x / 2);
            out[o] = out[o].max(plane[y * w + x]);
        }
    }
    out
}

/// SFU chain configuration for one bank/layer, plus its cycle model.
#[derive(Debug, Clone)]
pub struct SfuChain {
    pub scale: FixedPointScale,
    pub bits: u32,
    pub relu: bool,
    pub pool: bool,
    /// Units operate element-streamed; each stage is single-cycle, so the
    /// chain is pipelined with depth = number of active stages.
    pub stages: u32,
}

impl SfuChain {
    pub fn new(scale: FixedPointScale, bits: u32, relu: bool, pool: bool) -> Self {
        let stages = 2 + u32::from(relu) + u32::from(pool); // BN+Quant always
        SfuChain { scale, bits, relu, pool, stages }
    }

    /// Apply the (non-pool part of the) chain to a slice of MAC values.
    pub fn apply(&self, accs: &[i64], bias: &[i64]) -> Vec<i32> {
        assert_eq!(accs.len() % bias.len(), 0, "bias broadcast mismatch");
        accs.iter()
            .enumerate()
            .map(|(i, &a)| {
                fused_sfu(a, bias[i % bias.len()], self.scale, self.bits, self.relu)
            })
            .collect()
    }

    /// Cycles to stream `elements` values through the pipelined chain.
    pub fn cycles(&self, elements: u64) -> u64 {
        if elements == 0 {
            0
        } else {
            self.stages as u64 + elements - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;

    #[test]
    fn fixed_point_encoding_precision() {
        for scale in [1.0, 0.5, 0.01, 3.7e-4] {
            let f = FixedPointScale::encode(scale).unwrap();
            let approx = f.mult as f64 / f64::from(1u32 << f.shift);
            assert!((approx - scale).abs() < 1e-4, "scale {scale}");
        }
        assert!(FixedPointScale::encode(-1.0).is_err());
        assert!(FixedPointScale::encode(1e6).is_err());
    }

    #[test]
    fn fused_sfu_matches_python_reference_semantics() {
        // Mirror of python/tests/test_sfu.py fixed cases.
        let unit = FixedPointScale::encode(1.0).unwrap();
        assert_eq!(fused_sfu(-100, 0, unit, 8, true), 0);
        assert_eq!(fused_sfu(100, 0, unit, 8, true), 100);
        assert_eq!(fused_sfu(10_000, 0, unit, 8, true), 255);
        assert_eq!(fused_sfu(-10_000, 0, unit, 8, false), -128);
        assert_eq!(fused_sfu(10_000, 0, unit, 8, false), 255);
        assert_eq!(fused_sfu(-5, 10, unit, 8, true), 5); // bias pre-ReLU
    }

    #[test]
    fn rounding_is_round_half_up() {
        let half = FixedPointScale::encode(0.5).unwrap();
        assert_eq!(fused_sfu(3, 0, half, 8, true), 2); // 1.5 → 2
        assert_eq!(fused_sfu(1, 0, half, 8, true), 1); // 0.5 → 1
    }

    #[test]
    fn maxpool_basic() {
        let plane: Vec<i32> = (0..16).collect();
        let out = maxpool2x2(&plane, 4, 4);
        assert_eq!(out, vec![5, 7, 13, 15]);
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn maxpool_rejects_odd() {
        maxpool2x2(&[1, 2, 3], 1, 3);
    }

    #[test]
    fn chain_stages_and_cycles() {
        let s = FixedPointScale::encode(0.1).unwrap();
        let full = SfuChain::new(s, 8, true, true);
        assert_eq!(full.stages, 4);
        let lean = SfuChain::new(s, 8, false, false);
        assert_eq!(lean.stages, 2);
        assert_eq!(full.cycles(100), 4 + 99);
        assert_eq!(full.cycles(0), 0);
    }

    #[test]
    fn chain_apply_broadcasts_bias() {
        let s = FixedPointScale::encode(1.0).unwrap();
        let chain = SfuChain::new(s, 8, true, false);
        let out = chain.apply(&[1, 2, 3, 4], &[10, 20]);
        assert_eq!(out, vec![11, 22, 13, 24]);
    }

    #[test]
    fn fused_sfu_property_vs_float_model() {
        // Fixed-point requant must track the float computation within 1 LSB
        // (plus clamping) for in-range values.
        crate::testutil::check(60, |rng| {
            let scale = rng.range(1e-4, 1.5);
            let f = FixedPointScale::encode(scale).unwrap();
            let acc = rng.int_range(-(1 << 20), 1 << 20);
            let bias = rng.int_range(-(1 << 10), 1 << 10);
            let bits = rng.int_range(2, 10) as u32;
            let relu = rng.bool(0.5);
            let got = fused_sfu(acc, bias, f, bits, relu) as f64;
            let mut v = (acc + bias) as f64;
            if relu {
                v = v.max(0.0);
            }
            let want = (v * scale).round();
            let hi = ((1i64 << bits) - 1) as f64;
            let lo = if relu { 0.0 } else { -((1i64 << (bits - 1)) as f64) };
            let want = want.clamp(lo, hi);
            crate::prop_assert!(
                (got - want).abs() <= 1.0,
                "scale={scale} acc={acc} bias={bias} got={got} want={want}"
            );
            Ok(())
        });
    }
}
