//! The transpose unit (§IV-A.6): a dual-ported SRAM array written
//! row-wise and read column-wise, converting the SFU's word-oriented
//! outputs back into the bit-transposed layout the next bank's subarrays
//! require (and vice versa).

/// Dual-port SRAM transpose buffer of `rows` words × `bits` bit columns.
#[derive(Debug, Clone)]
pub struct TransposeUnit {
    rows: usize,
    bits: usize,
    data: Vec<u64>, // one word per row, low `bits` significant
    written: usize,
}

impl TransposeUnit {
    /// Paper example dimensions: 256 × 8 (area 30 534.894 µm² at 65 nm).
    pub const PAPER_ROWS: usize = 256;
    pub const PAPER_BITS: usize = 8;

    pub fn new(rows: usize, bits: usize) -> Self {
        assert!(bits <= 64 && bits >= 1 && rows >= 1);
        TransposeUnit { rows, bits, data: vec![0; rows], written: 0 }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Write one word horizontally (row-major fill).
    pub fn write_word(&mut self, value: u64) {
        assert!(self.written < self.rows, "transpose buffer full");
        assert!(
            value < (1u64 << self.bits) || self.bits == 64,
            "value {value} exceeds {} bits",
            self.bits
        );
        self.data[self.written] = value;
        self.written += 1;
    }

    /// Read bit-plane `bit` vertically: bit `bit` of every written word.
    pub fn read_plane(&self, bit: usize) -> Vec<bool> {
        assert!(bit < self.bits);
        self.data[..self.written]
            .iter()
            .map(|w| (w >> bit) & 1 == 1)
            .collect()
    }

    /// Transpose a batch in one call: words in, bit-planes out.
    pub fn transpose(words: &[u64], bits: usize) -> Vec<Vec<bool>> {
        (0..bits)
            .map(|b| words.iter().map(|w| (w >> b) & 1 == 1).collect())
            .collect()
    }

    /// Inverse: bit-planes in, words out.
    pub fn untranspose(planes: &[Vec<bool>]) -> Vec<u64> {
        if planes.is_empty() {
            return Vec::new();
        }
        let n = planes[0].len();
        let mut words = vec![0u64; n];
        for (b, plane) in planes.iter().enumerate() {
            assert_eq!(plane.len(), n, "ragged plane {b}");
            for (w, &bit) in words.iter_mut().zip(plane) {
                *w |= (bit as u64) << b;
            }
        }
        words
    }

    pub fn reset(&mut self) {
        self.written = 0;
    }

    /// Cycle model: dual-ported, one word written per cycle, one bit-plane
    /// (of up to `rows` bits) read per cycle.
    pub fn write_cycles(&self, words: u64) -> u64 {
        words
    }

    pub fn read_cycles(&self, planes: u64) -> u64 {
        planes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;

    #[test]
    fn write_then_read_planes() {
        let mut t = TransposeUnit::new(4, 4);
        for v in [0b1010u64, 0b0110, 0b1111, 0b0001] {
            t.write_word(v);
        }
        assert_eq!(t.read_plane(0), vec![false, false, true, true]);
        assert_eq!(t.read_plane(1), vec![true, true, true, false]);
        assert_eq!(t.read_plane(3), vec![true, false, true, false]);
    }

    #[test]
    #[should_panic(expected = "full")]
    fn overflow_rejected() {
        let mut t = TransposeUnit::new(1, 4);
        t.write_word(1);
        t.write_word(2);
    }

    #[test]
    fn transpose_roundtrip_property() {
        crate::testutil::check(30, |rng| {
            let bits = rng.int_range(1, 16) as usize;
            let n = rng.int_range(1, 64) as usize;
            let words: Vec<u64> = (0..n)
                .map(|_| rng.int_range(0, (1i64 << bits) - 1) as u64)
                .collect();
            let planes = TransposeUnit::transpose(&words, bits);
            prop_assert_eq!(planes.len(), bits);
            let back = TransposeUnit::untranspose(&planes);
            prop_assert_eq!(back, words);
            Ok(())
        });
    }

    #[test]
    fn cycle_model() {
        let t = TransposeUnit::new(256, 8);
        assert_eq!(t.write_cycles(256), 256);
        assert_eq!(t.read_cycles(8), 8);
    }
}
