//! The shift-add accumulator (§IV-A.2): collects the adder tree's per-bit
//! partial sums, left-shifting by the bit position (a counter tracks it)
//! until the 2n-th plane has arrived, then forwards the MAC value to the
//! SFU chain.
//!
//! Weight sign handling: operands are stored unsigned with a zero-point of
//! 2^(n-1) (asymmetric quantization); the coordinator applies the
//! correction `Σ a·w = Σ a·w_u − z·Σ a`. The accumulator itself also
//! supports a negatively-weighted plane (two's-complement MSB), matching
//! the L1 Pallas kernel — both paths are exercised by tests.

/// Shift-add accumulator for one MAC lane.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    acc: i64,
    planes_seen: u32,
}

impl Accumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one bit-plane partial sum at bit position `bit`, optionally
    /// negatively weighted (two's-complement weight MSB plane).
    pub fn add_plane(&mut self, plane_sum: i64, bit: u32, negative: bool) {
        let contribution = plane_sum << bit;
        if negative {
            self.acc -= contribution;
        } else {
            self.acc += contribution;
        }
        self.planes_seen += 1;
    }

    pub fn value(&self) -> i64 {
        self.acc
    }

    pub fn planes_seen(&self) -> u32 {
        self.planes_seen
    }

    pub fn reset(&mut self) {
        self.acc = 0;
        self.planes_seen = 0;
    }

    /// Cycles for one accumulation step (shift+add is single-cycle).
    pub const CYCLES_PER_PLANE: u64 = 1;
}

/// Reconstruct MAC values from product bit-planes: `plane_sums[b][m]` is
/// the adder-tree sum of product bit `b` for MAC `m`. Products are
/// unsigned (the in-DRAM primitive multiplies unsigned operands).
pub fn accumulate_planes(plane_sums: &[Vec<i64>]) -> Vec<i64> {
    if plane_sums.is_empty() {
        return Vec::new();
    }
    let num_macs = plane_sums[0].len();
    let mut accs = vec![Accumulator::new(); num_macs];
    for (bit, sums) in plane_sums.iter().enumerate() {
        assert_eq!(sums.len(), num_macs, "ragged plane at bit {bit}");
        for (a, &s) in accs.iter_mut().zip(sums) {
            a.add_plane(s, bit as u32, false);
        }
    }
    accs.iter().map(|a| a.value()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;

    #[test]
    fn shift_add_reconstructs_value() {
        // Product 13 = 0b1101 split into planes, one lane.
        let mut a = Accumulator::new();
        for (bit, v) in [1i64, 0, 1, 1].into_iter().enumerate() {
            a.add_plane(v, bit as u32, false);
        }
        assert_eq!(a.value(), 13);
        assert_eq!(a.planes_seen(), 4);
    }

    #[test]
    fn negative_msb_plane_twos_complement() {
        // value = -128·b7 + Σ 2^i·b_i : reconstruct -3 = 0b11111101.
        let bits = [1i64, 0, 1, 1, 1, 1, 1, 1];
        let mut a = Accumulator::new();
        for (bit, &v) in bits.iter().enumerate() {
            a.add_plane(v, bit as u32, bit == 7);
        }
        assert_eq!(a.value(), -3);
    }

    #[test]
    fn reset_clears_state() {
        let mut a = Accumulator::new();
        a.add_plane(5, 3, false);
        a.reset();
        assert_eq!(a.value(), 0);
        assert_eq!(a.planes_seen(), 0);
    }

    #[test]
    fn accumulate_planes_matches_direct_dot_product() {
        crate::testutil::check(40, |rng| {
            let n = rng.int_range(1, 8) as u32; // operand bits
            let k = rng.int_range(1, 16) as usize; // MAC depth
            let m = rng.int_range(1, 6) as usize; // MACs
            // Random operands per MAC lane.
            let mut products: Vec<Vec<u64>> = Vec::new();
            for _ in 0..m {
                products.push(
                    (0..k)
                        .map(|_| {
                            let a = rng.int_range(0, (1 << n) - 1) as u64;
                            let w = rng.int_range(0, (1 << n) - 1) as u64;
                            a * w
                        })
                        .collect(),
                );
            }
            // Build plane sums: bit b of each product, summed per MAC.
            let planes: Vec<Vec<i64>> = (0..2 * n)
                .map(|b| {
                    products
                        .iter()
                        .map(|macp| {
                            macp.iter().map(|p| ((p >> b) & 1) as i64).sum()
                        })
                        .collect()
                })
                .collect();
            let got = accumulate_planes(&planes);
            for (g, macp) in got.iter().zip(&products) {
                prop_assert_eq!(*g, macp.iter().sum::<u64>() as i64);
            }
            Ok(())
        });
    }

    #[test]
    fn empty_planes() {
        assert!(accumulate_planes(&[]).is_empty());
    }
}
