//! Inter-bank activation transfer (§IV-B: "the banks transfer data
//! sequentially using RowClone to the destination banks").
//!
//! Activations leave a bank through the transpose unit in bit-transposed
//! layout: `n` bit-plane rows per `cols`-wide slab, RowClone'd over the
//! internal bus one row at a time.

use crate::dram::DramTiming;
use crate::util::ceil_div;

/// DRAM rows needed to ship `values` n-bit values (transposed layout).
pub fn transfer_rows(values: usize, n_bits: usize, cols: usize) -> usize {
    if values == 0 {
        return 0;
    }
    n_bits * ceil_div(values, cols)
}

/// Serialized transfer time in ns.
pub fn transfer_ns(
    values: usize,
    n_bits: usize,
    cols: usize,
    timing: &DramTiming,
) -> f64 {
    transfer_rows(values, n_bits, cols) as f64 * timing.interbank_copy_ns(cols)
}

/// Bits moved (for bus-energy accounting).
pub fn transfer_bits(values: usize, n_bits: usize, cols: usize) -> u64 {
    (transfer_rows(values, n_bits, cols) * cols) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_count_rounds_up() {
        assert_eq!(transfer_rows(0, 8, 4096), 0);
        assert_eq!(transfer_rows(1, 8, 4096), 8);
        assert_eq!(transfer_rows(4096, 8, 4096), 8);
        assert_eq!(transfer_rows(4097, 8, 4096), 16);
    }

    #[test]
    fn time_scales_with_rows() {
        let t = DramTiming::ddr3_1600();
        let one_slab = transfer_ns(4096, 8, 4096, &t);
        let two_slabs = transfer_ns(8000, 8, 4096, &t);
        assert!((two_slabs / one_slab - 2.0).abs() < 1e-9);
    }

    #[test]
    fn wider_bus_is_faster() {
        let mut fast = DramTiming::ddr3_1600();
        fast.internal_bus_bits = 4096; // row-wide links (paper-favorable)
        let slow = DramTiming::ddr3_1600();
        assert!(
            transfer_ns(10_000, 8, 4096, &fast)
                < transfer_ns(10_000, 8, 4096, &slow)
        );
    }

    #[test]
    fn bits_accounting() {
        assert_eq!(transfer_bits(4096, 8, 4096), 8 * 4096);
    }
}
