//! Residual-connection dataflow (§IV-B, Fig 13): shortcut activations are
//! RowClone'd to a Reserved Bank; after the main path produces its output,
//! it is copied to the same bank, added with the in-DRAM adder [5], and
//! forwarded to the destination bank.

use crate::dram::DramTiming;
use crate::primitives::cost::add_aaps;
use crate::util::ceil_div;

use super::transfer::transfer_ns;

/// Time to execute one residual edge over `elems` n-bit activations in a
/// reserved bank with `cols`-wide subarrays:
/// shortcut copy-in + main copy-in + column-parallel ADD chunks + copy-out.
pub fn residual_cost_ns(
    elems: usize,
    n_bits: usize,
    cols: usize,
    timing: &DramTiming,
) -> f64 {
    if elems == 0 {
        return 0.0;
    }
    let copies = 3.0 * transfer_ns(elems, n_bits, cols, timing);
    // Each cols-wide chunk adds in parallel across columns; chunks are
    // sequential. (The sum may carry into n+1 bits; the SFU requantizes.)
    let chunks = ceil_div(elems, cols) as f64;
    let add = chunks * add_aaps(n_bits as u64) as f64 * timing.aap_ns();
    copies + add
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_elems_free() {
        let t = DramTiming::ddr3_1600();
        assert_eq!(residual_cost_ns(0, 8, 4096, &t), 0.0);
    }

    #[test]
    fn one_chunk_cost() {
        let t = DramTiming::ddr3_1600();
        let c = residual_cost_ns(4096, 8, 4096, &t);
        let copies = 3.0 * transfer_ns(4096, 8, 4096, &t);
        let add = 33.0 * t.aap_ns();
        assert!((c - (copies + add)).abs() < 1e-9);
    }

    #[test]
    fn cost_scales_with_elems() {
        let t = DramTiming::ddr3_1600();
        let small = residual_cost_ns(4096, 8, 4096, &t);
        let big = residual_cost_ns(16 * 4096, 8, 4096, &t);
        assert!((big / small - 16.0).abs() < 0.01);
    }

    #[test]
    fn add_uses_published_formula() {
        // ResNet residual at 8 bits: 4·8+1 = 33 AAPs per column chunk.
        assert_eq!(add_aaps(8), 33);
    }
}
