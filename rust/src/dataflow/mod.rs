//! Dataflow substrate (§IV-B, DESIGN.md S11): inter-bank transfers, the
//! layer-per-bank image pipeline, and residual-connection handling.

pub mod pipeline;
pub mod residual;
pub mod transfer;

pub use pipeline::{schedule, PipelineReport, StageCost};
pub use residual::residual_cost_ns;
pub use transfer::{transfer_ns, transfer_rows};
