//! The layer-per-bank image pipeline (§IV-B): every bank works on a
//! different image simultaneously; inter-bank transfers serialize on the
//! shared internal bus between compute phases.

/// Cost of one pipeline stage (= one bank = one layer).
#[derive(Debug, Clone, PartialEq)]
pub struct StageCost {
    pub name: String,
    /// In-bank compute time per image (multiply rounds + peripheral logic
    /// + restaging + residual adds attributed to this stage).
    pub compute_ns: f64,
    /// Outbound transfer to the next bank (serialized bus).
    pub transfer_ns: f64,
}

/// Steady-state pipeline characteristics.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineReport {
    pub stages: Vec<StageCost>,
    /// Single-image end-to-end latency (fill): Σ (compute + transfer).
    pub latency_ns: f64,
    /// Steady-state initiation interval: banks compute concurrently, so
    /// the compute term is the slowest stage, but transfers share one bus
    /// and serialize (§IV-B "banks transfer data sequentially").
    pub cycle_ns: f64,
    /// Index of the bottleneck (slowest compute) stage.
    pub bottleneck: usize,
}

impl PipelineReport {
    /// Images per second in steady state.
    pub fn throughput_ips(&self) -> f64 {
        1e9 / self.cycle_ns
    }

    /// Total time to push `images` through (fill + steady drains).
    pub fn makespan_ns(&self, images: usize) -> f64 {
        if images == 0 {
            return 0.0;
        }
        self.latency_ns + (images as f64 - 1.0) * self.cycle_ns
    }
}

/// Build the pipeline report from per-stage costs.
///
/// `overlapped_transfers = false`: one shared internal bus, every
/// inter-bank copy serializes between compute phases (conservative) —
/// `cycle = max(compute) + Σ transfer`. `true`: adjacent banks have
/// dedicated links (LISA-style, the paper-favorable reading of §IV-B) and
/// a stage's outbound copy overlaps other stages' compute —
/// `cycle = max(compute + transfer)`.
pub fn schedule(stages: Vec<StageCost>, overlapped_transfers: bool) -> PipelineReport {
    assert!(!stages.is_empty(), "pipeline needs at least one stage");
    let latency_ns = stages.iter().map(|s| s.compute_ns + s.transfer_ns).sum();
    let cycle_ns = if overlapped_transfers {
        stages
            .iter()
            .map(|s| s.compute_ns + s.transfer_ns)
            .fold(f64::NEG_INFINITY, f64::max)
    } else {
        let max_compute = stages
            .iter()
            .map(|s| s.compute_ns)
            .fold(f64::NEG_INFINITY, f64::max);
        let total_transfer: f64 = stages.iter().map(|s| s.transfer_ns).sum();
        max_compute + total_transfer
    };
    let bottleneck = stages
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.compute_ns.partial_cmp(&b.1.compute_ns).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    PipelineReport { latency_ns, cycle_ns, bottleneck, stages }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, c: f64, t: f64) -> StageCost {
        StageCost { name: name.into(), compute_ns: c, transfer_ns: t }
    }

    #[test]
    fn single_stage() {
        let r = schedule(vec![stage("a", 100.0, 10.0)], false);
        assert_eq!(r.latency_ns, 110.0);
        assert_eq!(r.cycle_ns, 110.0);
        assert_eq!(r.bottleneck, 0);
    }

    #[test]
    fn cycle_is_max_compute_plus_all_transfers() {
        let r = schedule(
            vec![
                stage("a", 100.0, 5.0),
                stage("b", 300.0, 10.0),
                stage("c", 50.0, 5.0),
            ],
            false,
        );
        assert_eq!(r.latency_ns, 470.0);
        assert_eq!(r.cycle_ns, 300.0 + 20.0);
        assert_eq!(r.bottleneck, 1);
    }

    #[test]
    fn overlapped_cycle_is_max_stage() {
        let r = schedule(
            vec![stage("a", 100.0, 50.0), stage("b", 120.0, 10.0)],
            true,
        );
        assert_eq!(r.cycle_ns, 150.0);
        // Overlap can only help.
        let serial = schedule(
            vec![stage("a", 100.0, 50.0), stage("b", 120.0, 10.0)],
            false,
        );
        assert!(r.cycle_ns <= serial.cycle_ns);
    }

    #[test]
    fn makespan_fill_plus_steady() {
        let r = schedule(vec![stage("a", 10.0, 0.0), stage("b", 20.0, 0.0)], false);
        assert_eq!(r.makespan_ns(1), r.latency_ns);
        assert_eq!(r.makespan_ns(11), r.latency_ns + 10.0 * r.cycle_ns);
        assert_eq!(r.makespan_ns(0), 0.0);
    }

    #[test]
    fn throughput_inverse_of_cycle() {
        let r = schedule(vec![stage("a", 1e6, 0.0)], false);
        assert!((r.throughput_ips() - 1000.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "at least one stage")]
    fn empty_pipeline_rejected() {
        schedule(vec![], false);
    }

    #[test]
    fn pipelining_beats_serial_for_multiple_images() {
        // The whole point of the §IV-B dataflow.
        let stages = vec![
            stage("l1", 100.0, 1.0),
            stage("l2", 100.0, 1.0),
            stage("l3", 100.0, 1.0),
        ];
        let r = schedule(stages, false);
        let serial = 100.0 * 3.0 + 3.0;
        assert!(r.makespan_ns(100) < 100.0 * serial);
    }
}
