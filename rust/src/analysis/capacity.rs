//! Mapping/capacity proofs: per-layer residency analysis over the
//! Algorithm-1 arithmetic, flagged statically — before the k-optimizer's
//! binary search or any pricing runs.
//!
//! The paper prices every layer as if its operand expansion were resident
//! (weights stacked in bank rows, one round per k-group). The mapper
//! (`mapping::map_layer`) quietly absorbs violations instead: extra
//! sequential *waves* when a group wants more subarrays than the bank
//! has, *restaged rounds* when the column stack overflows
//! `pairs_per_column`, and a silent clamp when the configured k exceeds a
//! layer's outer-loop count. All legal — and all serialization the spec's
//! author probably did not intend. This pass proves which layers are
//! resident and warns about the rest:
//!
//!   * `W021` — configured k exceeds the outer count (the mapper clamps).
//!   * `W020` — the layer is not fully resident at its effective k.
//!   * `W022` — *no* fully-resident k exists: probing the top of the
//!     feasible range (`outer.min(pairs_per_column)`, where waves are
//!     fewest and restaging is zero — the same bound the k-optimizer
//!     searches under) still leaves waves. The weights simply exceed the
//!     bank; only a geometry or precision change helps.
//!   * `W023` — the feasible k range is degenerate (the column stack caps
//!     k at 1 while the outer loop has room): the parallelism knob
//!     cannot move this layer at all.
//!
//! Residency at the configured k is read off the already-lowered plan's
//! mapping (no recomputation); only the `W022` probe maps again, once,
//! at the top of the range — O(1) per layer, no binary search.

use crate::mapping::{map_layer, outer_count, MapConfig};
use crate::plan::ExecutionPlan;
use crate::sim::SimConfig;
use crate::workloads::Network;

use super::codes;
use super::{Diagnostics, Location};

pub fn capacity_pass(net: &Network, cfg: &SimConfig, plan: &ExecutionPlan, d: &mut Diagnostics) {
    let g = &cfg.geometry;
    let max_pairs = g.pairs_per_column(cfg.n_bits).max(1);

    for (i, layer) in net.layers.iter().enumerate() {
        let loc = || Location::Layer { index: i, name: layer.name.clone() };
        let outer = outer_count(layer);
        let k_cfg = cfg.k_for(i);
        // The top of the feasible k range: beyond `outer` there is nothing
        // to divide; beyond `pairs_per_column` every extra group restages.
        let hi = outer.min(max_pairs);

        if k_cfg > outer {
            d.warn(
                codes::W_K_CLAMPED,
                loc(),
                format!(
                    "run.ks wants k={k_cfg} but the outer loop has only \
                     {outer} units; the mapper clamps to k={outer}"
                ),
            );
        }
        if hi == 1 && outer > 1 {
            d.warn(
                codes::W_DEGENERATE_K,
                loc(),
                format!(
                    "feasible k range is degenerate: {max_pairs} operand \
                     pair(s) fit a column at {} bits, so only k=1 maps \
                     without restaging (outer loop has {outer} units)",
                    cfg.n_bits
                ),
            );
        }

        let m = &plan.mapping.layers[i];
        if m.fully_resident() {
            continue;
        }
        d.warn(
            codes::W_NOT_RESIDENT,
            loc(),
            format!(
                "not fully resident at k={}: {} wave(s), {} restaged \
                 round(s) — rounds serialize beyond the paper's resident \
                 pricing assumption",
                m.k, m.waves, m.restaged_rounds
            ),
        );

        // Could *any* k fix it? Probe the top of the range, where waves
        // are minimal and restaging is still zero.
        let probe = MapConfig {
            geometry: g.clone(),
            n_bits: cfg.n_bits,
            ks: vec![hi],
        };
        let resident_k_exists = match map_layer(i, i, layer, &probe) {
            Ok(p) => p.fully_resident(),
            Err(_) => false,
        };
        if !resident_k_exists {
            d.warn(
                codes::W_NO_RESIDENT_K,
                loc(),
                format!(
                    "no fully-resident k exists (probed k={hi}, the top of \
                     the feasible range): the layer's weights exceed bank \
                     capacity at {} bits under this geometry",
                    cfg.n_bits
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::optimizer::min_resident_k;
    use crate::workloads::nets::{pimnet, vgg16};

    fn run(net: &Network, cfg: &SimConfig) -> Diagnostics {
        let mut d = Diagnostics::default();
        let plan = crate::plan::lower(
            net,
            &super::super::plan_check::map_config(cfg),
            cfg.shard,
        )
        .unwrap();
        capacity_pass(net, cfg, &plan, &mut d);
        d
    }

    #[test]
    fn clamp_is_w021() {
        // pimnet's head layer has fewer output channels than k=64 wants.
        let mut cfg = SimConfig::conservative(8);
        cfg.ks = vec![64];
        let d = run(&pimnet(), &cfg);
        assert!(
            d.iter().any(|f| f.code == codes::W_K_CLAMPED),
            "{}",
            d.render_text()
        );
    }

    #[test]
    fn residency_findings_agree_with_the_optimizer() {
        // The analyzer's W020/W022 verdicts must match the mapper and the
        // k-optimizer: W020 ⇔ !fully_resident at the effective k, and
        // W022 ⇔ min_resident_k() = None.
        for net in [pimnet(), vgg16()] {
            let cfg = SimConfig::conservative(8);
            let d = run(&net, &cfg);
            let mc = super::super::plan_check::map_config(&cfg);
            let mapping = crate::mapping::map_network(&net, &mc).unwrap();
            for (i, layer) in net.layers.iter().enumerate() {
                let loc = Location::Layer { index: i, name: layer.name.clone() };
                let flagged_w020 = d
                    .iter()
                    .any(|f| f.code == codes::W_NOT_RESIDENT && f.location == loc);
                assert_eq!(
                    flagged_w020,
                    !mapping.layers[i].fully_resident(),
                    "W020 disagrees with the mapper on {} layer {i}",
                    net.name
                );
                let flagged_w022 = d
                    .iter()
                    .any(|f| f.code == codes::W_NO_RESIDENT_K && f.location == loc);
                let optimizer_says_none =
                    min_resident_k(layer, &cfg.geometry, cfg.n_bits).is_none();
                assert_eq!(
                    flagged_w022, optimizer_says_none,
                    "W022 disagrees with min_resident_k on {} layer {i}",
                    net.name
                );
            }
        }
    }

    #[test]
    fn resident_config_is_silent() {
        // paper_ideal has effectively unlimited subarrays: everything is
        // resident at k=1 and the pass stays quiet.
        let mut cfg = SimConfig::conservative(8);
        cfg.geometry = crate::dram::DramGeometry::paper_ideal();
        let d = run(&pimnet(), &cfg);
        assert!(d.is_empty(), "{}", d.render_text());
    }
}
