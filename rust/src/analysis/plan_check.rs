//! Plan legality: run the exact lowering the pricing session performs and
//! turn its failure modes into coded diagnostics, then verify invariants
//! of the lowered plan the lowering code itself only promises implicitly.
//!
//! The lowering here is *identical* to `SimSession::report`'s (same
//! `MapConfig` from the same `SimConfig`, same `plan::lower` arithmetic),
//! so a plan error found statically is — by `PlanError: PartialEq`
//! construction — the very value `report()`/`serve()` would return. The
//! diagnostic carries it, which is what lets `Job::report` fail fast
//! without changing a single priced or errored result.

use crate::mapping::{MapConfig, MapError};
use crate::plan::{self, ExecutionPlan, PlanError};
use crate::sim::SimConfig;
use crate::workloads::Network;

use super::codes;
use super::{Diagnostics, Location};

/// The `MapConfig` the pricing session derives from a resolved
/// `SimConfig` — shared with the capacity pass so every probe sees the
/// same geometry the plan was lowered under.
pub fn map_config(cfg: &SimConfig) -> MapConfig {
    MapConfig {
        geometry: cfg.geometry.clone(),
        n_bits: cfg.n_bits,
        ks: cfg.ks.clone(),
    }
}

/// Lower `net` onto the grid; on failure emit the coded diagnostic
/// (carrying the exact [`PlanError`]) and return `None`.
pub fn plan_pass(net: &Network, cfg: &SimConfig, d: &mut Diagnostics) -> Option<ExecutionPlan> {
    match plan::lower(net, &map_config(cfg), cfg.shard) {
        Ok(plan) => Some(plan),
        Err(e) => {
            let code = match &e {
                PlanError::Map(MapError::BankOverflow { .. }) => codes::E_BANK_OVERFLOW,
                // `map_network` clamps k before mapping, so a KTooLarge
                // escaping it would breach its own contract.
                PlanError::Map(MapError::KTooLarge { .. }) => codes::E_PLAN_INVARIANT,
                PlanError::ReplicaTooLarge { .. } => codes::E_REPLICA_TOO_LARGE,
                PlanError::SegmentOverflow { .. } => codes::E_SEGMENT_OVERFLOW,
                PlanError::BadHybrid { .. } => codes::E_BAD_HYBRID,
            };
            d.plan_failure(code, Location::Global, e);
            None
        }
    }
}

/// Invariants a lowered plan must satisfy (all `E033` — defensive: the
/// lowering should make them unreachable) plus the residual-hop warning.
pub fn invariants(plan: &ExecutionPlan, d: &mut Diagnostics) {
    // Every replica pipeline must have at least one device.
    for (r, chain) in plan.chains.iter().enumerate() {
        if chain.is_empty() {
            d.error(
                codes::E_PLAN_INVARIANT,
                Location::Global,
                format!("replica {r} lowered to an empty device chain"),
            );
        }
    }

    // No two devices may claim the same (channel, rank) slot.
    let mut claimed: Vec<(usize, usize, usize)> = Vec::new(); // (ch, rank, dev)
    for dev in &plan.devices {
        for rank in dev.ranks.clone() {
            if let Some(&(_, _, other)) =
                claimed.iter().find(|&&(ch, r, _)| ch == dev.channel && r == rank)
            {
                d.error(
                    codes::E_PLAN_INVARIANT,
                    Location::Device { device: dev.id, channel: dev.channel },
                    format!(
                        "device {} claims rank {} on channel {} already owned \
                         by device {other}",
                        dev.id, rank, dev.channel
                    ),
                );
            } else {
                claimed.push((dev.channel, rank, dev.id));
            }
        }
    }

    // One bank per stage: the mapping may not assign two layers one bank.
    let mut banks: Vec<usize> =
        plan.mapping.layers.iter().map(|m| m.bank).collect();
    banks.sort_unstable();
    if banks.windows(2).any(|w| w[0] == w[1]) {
        d.error(
            codes::E_PLAN_INVARIANT,
            Location::Global,
            "two bank stages claim the same bank in the layer mapping".to_string(),
        );
    }
}

/// Residual edges whose endpoints land on different devices: legal (the
/// engine prices the inter-channel hop), but every image pays the premium
/// — worth surfacing before a sweep bakes it in.
pub fn residual_hops(net: &Network, plan: &ExecutionPlan, d: &mut Diagnostics) {
    if plan.replicas == 0 {
        return;
    }
    // Replica chains are structurally identical; inspect replica 0.
    for res in &net.residuals {
        let from = plan.device_hosting(0, res.from_layer);
        let into = plan.device_hosting(0, res.into_layer);
        if let (Some(from), Some(into)) = (from, into) {
            if from != into {
                let name = &net.layers[res.into_layer].name;
                d.warn(
                    codes::W_RESIDUAL_HOP,
                    Location::Layer { index: res.into_layer, name: name.clone() },
                    format!(
                        "residual from layer {} ({}) crosses devices {} → {}: \
                         every image pays the inter-channel hop on this edge",
                        res.from_layer, net.layers[res.from_layer].name, from, into
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::ShardPolicy;
    use crate::workloads::nets::{pimnet, resnet18};

    fn check(net: &Network, cfg: &SimConfig) -> Diagnostics {
        let mut d = Diagnostics::default();
        if let Some(plan) = plan_pass(net, cfg, &mut d) {
            invariants(&plan, &mut d);
            residual_hops(net, &plan, &mut d);
        }
        d
    }

    #[test]
    fn healthy_plans_have_no_findings() {
        let cfg = SimConfig::conservative(8);
        let d = check(&pimnet(), &cfg);
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn plan_failure_codes_match_variants() {
        let net = pimnet();
        let mut cfg = SimConfig::conservative(8);
        cfg.geometry.channels = 2;
        cfg.shard = ShardPolicy::Hybrid { replicas: 5 };
        let d = check(&net, &cfg);
        assert_eq!(d.iter().next().unwrap().code, codes::E_BAD_HYBRID);
        assert!(d.plan_error().is_some());
    }

    #[test]
    fn residual_crossing_a_split_is_w030() {
        // resnet18 layer-split across 2 channels: at least one of its 8
        // shortcuts spans the segment boundary.
        let net = resnet18();
        let mut cfg = SimConfig::conservative(8);
        cfg.geometry.channels = 2;
        cfg.shard = ShardPolicy::LayerSplit;
        let d = check(&net, &cfg);
        assert!(!d.has_errors(), "{}", d.render_text());
        assert!(
            d.iter().any(|f| f.code == codes::W_RESIDUAL_HOP),
            "{}",
            d.render_text()
        );
        // Replicated single-device plans never cross.
        let mut rep = SimConfig::conservative(8);
        rep.geometry.channels = 2;
        let d = check(&net, &rep);
        assert!(d.iter().all(|f| f.code != codes::W_RESIDUAL_HOP));
    }
}
