//! The diagnostic code registry (DESIGN.md §Static analysis).
//!
//! Codes are the machine-readable contract of the analyzer: `E0xx` are
//! errors (the spec cannot run, or would fail when it does), `W0xx` are
//! warnings (the spec runs, but something is degenerate, silently
//! clamped, or guaranteed to misbehave under load). Once published a
//! code's *meaning* is frozen — a code is never reused for a different
//! condition; retired codes leave a tombstone in DESIGN.md. Tooling that
//! matches on codes (CI sweeps, the golden corpus under
//! `examples/specs/bad/`) must keep working across releases.
//!
//! Every constant here must appear in DESIGN.md's registry table; CI
//! greps for exactly that.

// ---- Errors: spec documents -----------------------------------------------

/// The document is not valid JSON at all.
pub const E_JSON: &str = "E001";
/// The document parses but is not an accepted spec (unknown field, bad
/// `api_version`, wrong value type or range).
pub const E_SPEC: &str = "E002";
/// The spec parses but does not resolve into a runnable `Job` (unknown
/// builtin network, invalid inline network or geometry, malformed ks).
pub const E_RESOLVE: &str = "E003";

// ---- Errors: IR ----------------------------------------------------------

/// Structural graph violation: duplicate names, non-topological operand
/// references, wrong arity, no input, no compute node.
pub const E_IR_STRUCTURE: &str = "E010";
/// Shape inference failed: adjacent operators disagree about the tensor
/// flowing between them.
pub const E_IR_SHAPE: &str = "E011";
/// Fusion/legalization rejected the graph: an SFU op without a sole
/// compute consumer, a residual add off the compute spine, an op the
/// bank-op legalizer has no lowering for.
pub const E_IR_LOWER: &str = "E012";

// ---- Errors: mapping / plan ----------------------------------------------

/// The network's bank demand (layers + residual reserves) exceeds the
/// device grid's total banks.
pub const E_BANK_OVERFLOW: &str = "E021";
/// A full-network replica needs more ranks than one channel has
/// (`ShardPolicy::Replicate`).
pub const E_REPLICA_TOO_LARGE: &str = "E030";
/// A layer-split segment exceeds its channel's bank budget.
pub const E_SEGMENT_OVERFLOW: &str = "E031";
/// Hybrid replica count is zero or exceeds the channel count.
pub const E_BAD_HYBRID: &str = "E032";
/// A lowered plan violates its own invariants (overlapping rank claims,
/// duplicate bank assignment, an empty replica chain). Defensive: the
/// lowering code should make this unreachable.
pub const E_PLAN_INVARIANT: &str = "E033";

// ---- Warnings: IR --------------------------------------------------------

/// A non-terminal node has no consumers: dead compute that still gets a
/// bank, prices rounds, and feeds nothing.
pub const W_DEAD_NODE: &str = "W010";

// ---- Warnings: mapping / capacity ----------------------------------------

/// A layer is not fully resident at its configured k: extra waves or
/// operand restaging serialize what the paper prices as parallel.
pub const W_NOT_RESIDENT: &str = "W020";
/// The configured k exceeds the layer's outer-loop count; the mapper
/// silently clamps it.
pub const W_K_CLAMPED: &str = "W021";
/// No fully-resident k exists for this layer at any feasible k — the
/// weights exceed bank capacity however the parallelism knob is set.
pub const W_NO_RESIDENT_K: &str = "W022";
/// The feasible k range is degenerate (only k=1 fits the column stack)
/// while the outer loop has room: the parallelism knob is unusable.
pub const W_DEGENERATE_K: &str = "W023";

// ---- Warnings: plan ------------------------------------------------------

/// A residual shortcut crosses a device boundary; every image pays the
/// inter-channel hop premium on that edge.
pub const W_RESIDUAL_HOP: &str = "W030";

// ---- Warnings: serve / resilience ----------------------------------------

/// The per-request deadline sits below the plan's analytic latency lower
/// bound: every request times out.
pub const W_DEADLINE_UNREACHABLE: &str = "W040";
/// The bounded queue is smaller than the serve batch: a full batch can
/// never accumulate, so admission sheds under any sustained load.
pub const W_QUEUE_UNDERSIZED: &str = "W041";
/// A crash window opens only after the replay horizon (all offered
/// batches already executed): the fault never fires.
pub const W_CRASH_BEYOND_HORIZON: &str = "W042";
/// Faults are configured with seed 0 (the unset default): the schedule
/// is valid but almost certainly not the intended experiment.
pub const W_FAULTS_SEED_ZERO: &str = "W043";

// ---- Warnings: mapping search (pim::mapopt) -------------------------------

/// The search mapper is selected with a candidate budget of zero: no
/// candidate beyond the paper mapping is ever priced, so the "search"
/// degenerates to the paper result.
pub const W_SEARCH_BUDGET_ZERO: &str = "W050";
/// A layer's tiling knob is degenerate at the spec's k (MAC wider than a
/// row, no inner dimension, or the outer loop collapses): the search can
/// only revisit the paper staging for it.
pub const W_TILING_DEGENERATE: &str = "W051";
/// The configured beam width is below 1; the optimizer silently clamps
/// it to 1, expanding only the single best-bounded k-branch.
pub const W_BEAM_CLAMPED: &str = "W052";

// ---- Warnings: traffic / fleet --------------------------------------------

/// An open-loop arrival process is configured with `rate: 0`: no request
/// ever arrives, so the serve run measures an idle fleet.
pub const W_ARRIVAL_RATE_ZERO: &str = "W053";
/// A bursty arrival process whose on/off period is shorter than the
/// batching window: the batcher integrates over whole bursts, so the
/// carefully-shaped traffic is indistinguishable from uniform.
pub const W_BURST_INSIDE_WINDOW: &str = "W054";
/// A heterogeneous fleet (two or more distinct device presets) dispatched
/// round-robin: the capability-blind policy paces the whole fleet at the
/// slowest device; use `policy: "backlog"`.
pub const W_HETERO_BLIND_POLICY: &str = "W055";

/// The full registry: `(code, one-line meaning)`. The uniqueness test in
/// `tests/analysis_check.rs` and CI's DESIGN.md grep guard both walk this
/// table.
pub const REGISTRY: &[(&str, &str)] = &[
    (E_JSON, "spec document is not valid JSON"),
    (E_SPEC, "document is not an accepted spec (field/version/value)"),
    (E_RESOLVE, "spec does not resolve into a runnable Job"),
    (E_IR_STRUCTURE, "graph structure violation (names/arity/topology)"),
    (E_IR_SHAPE, "shape inference failed between adjacent operators"),
    (E_IR_LOWER, "fusion/legalization rejected the graph"),
    (E_BANK_OVERFLOW, "bank demand exceeds the device grid"),
    (E_REPLICA_TOO_LARGE, "replica does not fit one channel"),
    (E_SEGMENT_OVERFLOW, "layer-split segment exceeds channel budget"),
    (E_BAD_HYBRID, "hybrid replica count out of range"),
    (E_PLAN_INVARIANT, "lowered plan violates its own invariants"),
    (W_DEAD_NODE, "dead node: compute output nothing consumes"),
    (W_NOT_RESIDENT, "layer not fully resident at configured k"),
    (W_K_CLAMPED, "configured k exceeds outer count; clamped"),
    (W_NO_RESIDENT_K, "no fully-resident k exists for layer"),
    (W_DEGENERATE_K, "feasible k range collapsed to k=1"),
    (W_RESIDUAL_HOP, "residual edge crosses a device boundary"),
    (W_DEADLINE_UNREACHABLE, "deadline below analytic latency bound"),
    (W_QUEUE_UNDERSIZED, "queue_cap below serve batch"),
    (W_CRASH_BEYOND_HORIZON, "crash window beyond replay horizon"),
    (W_FAULTS_SEED_ZERO, "fault schedule configured with seed 0"),
    (W_SEARCH_BUDGET_ZERO, "search mapper with a zero candidate budget"),
    (W_TILING_DEGENERATE, "tiling knob degenerate at the spec's k"),
    (W_BEAM_CLAMPED, "beam width below 1; clamped to 1"),
    (W_ARRIVAL_RATE_ZERO, "open-loop arrival configured with rate 0"),
    (W_BURST_INSIDE_WINDOW, "burst period shorter than the batch window"),
    (W_HETERO_BLIND_POLICY, "heterogeneous fleet with round-robin dispatch"),
];
