//! `pim::analysis` — the static Spec → IR → Plan verifier
//! (DESIGN.md §Static analysis).
//!
//! The mapping pipeline only works when static invariants hold: weights
//! resident in bank rows given the k knob and the DRAM geometry, legal
//! bank-stage schedules, shard grids that fit channels × ranks, serve
//! policies that can actually meet their own deadlines. Before this
//! module those constraints surfaced as mid-pricing errors or
//! silently-degenerate plans. The analyzer proves or refutes them *before
//! any pricing runs*, and reports findings as [`Diagnostic`]s with stable
//! machine-readable codes ([`codes`]) — cheap, explainable rejection for
//! the thousands of machine-made candidate specs the ROADMAP's optimizer
//! items will generate.
//!
//! Passes, in order (each sees only what the previous proved exists):
//!
//!   1. **Document** — JSON parse / spec schema / resolution into a
//!      [`Job`] (`E001`–`E003`).
//!   2. **IR lints** — graph structure, staged shape inference,
//!      fusion/legalization, dead-node detection (`E010`–`E012`, `W010`).
//!      Only operator-graph specs have an IR to lint.
//!   3. **Plan** — the exact lowering the pricing session performs
//!      (`plan::lower` on the same `MapConfig`), so a plan error found
//!      here *is* the error pricing would hit (`E021`, `E030`–`E032`),
//!      plus post-lowering invariant checks (`E033`, `W030`).
//!   4. **Capacity** — per-layer residency proofs over the mapping
//!      arithmetic, flagged before any binary search runs
//!      (`W020`–`W023`).
//!   5. **Mapping search** — knob sanity for `run.mapper: "search"`
//!      (`W050`–`W052`); silent under the paper mapper.
//!   6. **Serve** — deadline/queue/fault-schedule sanity
//!      (`W040`–`W043`).
//!
//! The analyzer is *pure*: it never changes a priced result. Errors are
//! findings pricing would also report (fail-fast, identical error
//! values); warnings never block anything. Three surfaces:
//! `pim-dram check` (text or `--json`), [`Job::check`] (invoked
//! fail-fast at the head of `report()`/`serve()`), and the CI sweep over
//! `examples/specs/` + the golden corpus in `examples/specs/bad/`.

pub mod codes;

mod capacity;
mod ir_lints;
mod mapopt_check;
mod plan_check;
mod serve_check;

use std::collections::BTreeMap;
use std::fmt;

use crate::api::{Job, NetworkSpec, Spec};
use crate::plan::PlanError;
use crate::util::json::Json;

/// How bad a finding is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// The spec cannot run, or is guaranteed to fail when it does.
    Error,
    /// The spec runs, but something is degenerate or silently clamped.
    Warning,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Error => write!(f, "error"),
            Severity::Warning => write!(f, "warning"),
        }
    }
}

/// Where in the spec → IR → plan stack a finding anchors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Location {
    /// The document as a whole.
    Global,
    /// A dotted spec path, e.g. `serve.resilience.deadline_ms`.
    Spec { path: String },
    /// An operator-graph node, by name.
    Node { node: String },
    /// A lowered bank-stage layer.
    Layer { index: usize, name: String },
    /// A planned device slot.
    Device { device: usize, channel: usize },
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Location::Global => write!(f, "spec"),
            Location::Spec { path } => write!(f, "spec:{path}"),
            Location::Node { node } => write!(f, "node:{node}"),
            Location::Layer { index, name } => write!(f, "layer[{index}]:{name}"),
            Location::Device { device, channel } => {
                write!(f, "device[{device}]@ch{channel}")
            }
        }
    }
}

/// One finding: a stable code, a severity, a structured location and a
/// human message. Plan-stage errors additionally carry the exact
/// [`PlanError`] the pricing path would return, so fail-fast callers
/// ([`Job::report`]/[`Job::serve`]) surface a bitwise-identical error.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    pub location: Location,
    pub message: String,
    /// The underlying plan error, when this diagnostic *is* one.
    pub plan_error: Option<PlanError>,
}

impl Diagnostic {
    /// The stable one-line form golden files and grep-driven tooling
    /// match on: `severity[code] location` (no message — messages may
    /// improve without breaking the contract).
    pub fn summary(&self) -> String {
        format!("{}[{}] {}", self.severity, self.code, self.location)
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("code".to_string(), Json::Str(self.code.to_string()));
        o.insert("severity".to_string(), Json::Str(self.severity.to_string()));
        o.insert("location".to_string(), Json::Str(self.location.to_string()));
        o.insert("message".to_string(), Json::Str(self.message.clone()));
        Json::Obj(o)
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.summary(), self.message)
    }
}

/// An ordered bag of findings from one analysis run.
#[derive(Debug, Clone, Default)]
pub struct Diagnostics {
    diags: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn error(&mut self, code: &'static str, location: Location, message: String) {
        self.diags.push(Diagnostic {
            code,
            severity: Severity::Error,
            location,
            message,
            plan_error: None,
        });
    }

    /// An error that carries the exact plan error pricing would return.
    pub fn plan_failure(&mut self, code: &'static str, location: Location, cause: PlanError) {
        self.diags.push(Diagnostic {
            code,
            severity: Severity::Error,
            location,
            message: cause.to_string(),
            plan_error: Some(cause),
        });
    }

    pub fn warn(&mut self, code: &'static str, location: Location, message: String) {
        self.diags.push(Diagnostic {
            code,
            severity: Severity::Warning,
            location,
            message,
            plan_error: None,
        });
    }

    pub fn iter(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter()
    }

    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    pub fn len(&self) -> usize {
        self.diags.len()
    }

    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// The first carried [`PlanError`], if any finding is one — what the
    /// fail-fast read paths return.
    pub fn plan_error(&self) -> Option<&PlanError> {
        self.diags.iter().find_map(|d| d.plan_error.as_ref())
    }

    /// One `severity[code] location` line per finding — the stable form
    /// the golden corpus pins (newline-terminated; empty string when
    /// clean).
    pub fn summary_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&d.summary());
            out.push('\n');
        }
        out
    }

    /// Human rendering: one full line per finding.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out
    }

    /// Canonical JSON (byte-stable under `Json::pretty`): the findings in
    /// order plus the totals.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "diagnostics".to_string(),
            Json::Arr(self.diags.iter().map(Diagnostic::to_json).collect()),
        );
        o.insert("errors".to_string(), Json::Num(self.error_count() as f64));
        o.insert("warnings".to_string(), Json::Num(self.warning_count() as f64));
        Json::Obj(o)
    }
}

/// Analyze a JSON spec document. Never panics, never errors: malformed
/// input *is* the finding (`E001`/`E002`).
pub fn check_text(text: &str) -> Diagnostics {
    match Spec::from_json_text(text) {
        Ok(spec) => check_spec(&spec),
        Err(e) => {
            let mut d = Diagnostics::default();
            let msg = format!("{e:#}");
            // `util::json` errors have a fixed prefix; anything else the
            // parser accepted but the spec schema rejected.
            let code = if msg.contains("json parse error at byte") {
                codes::E_JSON
            } else {
                codes::E_SPEC
            };
            d.error(code, Location::Global, msg);
            d
        }
    }
}

/// Analyze a parsed [`Spec`]. IR errors short-circuit (a graph that does
/// not lower has no plan to analyze); a spec that does not resolve is a
/// single `E003`.
pub fn check_spec(spec: &Spec) -> Diagnostics {
    let mut d = Diagnostics::default();
    if let NetworkSpec::Graph(g) = &spec.network {
        ir_lints::lint_graph(g, &mut d);
        if d.has_errors() {
            return d;
        }
    }
    match Job::new(spec.clone()) {
        Ok(job) => {
            check_resolved(&job, &mut d);
            d
        }
        Err(e) => {
            d.error(codes::E_RESOLVE, Location::Global, format!("{e:#}"));
            d
        }
    }
}

/// Analyze an already-resolved [`Job`] — the `Job::check` entry point.
/// Resolution already succeeded, so the IR stage can only contribute
/// warnings here.
pub fn check_job(job: &Job) -> Diagnostics {
    let mut d = Diagnostics::default();
    if let NetworkSpec::Graph(g) = &job.spec().network {
        ir_lints::lint_graph(g, &mut d);
    }
    check_resolved(job, &mut d);
    d
}

/// The post-resolution passes: plan, then (only on a lowered plan)
/// capacity, invariants and serve sanity.
fn check_resolved(job: &Job, d: &mut Diagnostics) {
    let Some(plan) = plan_check::plan_pass(job.network(), job.config(), d) else {
        return;
    };
    plan_check::invariants(&plan, d);
    plan_check::residual_hops(job.network(), &plan, d);
    capacity::capacity_pass(job.network(), job.config(), &plan, d);
    mapopt_check::mapopt_pass(job, d);
    serve_check::serve_pass(job, d);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::ShardPolicy;

    #[test]
    fn clean_builtin_spec_has_no_findings() {
        // paper_favorable's geometry keeps every pimnet layer resident.
        let d = check_spec(&Spec::builtin("pimnet"));
        assert!(d.is_empty(), "{}", d.render_text());
        // The conservative die is tighter — conv2 wants 74 subarrays of a
        // 32-subarray bank, a W020 wave warning — but still error-free.
        let d = check_spec(&Spec::builtin("pimnet").with_preset("conservative"));
        assert_eq!(d.error_count(), 0, "{}", d.render_text());
        assert!(
            d.iter().any(|f| f.code == codes::W_NOT_RESIDENT),
            "{}",
            d.render_text()
        );
    }

    #[test]
    fn document_errors_are_coded() {
        // Truncated JSON → E001.
        let d = check_text("{\"api_version\": 1");
        assert_eq!(d.error_count(), 1);
        assert_eq!(d.iter().next().unwrap().code, codes::E_JSON);
        // Parses, but not a spec → E002.
        let d = check_text("{\"api_version\": 1, \"speed\": \"max\"}");
        assert_eq!(d.iter().next().unwrap().code, codes::E_SPEC);
        let d = check_text("{\"api_version\": 2, \"network\": \"pimnet\"}");
        assert_eq!(d.iter().next().unwrap().code, codes::E_SPEC);
        // A spec that does not resolve → E003.
        let d = check_spec(&Spec::builtin("lenet"));
        assert_eq!(d.iter().next().unwrap().code, codes::E_RESOLVE);
        assert!(d.iter().next().unwrap().message.contains("alexnet"));
    }

    #[test]
    fn plan_errors_carry_the_exact_plan_error() {
        // vgg16 needs 16 banks; a 1×1 grid of 8 banks overflows.
        let spec = Spec::builtin("vgg16").with_preset("conservative").with_grid(1, 1);
        let d = check_spec(&spec);
        assert!(d.has_errors());
        let diag = d.iter().next().unwrap();
        assert_eq!(diag.code, codes::E_BANK_OVERFLOW);
        // The carried error is the one pricing returns.
        let job = Job::new(spec).unwrap();
        let mut session = job.session();
        let want = session.report(job.config()).unwrap_err();
        assert_eq!(d.plan_error(), Some(&want));
    }

    #[test]
    fn replica_too_large_and_bad_hybrid_are_distinct_codes() {
        let spec = Spec::builtin("resnet18")
            .with_preset("conservative")
            .with_grid(4, 1);
        let d = check_spec(&spec);
        assert_eq!(d.iter().next().unwrap().code, codes::E_REPLICA_TOO_LARGE);

        let spec = Spec::builtin("pimnet")
            .with_preset("conservative")
            .with_grid(2, 4)
            .with_shard(ShardPolicy::Hybrid { replicas: 3 });
        let d = check_spec(&spec);
        assert_eq!(d.iter().next().unwrap().code, codes::E_BAD_HYBRID);
    }

    #[test]
    fn rendering_is_stable_and_json_is_canonical() {
        let mut d = Diagnostics::default();
        d.warn(
            codes::W_K_CLAMPED,
            Location::Layer { index: 3, name: "conv4".into() },
            "k=8 exceeds outer count 4; mapper clamps to 4".into(),
        );
        d.error(codes::E_RESOLVE, Location::Global, "boom".into());
        assert_eq!(
            d.summary_text(),
            "warning[W021] layer[3]:conv4\nerror[E003] spec\n"
        );
        assert!(d.render_text().contains("warning[W021] layer[3]:conv4: k=8"));
        let text = d.to_json().pretty();
        // Byte-stable: parse → pretty is a fixed point.
        let reparsed = Json::parse(&text).unwrap();
        assert_eq!(reparsed.pretty(), text);
        assert_eq!(reparsed.get("errors").unwrap().as_i64(), Some(1));
        assert_eq!(reparsed.get("warnings").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn location_display_forms() {
        for (loc, want) in [
            (Location::Global, "spec"),
            (Location::Spec { path: "serve.batch".into() }, "spec:serve.batch"),
            (Location::Node { node: "q_proj".into() }, "node:q_proj"),
            (Location::Layer { index: 0, name: "c1".into() }, "layer[0]:c1"),
            (Location::Device { device: 2, channel: 1 }, "device[2]@ch1"),
        ] {
            assert_eq!(loc.to_string(), want);
        }
    }
}
