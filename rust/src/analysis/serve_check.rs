//! Serve/resilience sanity: misconfigurations the serving layer accepts
//! and then quietly turns into a degenerate experiment — every request
//! timing out, every admission shed, a carefully-specified crash that can
//! never fire.
//!
//!   * `W040` — a per-request deadline below the plan's analytic latency
//!     bound. One batch takes at least `latency_ns` even with a fault-free
//!     fleet, so every request is dead on arrival.
//!   * `W041` — `queue_cap` smaller than the serve batch: a full batch
//!     can never accumulate behind one device, so sustained load sheds.
//!   * `W042` — a crash window that opens at or after the replay horizon
//!     (the number of batches the run offers): the fault never fires and
//!     the "degraded" experiment silently measures a healthy fleet.
//!   * `W043` — a non-noop fault schedule with `seed: 0` (the unset
//!     default): valid, deterministic, and almost never the intended
//!     experiment.
//!   * `W053` — an open-loop arrival process with `rate: 0`: the rate
//!     silently falls back to capacity-derived pacing, so the "open-loop"
//!     experiment is really the closed-loop one.
//!   * `W054` — a bursty arrival whose on/off period fits inside the
//!     batching window: the batcher integrates over whole bursts and the
//!     shaped traffic degenerates to uniform.
//!   * `W055` — a heterogeneous fleet dispatched round-robin: the
//!     capability-blind policy paces the fleet at its slowest device.
//!
//! `W040` is the one pass that needs a priced number; it prices through a
//! *fresh* `job.session()` (never `job.report()`, which itself runs this
//! analyzer fail-fast — pricing through it would recurse).

use crate::api::{DevicesSpec, Job};
use crate::coordinator::{ArrivalKind, Policy};
use crate::util::ceil_div;

use super::codes;
use super::{Diagnostics, Location};

fn spec_path(path: &str) -> Location {
    Location::Spec { path: path.to_string() }
}

pub fn serve_pass(job: &Job, d: &mut Diagnostics) {
    let Some(serve) = &job.spec().serve else { return };
    let batch = serve.batch.max(1);

    if let Some(res) = &serve.resilience {
        if res.queue_cap < batch {
            d.warn(
                codes::W_QUEUE_UNDERSIZED,
                spec_path("serve.resilience.queue_cap"),
                format!(
                    "queue_cap {} is smaller than the serve batch {batch}: a \
                     full batch can never queue behind one device, so \
                     sustained load is shed",
                    res.queue_cap
                ),
            );
        }
        if let Some(deadline_ms) = res.deadline_ms {
            // Analytic lower bound: one batch on a fault-free device. A
            // fresh session — `job.report()` would recurse through check().
            let mut session = job.session();
            if let Ok(report) = session.report(job.config()) {
                let deadline_ns = deadline_ms as f64 * 1e6;
                if deadline_ns < report.latency_ns {
                    d.warn(
                        codes::W_DEADLINE_UNREACHABLE,
                        spec_path("serve.resilience.deadline_ms"),
                        format!(
                            "deadline {deadline_ms} ms is below the plan's \
                             analytic batch latency {:.3} ms: every request \
                             times out even on a fault-free fleet",
                            report.latency_ns / 1e6
                        ),
                    );
                }
            }
        }
    }

    if let Some(arrival) = &serve.arrival {
        if arrival.rate_rps == 0.0 {
            d.warn(
                codes::W_ARRIVAL_RATE_ZERO,
                spec_path("serve.arrival.rate"),
                "open-loop arrival has rate 0: pacing falls back to the \
                 capacity-derived closed-loop schedule; set an explicit \
                 requests/s rate for a real open-loop experiment"
                    .to_string(),
            );
        }
        if arrival.kind == ArrivalKind::Bursty
            && arrival.period_ms < serve.batch_window_ms
        {
            d.warn(
                codes::W_BURST_INSIDE_WINDOW,
                spec_path("serve.arrival.period_ms"),
                format!(
                    "burst period {} ms fits inside the {} ms batching \
                     window: the batcher integrates over whole bursts, so \
                     the shaped traffic is indistinguishable from uniform",
                    arrival.period_ms, serve.batch_window_ms
                ),
            );
        }
    }

    if let Some(fleet) = serve.devices.as_ref().and_then(DevicesSpec::fleet) {
        let hetero = fleet.iter().any(|dev| *dev != fleet[0]);
        if hetero && serve.policy == Policy::RoundRobin {
            d.warn(
                codes::W_HETERO_BLIND_POLICY,
                spec_path("serve.policy"),
                "heterogeneous fleet dispatched round-robin: the \
                 capability-blind policy paces the whole fleet at its \
                 slowest device; use policy \"backlog\""
                    .to_string(),
            );
        }
    }

    if let Some(faults) = &serve.faults {
        if faults.is_noop() {
            return;
        }
        if faults.seed == 0 {
            d.warn(
                codes::W_FAULTS_SEED_ZERO,
                spec_path("serve.faults.seed"),
                "fault schedule uses seed 0 (the unset default); set an \
                 explicit seed so the experiment is the one you meant"
                    .to_string(),
            );
        }
        // Batches the run actually offers each device, at most: a crash
        // whose window opens later can never fire.
        let horizon = ceil_div(job.spec().images.max(1), batch) as u64;
        for (ci, crash) in faults.crash.iter().enumerate() {
            if crash.after >= horizon {
                d.warn(
                    codes::W_CRASH_BEYOND_HORIZON,
                    spec_path(&format!("serve.faults.crash[{ci}]")),
                    format!(
                        "crash of device {} opens after {} batch(es) but the \
                         run offers only {horizon}: the fault never fires",
                        crash.device, crash.after
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{DeviceSpec, Spec};
    use crate::coordinator::{CrashSpec, FaultSpec, ResilienceSpec, TrafficSpec};

    fn check(spec: Spec) -> Diagnostics {
        let job = Job::new(spec).unwrap();
        let mut d = Diagnostics::default();
        serve_pass(&job, &mut d);
        d
    }

    fn serving_spec() -> Spec {
        let mut spec = Spec::builtin("pimnet").with_preset("conservative");
        spec.serve = Some(Default::default());
        spec
    }

    #[test]
    fn specs_without_serve_are_silent() {
        let d = check(Spec::builtin("pimnet").with_preset("conservative"));
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn undersized_queue_is_w041() {
        let mut spec = serving_spec();
        let serve = spec.serve.as_mut().unwrap();
        serve.batch = 8;
        serve.resilience =
            Some(ResilienceSpec { queue_cap: 4, ..Default::default() });
        let d = check(spec);
        let f = d.iter().next().unwrap();
        assert_eq!(f.code, codes::W_QUEUE_UNDERSIZED);
        assert_eq!(
            f.location,
            Location::Spec { path: "serve.resilience.queue_cap".into() }
        );
    }

    #[test]
    fn impossible_deadline_is_w040_and_a_generous_one_is_not() {
        // Self-calibrating: price the batch first, then set deadlines on
        // either side of it.
        let job = Job::new(serving_spec()).unwrap();
        let mut session = job.session();
        let latency_ns = session.report(job.config()).unwrap().latency_ns;
        let lo_ms = (latency_ns / 1e6 / 2.0).floor() as u64;
        let hi_ms = (latency_ns / 1e6 * 2.0).ceil() as u64 + 1;

        for (deadline_ms, want) in [(lo_ms, true), (hi_ms, false)] {
            let mut spec = serving_spec();
            spec.serve.as_mut().unwrap().resilience = Some(ResilienceSpec {
                deadline_ms: Some(deadline_ms),
                ..Default::default()
            });
            let d = check(spec);
            assert_eq!(
                d.iter().any(|f| f.code == codes::W_DEADLINE_UNREACHABLE),
                want,
                "deadline {deadline_ms} ms vs latency {latency_ns} ns:\n{}",
                d.render_text()
            );
        }
    }

    #[test]
    fn fault_schedule_findings_are_w042_and_w043() {
        let mut spec = serving_spec();
        spec.images = 64;
        let serve = spec.serve.as_mut().unwrap();
        serve.batch = 8; // horizon: 64 / 8 = 8 batches
        serve.faults = Some(FaultSpec {
            seed: 0,
            transient: 0.1,
            crash: vec![
                CrashSpec { device: 0, after: 2, down_for: None },
                CrashSpec { device: 1, after: 8, down_for: Some(2) },
            ],
            ..Default::default()
        });
        let d = check(spec);
        assert!(d.iter().any(|f| f.code == codes::W_FAULTS_SEED_ZERO));
        let beyond: Vec<_> = d
            .iter()
            .filter(|f| f.code == codes::W_CRASH_BEYOND_HORIZON)
            .collect();
        assert_eq!(beyond.len(), 1, "{}", d.render_text());
        assert_eq!(
            beyond[0].location,
            Location::Spec { path: "serve.faults.crash[1]".into() }
        );
    }

    #[test]
    fn zero_rate_arrival_is_w053_and_an_explicit_rate_is_not() {
        for (rate_rps, want) in [(0.0, true), (500.0, false)] {
            let mut spec = serving_spec();
            spec.serve.as_mut().unwrap().arrival =
                Some(TrafficSpec { rate_rps, ..Default::default() });
            let d = check(spec);
            assert_eq!(
                d.iter().any(|f| f.code == codes::W_ARRIVAL_RATE_ZERO),
                want,
                "rate {rate_rps}:\n{}",
                d.render_text()
            );
        }
    }

    #[test]
    fn burst_period_inside_the_batch_window_is_w054() {
        let mut spec = serving_spec();
        let serve = spec.serve.as_mut().unwrap();
        serve.batch_window_ms = 10;
        serve.arrival = Some(TrafficSpec {
            kind: ArrivalKind::Bursty,
            rate_rps: 1000.0,
            period_ms: 4,
            ..Default::default()
        });
        let d = check(spec);
        let f = d.iter().next().unwrap();
        assert_eq!(f.code, codes::W_BURST_INSIDE_WINDOW);
        assert_eq!(
            f.location,
            Location::Spec { path: "serve.arrival.period_ms".into() }
        );
        // A Poisson process with the same short period is shapeless — no
        // burst to smooth away, no warning.
        let mut spec = serving_spec();
        let serve = spec.serve.as_mut().unwrap();
        serve.batch_window_ms = 10;
        serve.arrival =
            Some(TrafficSpec { rate_rps: 1000.0, period_ms: 4, ..Default::default() });
        assert!(check(spec).is_empty());
    }

    #[test]
    fn hetero_fleet_under_round_robin_is_w055() {
        let cloud = DeviceSpec { preset: "cloud".into(), ..Default::default() };
        let edge = DeviceSpec { preset: "edge".into(), ..Default::default() };

        let mut spec = serving_spec();
        spec.serve.as_mut().unwrap().devices =
            Some(DevicesSpec::Fleet(vec![cloud.clone(), edge.clone()]));
        let d = check(spec);
        let f = d.iter().next().unwrap();
        assert_eq!(f.code, codes::W_HETERO_BLIND_POLICY);
        assert_eq!(f.location, Location::Spec { path: "serve.policy".into() });

        // Backlog policy on the same fleet, and a homogeneous fleet under
        // round-robin, are both fine.
        let mut spec = serving_spec();
        let serve = spec.serve.as_mut().unwrap();
        serve.devices = Some(DevicesSpec::Fleet(vec![cloud.clone(), edge]));
        serve.policy = Policy::Backlog;
        assert!(check(spec).is_empty());

        let mut spec = serving_spec();
        spec.serve.as_mut().unwrap().devices =
            Some(DevicesSpec::Fleet(vec![cloud.clone(), cloud]));
        assert!(check(spec).is_empty());
    }

    #[test]
    fn noop_faults_do_not_warn_about_their_seed() {
        let mut spec = serving_spec();
        spec.serve.as_mut().unwrap().faults = Some(FaultSpec::default());
        let d = check(spec);
        assert!(d.is_empty(), "{}", d.render_text());
    }
}
