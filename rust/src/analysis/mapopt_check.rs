//! Mapping-search sanity (`pim::mapopt`): knob settings the optimizer
//! accepts and then quietly neutralizes. All three passes run only when
//! the spec opts into `run.mapper: "search"` — the paper mapper has none
//! of these knobs.
//!
//!   * `W050` — `search_budget: 0`: no candidate beyond the paper
//!     mapping is ever priced, so the search degenerates to the paper
//!     result (byte-identical, just slower to ask for).
//!   * `W052` — `beam: 0`: the optimizer clamps the beam to 1, so only
//!     the single best-bounded k-branch is expanded.
//!   * `W051` — per layer: the tiling knob is degenerate at the spec's k
//!     (MAC wider than a DRAM row, no inner dimension, or the outer loop
//!     collapses under k), so the search can only revisit the paper
//!     staging for that layer. Purely arithmetic — nothing is priced.

use crate::api::{Job, Mapper};
use crate::mapping::candidates::tiling_applicable;
use crate::mapping::outer_count;

use super::codes;
use super::{Diagnostics, Location};

pub fn mapopt_pass(job: &Job, d: &mut Diagnostics) {
    let run = &job.spec().run;
    if run.mapper != Mapper::Search {
        return;
    }

    if run.search_budget == 0 {
        d.warn(
            codes::W_SEARCH_BUDGET_ZERO,
            Location::Spec { path: "run.search_budget".to_string() },
            "search_budget 0 prices no candidate beyond the paper \
             mapping: the search degenerates to the paper result"
                .to_string(),
        );
    }
    if run.beam == 0 {
        d.warn(
            codes::W_BEAM_CLAMPED,
            Location::Spec { path: "run.beam".to_string() },
            "beam 0 is clamped to 1: only the single best-bounded \
             k-branch is expanded per layer"
                .to_string(),
        );
    }

    let cfg = job.config();
    for (i, layer) in job.network().layers.iter().enumerate() {
        let paper_k = cfg.k_for(i).min(outer_count(layer));
        if !tiling_applicable(layer, &cfg.geometry, paper_k) {
            d.warn(
                codes::W_TILING_DEGENERATE,
                Location::Layer { index: i, name: layer.name.clone() },
                format!(
                    "tiling is degenerate at k={paper_k}: the search can \
                     only revisit the paper staging for this layer"
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Spec;

    fn check(spec: Spec) -> Diagnostics {
        let job = Job::new(spec).unwrap();
        let mut d = Diagnostics::default();
        mapopt_pass(&job, &mut d);
        d
    }

    #[test]
    fn paper_mapper_is_silent() {
        let d = check(Spec::builtin("pimnet").with_preset("conservative"));
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn zero_knobs_are_w050_and_w052() {
        let mut spec = Spec::builtin("pimnet")
            .with_preset("conservative")
            .with_mapper(Mapper::Search);
        spec.run.search_budget = 0;
        spec.run.beam = 0;
        let d = check(spec);
        assert!(d.iter().any(|f| f.code == codes::W_SEARCH_BUDGET_ZERO));
        assert!(d.iter().any(|f| f.code == codes::W_BEAM_CLAMPED));
        assert!(d
            .iter()
            .any(|f| f.location == Location::Spec { path: "run.search_budget".into() }));
    }

    #[test]
    fn degenerate_tiling_is_w051_per_layer() {
        // mobilenet_mini's depthwise layers have macs_per_outer == 1 on
        // the conservative die, so their tiling knob is unsearchable.
        let spec = Spec::builtin("mobilenet_mini")
            .with_preset("conservative")
            .with_mapper(Mapper::Search);
        let job = Job::new(spec.clone()).unwrap();
        let cfg = job.config();
        let want: Vec<usize> = job
            .network()
            .layers
            .iter()
            .enumerate()
            .filter(|(i, l)| {
                let k = cfg.k_for(*i).min(outer_count(l));
                !tiling_applicable(l, &cfg.geometry, k)
            })
            .map(|(i, _)| i)
            .collect();
        let d = check(spec);
        let got: Vec<usize> = d
            .iter()
            .filter(|f| f.code == codes::W_TILING_DEGENERATE)
            .filter_map(|f| match &f.location {
                Location::Layer { index, .. } => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(got, want, "{}", d.render_text());
    }
}
