//! IR lints: structural validation, staged shape inference, lowering
//! legality and dead-node detection over `pim::ir` operator graphs.
//!
//! The pass pipeline itself (`ir::lower`) already *rejects* bad graphs —
//! but as one opaque `anyhow` error at resolve time. This pass re-runs
//! the same stages separately so each failure gets its own stable code
//! and, where derivable, a node-level location:
//!
//!   * `E010` — `Graph::validate` (names, arity, topological operand
//!     order, exactly one input, ≥ 1 compute node).
//!   * `E011` — shape inference, walked node-by-node here (instead of
//!     through `shape::infer`) so the diagnostic lands on the first node
//!     whose operands disagree.
//!   * `E012` — SFU fusion / bank-op legalization rejections.
//!   * `W010` — dead nodes: a non-terminal node nothing consumes. The
//!     lowering accepts these, maps them to bank stages, and prices their
//!     rounds — compute that feeds nothing.
//!
//! Stages short-circuit: a graph that fails `validate` is not
//! shape-walked (operand indices may be out of range), and a graph that
//! fails shape inference is not fused.

use crate::ir::passes::{fuse, legalize};
use crate::ir::shape::{output_shape, Shape};
use crate::ir::Graph;

use super::codes;
use super::{Diagnostics, Location};

/// Run every IR stage over `g`, appending findings to `d`.
pub fn lint_graph(g: &Graph, d: &mut Diagnostics) {
    if let Err(e) = g.validate() {
        d.error(codes::E_IR_STRUCTURE, Location::Global, format!("{e:#}"));
        return;
    }

    // Dead nodes are detectable as soon as the structure is sound. The
    // last node is the graph output — having no consumers is its job.
    let counts = g.consumer_counts();
    let last = g.nodes.len() - 1;
    for (i, node) in g.nodes.iter().enumerate() {
        if i != last && counts[i] == 0 {
            d.warn(
                codes::W_DEAD_NODE,
                Location::Node { node: node.name.clone() },
                format!(
                    "node `{}` ({:?}) has no consumers and is not the graph \
                     output; it still lowers to a bank stage and prices rounds",
                    node.name, node.op
                ),
            );
        }
    }

    // Shape walk, node-attributed: the same arithmetic as `shape::infer`,
    // stepped here so the first disagreement names its node.
    let mut shapes: Vec<Shape> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let ins: Vec<Shape> = node.inputs.iter().map(|id| shapes[id.0]).collect();
        match output_shape(node, &ins) {
            Ok(s) => shapes.push(s),
            Err(e) => {
                d.error(
                    codes::E_IR_SHAPE,
                    Location::Node { node: node.name.clone() },
                    format!("{e:#}"),
                );
                return;
            }
        }
    }

    // Fusion + legalization: sole-consumer SFU rules, residual spine
    // placement, bank-op coverage.
    let fused = match fuse(g) {
        Ok(f) => f,
        Err(e) => {
            d.error(codes::E_IR_LOWER, Location::Global, format!("{e:#}"));
            return;
        }
    };
    if let Err(e) = legalize(g, &shapes, &fused) {
        d.error(codes::E_IR_LOWER, Location::Global, format!("{e:#}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(g: &Graph) -> Diagnostics {
        let mut d = Diagnostics::default();
        lint_graph(g, &mut d);
        d
    }

    fn base_graph() -> Graph {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 1 });
        let c = g.conv("c1", x, 4, 3, 1, 1);
        g.relu("relu", c);
        g
    }

    #[test]
    fn clean_graph_lints_clean() {
        let d = lint(&base_graph());
        assert!(d.is_empty(), "{}", d.render_text());
    }

    #[test]
    fn structural_violation_is_e010() {
        let mut g = base_graph();
        // Second input node: validate demands exactly one.
        g.input("x2", Shape::Flat { n: 4 });
        let d = lint(&g);
        assert_eq!(d.iter().next().unwrap().code, codes::E_IR_STRUCTURE);
    }

    #[test]
    fn shape_disagreement_is_e011_on_the_node() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Mat { rows: 4, cols: 8 });
        let w = g.linear("w", x, 16); // 4×16
        // Contraction mismatch: (4×16)·(4×16) without transpose.
        g.matmul("mm", w, w);
        let d = lint(&g);
        let first = d.iter().next().unwrap();
        assert_eq!(first.code, codes::E_IR_SHAPE);
        assert_eq!(first.location, Location::Node { node: "mm".into() });
    }

    #[test]
    fn fusion_violation_is_e012() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 1 });
        // SFU op directly on the input (no compute producer to fuse into).
        g.relu("relu", x);
        g.conv("c1", x, 4, 3, 1, 1);
        let d = lint(&g);
        assert!(d.iter().any(|f| f.code == codes::E_IR_LOWER), "{}", d.render_text());
    }

    #[test]
    fn dead_node_is_w010() {
        let mut g = base_graph();
        // A second conv off the input; it becomes the terminal node, which
        // strands `relu` (the previous terminal) with zero consumers.
        let x = crate::ir::NodeId(0);
        g.conv("orphan", x, 2, 1, 1, 0);
        let d = lint(&g);
        let dead: Vec<_> =
            d.iter().filter(|f| f.code == codes::W_DEAD_NODE).collect();
        // `relu` (previous terminal) now has no consumers either — both
        // it and nothing else may be flagged; `orphan` is terminal so NOT
        // flagged.
        assert!(dead
            .iter()
            .all(|f| f.location == Location::Node { node: "relu".into() }));
        assert_eq!(dead.len(), 1, "{}", d.render_text());
    }
}
