//! GPU baseline (DESIGN.md S13): a roofline model of the NVIDIA Titan Xp
//! the paper compares against (§V-B: 3840 CUDA cores, 547.7 GB/s).
//!
//! Fig 16 compares PIM-DRAM against the *ideal* GPU — i.e. every layer
//! runs at its roofline-attainable rate — which is exactly what this model
//! computes: `t_layer = max(FLOPs / peak, bytes / BW)`. Fig 1 plots the
//! same roofline with VGG16's layers as points.

pub mod roofline;

pub use roofline::{GpuModel, RooflinePoint};
