//! Roofline model (Fig 1) and ideal-GPU layer timing (Fig 16 baseline).

use crate::workloads::{LayerDesc, Network};

/// A peak-rate GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuModel {
    pub name: String,
    /// Peak arithmetic throughput (FLOP/s).
    pub peak_flops: f64,
    /// Memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Achieved fraction of roofline (1.0 = the paper's "ideal GPU").
    pub efficiency: f64,
}

impl GpuModel {
    /// NVIDIA Titan Xp: 3840 CUDA cores × 1.582 GHz × 2 FLOP ≈ 12.15
    /// TFLOP/s fp32; 547.7 GB/s (the paper's §V-B numbers).
    pub fn titan_xp() -> Self {
        GpuModel {
            name: "TITAN Xp".into(),
            peak_flops: 12.15e12,
            mem_bw: 547.7e9,
            efficiency: 1.0,
        }
    }

    /// Ridge point: operational intensity where compute == memory bound.
    pub fn ridge_intensity(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Attainable FLOP/s at operational intensity `oi` (the roofline).
    pub fn attainable(&self, oi: f64) -> f64 {
        (oi * self.mem_bw).min(self.peak_flops) * self.efficiency
    }

    /// Ideal execution time of one layer for one input (seconds).
    pub fn layer_time_s(&self, layer: &LayerDesc, bytes_per_elem: usize) -> f64 {
        let compute = layer.flops() as f64 / self.peak_flops;
        let memory = layer.bytes(bytes_per_elem) as f64 / self.mem_bw;
        compute.max(memory) / self.efficiency
    }

    /// Ideal end-to-end time for one input through the network (seconds).
    pub fn network_time_s(&self, net: &Network, bytes_per_elem: usize) -> f64 {
        net.layers
            .iter()
            .map(|l| self.layer_time_s(l, bytes_per_elem))
            .sum()
    }

    /// Is the layer memory-bound on this GPU?
    pub fn memory_bound(&self, layer: &LayerDesc, bytes_per_elem: usize) -> bool {
        layer.op_intensity(bytes_per_elem) < self.ridge_intensity()
    }
}

/// One point on the roofline plot (a layer).
#[derive(Debug, Clone, PartialEq)]
pub struct RooflinePoint {
    pub layer: String,
    pub op_intensity: f64,
    pub attainable_gflops: f64,
    pub achieved_gflops: f64,
    pub memory_bound: bool,
}

/// Fig 1 data: every layer of `net` placed on `gpu`'s roofline.
pub fn roofline_points(
    gpu: &GpuModel,
    net: &Network,
    bytes_per_elem: usize,
) -> Vec<RooflinePoint> {
    net.layers
        .iter()
        .map(|l| {
            let oi = l.op_intensity(bytes_per_elem);
            let att = gpu.attainable(oi);
            let t = gpu.layer_time_s(l, bytes_per_elem);
            RooflinePoint {
                layer: l.name.clone(),
                op_intensity: oi,
                attainable_gflops: att / 1e9,
                achieved_gflops: (l.flops() as f64 / t) / 1e9,
                memory_bound: gpu.memory_bound(l, bytes_per_elem),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::vgg16;

    #[test]
    fn titan_xp_ridge_point() {
        let gpu = GpuModel::titan_xp();
        // 12.15 TF / 547.7 GB/s ≈ 22.2 FLOP/byte.
        assert!((gpu.ridge_intensity() - 22.18).abs() < 0.2);
    }

    #[test]
    fn attainable_clamps_at_peak() {
        let gpu = GpuModel::titan_xp();
        assert_eq!(gpu.attainable(1e6), gpu.peak_flops);
        assert!((gpu.attainable(1.0) - gpu.mem_bw).abs() < 1.0);
    }

    #[test]
    fn vgg16_fc_layers_memory_bound() {
        // Fig 1's claim: some VGG16 layers are memory bound on Titan Xp.
        let gpu = GpuModel::titan_xp();
        let net = vgg16();
        let points = roofline_points(&gpu, &net, 4);
        let bound: Vec<&str> = points
            .iter()
            .filter(|p| p.memory_bound)
            .map(|p| p.layer.as_str())
            .collect();
        assert!(bound.contains(&"fc6"), "memory-bound set: {bound:?}");
        assert!(bound.contains(&"fc7"));
        // And the big convs are compute bound.
        assert!(!points.iter().find(|p| p.layer == "conv3_2").unwrap().memory_bound);
    }

    #[test]
    fn memory_bound_layer_time_set_by_bandwidth() {
        let gpu = GpuModel::titan_xp();
        let net = vgg16();
        let fc6 = net.layers.iter().find(|l| l.name == "fc6").unwrap();
        let t = gpu.layer_time_s(fc6, 4);
        let t_mem = fc6.bytes(4) as f64 / gpu.mem_bw;
        assert!((t - t_mem).abs() / t_mem < 1e-9);
    }

    #[test]
    fn achieved_equals_attainable_for_ideal_gpu() {
        let gpu = GpuModel::titan_xp();
        for p in roofline_points(&gpu, &vgg16(), 4) {
            assert!(
                (p.achieved_gflops - p.attainable_gflops).abs()
                    / p.attainable_gflops
                    < 1e-9,
                "{}",
                p.layer
            );
        }
    }

    #[test]
    fn efficiency_scales_time() {
        let mut gpu = GpuModel::titan_xp();
        let net = vgg16();
        let t1 = gpu.network_time_s(&net, 4);
        gpu.efficiency = 0.5;
        let t2 = gpu.network_time_s(&net, 4);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
