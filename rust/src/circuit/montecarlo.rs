//! Monte Carlo robustness analysis of the AND primitive (Fig 15
//! reproduction): 100 000 samples per input case with C/V/offset variation,
//! pre-sense bitline histograms, sense-margin statistics and failure rate.

use super::transient::{AndInputs, VariationSample};
use super::CircuitParams;
use crate::util::rng::Rng;
use crate::util::stats::{Histogram, Summary};

/// Result of a Monte Carlo run for all four input cases.
#[derive(Debug, Clone)]
pub struct MonteCarloResult {
    pub samples_per_case: usize,
    /// Pre-sense BL summaries, indexed like `AndInputs::all_cases()`.
    pub case_summaries: Vec<(AndInputs, Summary)>,
    /// Pre-sense BL histograms per case.
    pub histograms: Vec<(AndInputs, Histogram)>,
    /// Sense margin: separation between the (1,1) distribution mean and the
    /// closest 0-case mean (the paper reports ≈ 200 mV mean margin).
    pub sense_margin_v: f64,
    /// Worst-case margin: min over samples of distance to VDD/2, signed
    /// positive when on the correct side.
    pub worst_margin_v: f64,
    /// Samples whose sensed value (incl. SA offset) was wrong.
    pub failures: u64,
}

impl MonteCarloResult {
    pub fn failure_rate(&self) -> f64 {
        self.failures as f64 / (self.samples_per_case * 4) as f64
    }
}

/// Run the Monte Carlo analysis. Uses the analytic pre-sense fast path
/// (validated against the transient integrator in `transient::tests`), so
/// 400 000 total samples complete in well under a second.
pub fn run_monte_carlo(
    p: &CircuitParams,
    samples_per_case: usize,
    seed: u64,
) -> MonteCarloResult {
    let half = p.vdd / 2.0;
    let mut case_summaries = Vec::new();
    let mut histograms = Vec::new();
    let mut failures = 0u64;
    let mut worst_margin = f64::INFINITY;

    for (case_idx, inputs) in AndInputs::all_cases().into_iter().enumerate() {
        let mut rng = Rng::new(seed ^ (case_idx as u64).wrapping_mul(0x9E37));
        let mut summary = Summary::new();
        let mut hist = Histogram::new(half - 0.25, half + 0.25, 60);
        for _ in 0..samples_per_case {
            let s = VariationSample::sampled(p, inputs, &mut rng);
            let v = s.presense_bl(p, inputs);
            summary.push(v);
            hist.add(v);
            let sensed = v + s.sa_offset > half;
            if sensed != inputs.expected() {
                failures += 1;
            }
            let margin = if inputs.expected() { v - half } else { half - v };
            worst_margin = worst_margin.min(margin);
        }
        case_summaries.push((inputs, summary));
        histograms.push((inputs, hist));
    }

    // Mean separation: (1,1) vs closest 0-case.
    let mean_11 = case_summaries
        .iter()
        .find(|(i, _)| i.expected())
        .map(|(_, s)| s.mean())
        .unwrap();
    let closest_zero = case_summaries
        .iter()
        .filter(|(i, _)| !i.expected())
        .map(|(_, s)| s.mean())
        .fold(f64::NEG_INFINITY, f64::max);
    MonteCarloResult {
        samples_per_case,
        sense_margin_v: mean_11 - closest_zero,
        worst_margin_v: worst_margin,
        failures,
        case_summaries,
        histograms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_mc() -> MonteCarloResult {
        run_monte_carlo(&CircuitParams::cmos65nm(), 5_000, 42)
    }

    #[test]
    fn sense_margin_near_200mv() {
        // The paper's headline Fig 15 number: mean sense margin ≈ 200 mV.
        let r = quick_mc();
        assert!(
            (r.sense_margin_v - 0.2).abs() < 0.02,
            "margin {}",
            r.sense_margin_v
        );
    }

    #[test]
    fn no_failures_at_nominal_variation() {
        let r = quick_mc();
        assert_eq!(r.failures, 0, "failure rate {}", r.failure_rate());
        assert!(r.worst_margin_v > 0.0);
    }

    #[test]
    fn one_one_distribution_above_half() {
        let p = CircuitParams::cmos65nm();
        let r = quick_mc();
        for (inputs, s) in &r.case_summaries {
            if inputs.expected() {
                assert!(s.mean() > p.vdd / 2.0 + 0.05);
            } else {
                assert!(s.mean() < p.vdd / 2.0 - 0.05);
            }
            assert_eq!(s.len(), 5_000);
        }
    }

    #[test]
    fn histograms_capture_all_samples() {
        let r = quick_mc();
        for (_, h) in &r.histograms {
            assert_eq!(h.total(), 5_000);
            // All samples should be within the plotting window.
            assert_eq!(h.underflow + h.overflow, 0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_monte_carlo(&CircuitParams::cmos65nm(), 1_000, 7);
        let b = run_monte_carlo(&CircuitParams::cmos65nm(), 1_000, 7);
        assert_eq!(a.sense_margin_v, b.sense_margin_v);
        assert_eq!(a.failures, b.failures);
    }

    #[test]
    fn excessive_variation_causes_failures() {
        // Failure-injection: crank σ(V_cell) until the margin collapses.
        let mut p = CircuitParams::cmos65nm();
        p.sigma_v_cell = 0.5;
        p.sigma_sa_offset = 0.15;
        let r = run_monte_carlo(&p, 5_000, 3);
        assert!(r.failures > 0, "expected failures under extreme variation");
    }
}
