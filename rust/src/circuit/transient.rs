//! Transient simulation of the AND operation (Fig 14 reproduction).
//!
//! Nodes: `BL` (bitline), `S1` (top plate of cell A), `S2` (top plate of
//! cell A-1). Four phases — precharge, charge-share, sense, restore — per
//! the §III-A sequence. For the (1,1) input case BL/S1/S2 regenerate to
//! VDD; every other case collapses to GND, exactly the waveform families
//! the paper shows.

use super::waveform::Waveform;
use super::CircuitParams;

/// Input case for the AND: logical values stored in compute rows A and A-1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AndInputs {
    pub a: bool,
    pub b: bool,
}

impl AndInputs {
    pub fn all_cases() -> [AndInputs; 4] {
        [
            AndInputs { a: false, b: false },
            AndInputs { a: false, b: true },
            AndInputs { a: true, b: false },
            AndInputs { a: true, b: true },
        ]
    }

    pub fn expected(&self) -> bool {
        self.a && self.b
    }

    pub fn label(&self) -> String {
        format!("{},{}", self.a as u8, self.b as u8)
    }
}

/// Simulation phase boundaries (returned for annotation/plotting).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    pub share_start_ns: f64,
    pub sense_start_ns: f64,
    pub restore_start_ns: f64,
    pub end_ns: f64,
}

/// Simulate the full AND transient for one input case. Optional `vary`
/// callback perturbs (c_cell, c_bl, v_cell_a, v_cell_b, sa_offset) for
/// Monte Carlo reuse; `None` runs nominal.
pub fn simulate_and(
    p: &CircuitParams,
    inputs: AndInputs,
    vary: Option<&VariationSample>,
) -> (Waveform, Phase) {
    let nominal = VariationSample::nominal(p, inputs);
    let var = vary.unwrap_or(&nominal);

    let half = p.vdd / 2.0;
    let mut v_bl = 0.0; // bitline starts discharged pre-precharge
    let mut s1 = var.v_cell_a; // plate of cell A (stores operand a)
    let mut s2 = var.v_cell_b; // plate of cell A-1 (stores operand b)

    let phase = Phase {
        share_start_ns: p.t_precharge_ns,
        sense_start_ns: p.t_precharge_ns + p.t_share_ns,
        restore_start_ns: p.t_precharge_ns + p.t_share_ns + p.t_sense_ns,
        end_ns: p.t_precharge_ns + p.t_share_ns + p.t_sense_ns + p.t_restore_ns,
    };

    let mut wf = Waveform::new(&["BL", "S1", "S2"]);
    let tau_pre = 0.2; // precharge driver is strong
    let tau_share = p.tau_share_ns().max(p.dt_ns);
    let ratio = var.c_cell / (var.c_cell + var.c_bl);

    // Which cell the AND-WL connects (see module docs): A=1 → cell A-1
    // (NMOS), A=0 → cell A (PMOS).
    let connects_s2 = inputs.a;

    let mut t = 0.0;
    let mut sensed_decided: Option<bool> = None;
    while t <= phase.end_ns + 1e-9 {
        wf.push(t, &[v_bl, s1, s2]);
        let dt = p.dt_ns;
        if t < phase.share_start_ns {
            // Precharge: BL → VDD/2 (cells isolated).
            v_bl += (half - v_bl) * (dt / tau_pre).min(1.0);
        } else if t < phase.sense_start_ns {
            // Charge share: connected cell and BL relax toward the common
            // charge-conservation voltage.
            let vc: &mut f64 = if connects_s2 { &mut s2 } else { &mut s1 };
            let v_final = v_bl * (1.0 - ratio) + *vc * ratio;
            let k = (dt / tau_share).min(1.0);
            v_bl += (v_final - v_bl) * k;
            *vc += (v_final - *vc) * k;
        } else if t < phase.restore_start_ns {
            // Sense: decide once at enable (offset applied), then regenerate.
            let target = *sensed_decided.get_or_insert_with(|| {
                v_bl + var.sa_offset > half
            });
            let rail = if target { p.vdd } else { 0.0 };
            let k = (dt / p.tau_sense_ns).min(1.0);
            v_bl += (rail - v_bl) * k;
            // Connected cell keeps tracking the bitline during regeneration.
            if connects_s2 {
                s2 += (rail - s2) * k;
            } else {
                s1 += (rail - s1) * k;
            }
        } else {
            // Restore: both compute-row wordlines open; both cells are
            // driven to the sensed rail (they store the AND result).
            let rail = if sensed_decided.unwrap_or(false) { p.vdd } else { 0.0 };
            let k = (dt / p.tau_sense_ns).min(1.0);
            v_bl += (rail - v_bl) * k;
            s1 += (rail - s1) * k;
            s2 += (rail - s2) * k;
        }
        t += dt;
    }
    (wf, phase)
}

/// One Monte Carlo variation sample (also used for the nominal run).
#[derive(Debug, Clone)]
pub struct VariationSample {
    pub c_cell: f64,
    pub c_bl: f64,
    pub v_cell_a: f64,
    pub v_cell_b: f64,
    pub sa_offset: f64,
}

impl VariationSample {
    pub fn nominal(p: &CircuitParams, inputs: AndInputs) -> Self {
        VariationSample {
            c_cell: p.c_cell_ff,
            c_bl: p.c_bl_ff,
            v_cell_a: if inputs.a { p.vdd } else { 0.0 },
            v_cell_b: if inputs.b { p.vdd } else { 0.0 },
            sa_offset: 0.0,
        }
    }

    pub fn sampled(
        p: &CircuitParams,
        inputs: AndInputs,
        rng: &mut crate::util::rng::Rng,
    ) -> Self {
        let clamp01 = |v: f64| v.clamp(0.0, p.vdd);
        VariationSample {
            c_cell: p.c_cell_ff * (1.0 + p.sigma_c_cell * rng.normal()),
            c_bl: p.c_bl_ff * (1.0 + p.sigma_c_bl * rng.normal()),
            v_cell_a: clamp01(
                if inputs.a { p.vdd } else { 0.0 } + p.sigma_v_cell * rng.normal(),
            ),
            v_cell_b: clamp01(
                if inputs.b { p.vdd } else { 0.0 } + p.sigma_v_cell * rng.normal(),
            ),
            sa_offset: p.sigma_sa_offset * rng.normal(),
        }
    }

    /// Analytic pre-sense bitline voltage for this sample (fast path for
    /// Monte Carlo — avoids full transient integration).
    pub fn presense_bl(&self, p: &CircuitParams, inputs: AndInputs) -> f64 {
        let half = p.vdd / 2.0;
        let ratio = self.c_cell / (self.c_cell + self.c_bl);
        let v_cell = if inputs.a { self.v_cell_b } else { self.v_cell_a };
        half + (v_cell - half) * ratio
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn and_truth_table_from_transients() {
        let p = CircuitParams::cmos65nm();
        for inputs in AndInputs::all_cases() {
            let (wf, _) = simulate_and(&p, inputs, None);
            let v_final = wf.final_value("BL").unwrap();
            let sensed = v_final > p.vdd / 2.0;
            assert_eq!(sensed, inputs.expected(), "case {}", inputs.label());
            // Rail-to-rail regeneration.
            if sensed {
                assert!(v_final > 0.95 * p.vdd, "case {}: {v_final}", inputs.label());
            } else {
                assert!(v_final < 0.05 * p.vdd, "case {}: {v_final}", inputs.label());
            }
        }
    }

    #[test]
    fn cells_store_result_after_restore() {
        // §III-A: after the AND, both compute rows hold the result.
        let p = CircuitParams::cmos65nm();
        for inputs in AndInputs::all_cases() {
            let (wf, _) = simulate_and(&p, inputs, None);
            let rail = if inputs.expected() { p.vdd } else { 0.0 };
            assert!((wf.final_value("S1").unwrap() - rail).abs() < 0.05 * p.vdd);
            assert!((wf.final_value("S2").unwrap() - rail).abs() < 0.05 * p.vdd);
        }
    }

    #[test]
    fn presense_voltage_direction() {
        let p = CircuitParams::cmos65nm();
        for inputs in AndInputs::all_cases() {
            let s = VariationSample::nominal(&p, inputs);
            let v = s.presense_bl(&p, inputs);
            if inputs.expected() {
                assert!(v > p.vdd / 2.0);
            } else {
                assert!(v < p.vdd / 2.0 + 1e-12);
            }
        }
    }

    #[test]
    fn presense_matches_transient_share_value() {
        // The analytic MC fast path must agree with the integrated transient
        // at the sense instant (within integration tolerance).
        let p = CircuitParams::cmos65nm();
        for inputs in AndInputs::all_cases() {
            let (wf, phase) = simulate_and(&p, inputs, None);
            let idx = wf
                .t_ns
                .iter()
                .position(|&t| t >= phase.sense_start_ns - p.dt_ns / 2.0)
                .unwrap();
            let v_transient = wf.node("BL").unwrap()[idx - 1];
            let s = VariationSample::nominal(&p, inputs);
            let v_analytic = s.presense_bl(&p, inputs);
            assert!(
                (v_transient - v_analytic).abs() < 0.01,
                "case {}: transient {v_transient} vs analytic {v_analytic}",
                inputs.label()
            );
        }
    }

    #[test]
    fn phases_ordered() {
        let p = CircuitParams::cmos65nm();
        let (_, ph) = simulate_and(&p, AndInputs { a: true, b: true }, None);
        assert!(ph.share_start_ns < ph.sense_start_ns);
        assert!(ph.sense_start_ns < ph.restore_start_ns);
        assert!(ph.restore_start_ns < ph.end_ns);
    }
}
