//! Circuit-level substrate (DESIGN.md S6): first-order transient and Monte
//! Carlo simulation of the proposed AND primitive's bitline behaviour.
//!
//! The paper validates the 3-transistor AND with HSPICE at 65 nm (Fig 14)
//! plus 100 000-sample Monte Carlo (Fig 15, sense margin ≈ 200 mV mean).
//! HSPICE and the Rambus netlists are not available here, so this module
//! implements the minimal physics that produces those observables:
//!
//!   * precharge:     BL driven to VDD/2;
//!   * charge share:  the AND-WL connects exactly one cell capacitor to the
//!     bitline (cell A-1 through the NMOS when A = 1, cell A through the
//!     PMOS when A = 0); RC relaxation toward the charge-conservation value;
//!   * sense:         latch-type amplifier regenerates exponentially toward
//!     the rail selected by comparison with VDD/2;
//!   * restore:       both compute-row cells track the regenerated bitline
//!     (they store the AND result — §III-A).
//!
//! All voltages in volts, times in nanoseconds, capacitances in femtofarads.

pub mod montecarlo;
pub mod transient;
pub mod waveform;

pub use montecarlo::{run_monte_carlo, MonteCarloResult};
pub use transient::{simulate_and, AndInputs, Phase};
pub use waveform::Waveform;

/// Electrical parameters of the subarray bitline structure.
#[derive(Debug, Clone, PartialEq)]
pub struct CircuitParams {
    /// Supply voltage (V). DRAM core at 65 nm.
    pub vdd: f64,
    /// Cell storage capacitance (fF).
    pub c_cell_ff: f64,
    /// Bitline parasitic capacitance (fF).
    pub c_bl_ff: f64,
    /// Access-path resistance during charge sharing (kΩ).
    pub r_access_kohm: f64,
    /// Sense-amp regeneration time constant (ns).
    pub tau_sense_ns: f64,
    /// Simulation timestep (ns).
    pub dt_ns: f64,
    /// Phase durations (ns).
    pub t_precharge_ns: f64,
    pub t_share_ns: f64,
    pub t_sense_ns: f64,
    pub t_restore_ns: f64,
    // Monte Carlo variation (1σ, relative unless noted):
    /// Cell capacitance variation.
    pub sigma_c_cell: f64,
    /// Bitline capacitance variation.
    pub sigma_c_bl: f64,
    /// Stored cell voltage offset σ in volts (leakage/retention noise).
    pub sigma_v_cell: f64,
    /// Sense-amp input-referred offset σ in volts.
    pub sigma_sa_offset: f64,
}

impl CircuitParams {
    /// 65 nm-class defaults calibrated so the nominal pre-sense separation
    /// between the (1,1) case and the 0-cases is ≈ 200 mV (paper Fig 15:
    /// "large enough sense margin of BL between all input cases (mean is
    /// 200 mV)"): transfer ratio C_cell/(C_cell+C_BL) = 1/6, VDD = 1.2 V →
    /// full separation VDD/6 = 200 mV.
    pub fn cmos65nm() -> Self {
        CircuitParams {
            vdd: 1.2,
            c_cell_ff: 20.0,
            c_bl_ff: 100.0,
            r_access_kohm: 8.0,
            tau_sense_ns: 0.35,
            dt_ns: 0.01,
            t_precharge_ns: 2.0,
            t_share_ns: 3.0,
            t_sense_ns: 3.0,
            t_restore_ns: 4.0,
            sigma_c_cell: 0.05,
            sigma_c_bl: 0.03,
            sigma_v_cell: 0.02,
            sigma_sa_offset: 0.01,
        }
    }

    /// Charge-sharing transfer ratio C_cell / (C_cell + C_BL).
    pub fn transfer_ratio(&self) -> f64 {
        self.c_cell_ff / (self.c_cell_ff + self.c_bl_ff)
    }

    /// Nominal post-share bitline voltage when a cell storing `v_cell`
    /// shares with the precharged bitline.
    pub fn shared_voltage(&self, v_cell: f64) -> f64 {
        let half = self.vdd / 2.0;
        half + (v_cell - half) * self.transfer_ratio()
    }

    /// RC time constant of the share phase (ns): R_on · (C_cell ∥ C_BL).
    pub fn tau_share_ns(&self) -> f64 {
        let c_series =
            self.c_cell_ff * self.c_bl_ff / (self.c_cell_ff + self.c_bl_ff);
        // kΩ · fF = ps; convert to ns.
        self.r_access_kohm * c_series / 1000.0
    }
}

impl Default for CircuitParams {
    fn default() -> Self {
        Self::cmos65nm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_ratio_one_sixth() {
        let p = CircuitParams::cmos65nm();
        assert!((p.transfer_ratio() - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn shared_voltage_signs() {
        let p = CircuitParams::cmos65nm();
        assert!(p.shared_voltage(p.vdd) > p.vdd / 2.0);
        assert!(p.shared_voltage(0.0) < p.vdd / 2.0);
        // Nominal separation: exactly VDD * ratio = 200 mV.
        let sep = p.shared_voltage(p.vdd) - p.shared_voltage(0.0);
        assert!((sep - 0.2).abs() < 1e-12);
    }

    #[test]
    fn tau_share_fast_relative_to_phase() {
        let p = CircuitParams::cmos65nm();
        // Charge sharing must settle well within the share phase.
        assert!(p.tau_share_ns() * 5.0 < p.t_share_ns);
    }
}
