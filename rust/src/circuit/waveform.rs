//! Waveform container for transient results (Fig 14 reproduction): named
//! node traces over a shared time base, CSV export and simple ASCII plots.

/// A set of node voltage traces over time.
#[derive(Debug, Clone, Default)]
pub struct Waveform {
    pub t_ns: Vec<f64>,
    pub nodes: Vec<(String, Vec<f64>)>,
}

impl Waveform {
    pub fn new(node_names: &[&str]) -> Self {
        Waveform {
            t_ns: Vec::new(),
            nodes: node_names
                .iter()
                .map(|n| (n.to_string(), Vec::new()))
                .collect(),
        }
    }

    /// Append one sample: time plus a voltage per node (ordered).
    pub fn push(&mut self, t_ns: f64, voltages: &[f64]) {
        assert_eq!(voltages.len(), self.nodes.len(), "node count mismatch");
        self.t_ns.push(t_ns);
        for (slot, &v) in self.nodes.iter_mut().zip(voltages) {
            slot.1.push(v);
        }
    }

    pub fn len(&self) -> usize {
        self.t_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t_ns.is_empty()
    }

    pub fn node(&self, name: &str) -> Option<&[f64]> {
        self.nodes
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
    }

    /// Final value of a node.
    pub fn final_value(&self, name: &str) -> Option<f64> {
        self.node(name).and_then(|v| v.last().copied())
    }

    /// CSV export: `t_ns,node1,node2,...`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_ns");
        for (name, _) in &self.nodes {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for i in 0..self.t_ns.len() {
            out.push_str(&format!("{:.4}", self.t_ns[i]));
            for (_, vs) in &self.nodes {
                out.push_str(&format!(",{:.5}", vs[i]));
            }
            out.push('\n');
        }
        out
    }

    /// Coarse ASCII strip chart of one node (for bench output).
    pub fn ascii(&self, name: &str, rows: usize, cols: usize) -> String {
        let Some(vs) = self.node(name) else {
            return format!("(no node {name})");
        };
        if vs.is_empty() {
            return String::new();
        }
        let vmin = vs.iter().copied().fold(f64::INFINITY, f64::min);
        let vmax = vs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let span = (vmax - vmin).max(1e-9);
        let mut grid = vec![vec![b' '; cols]; rows];
        for (i, &v) in vs.iter().enumerate() {
            let x = i * (cols - 1) / (vs.len() - 1).max(1);
            let y = ((vmax - v) / span * (rows - 1) as f64).round() as usize;
            grid[y.min(rows - 1)][x] = b'*';
        }
        let mut out = format!("{name}: [{vmin:.3} V .. {vmax:.3} V]\n");
        for row in grid {
            out.push_str(std::str::from_utf8(&row).unwrap());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_lookup() {
        let mut w = Waveform::new(&["BL", "S1"]);
        w.push(0.0, &[0.6, 1.2]);
        w.push(0.1, &[0.65, 1.2]);
        assert_eq!(w.len(), 2);
        assert_eq!(w.node("BL").unwrap(), &[0.6, 0.65]);
        assert_eq!(w.final_value("S1"), Some(1.2));
        assert!(w.node("nope").is_none());
    }

    #[test]
    #[should_panic(expected = "node count mismatch")]
    fn push_checks_arity() {
        let mut w = Waveform::new(&["BL"]);
        w.push(0.0, &[0.1, 0.2]);
    }

    #[test]
    fn csv_format() {
        let mut w = Waveform::new(&["a"]);
        w.push(1.0, &[0.5]);
        let csv = w.to_csv();
        assert!(csv.starts_with("t_ns,a\n"));
        assert!(csv.contains("1.0000,0.50000"));
    }

    #[test]
    fn ascii_plot_has_stars() {
        let mut w = Waveform::new(&["x"]);
        for i in 0..50 {
            w.push(i as f64, &[(i as f64 / 50.0).sin()]);
        }
        let plot = w.ascii("x", 8, 40);
        assert!(plot.contains('*'));
    }
}
