//! DRAM refresh overhead model — a real-DRAM constraint the paper never
//! mentions, needed for an honest system claim: PIM compute streams AAPs
//! back-to-back, but every tREFI the bank must still refresh, stealing
//! tRFC. Long multiplies are therefore stretched by the refresh duty
//! factor, and data held in compute rows survives because every AAP is a
//! full restore.

/// Refresh parameters (DDR3-1600, 2 Gb-class die).
#[derive(Debug, Clone, PartialEq)]
pub struct RefreshParams {
    /// Average refresh interval (ns). DDR3: 7.8 µs.
    pub trefi_ns: f64,
    /// Refresh cycle time (ns). DDR3 2 Gb: 160 ns.
    pub trfc_ns: f64,
}

impl RefreshParams {
    pub fn ddr3_1600() -> Self {
        RefreshParams { trefi_ns: 7_800.0, trfc_ns: 160.0 }
    }

    /// Fraction of time stolen by refresh.
    pub fn duty(&self) -> f64 {
        self.trfc_ns / self.trefi_ns
    }

    /// Stretch a busy interval by the refresh duty: the controller must
    /// interleave `ceil(busy/tREFI)` refreshes into it.
    pub fn stretch_ns(&self, busy_ns: f64) -> f64 {
        if busy_ns <= 0.0 {
            return 0.0;
        }
        let refreshes = (busy_ns / self.trefi_ns).ceil();
        busy_ns + refreshes * self.trfc_ns
    }

    /// Refresh-aware effective AAP rate multiplier (≥ 1).
    pub fn slowdown(&self) -> f64 {
        1.0 + self.duty()
    }
}

impl Default for RefreshParams {
    fn default() -> Self {
        Self::ddr3_1600()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;

    #[test]
    fn ddr3_duty_about_two_percent() {
        let r = RefreshParams::ddr3_1600();
        assert!((r.duty() - 0.0205).abs() < 0.001);
        assert!(r.slowdown() > 1.0 && r.slowdown() < 1.05);
    }

    #[test]
    fn stretch_adds_at_least_one_refresh() {
        let r = RefreshParams::ddr3_1600();
        // A short burst still crosses at most one refresh boundary.
        assert_eq!(r.stretch_ns(1000.0), 1000.0 + 160.0);
        // An 8-bit multiply (1592 AAPs ≈ 77.6 µs) spans ~10 tREFI.
        let mult = 1592.0 * 48.75;
        let stretched = r.stretch_ns(mult);
        assert!((stretched - mult - 10.0 * 160.0).abs() < 1e-9);
    }

    #[test]
    fn stretch_zero_is_zero() {
        assert_eq!(RefreshParams::ddr3_1600().stretch_ns(0.0), 0.0);
    }

    #[test]
    fn stretch_monotone_property() {
        crate::testutil::check(40, |rng| {
            let r = RefreshParams::ddr3_1600();
            let a = rng.range(0.0, 1e7);
            let b = rng.range(0.0, 1e7);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            prop_assert!(r.stretch_ns(lo) <= r.stretch_ns(hi) + 1e-9);
            prop_assert!(r.stretch_ns(hi) >= hi);
            Ok(())
        });
    }
}
