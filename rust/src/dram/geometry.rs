//! DRAM organization: channels → ranks → banks → subarrays → rows × cols
//! (paper Fig 2/3). The evaluation uses 4096×4096 subarrays (§V-B).

/// Device geometry. All counts are per the level above.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DramGeometry {
    pub channels: usize,
    pub ranks_per_channel: usize,
    pub banks_per_rank: usize,
    pub subarrays_per_bank: usize,
    /// Rows per subarray (wordlines).
    pub rows: usize,
    /// Columns per subarray (bitlines).
    pub cols: usize,
    /// Reserved compute rows per subarray (paper: 9 + intermediate rows).
    pub compute_rows: usize,
}

impl DramGeometry {
    /// The paper's evaluation configuration: DDR3 with 4096×4096 subarrays.
    /// Four ranks (32 banks) — the minimum that fits ResNet18's 18 layer
    /// banks + 8 residual reserve banks (§IV-B assumes one bank per layer;
    /// a 2-rank module's 16 banks cannot host it — DESIGN.md §7).
    pub fn paper_default() -> Self {
        DramGeometry {
            channels: 1,
            ranks_per_channel: 4,
            banks_per_rank: 8,
            subarrays_per_bank: 32,
            rows: 4096,
            cols: 4096,
            compute_rows: 9,
        }
    }

    /// The configuration the paper's simulator implicitly assumes: enough
    /// subarrays per bank that every layer's operand expansion is resident
    /// at P1 (see DESIGN.md §7 and `mapping` module docs). Unphysical for
    /// a DDR3 die — used to reproduce Fig 16's shape; compare with
    /// `paper_default` via the ablation_subarray bench.
    pub fn paper_ideal() -> Self {
        DramGeometry {
            subarrays_per_bank: 1 << 20,
            ..Self::paper_default()
        }
    }

    pub fn total_banks(&self) -> usize {
        self.channels * self.ranks_per_channel * self.banks_per_rank
    }

    pub fn total_subarrays(&self) -> usize {
        self.total_banks() * self.subarrays_per_bank
    }

    /// Data rows usable for operand storage in one subarray, once compute
    /// rows and the `n-1` intermediate rows for n-bit multiply are reserved.
    pub fn data_rows(&self, operand_bits: usize) -> usize {
        let reserved = self.compute_rows + operand_bits.saturating_sub(1);
        self.rows.saturating_sub(reserved)
    }

    /// Capacity of one subarray in bits (data rows only, n-bit operands).
    pub fn subarray_data_bits(&self, operand_bits: usize) -> usize {
        self.data_rows(operand_bits) * self.cols
    }

    /// How many operand *pairs* (activation, weight — 2n rows per pair,
    /// §IV-B) fit stacked in one column of a subarray.
    pub fn pairs_per_column(&self, operand_bits: usize) -> usize {
        self.data_rows(operand_bits) / (2 * operand_bits)
    }

    /// Total device capacity in bytes (raw, ignoring compute rows).
    pub fn capacity_bytes(&self) -> usize {
        self.total_subarrays() * self.rows * self.cols / 8
    }

    /// Area overhead fraction of the reserved compute rows + the 3 extra
    /// AND transistors ("three extra transistors is equivalent to three
    /// extra rows", §III-A) — the paper claims < 1 %.
    pub fn compute_area_overhead(&self) -> f64 {
        (self.compute_rows + 3) as f64 / self.rows as f64
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.channels > 0, "channels must be > 0");
        anyhow::ensure!(self.ranks_per_channel > 0, "ranks must be > 0");
        anyhow::ensure!(self.banks_per_rank > 0, "banks must be > 0");
        anyhow::ensure!(self.subarrays_per_bank > 0, "subarrays must be > 0");
        anyhow::ensure!(
            self.rows > self.compute_rows + 16,
            "rows ({}) must exceed compute rows + headroom",
            self.rows
        );
        anyhow::ensure!(self.cols >= 64, "cols ({}) too small", self.cols);
        Ok(())
    }
}

impl Default for DramGeometry {
    fn default() -> Self {
        Self::paper_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_valid() {
        let g = DramGeometry::paper_default();
        g.validate().unwrap();
        assert_eq!(g.total_banks(), 32);
        assert_eq!(g.total_subarrays(), 1024);
    }

    #[test]
    fn area_overhead_below_one_percent() {
        // The paper's headline claim: < 1 % overhead at 4096 rows.
        let g = DramGeometry::paper_default();
        assert!(g.compute_area_overhead() < 0.01);
    }

    #[test]
    fn pairs_per_column_8bit() {
        let g = DramGeometry::paper_default();
        // (4096 - 9 - 7) / 16 = 255 pairs per column at 8-bit.
        assert_eq!(g.pairs_per_column(8), 255);
    }

    #[test]
    fn data_rows_reserves_intermediates() {
        let g = DramGeometry::paper_default();
        assert_eq!(g.data_rows(8), 4096 - 9 - 7);
        assert_eq!(g.data_rows(2), 4096 - 9 - 1);
    }

    #[test]
    fn capacity() {
        let g = DramGeometry::paper_default();
        // 1024 subarrays × 16 Mib = 2 GiB.
        assert_eq!(g.capacity_bytes(), 1 << 31);
    }

    #[test]
    fn invalid_geometries_rejected() {
        let mut g = DramGeometry::paper_default();
        g.rows = 8;
        assert!(g.validate().is_err());
        let mut g2 = DramGeometry::paper_default();
        g2.cols = 8;
        assert!(g2.validate().is_err());
        let mut g3 = DramGeometry::paper_default();
        g3.channels = 0;
        assert!(g3.validate().is_err());
    }
}
