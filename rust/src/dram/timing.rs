//! DDR3-1600 timing and energy parameters (§V-B evaluates DDR3-1600).
//!
//! The AAP (ACTIVATE-ACTIVATE-PRECHARGE) compound command is the unit the
//! in-DRAM primitives are priced in, following Ambit/RowClone: an AAP keeps
//! the row cycle going for `tRAS + tRP`. Energy constants are adapted from
//! the Rambus DRAM power model the paper cites ([16]) — order-of-magnitude
//! calibrated, and only *relative* energies matter for the experiments.

/// DRAM timing parameters in nanoseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct DramTiming {
    /// Clock period (DDR3-1600: 1.25 ns, 800 MHz I/O clock).
    pub tck_ns: f64,
    /// ACTIVATE to internal read/write delay.
    pub trcd_ns: f64,
    /// ACTIVATE to PRECHARGE minimum.
    pub tras_ns: f64,
    /// PRECHARGE period.
    pub trp_ns: f64,
    /// Column access strobe latency.
    pub tcas_ns: f64,
    /// Internal bus width in bits for inter-bank RowClone (global I/O).
    pub internal_bus_bits: usize,
    /// External channel interface width in bits — the path an activation
    /// takes when a layer-split plan hands it to a device on another
    /// channel. Stays at the DDR pin width even when a paper-favorable
    /// stance widens the *internal* links, so cross-channel hops are
    /// always priced dearer than in-module RowClones.
    pub channel_bus_bits: usize,
    /// Energy per ACTIVATE+PRECHARGE of one row (nJ).
    pub act_pre_energy_nj: f64,
    /// Extra energy per additional simultaneously-activated row (nJ).
    pub multi_act_energy_nj: f64,
    /// Energy per bit moved over the internal bus (pJ/bit).
    pub bus_energy_pj_per_bit: f64,
}

impl DramTiming {
    /// DDR3-1600 (11-11-11) — the paper's evaluation configuration.
    pub fn ddr3_1600() -> Self {
        DramTiming {
            tck_ns: 1.25,
            trcd_ns: 13.75,
            tras_ns: 35.0,
            trp_ns: 13.75,
            tcas_ns: 13.75,
            internal_bus_bits: 64,
            channel_bus_bits: 64,
            act_pre_energy_nj: 2.5,
            multi_act_energy_nj: 0.9,
            bus_energy_pj_per_bit: 4.0,
        }
    }

    /// DDR4-2400-ish variant for ablations.
    pub fn ddr4_2400() -> Self {
        DramTiming {
            tck_ns: 0.833,
            trcd_ns: 12.5,
            tras_ns: 32.0,
            trp_ns: 12.5,
            tcas_ns: 12.5,
            internal_bus_bits: 64,
            channel_bus_bits: 64,
            act_pre_energy_nj: 2.1,
            multi_act_energy_nj: 0.8,
            bus_energy_pj_per_bit: 3.2,
        }
    }

    /// Latency of one AAP (ACTIVATE–ACTIVATE–PRECHARGE) compound op.
    ///
    /// Following Ambit, back-to-back activates overlap with the row cycle;
    /// an AAP costs one full row cycle `tRAS + tRP`.
    pub fn aap_ns(&self) -> f64 {
        self.tras_ns + self.trp_ns
    }

    /// Latency of a plain ACTIVATE + PRECHARGE (row cycle, tRC).
    pub fn trc_ns(&self) -> f64 {
        self.tras_ns + self.trp_ns
    }

    /// Latency to RowClone one row of `row_bits` across banks: source row
    /// cycle + destination row cycle + serialized bus transfer.
    pub fn interbank_copy_ns(&self, row_bits: usize) -> f64 {
        let beats = crate::util::ceil_div(row_bits, self.internal_bus_bits);
        2.0 * self.trc_ns() + beats as f64 * self.tck_ns
    }

    /// Latency to move one row of `row_bits` to a device on another
    /// channel: read row cycle on the source + write row cycle on the
    /// destination + a column access on each side + serialized beats over
    /// the external channel interface. Strictly dearer than
    /// [`Self::interbank_copy_ns`] for the same row (the two extra tCAS,
    /// and a bus never wider than the internal one).
    pub fn interchannel_copy_ns(&self, row_bits: usize) -> f64 {
        let beats = crate::util::ceil_div(row_bits, self.channel_bus_bits);
        2.0 * self.trc_ns() + 2.0 * self.tcas_ns + beats as f64 * self.tck_ns
    }

    /// Energy of a multi-row activation with `rows` simultaneous rows (nJ).
    pub fn multi_act_energy(&self, rows: usize) -> f64 {
        self.act_pre_energy_nj
            + self.multi_act_energy_nj * rows.saturating_sub(1) as f64
    }
}

impl Default for DramTiming {
    fn default() -> Self {
        Self::ddr3_1600()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddr3_aap_is_row_cycle() {
        let t = DramTiming::ddr3_1600();
        assert!((t.aap_ns() - 48.75).abs() < 1e-9);
    }

    #[test]
    fn interbank_copy_scales_with_row_width() {
        let t = DramTiming::ddr3_1600();
        let narrow = t.interbank_copy_ns(64);
        let wide = t.interbank_copy_ns(8192);
        assert!(wide > narrow);
        // 8192/64 = 128 beats at 1.25ns = 160ns on top of 2*48.75.
        assert!((wide - (97.5 + 160.0)).abs() < 1e-9);
    }

    #[test]
    fn interchannel_hop_dearer_than_interbank() {
        let mut t = DramTiming::ddr3_1600();
        assert!(t.interchannel_copy_ns(4096) > t.interbank_copy_ns(4096));
        // Even with paper-favorable row-wide internal links the external
        // channel interface stays at pin width.
        t.internal_bus_bits = 4096;
        assert!(t.interchannel_copy_ns(4096) > t.interbank_copy_ns(4096));
    }

    #[test]
    fn ddr4_is_faster() {
        assert!(DramTiming::ddr4_2400().aap_ns() < DramTiming::ddr3_1600().aap_ns());
    }

    #[test]
    fn multi_act_energy_grows() {
        let t = DramTiming::ddr3_1600();
        assert!(t.multi_act_energy(5) > t.multi_act_energy(3));
        assert!((t.multi_act_energy(1) - t.act_pre_energy_nj).abs() < 1e-12);
    }
}
