//! DRAM device substrate (DESIGN.md S1): geometry, DDR3-1600 timing,
//! command accounting, and a bit-exact functional subarray model with
//! multi-row-activation (charge-sharing majority) semantics.
//!
//! Everything the paper's in-house simulator assumed about the memory is
//! explicit here: the in-DRAM compute primitives (`crate::primitives`)
//! drive a [`Subarray`] and log commands into [`CommandStats`]; the timing
//! model prices those commands in nanoseconds; the architecture simulator
//! (`crate::sim`) composes banks into the full device.

pub mod command;
pub mod geometry;
pub mod refresh;
pub mod subarray;
pub mod timing;

pub use command::{Command, CommandStats};
pub use geometry::DramGeometry;
pub use refresh::RefreshParams;
pub use subarray::{BitRow, Subarray};
pub use timing::DramTiming;
