//! Bit-exact functional model of a DRAM subarray with PIM extensions:
//! multi-row activation (charge-sharing majority), dual-contact-cell
//! complements, RowClone copies, and the 3-transistor AND wordline (§III-A).
//!
//! Rows are packed `u64` words so every operation is column-parallel, like
//! the real array: one `maj5` call computes 4096 majority functions.

/// A packed row of bits (one wordline's cells across all bitlines).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitRow {
    words: Vec<u64>,
    cols: usize,
}

impl BitRow {
    pub fn zeros(cols: usize) -> Self {
        BitRow { words: vec![0; cols.div_ceil(64)], cols }
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, col: usize) -> bool {
        debug_assert!(col < self.cols);
        (self.words[col / 64] >> (col % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, col: usize, v: bool) {
        debug_assert!(col < self.cols);
        let mask = 1u64 << (col % 64);
        if v {
            self.words[col / 64] |= mask;
        } else {
            self.words[col / 64] &= !mask;
        }
    }

    /// Build from a predicate over column indices.
    pub fn from_fn(cols: usize, f: impl Fn(usize) -> bool) -> Self {
        let mut row = BitRow::zeros(cols);
        for c in 0..cols {
            if f(c) {
                row.set(c, true);
            }
        }
        row
    }

    /// Mask of valid bits in the last word.
    fn tail_mask(&self) -> u64 {
        let rem = self.cols % 64;
        if rem == 0 {
            u64::MAX
        } else {
            (1u64 << rem) - 1
        }
    }

    /// Bitwise complement (dual-contact-cell read).
    pub fn not(&self) -> BitRow {
        let mut out = self.clone();
        for w in &mut out.words {
            *w = !*w;
        }
        if let Some(last) = out.words.last_mut() {
            *last &= self.tail_mask();
        }
        out
    }

    pub fn and(&self, other: &BitRow) -> BitRow {
        self.zip(other, |a, b| a & b)
    }

    pub fn or(&self, other: &BitRow) -> BitRow {
        self.zip(other, |a, b| a | b)
    }

    pub fn xor(&self, other: &BitRow) -> BitRow {
        self.zip(other, |a, b| a ^ b)
    }

    fn zip(&self, other: &BitRow, f: impl Fn(u64, u64) -> u64) -> BitRow {
        assert_eq!(self.cols, other.cols, "column count mismatch");
        BitRow {
            words: self
                .words
                .iter()
                .zip(&other.words)
                .map(|(&a, &b)| f(a, b))
                .collect(),
            cols: self.cols,
        }
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fast zero test (hot path: ripple-carry early exit).
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// `self ^= other`, allocation-free (hot path).
    #[inline]
    pub fn xor_assign(&mut self, other: &BitRow) {
        debug_assert_eq!(self.cols, other.cols);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a ^= b;
        }
    }

    /// `out = self & other`, reusing `out`'s buffer (hot path).
    #[inline]
    pub fn and_into(&self, other: &BitRow, out: &mut BitRow) {
        debug_assert_eq!(self.cols, other.cols);
        debug_assert_eq!(self.cols, out.cols);
        for ((o, a), b) in out.words.iter_mut().zip(&self.words).zip(&other.words)
        {
            *o = a & b;
        }
    }

    /// Column-parallel 3-input majority (triple-row activation result).
    pub fn maj3(a: &BitRow, b: &BitRow, c: &BitRow) -> BitRow {
        assert!(a.cols == b.cols && b.cols == c.cols);
        BitRow {
            words: (0..a.words.len())
                .map(|i| {
                    let (x, y, z) = (a.words[i], b.words[i], c.words[i]);
                    (x & y) | (y & z) | (x & z)
                })
                .collect(),
            cols: a.cols,
        }
    }

    /// Column-parallel 5-input majority (quintuple-row activation, Fig 4).
    pub fn maj5(rows: [&BitRow; 5]) -> BitRow {
        let cols = rows[0].cols;
        assert!(rows.iter().all(|r| r.cols == cols));
        let n_words = rows[0].words.len();
        let mut words = vec![0u64; n_words];
        for (i, word) in words.iter_mut().enumerate() {
            let v: [u64; 5] = [
                rows[0].words[i],
                rows[1].words[i],
                rows[2].words[i],
                rows[3].words[i],
                rows[4].words[i],
            ];
            // Bit-parallel counting via carry-save: count = sum of 5 bits,
            // majority when count >= 3.
            let (s01, c01) = (v[0] ^ v[1], v[0] & v[1]);
            let (s23, c23) = (v[2] ^ v[3], v[2] & v[3]);
            let s = s01 ^ s23 ^ v[4]; // bit 0 of count
            let carry1 = (s01 & s23) | ((s01 ^ s23) & v[4]); // carries into bit1
            // bit1 = c01 ^ c23 ^ carry1; bit2 = majority of those carries
            let b1 = c01 ^ c23 ^ carry1;
            let b2 = (c01 & c23) | ((c01 ^ c23) & carry1);
            // count >= 3  <=>  bit2 | (bit1 & bit0)
            *word = b2 | (b1 & s);
        }
        let mut out = BitRow { words, cols };
        if let Some(last) = out.words.last_mut() {
            let rem = cols % 64;
            if rem != 0 {
                *last &= (1u64 << rem) - 1;
            }
        }
        out
    }
}

/// Source term for a multi-row activation: a row index, optionally read
/// through the dual-contact cell's complementary wordline.
#[derive(Debug, Clone, Copy)]
pub struct ActRow {
    pub row: usize,
    pub complement: bool,
}

impl ActRow {
    pub fn plain(row: usize) -> Self {
        ActRow { row, complement: false }
    }
    pub fn neg(row: usize) -> Self {
        ActRow { row, complement: true }
    }
}

/// Functional subarray: `rows` wordlines × `cols` bitlines.
#[derive(Debug, Clone)]
pub struct Subarray {
    rows: Vec<BitRow>,
    cols: usize,
}

impl Subarray {
    pub fn new(rows: usize, cols: usize) -> Self {
        Subarray { rows: vec![BitRow::zeros(cols); rows], cols }
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row(&self, r: usize) -> &BitRow {
        &self.rows[r]
    }

    pub fn write_row(&mut self, r: usize, data: &BitRow) {
        assert_eq!(data.cols(), self.cols);
        self.rows[r] = data.clone();
    }

    pub fn set_bit(&mut self, r: usize, c: usize, v: bool) {
        self.rows[r].set(c, v);
    }

    pub fn get_bit(&self, r: usize, c: usize) -> bool {
        self.rows[r].get(c)
    }

    /// RowClone intra-subarray copy (functional part; cost logged by caller).
    pub fn copy_row(&mut self, src: usize, dst: usize) {
        let data = self.rows[src].clone();
        self.rows[dst] = data;
    }

    /// Multi-row activation: charge-share the listed rows (with optional
    /// DCC complement), sense the majority, and drive the result back into
    /// every activated cell (complemented cells store the complement).
    /// Returns the sensed value. Panics unless 3 or 5 rows are activated.
    pub fn multi_activate(&mut self, sources: &[ActRow]) -> BitRow {
        let read = |s: &ActRow| -> BitRow {
            if s.complement {
                self.rows[s.row].not()
            } else {
                self.rows[s.row].clone()
            }
        };
        let sensed = match sources.len() {
            3 => BitRow::maj3(&read(&sources[0]), &read(&sources[1]), &read(&sources[2])),
            5 => {
                let vals: Vec<BitRow> = sources.iter().map(read).collect();
                BitRow::maj5([&vals[0], &vals[1], &vals[2], &vals[3], &vals[4]])
            }
            n => panic!("multi_activate supports 3 or 5 rows, got {n}"),
        };
        // Charge restoration overwrites all activated cells.
        let negated = sensed.not();
        for s in sources {
            self.rows[s.row] = if s.complement { negated.clone() } else { sensed.clone() };
        }
        sensed
    }

    /// The proposed AND operation (§III-A): operands already sit in the two
    /// compute rows `a` and `a1`; activating AND-WL connects, per column,
    /// cell `a1` to the bitline when `a` stores 1 (NMOS) and cell `a` (a 0)
    /// when `a` stores 0 (PMOS). Sensed value = `a AND a1`, then driven into
    /// the rows listed in `store_to`.
    pub fn and_wl(&mut self, a: usize, a1: usize, store_to: &[usize]) -> BitRow {
        let sensed = self.rows[a].and(&self.rows[a1]);
        for &dst in store_to {
            self.rows[dst] = sensed.clone();
        }
        sensed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row_of(bits: &[u8]) -> BitRow {
        BitRow::from_fn(bits.len(), |i| bits[i] == 1)
    }

    #[test]
    fn bitrow_get_set() {
        let mut r = BitRow::zeros(100);
        r.set(0, true);
        r.set(63, true);
        r.set(64, true);
        r.set(99, true);
        assert!(r.get(0) && r.get(63) && r.get(64) && r.get(99));
        assert!(!r.get(1) && !r.get(65));
        assert_eq!(r.count_ones(), 4);
        r.set(0, false);
        assert!(!r.get(0));
    }

    #[test]
    fn not_respects_tail() {
        let r = BitRow::zeros(70);
        let n = r.not();
        assert_eq!(n.count_ones(), 70);
    }

    #[test]
    fn maj3_truth_table() {
        for mask in 0..8u32 {
            let a = row_of(&[(mask & 1) as u8]);
            let b = row_of(&[((mask >> 1) & 1) as u8]);
            let c = row_of(&[((mask >> 2) & 1) as u8]);
            let want = (mask & 1) + ((mask >> 1) & 1) + ((mask >> 2) & 1) >= 2;
            assert_eq!(BitRow::maj3(&a, &b, &c).get(0), want, "mask={mask}");
        }
    }

    #[test]
    fn maj5_truth_table_exhaustive() {
        for mask in 0..32u32 {
            let rows: Vec<BitRow> =
                (0..5).map(|i| row_of(&[((mask >> i) & 1) as u8])).collect();
            let want = (0..5).map(|i| (mask >> i) & 1).sum::<u32>() >= 3;
            let got =
                BitRow::maj5([&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]]);
            assert_eq!(got.get(0), want, "mask={mask:05b}");
        }
    }

    #[test]
    fn maj5_column_parallel_wide() {
        // Cross-check the bit-parallel formula against per-column counting
        // on a wide random-ish pattern spanning word boundaries.
        let cols = 257;
        let rows: Vec<BitRow> = (0..5)
            .map(|r| BitRow::from_fn(cols, |c| (c * 7 + r * 13) % 3 == 0))
            .collect();
        let got = BitRow::maj5([&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]]);
        for c in 0..cols {
            let count = rows.iter().filter(|r| r.get(c)).count();
            assert_eq!(got.get(c), count >= 3, "col {c}");
        }
    }

    #[test]
    fn adder_identities() {
        // Ambit/paper equations (1)-(2): Cout = MAJ3(A,B,Cin);
        // Sum = MAJ5(A,B,Cin,!Cout,!Cout) must equal A^B^Cin.
        for mask in 0..8u32 {
            let a = row_of(&[(mask & 1) as u8]);
            let b = row_of(&[((mask >> 1) & 1) as u8]);
            let cin = row_of(&[((mask >> 2) & 1) as u8]);
            let cout = BitRow::maj3(&a, &b, &cin);
            let ncout = cout.not();
            let sum = BitRow::maj5([&a, &b, &cin, &ncout, &ncout]);
            let want_sum = a.xor(&b).xor(&cin);
            assert_eq!(sum.get(0), want_sum.get(0), "mask={mask}");
        }
    }

    #[test]
    fn multi_activate_writes_back() {
        let mut sa = Subarray::new(8, 4);
        sa.write_row(0, &row_of(&[1, 1, 0, 0]));
        sa.write_row(1, &row_of(&[1, 0, 1, 0]));
        sa.write_row(2, &row_of(&[1, 0, 0, 0]));
        let sensed = sa.multi_activate(&[
            ActRow::plain(0),
            ActRow::plain(1),
            ActRow::plain(2),
        ]);
        assert_eq!(sensed, row_of(&[1, 0, 0, 0]));
        // Charge restoration: all three rows now hold the majority.
        assert_eq!(sa.row(0), &row_of(&[1, 0, 0, 0]));
        assert_eq!(sa.row(1), &row_of(&[1, 0, 0, 0]));
        assert_eq!(sa.row(2), &row_of(&[1, 0, 0, 0]));
    }

    #[test]
    fn multi_activate_complement_writeback() {
        let mut sa = Subarray::new(8, 1);
        sa.write_row(0, &row_of(&[1]));
        sa.write_row(1, &row_of(&[1]));
        sa.write_row(2, &row_of(&[0]));
        // rows: 1,1,!0=1 -> majority 1; DCC row 2 stores complement (0... wait,
        // complement of sensed 1 is 0, and row2 participated complemented).
        let sensed = sa.multi_activate(&[
            ActRow::plain(0),
            ActRow::plain(1),
            ActRow::neg(2),
        ]);
        assert!(sensed.get(0));
        assert!(!sa.get_bit(2, 0), "DCC cell stores complement of sensed");
    }

    #[test]
    fn and_wl_all_combinations() {
        let mut sa = Subarray::new(8, 4);
        // columns encode (A, B) = (0,0), (0,1), (1,0), (1,1)
        sa.write_row(0, &row_of(&[0, 0, 1, 1])); // A
        sa.write_row(1, &row_of(&[0, 1, 0, 1])); // A-1 (= B)
        let sensed = sa.and_wl(0, 1, &[3]);
        assert_eq!(sensed, row_of(&[0, 0, 0, 1]));
        assert_eq!(sa.row(3), &row_of(&[0, 0, 0, 1]));
    }

    #[test]
    fn copy_row_clones_data() {
        let mut sa = Subarray::new(4, 8);
        sa.write_row(0, &BitRow::from_fn(8, |c| c % 2 == 0));
        sa.copy_row(0, 3);
        assert_eq!(sa.row(3), sa.row(0));
    }

    #[test]
    #[should_panic(expected = "3 or 5 rows")]
    fn multi_activate_rejects_even_counts() {
        let mut sa = Subarray::new(4, 4);
        sa.multi_activate(&[ActRow::plain(0), ActRow::plain(1)]);
    }
}
