//! DRAM command accounting: the primitives log every operation here and the
//! timing model prices the totals. Counters (not a full trace) keep the
//! simulator hot path allocation-free; an optional bounded trace ring is
//! available for debugging.

use super::timing::DramTiming;

/// A DRAM-level operation issued by the PIM primitives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Command {
    /// ACTIVATE-ACTIVATE-PRECHARGE compound op with `rows` simultaneously
    /// activated rows in the second activation (1 = copy, 3 = majority-3,
    /// 5 = majority-5).
    Aap { rows: u8 },
    /// Plain row activate + precharge (read/write access).
    RowCycle,
    /// Intra-subarray RowClone copy (priced as one AAP).
    RowCloneIntra,
    /// Inter-bank RowClone of one row over the internal bus.
    RowCloneInter { row_bits: u32 },
}

/// Aggregated command counts + derived time/energy.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CommandStats {
    pub aap_single: u64,
    pub aap_triple: u64,
    pub aap_quint: u64,
    pub row_cycles: u64,
    pub rowclone_intra: u64,
    pub rowclone_inter: u64,
    pub rowclone_inter_bits: u64,
}

impl CommandStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, cmd: Command) {
        match cmd {
            Command::Aap { rows: 1 } => self.aap_single += 1,
            Command::Aap { rows: 3 } => self.aap_triple += 1,
            Command::Aap { rows: 5 } => self.aap_quint += 1,
            Command::Aap { rows } => {
                debug_assert!(false, "unexpected AAP row count {rows}");
                self.aap_single += 1;
            }
            Command::RowCycle => self.row_cycles += 1,
            Command::RowCloneIntra => self.rowclone_intra += 1,
            Command::RowCloneInter { row_bits } => {
                self.rowclone_inter += 1;
                self.rowclone_inter_bits += row_bits as u64;
            }
        }
    }

    /// Total AAP-class operations (what the paper's formulas count).
    pub fn total_aaps(&self) -> u64 {
        self.aap_single + self.aap_triple + self.aap_quint + self.rowclone_intra
    }

    /// Latency in nanoseconds under `timing`, assuming the commands of one
    /// stats block are serialized (one subarray's command stream).
    pub fn latency_ns(&self, timing: &DramTiming) -> f64 {
        let aap = self.total_aaps() as f64 * timing.aap_ns();
        let rc = self.row_cycles as f64 * timing.trc_ns();
        let inter = if self.rowclone_inter > 0 {
            // Bus beats + two row cycles per copied row.
            let beats = crate::util::ceil_div(
                self.rowclone_inter_bits as usize,
                timing.internal_bus_bits,
            );
            2.0 * self.rowclone_inter as f64 * timing.trc_ns()
                + beats as f64 * timing.tck_ns
        } else {
            0.0
        };
        aap + rc + inter
    }

    /// Energy in nanojoules under `timing`.
    pub fn energy_nj(&self, timing: &DramTiming) -> f64 {
        // Each AAP = two activations (the second possibly multi-row) + PRE.
        let single = self.aap_single as f64
            * (timing.act_pre_energy_nj + timing.multi_act_energy(1));
        let triple = self.aap_triple as f64
            * (timing.act_pre_energy_nj + timing.multi_act_energy(3));
        let quint = self.aap_quint as f64
            * (timing.act_pre_energy_nj + timing.multi_act_energy(5));
        let rc = self.row_cycles as f64 * timing.act_pre_energy_nj;
        let intra = self.rowclone_intra as f64 * 2.0 * timing.act_pre_energy_nj;
        let inter = self.rowclone_inter as f64 * 2.0 * timing.act_pre_energy_nj
            + self.rowclone_inter_bits as f64 * timing.bus_energy_pj_per_bit / 1000.0;
        single + triple + quint + rc + intra + inter
    }

    /// Merge another stats block (e.g. per-subarray → per-bank totals).
    pub fn merge(&mut self, other: &CommandStats) {
        self.aap_single += other.aap_single;
        self.aap_triple += other.aap_triple;
        self.aap_quint += other.aap_quint;
        self.row_cycles += other.row_cycles;
        self.rowclone_intra += other.rowclone_intra;
        self.rowclone_inter += other.rowclone_inter;
        self.rowclone_inter_bits += other.rowclone_inter_bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_total() {
        let mut s = CommandStats::new();
        s.record(Command::Aap { rows: 1 });
        s.record(Command::Aap { rows: 3 });
        s.record(Command::Aap { rows: 5 });
        s.record(Command::RowCloneIntra);
        s.record(Command::RowCycle);
        assert_eq!(s.total_aaps(), 4);
        assert_eq!(s.row_cycles, 1);
    }

    #[test]
    fn latency_counts_aaps() {
        let t = DramTiming::ddr3_1600();
        let mut s = CommandStats::new();
        for _ in 0..10 {
            s.record(Command::Aap { rows: 3 });
        }
        assert!((s.latency_ns(&t) - 10.0 * t.aap_ns()).abs() < 1e-9);
    }

    #[test]
    fn interbank_latency_includes_bus() {
        let t = DramTiming::ddr3_1600();
        let mut s = CommandStats::new();
        s.record(Command::RowCloneInter { row_bits: 4096 });
        let expect = 2.0 * t.trc_ns() + (4096 / 64) as f64 * t.tck_ns;
        assert!((s.latency_ns(&t) - expect).abs() < 1e-9);
    }

    #[test]
    fn energy_multi_row_costs_more() {
        let t = DramTiming::ddr3_1600();
        let mut s1 = CommandStats::new();
        s1.record(Command::Aap { rows: 1 });
        let mut s5 = CommandStats::new();
        s5.record(Command::Aap { rows: 5 });
        assert!(s5.energy_nj(&t) > s1.energy_nj(&t));
    }

    #[test]
    fn merge_sums_fields() {
        let mut a = CommandStats::new();
        a.record(Command::Aap { rows: 1 });
        let mut b = CommandStats::new();
        b.record(Command::Aap { rows: 3 });
        b.record(Command::RowCloneInter { row_bits: 128 });
        a.merge(&b);
        assert_eq!(a.total_aaps(), 2);
        assert_eq!(a.rowclone_inter_bits, 128);
    }
}
