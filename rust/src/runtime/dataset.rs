//! Test-set loader: the quantized digits images (`digits_test.bin`,
//! int32 LE) and labels (`digits_labels.bin`, u8) emitted by `aot.py`.

use std::path::Path;

use anyhow::{Context, Result};

use super::manifest::ArtifactManifest;

/// The synthetic digits evaluation set, already quantized to wa-bit ints.
#[derive(Debug, Clone)]
pub struct DigitsDataset {
    /// All images, flattened `[count, 16, 16, 1]`.
    pub images: Vec<i32>,
    pub labels: Vec<u8>,
    pub count: usize,
    /// Elements per image.
    pub image_elems: usize,
}

impl DigitsDataset {
    pub fn load(dir: &Path, manifest: &ArtifactManifest) -> Result<DigitsDataset> {
        let img_path = dir.join(&manifest.test_images_file);
        let bytes = std::fs::read(&img_path)
            .with_context(|| format!("reading {}", img_path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "image file not i32-aligned");
        let images: Vec<i32> = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let lbl_path = dir.join(&manifest.test_labels_file);
        let labels = std::fs::read(&lbl_path)
            .with_context(|| format!("reading {}", lbl_path.display()))?;

        let count = manifest.test_count;
        anyhow::ensure!(labels.len() == count, "label count mismatch");
        anyhow::ensure!(
            images.len() % count == 0,
            "image elements not divisible by count"
        );
        let image_elems = images.len() / count;
        Ok(DigitsDataset { images, labels, count, image_elems })
    }

    /// Slice one batch of `batch` images starting at `start` (wraps).
    pub fn batch(&self, start: usize, batch: usize) -> (Vec<i32>, Vec<u8>) {
        let mut imgs = Vec::with_capacity(batch * self.image_elems);
        let mut lbls = Vec::with_capacity(batch);
        for i in 0..batch {
            let idx = (start + i) % self.count;
            let off = idx * self.image_elems;
            imgs.extend_from_slice(&self.images[off..off + self.image_elems]);
            lbls.push(self.labels[idx]);
        }
        (imgs, lbls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    #[test]
    fn loads_if_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        let ds = DigitsDataset::load(&dir, &m).unwrap();
        assert_eq!(ds.count, m.test_count);
        assert_eq!(ds.image_elems, 16 * 16);
        // Quantized range check.
        let max = *ds.images.iter().max().unwrap();
        let min = *ds.images.iter().min().unwrap();
        assert!(min >= 0 && max < (1 << m.wa));
        // Batch wrap-around.
        let (imgs, lbls) = ds.batch(ds.count - 2, 4);
        assert_eq!(imgs.len(), 4 * ds.image_elems);
        assert_eq!(lbls.len(), 4);
        assert_eq!(lbls[0], ds.labels[ds.count - 2]);
        assert_eq!(lbls[2], ds.labels[0]);
    }
}
