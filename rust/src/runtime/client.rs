//! Thin, typed wrapper over the `xla` crate's PJRT CPU client.

use std::path::Path;

use anyhow::{Context, Result};

/// A host tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    I32(Vec<i32>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
}

impl Tensor {
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32(data, shape.to_vec())
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32(data, shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::I32(_, s) | Tensor::F32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::I32(d, _) => d.len(),
            Tensor::F32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            Tensor::F32(..) => anyhow::bail!("tensor is f32, expected i32"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            Tensor::I32(..) => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::I32(d, _) => xla::Literal::vec1(d.as_slice()),
            Tensor::F32(d, _) => xla::Literal::vec1(d.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::S32 => Ok(Tensor::I32(lit.to_vec::<i32>()?, dims)),
            xla::ElementType::F32 => Ok(Tensor::F32(lit.to_vec::<f32>()?, dims)),
            other => anyhow::bail!("unsupported artifact output dtype {other:?}"),
        }
    }
}

/// The PJRT CPU runtime. Compilation happens once per module; execution is
/// reentrant.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModule {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }
}

/// A compiled executable (one per model/layer variant).
pub struct LoadedModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModule {
    /// Execute with host tensors. The AOT path lowers with
    /// `return_tuple=True`, so the root is always a tuple; its elements are
    /// returned in order.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Execute expecting exactly one output tensor.
    pub fn run1(&self, inputs: &[Tensor]) -> Result<Tensor> {
        let mut out = self.run(inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        Ok(out.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::i32(vec![1, 2, 3, 4, 5, 6], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn tensor_size_mismatch_panics() {
        Tensor::f32(vec![1.0], &[2, 2]);
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs so
    // they can share one client (creating many CPU clients is slow).
}
