//! Artifact manifest parsing (`artifacts/manifest.json`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Per-layer artifact metadata (one PIM bank's executable).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub out_dtype: String,
    pub mac_size: usize,
    pub num_macs: usize,
    pub relu: bool,
    pub pool: bool,
    pub w_scale: f64,
    pub in_scale: f64,
    pub out_scale: f64,
}

/// The whole artifact bundle description.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactManifest {
    pub wa: usize,
    pub ww: usize,
    pub batch: usize,
    pub input_scale: f64,
    pub model_hlo: String,
    pub mvm_hlo: String,
    pub mvm_shape: (usize, usize, usize),
    pub test_count: usize,
    pub test_images_file: String,
    pub test_labels_file: String,
    pub float_test_accuracy: f64,
    pub quant_test_accuracy: f64,
    pub layers: Vec<LayerMeta>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ArtifactManifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let usize_vec = |v: &Json| -> Result<Vec<usize>> {
            Ok(v.i64_vec()?.into_iter().map(|x| x as usize).collect())
        };
        let layers = j
            .req_arr("layers")?
            .iter()
            .map(|l| -> Result<LayerMeta> {
                Ok(LayerMeta {
                    name: l.req_str("name")?.to_string(),
                    file: l.req_str("file")?.to_string(),
                    kind: l.req_str("kind")?.to_string(),
                    in_shape: usize_vec(
                        l.get("in_shape").context("in_shape")?,
                    )?,
                    out_shape: usize_vec(
                        l.get("out_shape").context("out_shape")?,
                    )?,
                    out_dtype: l.req_str("out_dtype")?.to_string(),
                    mac_size: l.req_i64("mac_size")? as usize,
                    num_macs: l.req_i64("num_macs")? as usize,
                    relu: l.get("relu").and_then(Json::as_bool).unwrap_or(false),
                    pool: l.get("pool").and_then(Json::as_bool).unwrap_or(false),
                    w_scale: l.req_f64("w_scale")?,
                    in_scale: l.req_f64("in_scale")?,
                    out_scale: l.req_f64("out_scale")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mvm = j.req_arr("mvm_shape")?;
        anyhow::ensure!(mvm.len() == 3, "mvm_shape must have 3 dims");
        let ti = j.get("test_images").context("test_images")?;
        let tl = j.get("test_labels").context("test_labels")?;

        Ok(ArtifactManifest {
            wa: j.req_i64("wa")? as usize,
            ww: j.req_i64("ww")? as usize,
            batch: j.req_i64("batch")? as usize,
            input_scale: j.req_f64("input_scale")?,
            model_hlo: j.req_str("model_hlo")?.to_string(),
            mvm_hlo: j.req_str("mvm_hlo")?.to_string(),
            mvm_shape: (
                mvm[0].as_usize().context("mvm m")?,
                mvm[1].as_usize().context("mvm k")?,
                mvm[2].as_usize().context("mvm n")?,
            ),
            test_count: ti.req_i64("count")? as usize,
            test_images_file: ti.req_str("file")?.to_string(),
            test_labels_file: tl.req_str("file")?.to_string(),
            float_test_accuracy: j.req_f64("float_test_accuracy")?,
            quant_test_accuracy: j.req_f64("quant_test_accuracy")?,
            layers,
        })
    }

    /// Shape-chain check: each layer feeds the next.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.layers.is_empty(), "no layers in manifest");
        for (a, b) in self.layers.iter().zip(self.layers.iter().skip(1)) {
            let out: usize = a.out_shape.iter().product();
            let inp: usize = b.in_shape.iter().product();
            anyhow::ensure!(
                out == inp,
                "layer chain break: {} out {} != {} in {}",
                a.name,
                out,
                b.name,
                inp
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "wa": 8, "ww": 8, "batch": 8, "input_scale": 0.004,
      "model_hlo": "model.hlo.txt", "mvm_hlo": "mvm.hlo.txt",
      "mvm_shape": [8, 64, 64],
      "test_images": {"file": "digits_test.bin", "count": 64,
                       "shape": [16,16,1], "dtype": "i32"},
      "test_labels": {"file": "digits_labels.bin", "count": 64},
      "float_test_accuracy": 1.0, "quant_test_accuracy": 0.98,
      "train_loss_first": 2.6, "train_loss_last": 0.01,
      "layers": [
        {"name": "conv1", "file": "layers/l0_conv1.hlo.txt", "kind": "conv",
         "in_shape": [8,16,16,1], "out_shape": [8,8,8,16], "out_dtype": "i32",
         "mac_size": 9, "num_macs": 4096, "relu": true, "pool": true,
         "w_scale": 0.01, "in_scale": 0.004, "out_scale": 0.02},
        {"name": "fc", "file": "layers/l1_fc.hlo.txt", "kind": "linear",
         "in_shape": [8,8,8,16], "out_shape": [8,10], "out_dtype": "f32",
         "mac_size": 1024, "num_macs": 10, "relu": false, "pool": false,
         "w_scale": 0.01, "in_scale": 0.02, "out_scale": 0.0}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.wa, 8);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].out_shape, vec![8, 8, 8, 16]);
        assert_eq!(m.mvm_shape, (8, 64, 64));
        assert!(m.layers[0].pool);
        m.validate().unwrap();
    }

    #[test]
    fn chain_break_detected() {
        let broken = SAMPLE.replace("\"in_shape\": [8,8,8,16]", "\"in_shape\": [8,4,4,16]");
        let m = ArtifactManifest::parse(&broken).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_field_errors() {
        assert!(ArtifactManifest::parse("{}").is_err());
        let no_wa = SAMPLE.replace("\"wa\": 8,", "");
        assert!(ArtifactManifest::parse(&no_wa).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        m.validate().unwrap();
        assert_eq!(m.layers.len(), 4);
        assert_eq!(m.layers[0].mac_size, 9);
        assert!(m.quant_test_accuracy > 0.5);
    }
}
