//! PimNet executor: compiles every per-layer artifact (= per-bank
//! executable) once, then runs batches through the chain. This is the
//! numeric payload the coordinator pipelines — each stage here corresponds
//! to one PIM bank in the timing model.

use std::path::Path;

use anyhow::Result;

use super::client::{LoadedModule, Runtime, Tensor};
use super::manifest::ArtifactManifest;

/// Compiled PimNet: per-layer executables + the fused full-model module.
pub struct PimNetExecutor {
    pub manifest: ArtifactManifest,
    layers: Vec<LoadedModule>,
    full_model: LoadedModule,
}

impl PimNetExecutor {
    pub fn load(rt: &Runtime, dir: &Path) -> Result<PimNetExecutor> {
        let manifest = ArtifactManifest::load(dir)?;
        manifest.validate()?;
        let layers = manifest
            .layers
            .iter()
            .map(|l| rt.load_hlo_text(&dir.join(&l.file)))
            .collect::<Result<Vec<_>>>()
            .map_err(|e| e.context("loading layer artifacts"))?;
        let full_model = rt.load_hlo_text(&dir.join(&manifest.model_hlo))?;
        Ok(PimNetExecutor { manifest, layers, full_model })
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }

    /// Run one layer (bank stage) on its input activations.
    pub fn run_layer(&self, idx: usize, input: Tensor) -> Result<Tensor> {
        anyhow::ensure!(idx < self.layers.len(), "layer index {idx}");
        let meta = &self.manifest.layers[idx];
        anyhow::ensure!(
            input.shape() == meta.in_shape.as_slice(),
            "layer {} expects shape {:?}, got {:?}",
            meta.name,
            meta.in_shape,
            input.shape()
        );
        self.layers[idx].run1(&[input])
    }

    /// Run a full batch layer-by-layer (the per-bank path the coordinator
    /// pipelines). Input: quantized i32 `[batch, 16, 16, 1]`.
    pub fn run_chain(&self, images: Vec<i32>) -> Result<Tensor> {
        let shape = &self.manifest.layers[0].in_shape;
        let mut act = Tensor::i32(images, shape);
        for idx in 0..self.layers.len() {
            act = self.run_layer(idx, act)?;
        }
        Ok(act)
    }

    /// Run the fused single-module forward (cross-check for the chain).
    pub fn run_full(&self, images: Vec<i32>) -> Result<Tensor> {
        let shape = &self.manifest.layers[0].in_shape;
        self.full_model.run1(&[Tensor::i32(images, shape)])
    }

    /// Argmax over the logits tensor `[batch, 10]`.
    pub fn classify(logits: &Tensor) -> Result<Vec<usize>> {
        let data = logits.as_f32()?;
        let classes = *logits.shape().last().unwrap();
        Ok(data
            .chunks(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect())
    }
}

// Integration tests (need artifacts + a PJRT client) live in
// rust/tests/runtime_integration.rs.
