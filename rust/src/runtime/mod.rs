//! PJRT runtime (DESIGN.md S14): loads the AOT HLO-text artifacts produced
//! by `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the coordinator's hot path. Python never runs
//! here — the interchange is HLO text (see /opt/xla-example/README.md for
//! why text, not serialized protos).

// The PJRT client/executor pair needs the `xla` runtime; everything else
// (manifest/dataset parsing, artifact discovery) is hermetic and stays in
// the default build so the coordinator's simulated path can reuse it.
#[cfg(feature = "pjrt")]
pub mod client;
pub mod dataset;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use client::{LoadedModule, Runtime, Tensor};
pub use dataset::DigitsDataset;
#[cfg(feature = "pjrt")]
pub use executor::PimNetExecutor;
pub use manifest::{ArtifactManifest, LayerMeta};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$PIM_ARTIFACTS` or `./artifacts`
/// relative to the crate root / current dir.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PIM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let candidates = [
        PathBuf::from("artifacts"),
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.join("manifest.json").exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}

/// True when `make artifacts` has been run.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
