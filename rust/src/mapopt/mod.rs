//! `pim::mapopt` — search-based per-layer mapping optimizer
//! (DESIGN.md §Mapping optimizer).
//!
//! Algorithm 1 binary-searches one knob (the parallelism divisor k); the
//! real design space also has *how operands are staged*: loop-tiling
//! factors over the layer's outer dimension and a sequential vs
//! row-aligned placement whose row-activation cost comes from
//! tile-crossing analysis against the DRAM row width
//! (`mapping::candidates`). This module searches that space per layer:
//!
//!   * **Candidates** — `candidate_ks` (the spec's k, 1, the minimum
//!     resident k, powers of two) × `candidates_at_k` (untiled plus a
//!     power-of-two tile ladder × both layouts when the layer is not
//!     resident).
//!   * **Beam + branch-and-bound** — k-branches are ordered by a
//!     monotone lower bound (`engine::stage_lower_bound_ns`: the
//!     refresh-stretched multiply term of the untiled mapping plus the
//!     outbound transfer — no candidate at that k can price below it);
//!     only the best `beam` branches are expanded, and a branch whose
//!     bound already exceeds the incumbent is pruned without pricing.
//!   * **Exact pricing** — every surviving candidate is priced through
//!     the cached [`SimSession`] arena (`candidate_slot`), so repeated
//!     searches, the final `report_with`, and the paper baseline all
//!     share one fingerprint's cache fills.
//!
//! Guarantees: the paper candidate is always priced, the incumbent is
//! only replaced by a *strictly* cheaper stage cost, and if re-lowering
//! the chosen assignment ever erased the per-layer wins end-to-end the
//! optimizer falls back to the paper mapping — so the searched report is
//! never worse than the paper report, and the whole search is
//! deterministic (no RNG; ties keep the earliest candidate in a fixed
//! enumeration order).

use crate::mapping::candidates::{
    candidate_ks, candidates_at_k, map_candidate, tiling_applicable, LayerCandidate,
};
use crate::mapping::{map_layer, outer_count, MapConfig, NetworkMapping};
use crate::plan::{self, ExecutionPlan, PlanError};
use crate::sim::engine::{stage_lower_bound_ns, PriceCtx};
use crate::sim::{SimConfig, SimReport, SimSession};
use crate::workloads::Network;

/// Search knobs, mirroring `RunSpec`'s `beam`/`search_budget` fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SearchKnobs {
    /// k-branches expanded per layer (beam width); values below 1 are
    /// clamped to 1 (diagnostic W052).
    pub beam: usize,
    /// Exact pricings spent per layer beyond the always-priced paper
    /// candidate; 0 degenerates the search to the paper mapping (W050).
    pub budget: usize,
}

impl Default for SearchKnobs {
    fn default() -> Self {
        SearchKnobs { beam: 4, budget: 64 }
    }
}

/// The chosen mapping for one layer, with its exact stage price.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerChoice {
    pub layer_idx: usize,
    pub name: String,
    pub cand: LayerCandidate,
    /// Exact `stage_ns` (compute + transfer) of the chosen candidate.
    pub stage_ns: f64,
    /// Exact `stage_ns` of the paper mapping at the spec's k.
    pub paper_stage_ns: f64,
    /// Chosen mapping is fully resident (no waves, no restaging).
    pub resident: bool,
}

impl LayerChoice {
    /// Strict per-layer win over the paper mapping.
    pub fn improved(&self) -> bool {
        self.stage_ns < self.paper_stage_ns
    }
}

/// Everything one search run produces.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub choices: Vec<LayerChoice>,
    /// The paper mapping's report under the same config (the baseline).
    pub paper: SimReport,
    /// The chosen assignment's report; never worse than `paper` on
    /// latency (fallback guarantee above).
    pub searched: SimReport,
    /// Exact pricings performed, paper candidates included.
    pub candidates_priced: usize,
    /// k-branches discarded by the lower bound without pricing.
    pub pruned_branches: usize,
    /// Layers whose tiling knob is unsearchable at the spec's k (W051).
    pub degenerate_tiling: Vec<usize>,
    /// The end-to-end assignment fell back to the paper mapping.
    pub fell_back: bool,
}

impl SearchOutcome {
    /// Per-layer assignment the searched report was priced under.
    pub fn assignment(&self) -> Vec<LayerCandidate> {
        self.choices.iter().map(|c| c.cand).collect()
    }

    /// Strict end-to-end latency win over the paper mapping.
    pub fn improved(&self) -> bool {
        self.searched.latency_ns < self.paper.latency_ns
    }

    /// Layers whose chosen candidate strictly beats the paper mapping
    /// (the incumbent is only ever replaced by a strictly cheaper one,
    /// so this is exactly the count of changed layers).
    pub fn changed_layers(&self) -> usize {
        self.choices.iter().filter(|c| c.improved()).count()
    }

    /// Lower the chosen assignment onto the device grid: the plan
    /// carries the searched mapping (tiling and layout included) via
    /// `plan::lower_mapped`, so downstream consumers see the same
    /// mapping the searched report priced.
    pub fn plan(&self, net: &Network, cfg: &SimConfig) -> Result<ExecutionPlan, PlanError> {
        let mut probe = MapConfig {
            geometry: cfg.geometry.clone(),
            n_bits: cfg.n_bits,
            ks: vec![1],
        };
        let layers = self
            .choices
            .iter()
            .map(|c| {
                map_candidate(
                    c.layer_idx,
                    c.layer_idx,
                    &net.layers[c.layer_idx],
                    &mut probe,
                    &c.cand,
                )
            })
            .collect::<Result<Vec<_>, _>>()
            .map_err(PlanError::Map)?;
        let mapping = NetworkMapping {
            net_name: net.name.clone(),
            layers,
            residual_banks: net.residuals.len(),
            total_banks: net.layers.len() + net.residuals.len(),
        };
        plan::lower_mapped(net, &cfg.geometry, mapping, cfg.shard)
    }
}

/// Run the per-layer beam search under `cfg` and price both mappings
/// through `session` (the caller keeps the session, so sweeps over specs
/// differing only in searched knobs hit the same arena).
pub fn optimize(
    session: &mut SimSession<'_>,
    cfg: &SimConfig,
    knobs: &SearchKnobs,
) -> Result<SearchOutcome, PlanError> {
    let net = session.network();
    let beam = knobs.beam.max(1);
    let ctx = PriceCtx::new(cfg);
    let mut probe = MapConfig {
        geometry: cfg.geometry.clone(),
        n_bits: cfg.n_bits,
        ks: vec![1],
    };

    let mut choices = Vec::with_capacity(net.layers.len());
    let mut candidates_priced = 0usize;
    let mut pruned_branches = 0usize;
    let mut degenerate_tiling = Vec::new();

    for (i, layer) in net.layers.iter().enumerate() {
        // The same clamp `map_network` / the session apply to the spec k.
        let paper_k = cfg.k_for(i).min(outer_count(layer));
        let paper_cand = LayerCandidate::paper(paper_k);
        let paper_slot = session.candidate_slot(cfg, i, &paper_cand)?;
        let paper_stage = session.layer_sim(paper_slot).stage_ns();
        candidates_priced += 1;

        if !tiling_applicable(layer, &cfg.geometry, paper_k) {
            degenerate_tiling.push(i);
        }

        let mut best = (paper_cand, paper_stage);
        let mut remaining = knobs.budget;

        if remaining > 0 {
            // Order k-branches by the monotone lower bound, keep `beam`.
            let mut branches: Vec<(f64, usize)> = Vec::new();
            for k in candidate_ks(layer, &cfg.geometry, cfg.n_bits, paper_k) {
                probe.ks[0] = k;
                let m = map_layer(i, i, layer, &probe).map_err(PlanError::Map)?;
                branches.push((stage_lower_bound_ns(layer, &m, cfg, &ctx), k));
            }
            branches.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            if branches.len() > beam {
                pruned_branches += branches.len() - beam;
                branches.truncate(beam);
            }

            'branches: for (lb, k) in branches {
                if lb >= best.1 {
                    // No candidate at this k can beat the incumbent.
                    pruned_branches += 1;
                    continue;
                }
                for cand in candidates_at_k(layer, &mut probe, k) {
                    if cand == paper_cand {
                        continue; // already priced
                    }
                    if remaining == 0 {
                        break 'branches;
                    }
                    remaining -= 1;
                    let slot = session.candidate_slot(cfg, i, &cand)?;
                    candidates_priced += 1;
                    let stage = session.layer_sim(slot).stage_ns();
                    if stage < best.1 {
                        best = (cand, stage);
                    }
                }
            }
        }

        let chosen_slot = session.candidate_slot(cfg, i, &best.0)?;
        let resident = session.layer_sim(chosen_slot).mapping.fully_resident();
        choices.push(LayerChoice {
            layer_idx: i,
            name: layer.name.clone(),
            cand: best.0,
            stage_ns: best.1,
            paper_stage_ns: paper_stage,
            resident,
        });
    }

    let paper = session.report(cfg)?;
    let assignment: Vec<LayerCandidate> = choices.iter().map(|c| c.cand).collect();
    let mut searched = session.report_with(cfg, &assignment)?;
    let mut fell_back = false;
    if searched.latency_ns > paper.latency_ns {
        // Re-lowering the per-layer wins moved a split boundary against
        // us (only possible under layer-split shards): keep the paper
        // mapping — the searched report must never be worse.
        for c in &mut choices {
            let paper_k = cfg.k_for(c.layer_idx).min(outer_count(&net.layers[c.layer_idx]));
            c.cand = LayerCandidate::paper(paper_k);
            c.stage_ns = c.paper_stage_ns;
        }
        searched = paper.clone();
        fell_back = true;
    }

    Ok(SearchOutcome {
        choices,
        paper,
        searched,
        candidates_priced,
        pruned_branches,
        degenerate_tiling,
        fell_back,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::{mobilenet_mini, tinyformer};

    #[test]
    fn search_strictly_beats_paper_on_mobilenet_mini() {
        let net = mobilenet_mini();
        let mut session = SimSession::new(&net);
        let cfg = SimConfig::conservative(8);
        let out = optimize(&mut session, &cfg, &SearchKnobs::default()).unwrap();
        assert!(out.improved(), "no strict win: {:?}", out.searched.latency_ns);
        assert!(!out.fell_back);
        for c in &out.choices {
            assert!(c.stage_ns <= c.paper_stage_ns, "{} got worse", c.name);
        }
    }

    #[test]
    fn zero_budget_degenerates_to_paper() {
        let net = tinyformer();
        let mut session = SimSession::new(&net);
        let cfg = SimConfig::conservative(8);
        let knobs = SearchKnobs { beam: 4, budget: 0 };
        let out = optimize(&mut session, &cfg, &knobs).unwrap();
        assert!(out.choices.iter().all(|c| c.cand.is_paper()));
        assert_eq!(out.searched.latency_ns.to_bits(), out.paper.latency_ns.to_bits());
    }

    #[test]
    fn lower_bound_is_sound_for_every_candidate() {
        // The pruning rule is only safe if no candidate at a k ever
        // prices below that k's bound. Exhaustive over vgg16's enumerated
        // candidate space on the conservative die.
        let net = crate::workloads::nets::vgg16();
        let cfg = SimConfig::conservative(8);
        let ctx = PriceCtx::new(&cfg);
        let mut probe = MapConfig {
            geometry: cfg.geometry.clone(),
            n_bits: cfg.n_bits,
            ks: vec![1],
        };
        let mut session = SimSession::new(&net);
        let mut checked = 0usize;
        for (i, layer) in net.layers.iter().enumerate() {
            for k in candidate_ks(layer, &cfg.geometry, cfg.n_bits, 1) {
                probe.ks[0] = k;
                let m = map_layer(i, i, layer, &probe).unwrap();
                let lb = stage_lower_bound_ns(layer, &m, &cfg, &ctx);
                for cand in candidates_at_k(layer, &mut probe, k) {
                    let slot = session.candidate_slot(&cfg, i, &cand).unwrap();
                    let exact = session.layer_sim(slot).stage_ns();
                    assert!(
                        lb <= exact * (1.0 + 1e-12) + 1e-9,
                        "{}/{} k={k} {cand:?}: bound {lb} > exact {exact}",
                        net.name,
                        layer.name
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > net.layers.len(), "candidate space collapsed");
    }

    #[test]
    fn search_is_deterministic() {
        let net = mobilenet_mini();
        let cfg = SimConfig::conservative(8);
        let mut s1 = SimSession::new(&net);
        let mut s2 = SimSession::new(&net);
        let a = optimize(&mut s1, &cfg, &SearchKnobs::default()).unwrap();
        let b = optimize(&mut s2, &cfg, &SearchKnobs::default()).unwrap();
        assert_eq!(a.assignment(), b.assignment());
        assert_eq!(a.searched.latency_ns.to_bits(), b.searched.latency_ns.to_bits());
    }
}
