//! Shape inference — the first `pim::ir` pass.
//!
//! Every value in a [`Graph`](crate::ir::Graph) carries one of three
//! shapes: a spatial feature map (`h × w × c`), a flat feature vector, or
//! a token/feature matrix (`rows × cols`). [`infer`] walks the graph in
//! program order (a topological order by construction) and derives every
//! node's output shape from its operator and operand shapes, rejecting
//! inconsistent graphs with errors that name the node and both shapes.
//!
//! One deliberate exception, inherited from the paper's Fig 13 dataflow:
//! the **shortcut** operand of an [`Op::ElemwiseAdd`] may disagree with
//! the main-path operand. ResNet-style downsample projections are folded
//! into the reserved bank that executes the add (see `workloads::nets`
//! module docs), so the add's output shape is the *main* operand's shape
//! and the shortcut is not shape-checked against it.

use anyhow::Result;

use super::{Graph, Node, Op};

/// The shape of one value edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// Spatial feature map, `h × w` with `c` channels.
    Map { h: usize, w: usize, c: usize },
    /// Flat feature vector.
    Flat { n: usize },
    /// Token/feature matrix, `rows × cols` (e.g. sequence × model dim).
    Mat { rows: usize, cols: usize },
}

impl Shape {
    /// Total element count.
    pub fn elems(&self) -> usize {
        match *self {
            Shape::Map { h, w, c } => h * w * c,
            Shape::Flat { n } => n,
            Shape::Mat { rows, cols } => rows * cols,
        }
    }

    fn valid(&self) -> bool {
        self.elems() > 0
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Shape::Map { h, w, c } => write!(f, "{h}x{w}x{c}"),
            Shape::Flat { n } => write!(f, "[{n}]"),
            Shape::Mat { rows, cols } => write!(f, "{rows}x{cols}"),
        }
    }
}

/// Output shape of one node given its operands' shapes — the single
/// inference rule [`infer`] applies per node, exported so tests can
/// re-check every edge independently.
pub fn output_shape(node: &Node, inputs: &[Shape]) -> Result<Shape> {
    let name = &node.name;
    let map_input = |what: &str| -> Result<(usize, usize, usize)> {
        match inputs[0] {
            Shape::Map { h, w, c } => Ok((h, w, c)),
            other => anyhow::bail!(
                "node `{name}`: {what} needs a feature-map input, got {other}"
            ),
        }
    };
    let out = match node.op {
        Op::Input { shape } => {
            anyhow::ensure!(
                shape.valid(),
                "node `{name}`: input dimensions must be >= 1"
            );
            shape
        }
        Op::Conv { out_ch, kh, kw, stride, pad } => {
            let (h, w, c) = map_input("conv")?;
            conv_out(name, h, w, c, out_ch, kh, kw, stride, pad)?
        }
        Op::DepthwiseConv { kh, kw, stride, pad } => {
            let (h, w, c) = map_input("depthwise conv")?;
            conv_out(name, h, w, c, c, kh, kw, stride, pad)?
        }
        Op::Linear { out_features } => {
            anyhow::ensure!(
                out_features >= 1,
                "node `{name}`: out_features must be >= 1"
            );
            match inputs[0] {
                // A matrix input applies the linear map per row.
                Shape::Mat { rows, .. } => Shape::Mat { rows, cols: out_features },
                // Feature maps flatten implicitly, as the classic CNN
                // conv → fc transition always did.
                Shape::Map { .. } | Shape::Flat { .. } => {
                    Shape::Flat { n: out_features }
                }
            }
        }
        Op::MatMul { transpose_rhs } => {
            let (m, k) = match inputs[0] {
                Shape::Mat { rows, cols } => (rows, cols),
                other => anyhow::bail!(
                    "node `{name}`: matmul lhs must be a matrix, got {other}"
                ),
            };
            let (rk, n) = match (inputs[1], transpose_rhs) {
                (Shape::Mat { rows, cols }, false) => (rows, cols),
                (Shape::Mat { rows, cols }, true) => (cols, rows),
                (other, _) => anyhow::bail!(
                    "node `{name}`: matmul rhs must be a matrix, got {other}"
                ),
            };
            anyhow::ensure!(
                rk == k,
                "node `{name}`: matmul contraction mismatch — lhs {} vs rhs {}{}",
                inputs[0],
                inputs[1],
                if transpose_rhs { " (transposed)" } else { "" }
            );
            Shape::Mat { rows: m, cols: n }
        }
        // The shortcut operand (inputs[0]) is exempt from the shape
        // check: a mismatched shortcut is the Fig 13 stance where the
        // downsample projection folds into the reserved bank.
        Op::ElemwiseAdd => inputs[1],
        Op::Pool => {
            let (h, w, c) = map_input("pool")?;
            anyhow::ensure!(
                h >= 2 && w >= 2,
                "node `{name}`: 2x2/stride-2 pool needs h,w >= 2, got {h}x{w}"
            );
            Shape::Map { h: h / 2, w: w / 2, c }
        }
        Op::GlobalAvgPool => {
            let (_, _, c) = map_input("global average pool")?;
            Shape::Flat { n: c }
        }
        Op::Activation { .. } => inputs[0],
    };
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn conv_out(
    name: &str,
    h: usize,
    w: usize,
    c: usize,
    out_ch: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> Result<Shape> {
    anyhow::ensure!(
        c >= 1 && out_ch >= 1 && kh >= 1 && kw >= 1 && stride >= 1,
        "node `{name}`: conv dimensions and stride must be >= 1"
    );
    anyhow::ensure!(
        h + 2 * pad >= kh && w + 2 * pad >= kw,
        "node `{name}`: {kh}x{kw} kernel exceeds the padded {h}x{w} input"
    );
    Ok(Shape::Map {
        h: (h + 2 * pad - kh) / stride + 1,
        w: (w + 2 * pad - kw) / stride + 1,
        c: out_ch,
    })
}

/// Infer every node's output shape, program order. Fails on the first
/// producer/consumer disagreement.
pub fn infer(g: &Graph) -> Result<Vec<Shape>> {
    let mut shapes: Vec<Shape> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let inputs: Vec<Shape> =
            node.inputs.iter().map(|id| shapes[id.0]).collect();
        shapes.push(output_shape(node, &inputs)?);
    }
    Ok(shapes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Graph;

    #[test]
    fn conv_chain_infers_spatial_dims() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 227, w: 227, c: 3 });
        let c = g.conv("c1", x, 96, 11, 4, 0);
        let r = g.relu("c1.relu", c);
        let p = g.pool("c1.pool", r);
        let shapes = infer(&g).unwrap();
        assert_eq!(shapes[c.0], Shape::Map { h: 55, w: 55, c: 96 });
        assert_eq!(shapes[r.0], shapes[c.0]);
        assert_eq!(shapes[p.0], Shape::Map { h: 27, w: 27, c: 96 });
    }

    #[test]
    fn matmul_contraction_checked() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Mat { rows: 4, cols: 8 });
        let q = g.linear("q", x, 8);
        let k = g.linear("k", x, 8);
        let s = g.matmul_t("s", q, k);
        let shapes = infer(&g).unwrap();
        assert_eq!(shapes[s.0], Shape::Mat { rows: 4, cols: 4 });

        // Untransposed rhs with mismatched inner dim is rejected.
        let mut g = Graph::new("bad");
        let x = g.input("x", Shape::Mat { rows: 4, cols: 8 });
        let q = g.linear("q", x, 6);
        let k = g.linear("k", x, 8);
        g.matmul("s", q, k);
        let err = infer(&g).unwrap_err();
        assert!(err.to_string().contains("contraction"), "{err}");
    }

    #[test]
    fn pool_on_flat_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Flat { n: 64 });
        g.pool("p", x);
        let err = infer(&g).unwrap_err();
        assert!(err.to_string().contains("feature-map"), "{err}");
    }

    #[test]
    fn oversized_kernel_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 4, w: 4, c: 1 });
        g.conv("c", x, 8, 11, 4, 0);
        let err = infer(&g).unwrap_err();
        assert!(err.to_string().contains("kernel"), "{err}");
    }

    #[test]
    fn shortcut_operand_is_exempt() {
        // Downsample residual: the shortcut's shape differs from the main
        // path; the add takes the main path's shape (Fig 13 stance).
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let c1 = g.conv("c1", x, 8, 3, 2, 1);
        let c2 = g.conv("c2", c1, 8, 3, 1, 1);
        let a = g.add("a", x, c2);
        let shapes = infer(&g).unwrap();
        assert_eq!(shapes[a.0], Shape::Map { h: 4, w: 4, c: 8 });
    }
}
