//! Bank-stage scheduling — the final `pim::ir` pass.
//!
//! Runs the whole pipeline (validate → shape inference → SFU fusion →
//! legalization) and emits the lowered [`Network`]: one bank stage per
//! compute node in topological program order, one reserved-bank
//! [`Residual`](crate::workloads::Residual) edge per `ElemwiseAdd`, in
//! add order. The result is exactly the per-bank stage form `mapping`,
//! `plan::lower`/`plan::layout` and the pricing engine consume — graphs
//! that describe the paper's networks lower to **structurally identical**
//! `Network` values, which is what makes the IR migration bitwise-safe
//! (`tests/ir_equivalence.rs`).
//!
//! The lowered chain is priced as a linear layer-per-bank pipeline (the
//! paper's dataflow): a stage's activations ride to the next stage's
//! bank. Fan-out in the graph (several consumers of one value, e.g.
//! attention's Q/K/V reading the same embedding) is therefore modeled as
//! repeated reads of the producing bank's output — the transfer cost
//! stays attributed to the producer stage, matching how the flat chain
//! always priced it.

use anyhow::Result;

use crate::workloads::Network;

use super::{passes, shape, Graph};

/// Lower a graph to the per-bank stage form.
pub fn lower(g: &Graph) -> Result<Network> {
    g.validate()?;
    let shapes = shape::infer(g)
        .map_err(|e| e.context(format!("shape inference over graph `{}`", g.name)))?;
    let fused = passes::fuse(g)
        .map_err(|e| e.context(format!("SFU fusion over graph `{}`", g.name)))?;
    let layers = passes::legalize(g, &shapes, &fused)
        .map_err(|e| e.context(format!("legalizing graph `{}`", g.name)))?;
    Ok(Network { name: g.name.clone(), layers, residuals: fused.residuals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Shape;
    use crate::workloads::{LayerDesc, Residual};

    /// The smallest interesting graph: conv+relu+pool, fc chain — must
    /// lower to exactly what the flat constructors build.
    #[test]
    fn lowering_matches_flat_construction() {
        let mut g = Graph::new("tiny");
        let x = g.input("in", Shape::Map { h: 8, w: 8, c: 1 });
        let c = g.conv("c1", x, 8, 3, 1, 1);
        let r = g.relu("c1.relu", c);
        let p = g.pool("c1.pool", r);
        let f1 = g.linear("fc1", p, 32);
        let f1r = g.relu("fc1.relu", f1);
        g.linear("fc2", f1r, 10);

        let net = lower(&g).unwrap();
        let flat = Network {
            name: "tiny".to_string(),
            layers: vec![
                LayerDesc::conv("c1", (8, 8), 1, 8, 3, 1, 1, true),
                LayerDesc::linear("fc1", 128, 32, true),
                LayerDesc::linear("fc2", 32, 10, false),
            ],
            residuals: vec![],
        };
        assert_eq!(net, flat);
        net.validate().unwrap();
    }

    #[test]
    fn residual_block_lowers_to_edge_list() {
        let mut g = Graph::new("res");
        let x = g.input("in", Shape::Map { h: 8, w: 8, c: 4 });
        let c0 = g.conv("c0", x, 4, 3, 1, 1);
        let c1 = g.conv("c1", c0, 4, 3, 1, 1);
        let c2 = g.conv("c2", c1, 4, 3, 1, 1);
        let a = g.add("a", c0, c2);
        let c3 = g.conv("c3", a, 4, 3, 1, 1);
        g.add("a2", a, c3);
        let net = lower(&g).unwrap();
        assert_eq!(net.layers.len(), 4);
        assert_eq!(
            net.residuals,
            vec![
                Residual { from_layer: 0, into_layer: 2 },
                Residual { from_layer: 2, into_layer: 3 },
            ]
        );
        net.validate().unwrap();
    }

    #[test]
    fn lowering_errors_name_the_pass() {
        // Shape error carries the graph name.
        let mut g = Graph::new("bad");
        let x = g.input("in", Shape::Map { h: 4, w: 4, c: 1 });
        g.conv("c", x, 8, 11, 4, 0);
        let err = format!("{:#}", lower(&g).unwrap_err());
        assert!(err.contains("shape inference") && err.contains("bad"), "{err}");
    }
}
