//! SFU fusion and bank-op legalization — `pim::ir` passes 2 and 3.
//!
//! **Fusion** walks the graph in (topological) program order and folds
//! every `Activation`/`Pool`/`GlobalAvgPool` node into the SFU chain of
//! the bank stage that produces its operand — the peripheral units of
//! §IV-A run behind the adder tree, so they never get a bank of their
//! own. `ElemwiseAdd` nodes become reserved-bank residual edges between
//! the stages that carry their operands (Fig 13). Fusion is legal only
//! when the fused node is its operand's sole consumer (another consumer
//! would observe the pre-chain value) and the operand is carried by a
//! compute stage (not the graph input, not a residual add).
//!
//! **Legalization** rewrites each fused stage onto the bank
//! multiplication primitive as a `workloads::LayerDesc`:
//!
//! | graph op | input shape | bank op |
//! |----------|-------------|---------|
//! | `Conv` | map | dense conv (`groups = 1`) |
//! | `DepthwiseConv` | map | grouped conv (`groups = in_ch = out_ch`) |
//! | `Linear` | flat / map | `Linear` (maps flatten implicitly) |
//! | `Linear` | matrix | `MatMul` (per-row linear; weights resident) |
//! | `MatMul` | matrix × matrix | `MatMul` (`k×n` operand resident) |
//!
//! Pool/GAP flags are legal only on stages producing feature maps — the
//! pooling unit walks spatial windows, which flat vectors and matrices do
//! not have.

use anyhow::Result;

use crate::workloads::{LayerDesc, LayerKind, Residual};

use super::shape::Shape;
use super::{Graph, NodeId, Op};

/// The SFU chain fused behind one bank stage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SfuChain {
    pub relu: bool,
    pub pool: bool,
    pub gap: bool,
}

/// One bank stage after fusion: a compute node plus its SFU chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BankStage {
    /// The compute node this stage executes.
    pub node: NodeId,
    pub chain: SfuChain,
}

/// Fusion output: bank stages in topological program order, residual
/// edges (stage-indexed), and the stage that carries each node's value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedGraph {
    pub stages: Vec<BankStage>,
    pub residuals: Vec<Residual>,
    /// Per node: the stage index whose bank holds the node's value after
    /// fusion (`None` for the graph input).
    pub carrier: Vec<Option<usize>>,
}

/// Pass 2: fold SFU nodes into their producer stages and turn adds into
/// residual edges. Expects a [`Graph::validate`]d graph.
pub fn fuse(g: &Graph) -> Result<FusedGraph> {
    let consumers = g.consumer_counts();
    let mut stages: Vec<BankStage> = Vec::new();
    let mut residuals: Vec<Residual> = Vec::new();
    let mut carrier: Vec<Option<usize>> = Vec::with_capacity(g.nodes.len());

    for (i, node) in g.nodes.iter().enumerate() {
        let name = &node.name;
        let carried = match node.op {
            Op::Input { .. } => None,
            op if op.is_compute() => {
                stages.push(BankStage { node: NodeId(i), chain: SfuChain::default() });
                Some(stages.len() - 1)
            }
            Op::ElemwiseAdd => {
                let from = carrier[node.inputs[0].0].ok_or_else(|| {
                    anyhow::anyhow!(
                        "add `{name}`: a shortcut from the graph input has no \
                         producing bank — insert a compute node first"
                    )
                })?;
                let into = carrier[node.inputs[1].0].ok_or_else(|| {
                    anyhow::anyhow!(
                        "add `{name}`: the main operand must come from a \
                         compute stage, not the graph input"
                    )
                })?;
                anyhow::ensure!(
                    from < into,
                    "add `{name}`: the shortcut must come from an earlier \
                     stage than the main path (got stage {from} -> {into}); \
                     swap the operands"
                );
                residuals.push(Residual { from_layer: from, into_layer: into });
                Some(into)
            }
            Op::Pool | Op::GlobalAvgPool | Op::Activation { .. } => {
                let src = node.inputs[0];
                let src_node = g.node(src);
                anyhow::ensure!(
                    !matches!(src_node.op, Op::Input { .. } | Op::ElemwiseAdd),
                    "`{name}` cannot fuse into `{}` — SFU ops chain behind a \
                     compute stage, not the graph input or a residual add \
                     (move it before the add or after a compute op)",
                    src_node.name
                );
                anyhow::ensure!(
                    consumers[src.0] == 1,
                    "`{name}` cannot fuse: `{}` has {} consumers, so fusing \
                     would hide its pre-chain value",
                    src_node.name,
                    consumers[src.0]
                );
                let stage = carrier[src.0].expect("non-input, non-add carrier");
                let stage_node = stages[stage].node;
                let chain = &mut stages[stage].chain;
                let (flag, what): (&mut bool, &str) = match node.op {
                    Op::Pool => (&mut chain.pool, "pool"),
                    Op::GlobalAvgPool => (&mut chain.gap, "global average pool"),
                    _ => (&mut chain.relu, "activation"),
                };
                anyhow::ensure!(
                    !*flag,
                    "`{name}`: stage `{}` already has a fused {what}",
                    g.node(stage_node).name
                );
                *flag = true;
                Some(stage)
            }
            // Compute ops are consumed by the `is_compute` guard arm.
            _ => unreachable!(),
        };
        carrier.push(carried);
    }
    Ok(FusedGraph { stages, residuals, carrier })
}

/// Pass 3: legalize each fused stage onto the bank multiplication
/// primitive, producing the lowered per-bank [`LayerDesc`] list.
pub fn legalize(g: &Graph, shapes: &[Shape], fused: &FusedGraph) -> Result<Vec<LayerDesc>> {
    fused
        .stages
        .iter()
        .map(|stage| {
            let node = g.node(stage.node);
            let name = &node.name;
            let in_shape = shapes[node.inputs[0].0];
            let out_shape = shapes[stage.node.0];
            let kind = match node.op {
                Op::Conv { out_ch, kh, kw, stride, pad } => match in_shape {
                    Shape::Map { h, w, c } => LayerKind::Conv {
                        in_h: h,
                        in_w: w,
                        in_ch: c,
                        out_ch,
                        kh,
                        kw,
                        stride,
                        pad,
                        groups: 1,
                    },
                    other => anyhow::bail!(
                        "stage `{name}`: conv on non-map input {other}"
                    ),
                },
                Op::DepthwiseConv { kh, kw, stride, pad } => match in_shape {
                    Shape::Map { h, w, c } => LayerKind::Conv {
                        in_h: h,
                        in_w: w,
                        in_ch: c,
                        out_ch: c,
                        kh,
                        kw,
                        stride,
                        pad,
                        groups: c,
                    },
                    other => anyhow::bail!(
                        "stage `{name}`: depthwise conv on non-map input {other}"
                    ),
                },
                Op::Linear { out_features } => match in_shape {
                    // Per-row linear on a matrix is a matmul against the
                    // resident weight operand.
                    Shape::Mat { rows, cols } => {
                        LayerKind::MatMul { m: rows, k: cols, n: out_features }
                    }
                    flat_or_map => LayerKind::Linear {
                        in_features: flat_or_map.elems(),
                        out_features,
                    },
                },
                Op::MatMul { .. } => {
                    let (m, k) = match in_shape {
                        Shape::Mat { rows, cols } => (rows, cols),
                        other => anyhow::bail!(
                            "stage `{name}`: matmul on non-matrix input {other}"
                        ),
                    };
                    let n = match out_shape {
                        Shape::Mat { cols, .. } => cols,
                        other => anyhow::bail!(
                            "stage `{name}`: matmul produced non-matrix {other}"
                        ),
                    };
                    LayerKind::MatMul { m, k, n }
                }
                _ => unreachable!("fusion only emits compute stages"),
            };
            if stage.chain.pool || stage.chain.gap {
                anyhow::ensure!(
                    matches!(kind, LayerKind::Conv { .. }),
                    "stage `{name}`: pool/global-average-pool need a spatial \
                     feature map, but the stage lowers to a {} bank op",
                    match kind {
                        LayerKind::Linear { .. } => "linear",
                        LayerKind::MatMul { .. } => "matmul",
                        LayerKind::Conv { .. } => unreachable!(),
                    }
                );
            }
            Ok(LayerDesc {
                name: name.clone(),
                kind,
                pool: stage.chain.pool,
                gap: stage.chain.gap,
                relu: stage.chain.relu,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::shape;

    #[test]
    fn sfu_nodes_fuse_into_their_producer() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 1 });
        let c = g.conv("c1", x, 8, 3, 1, 1);
        let r = g.relu("c1.relu", c);
        g.pool("c1.pool", r);
        let fused = fuse(&g).unwrap();
        assert_eq!(fused.stages.len(), 1);
        assert_eq!(
            fused.stages[0].chain,
            SfuChain { relu: true, pool: true, gap: false }
        );
        assert!(fused.residuals.is_empty());
    }

    #[test]
    fn adds_become_residual_edges() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let c0 = g.conv("c0", x, 4, 3, 1, 1);
        let c1 = g.conv("c1", c0, 4, 3, 1, 1);
        let c2 = g.conv("c2", c1, 4, 3, 1, 1);
        let a = g.add("a", c0, c2);
        g.linear("fc", a, 10);
        let fused = fuse(&g).unwrap();
        assert_eq!(fused.residuals, vec![Residual { from_layer: 0, into_layer: 2 }]);
        // The add's value is carried by the into stage; fc chains off it.
        assert_eq!(fused.carrier[a.0], Some(2));
        assert_eq!(fused.stages.len(), 4);
    }

    #[test]
    fn backwards_add_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let c0 = g.conv("c0", x, 4, 3, 1, 1);
        let c1 = g.conv("c1", c0, 4, 3, 1, 1);
        g.add("a", c1, c0); // operands swapped
        let err = fuse(&g).unwrap_err().to_string();
        assert!(err.contains("swap"), "{err}");
    }

    #[test]
    fn add_from_graph_input_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let c = g.conv("c", x, 4, 3, 1, 1);
        g.add("a", x, c);
        let err = fuse(&g).unwrap_err().to_string();
        assert!(err.contains("graph input"), "{err}");
    }

    #[test]
    fn fusion_through_multi_consumer_value_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let c0 = g.conv("c0", x, 4, 3, 1, 1);
        let r = g.relu("r", c0); // c0 also feeds the add below
        let c1 = g.conv("c1", r, 4, 3, 1, 1);
        g.add("a", c0, c1);
        let err = fuse(&g).unwrap_err().to_string();
        assert!(err.contains("consumers"), "{err}");
    }

    #[test]
    fn double_pool_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 1 });
        let c = g.conv("c", x, 8, 3, 1, 1);
        let p = g.pool("p1", c);
        g.pool("p2", p);
        let err = fuse(&g).unwrap_err().to_string();
        assert!(err.contains("already has"), "{err}");
    }

    #[test]
    fn legalization_covers_all_bank_ops() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let dw = g.depthwise("dw", x, 3, 1, 1);
        let pw = g.conv("pw", dw, 8, 1, 1, 0);
        let gp = g.global_avg_pool("gp", pw);
        g.linear("fc", gp, 10);
        let shapes = shape::infer(&g).unwrap();
        let fused = fuse(&g).unwrap();
        let layers = legalize(&g, &shapes, &fused).unwrap();
        assert_eq!(layers.len(), 3);
        assert!(matches!(
            layers[0].kind,
            LayerKind::Conv { groups: 4, in_ch: 4, out_ch: 4, .. }
        ));
        assert!(matches!(layers[1].kind, LayerKind::Conv { groups: 1, .. }));
        assert!(layers[1].gap);
        assert!(matches!(
            layers[2].kind,
            LayerKind::Linear { in_features: 8, out_features: 10 }
        ));
    }

    #[test]
    fn per_row_linear_legalizes_to_matmul() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Mat { rows: 4, cols: 16 });
        let q = g.linear("q", x, 8);
        let k = g.linear("k", x, 8);
        let s = g.matmul_t("s", q, k);
        let _ = s;
        let shapes = shape::infer(&g).unwrap();
        let fused = fuse(&g).unwrap();
        let layers = legalize(&g, &shapes, &fused).unwrap();
        assert!(matches!(layers[0].kind, LayerKind::MatMul { m: 4, k: 16, n: 8 }));
        assert!(matches!(layers[2].kind, LayerKind::MatMul { m: 4, k: 8, n: 4 }));
    }

    #[test]
    fn pool_on_matmul_stage_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Mat { rows: 4, cols: 16 });
        let q = g.linear("q", x, 8);
        let _ = q;
        // Hand-build an illegal pool over a matrix by bypassing shape
        // inference: fuse alone accepts it, legalization must reject.
        let p = g.push("p", Op::Pool, vec![q]);
        let _ = p;
        let fused = fuse(&g).unwrap();
        // Shapes for legalization: infer would fail on the pool, which is
        // the first line of defense; legalize guards stages regardless.
        let shapes = vec![
            Shape::Mat { rows: 4, cols: 16 },
            Shape::Mat { rows: 4, cols: 8 },
            Shape::Mat { rows: 4, cols: 8 },
        ];
        let err = legalize(&g, &shapes, &fused).unwrap_err().to_string();
        assert!(err.contains("feature map"), "{err}");
        assert!(shape::infer(&g).is_err(), "shape inference also rejects");
    }
}
