//! `pim::ir` — the typed operator-graph IR and its pass-based lowering
//! (DESIGN.md §IR).
//!
//! The workload model used to be a flat `Vec<LayerDesc>` with boolean
//! `pool`/`gap`/`relu` flags and a bolted-on residual side-table, which
//! structurally locked the repro to the paper's four CNNs. This module
//! replaces the *authoring* layer with a small dataflow graph:
//!
//!   * [`Graph`] — named single-output nodes ([`Node`]) with explicit
//!     value edges ([`NodeId`] operands). Residual shortcuts are ordinary
//!     [`Op::ElemwiseAdd`] nodes, not a parallel list.
//!   * [`Op`] — the operator set: `Conv`, `DepthwiseConv`, `Linear`,
//!     `MatMul`, `ElemwiseAdd`, `Pool`, `GlobalAvgPool`, `Activation`.
//!
//! Lowering ([`lower`]) runs a fixed pass pipeline:
//!
//!   1. **shape inference** ([`shape::infer`]) — per-value shapes
//!      (feature map / flat vector / matrix), producer ↔ consumer
//!      agreement, kernel/stride validity.
//!   2. **SFU fusion** ([`passes::fuse`]) — `Activation`/`Pool`/
//!      `GlobalAvgPool` nodes fold into the SFU chain of the bank stage
//!      that produces their operand (the paper's §IV-A peripheral units),
//!      and `ElemwiseAdd` nodes become reserved-bank residual edges.
//!   3. **legalization** ([`passes::legalize`]) — every compute node is
//!      rewritten onto the bank multiplication primitive: dense conv,
//!      grouped/depthwise conv, flat linear, and `m×k·k×n` matmul (which
//!      also covers per-token linear on matrix values).
//!   4. **bank-stage scheduling** ([`lower`]) — stages are emitted in
//!      topological (program) order, one bank per stage plus one reserved
//!      bank per residual edge, producing the `workloads::Network` form
//!      that `mapping`, `plan` and the pricing engine consume unchanged.
//!
//! Graphs are constructed in topological order by the builder methods
//! (an operand must already exist to be referenced), so program order
//! *is* a topological order and scheduling is deterministic — a property
//! the bitwise-equivalence bar (`tests/ir_equivalence.rs`) relies on.

pub mod lower;
pub mod passes;
pub mod shape;

pub use lower::lower;
pub use shape::{infer as infer_shapes, Shape};

/// A value id: the node that produces the value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NodeId(pub usize);

/// Pointwise activation functions the SFU chain can absorb. The SFU
/// prices every fused nonlinearity identically (one pipeline pass), so
/// the distinction is semantic, not a cost-model input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActFn {
    Relu,
    Softmax,
}

/// One operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// The graph input (exactly one per graph, no operands).
    Input { shape: Shape },
    /// Dense convolution (square or rectangular kernel).
    Conv { out_ch: usize, kh: usize, kw: usize, stride: usize, pad: usize },
    /// Depthwise convolution: one filter per channel.
    DepthwiseConv { kh: usize, kw: usize, stride: usize, pad: usize },
    /// Linear map: flat vectors map to flat vectors, matrices per-row.
    Linear { out_features: usize },
    /// Matrix product of two value operands; `transpose_rhs` contracts
    /// against the rhs rows (attention's `Q·Kᵀ`).
    MatMul { transpose_rhs: bool },
    /// Residual add: operands are `[shortcut, main]`. Lowering turns it
    /// into a reserved-bank edge; the shortcut may be shape-projected by
    /// that bank (Fig 13), so only the main operand sets the shape.
    ElemwiseAdd,
    /// The SFU pooling unit: 2×2/stride-2 max pool.
    Pool,
    /// The pooling unit in running-average mode: h×w×c → c.
    GlobalAvgPool,
    /// Pointwise activation, fused into the producer's SFU chain.
    Activation { f: ActFn },
}

impl Op {
    /// Operand count the operator requires.
    pub fn arity(&self) -> usize {
        match self {
            Op::Input { .. } => 0,
            Op::MatMul { .. } | Op::ElemwiseAdd => 2,
            _ => 1,
        }
    }

    /// Does this node become a bank stage of its own when lowered?
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Op::Conv { .. }
                | Op::DepthwiseConv { .. }
                | Op::Linear { .. }
                | Op::MatMul { .. }
        )
    }
}

/// One graph node: a named operator applied to earlier nodes' values.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
}

/// A typed operator graph. Nodes are stored in construction order, which
/// the builder keeps topological (operands must already exist).
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { name: name.to_string(), nodes: Vec::new() }
    }

    /// Append a node; `inputs` must reference existing nodes.
    pub fn push(&mut self, name: &str, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node { name: name.to_string(), op, inputs });
        id
    }

    pub fn input(&mut self, name: &str, shape: Shape) -> NodeId {
        self.push(name, Op::Input { shape }, vec![])
    }

    /// Square-kernel convolution.
    pub fn conv(
        &mut self,
        name: &str,
        src: NodeId,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> NodeId {
        self.push(name, Op::Conv { out_ch, kh: k, kw: k, stride, pad }, vec![src])
    }

    /// Square-kernel depthwise convolution.
    pub fn depthwise(
        &mut self,
        name: &str,
        src: NodeId,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> NodeId {
        self.push(name, Op::DepthwiseConv { kh: k, kw: k, stride, pad }, vec![src])
    }

    pub fn linear(&mut self, name: &str, src: NodeId, out_features: usize) -> NodeId {
        self.push(name, Op::Linear { out_features }, vec![src])
    }

    /// `lhs · rhs`.
    pub fn matmul(&mut self, name: &str, lhs: NodeId, rhs: NodeId) -> NodeId {
        self.push(name, Op::MatMul { transpose_rhs: false }, vec![lhs, rhs])
    }

    /// `lhs · rhsᵀ` (attention scores).
    pub fn matmul_t(&mut self, name: &str, lhs: NodeId, rhs: NodeId) -> NodeId {
        self.push(name, Op::MatMul { transpose_rhs: true }, vec![lhs, rhs])
    }

    /// Residual add of `shortcut` into `main` (the later stage).
    pub fn add(&mut self, name: &str, shortcut: NodeId, main: NodeId) -> NodeId {
        self.push(name, Op::ElemwiseAdd, vec![shortcut, main])
    }

    pub fn relu(&mut self, name: &str, src: NodeId) -> NodeId {
        self.push(name, Op::Activation { f: ActFn::Relu }, vec![src])
    }

    pub fn softmax(&mut self, name: &str, src: NodeId) -> NodeId {
        self.push(name, Op::Activation { f: ActFn::Softmax }, vec![src])
    }

    pub fn pool(&mut self, name: &str, src: NodeId) -> NodeId {
        self.push(name, Op::Pool, vec![src])
    }

    pub fn global_avg_pool(&mut self, name: &str, src: NodeId) -> NodeId {
        self.push(name, Op::GlobalAvgPool, vec![src])
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    /// How many nodes read each node's value.
    pub fn consumer_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nodes.len()];
        for node in &self.nodes {
            for id in &node.inputs {
                counts[id.0] += 1;
            }
        }
        counts
    }

    /// Structural validation: non-empty, unique names, exactly one input
    /// node, correct arities, operands strictly earlier (topological
    /// program order), at least one compute node.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "graph needs a non-empty name");
        anyhow::ensure!(
            !self.nodes.is_empty(),
            "graph `{}` has no nodes",
            self.name
        );
        let mut seen = std::collections::BTreeSet::new();
        let mut inputs = 0usize;
        let mut computes = 0usize;
        for (i, node) in self.nodes.iter().enumerate() {
            anyhow::ensure!(
                !node.name.is_empty(),
                "graph `{}`: node {i} needs a non-empty name",
                self.name
            );
            anyhow::ensure!(
                seen.insert(node.name.as_str()),
                "graph `{}`: duplicate node name `{}`",
                self.name,
                node.name
            );
            anyhow::ensure!(
                node.inputs.len() == node.op.arity(),
                "graph `{}`: node `{}` takes {} operand(s), got {}",
                self.name,
                node.name,
                node.op.arity(),
                node.inputs.len()
            );
            for id in &node.inputs {
                anyhow::ensure!(
                    id.0 < i,
                    "graph `{}`: node `{}` reads a later/undefined value \
                     (nodes must be declared before use)",
                    self.name,
                    node.name
                );
            }
            match node.op {
                Op::Input { .. } => inputs += 1,
                op if op.is_compute() => computes += 1,
                _ => {}
            }
        }
        anyhow::ensure!(
            inputs == 1,
            "graph `{}` needs exactly one input node, found {inputs}",
            self.name
        );
        anyhow::ensure!(
            computes >= 1,
            "graph `{}` needs at least one compute node (conv/depthwise/\
             linear/matmul)",
            self.name
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_keeps_topological_order() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 1 });
        let c = g.conv("c", x, 8, 3, 1, 1);
        let r = g.relu("r", c);
        g.linear("fc", r, 10);
        g.validate().unwrap();
        assert_eq!(g.node(c).inputs, vec![x]);
        assert_eq!(g.consumer_counts(), vec![1, 1, 1, 0]);
    }

    #[test]
    fn validation_catches_structural_errors() {
        // Duplicate name.
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Flat { n: 8 });
        g.linear("fc", x, 8);
        g.linear("fc", x, 8);
        assert!(g.validate().unwrap_err().to_string().contains("duplicate"));

        // No input node.
        let mut g = Graph::new("t");
        g.push("fc", Op::Linear { out_features: 8 }, vec![]);
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("operand"), "{err}");

        // Two input nodes.
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Flat { n: 8 });
        g.input("y", Shape::Flat { n: 8 });
        g.linear("fc", x, 8);
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("exactly one input"), "{err}");

        // No compute node.
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 1 });
        g.pool("p", x);
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("compute"), "{err}");
    }

    #[test]
    fn forward_reference_rejected() {
        let mut g = Graph::new("t");
        let x = g.input("x", Shape::Flat { n: 8 });
        g.push("fc", Op::Linear { out_features: 8 }, vec![NodeId(5)]);
        let _ = x;
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("before use"), "{err}");
    }
}
