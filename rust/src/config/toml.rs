//! Minimal TOML-subset parser (serde/toml unavailable offline).
//!
//! Supports what the experiment configs need: `[section]` headers,
//! `key = value` with integer, float, boolean, string and flat-array
//! values, `#` comments, and blank lines. Keys are namespaced as
//! `section.key` in the resulting map.

use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    IntArray(Vec<i64>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int_array(&self) -> Option<&[i64]> {
        match self {
            Value::IntArray(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed config: flat map of `section.key` → value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Toml {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.into() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?;
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err("expected `key = value`"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            entries.insert(full_key, value);
        }
        Ok(Toml { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let items = inner
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| t.parse::<i64>().map_err(|_| format!("bad int `{t}`")))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::IntArray(items));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig16"
[dram]
cols = 4096        # per subarray
aap_scale = 1.5
wide_bus = true
[map]
ks = [1, 2, 4]
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = Toml::parse(SAMPLE).unwrap();
        assert_eq!(t.get_str("name", ""), "fig16");
        assert_eq!(t.get_usize("dram.cols", 0), 4096);
        assert_eq!(t.get_f64("dram.aap_scale", 0.0), 1.5);
        assert!(t.get_bool("dram.wide_bus", false));
        assert_eq!(
            t.get("map.ks").unwrap().as_int_array().unwrap(),
            &[1, 2, 4]
        );
    }

    #[test]
    fn defaults_on_missing() {
        let t = Toml::parse("").unwrap();
        assert_eq!(t.get_usize("nope", 7), 7);
        assert_eq!(t.get_str("nope", "d"), "d");
    }

    #[test]
    fn comments_inside_strings_kept() {
        let t = Toml::parse("k = \"a#b\"").unwrap();
        assert_eq!(t.get_str("k", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Toml::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err2 = Toml::parse("[unterminated\n").unwrap_err();
        assert_eq!(err2.line, 1);
        assert!(Toml::parse("k = [1, x]").is_err());
        assert!(Toml::parse("k = \"open").is_err());
    }

    #[test]
    fn int_parses_before_float() {
        let t = Toml::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(t.get("a"), Some(&Value::Int(3)));
        assert_eq!(t.get("b"), Some(&Value::Float(3.5)));
        // Ints coerce to float on request.
        assert_eq!(t.get_f64("a", 0.0), 3.0);
    }
}
