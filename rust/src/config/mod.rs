//! Configuration system (DESIGN.md S16): a TOML-subset parser plus the
//! typed experiment configuration the CLI and benches consume.
//!
//! A config file looks like:
//!
//! ```toml
//! preset = "paper_favorable"   # or "conservative"
//! network = "vgg16"
//! n_bits = 8
//! shard = "replicate"          # or "layersplit" / "hybrid:<replicas>"
//!
//! [map]
//! ks = [1, 1, 1, 1]            # per-layer parallelism (or single value)
//!
//! [dram]
//! channels = 1
//! ranks_per_channel = 4
//! subarrays_per_bank = 32
//! cols = 4096
//! internal_bus_bits = 64
//!
//! [arch]
//! adder_inputs = 4096
//! tree_per_subarray = false
//! ```
//!
//! Every key is optional; unspecified keys inherit from the preset.
//!
//! Since the `api` redesign this module is a thin shim: the TOML keys
//! deserialize into an [`crate::api::Spec`] (`Spec::from_toml`) and
//! resolve through [`crate::api::Job`], so the TOML path and every other
//! front door share one validation and resolution sequence. Prefer
//! `api::Job::from_toml` in new code; [`load_experiment`] remains for
//! callers that want the flattened [`Experiment`] view.

pub mod toml;

use crate::sim::SimConfig;
use crate::workloads::Network;

pub use toml::{Toml, TomlError, Value};

/// A fully-resolved experiment configuration.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub network: Network,
    pub sim: SimConfig,
    /// Batch of images for makespan reporting.
    pub images: usize,
}

/// Resolve an experiment from config text. Deprecated-style shim: parses
/// and validates through `api::Spec`/`api::Job` — key names, defaults and
/// error behavior are unchanged from the pre-`api` loader.
pub fn load_experiment(text: &str) -> anyhow::Result<Experiment> {
    let spec = crate::api::Spec::from_toml(text)?;
    let images = spec.images;
    let job = crate::api::Job::new(spec)?;
    Ok(Experiment {
        network: job.network().clone(),
        sim: job.config().clone(),
        images,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_resolve() {
        let e = load_experiment("").unwrap();
        assert_eq!(e.network.name, "pimnet");
        assert_eq!(e.sim.n_bits, 8);
        assert!(e.sim.tree_per_subarray); // paper_favorable default
    }

    #[test]
    fn preset_and_overrides() {
        let e = load_experiment(
            "preset = \"conservative\"\nnetwork = \"alexnet\"\nn_bits = 4\n\
             [map]\nks = [2]\n[arch]\nadder_inputs = 1024\n",
        )
        .unwrap();
        assert_eq!(e.network.name, "alexnet");
        assert_eq!(e.sim.n_bits, 4);
        assert_eq!(e.sim.ks, vec![2]);
        assert_eq!(e.sim.adder_inputs, 1024);
        assert!(!e.sim.tree_per_subarray);
    }

    #[test]
    fn per_layer_ks_length_checked() {
        let err = load_experiment(
            "network = \"alexnet\"\n[map]\nks = [1, 2]\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("map.ks"));
    }

    #[test]
    fn bad_preset_rejected() {
        assert!(load_experiment("preset = \"nope\"").is_err());
    }

    #[test]
    fn geometry_validated() {
        let err =
            load_experiment("[dram]\nrows = 4\n").unwrap_err();
        assert!(err.to_string().contains("rows"));
    }

    #[test]
    fn experiment_simulates() {
        let e = load_experiment("network = \"pimnet\"").unwrap();
        let r = crate::sim::simulate(&e.network, &e.sim).unwrap();
        assert!(r.throughput_ips() > 0.0);
    }

    #[test]
    fn scaleout_keys_resolve() {
        let e = load_experiment(
            "network = \"pimnet\"\npreset = \"conservative\"\n\
             shard = \"layersplit\"\n\
             [dram]\nchannels = 2\nranks_per_channel = 2\n",
        )
        .unwrap();
        assert_eq!(e.sim.geometry.channels, 2);
        assert_eq!(e.sim.geometry.ranks_per_channel, 2);
        assert_eq!(e.sim.shard, crate::plan::ShardPolicy::LayerSplit);
        let r = crate::sim::simulate(&e.network, &e.sim).unwrap();
        assert_eq!(r.replicas(), 1);
        assert_eq!(r.scale_out.devices.len(), 2);
        assert!(r.scale_out.hop_ns_total > 0.0);
    }

    #[test]
    fn bad_shard_rejected() {
        assert!(load_experiment("shard = \"diagonal\"").is_err());
    }
}
