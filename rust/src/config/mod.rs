//! Configuration system (DESIGN.md S16): a TOML-subset parser plus the
//! typed experiment configuration the CLI and benches consume.
//!
//! A config file looks like:
//!
//! ```toml
//! preset = "paper_favorable"   # or "conservative"
//! network = "vgg16"
//! n_bits = 8
//! shard = "replicate"          # or "layersplit" / "hybrid:<replicas>"
//!
//! [map]
//! ks = [1, 1, 1, 1]            # per-layer parallelism (or single value)
//!
//! [dram]
//! channels = 1
//! ranks_per_channel = 4
//! subarrays_per_bank = 32
//! cols = 4096
//! internal_bus_bits = 64
//!
//! [arch]
//! adder_inputs = 4096
//! tree_per_subarray = false
//! ```
//!
//! Every key is optional; unspecified keys inherit from the preset.

pub mod toml;

use crate::sim::SimConfig;
use crate::workloads::{nets, Network};

pub use toml::{Toml, TomlError, Value};

/// A fully-resolved experiment configuration.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub network: Network,
    pub sim: SimConfig,
    /// Batch of images for makespan reporting.
    pub images: usize,
}

/// Resolve an experiment from config text.
pub fn load_experiment(text: &str) -> anyhow::Result<Experiment> {
    let t = Toml::parse(text)?;
    let preset = t.get_str("preset", "paper_favorable");
    let n_bits = t.get_usize("n_bits", 8);
    let mut sim = match preset {
        "paper_favorable" => SimConfig::paper_favorable(n_bits),
        "conservative" => SimConfig::conservative(n_bits),
        other => anyhow::bail!("unknown preset `{other}`"),
    };

    let network = nets::by_name(t.get_str("network", "pimnet"))?;

    if let Some(ks) = t.get("map.ks").and_then(Value::as_int_array) {
        anyhow::ensure!(
            ks.len() == 1 || ks.len() == network.layers.len(),
            "map.ks must have 1 or {} entries, got {}",
            network.layers.len(),
            ks.len()
        );
        sim.ks = ks.iter().map(|&v| v.max(1) as usize).collect();
    }

    if let Some(s) = t.get("shard").and_then(Value::as_str) {
        sim.shard = crate::plan::ShardPolicy::parse(s)?;
    }
    sim.geometry.channels = t.get_usize("dram.channels", sim.geometry.channels);
    sim.geometry.ranks_per_channel =
        t.get_usize("dram.ranks_per_channel", sim.geometry.ranks_per_channel);
    sim.geometry.subarrays_per_bank =
        t.get_usize("dram.subarrays_per_bank", sim.geometry.subarrays_per_bank);
    sim.geometry.cols = t.get_usize("dram.cols", sim.geometry.cols);
    sim.geometry.rows = t.get_usize("dram.rows", sim.geometry.rows);
    sim.timing.internal_bus_bits =
        t.get_usize("dram.internal_bus_bits", sim.timing.internal_bus_bits);
    sim.adder_inputs = t.get_usize("arch.adder_inputs", sim.adder_inputs);
    sim.tree_per_subarray =
        t.get_bool("arch.tree_per_subarray", sim.tree_per_subarray);
    sim.geometry.validate()?;
    anyhow::ensure!(
        sim.adder_inputs.is_power_of_two(),
        "arch.adder_inputs must be a power of two"
    );

    Ok(Experiment {
        network,
        sim,
        images: t.get_usize("images", 64),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_resolve() {
        let e = load_experiment("").unwrap();
        assert_eq!(e.network.name, "pimnet");
        assert_eq!(e.sim.n_bits, 8);
        assert!(e.sim.tree_per_subarray); // paper_favorable default
    }

    #[test]
    fn preset_and_overrides() {
        let e = load_experiment(
            "preset = \"conservative\"\nnetwork = \"alexnet\"\nn_bits = 4\n\
             [map]\nks = [2]\n[arch]\nadder_inputs = 1024\n",
        )
        .unwrap();
        assert_eq!(e.network.name, "alexnet");
        assert_eq!(e.sim.n_bits, 4);
        assert_eq!(e.sim.ks, vec![2]);
        assert_eq!(e.sim.adder_inputs, 1024);
        assert!(!e.sim.tree_per_subarray);
    }

    #[test]
    fn per_layer_ks_length_checked() {
        let err = load_experiment(
            "network = \"alexnet\"\n[map]\nks = [1, 2]\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("map.ks"));
    }

    #[test]
    fn bad_preset_rejected() {
        assert!(load_experiment("preset = \"nope\"").is_err());
    }

    #[test]
    fn geometry_validated() {
        let err =
            load_experiment("[dram]\nrows = 4\n").unwrap_err();
        assert!(err.to_string().contains("rows"));
    }

    #[test]
    fn experiment_simulates() {
        let e = load_experiment("network = \"pimnet\"").unwrap();
        let r = crate::sim::simulate(&e.network, &e.sim).unwrap();
        assert!(r.throughput_ips() > 0.0);
    }

    #[test]
    fn scaleout_keys_resolve() {
        let e = load_experiment(
            "network = \"pimnet\"\npreset = \"conservative\"\n\
             shard = \"layersplit\"\n\
             [dram]\nchannels = 2\nranks_per_channel = 2\n",
        )
        .unwrap();
        assert_eq!(e.sim.geometry.channels, 2);
        assert_eq!(e.sim.geometry.ranks_per_channel, 2);
        assert_eq!(e.sim.shard, crate::plan::ShardPolicy::LayerSplit);
        let r = crate::sim::simulate(&e.network, &e.sim).unwrap();
        assert_eq!(r.replicas(), 1);
        assert_eq!(r.scale_out.devices.len(), 2);
        assert!(r.scale_out.hop_ns_total > 0.0);
    }

    #[test]
    fn bad_shard_rejected() {
        assert!(load_experiment("shard = \"diagonal\"").is_err());
    }
}
