//! The builtin networks, authored as `pim::ir` operator graphs: the
//! paper's evaluation CNNs (AlexNet, VGG-16, ResNet-18 — §V-B), PimNet
//! (the runnable AOT workload), and two post-paper generality workloads —
//! `mobilenet_mini` (depthwise-separable CNN) and `tinyformer` (a
//! transformer block: MatMul attention + MLP + residual edges).
//!
//! Every builtin is a graph builder (`*_graph()`) plus a lowered-form
//! shim (`alexnet()` etc. — `ir::lower` applied to the graph). The four
//! paper networks lower to **exactly** the flat layer chains the
//! pre-IR constructors built (`tests/ir_equivalence.rs` holds the whole
//! pricing stack to bitwise identity against them).
//!
//! Modeling notes (DESIGN.md §2/§IR): pooling is the SFU pooling unit,
//! i.e. 2×2/stride-2 with floor division on odd dims (AlexNet's
//! overlapping 3×3/s2 pools produce the same output dims); ResNet-18's
//! downsample 1×1 convs are folded into the residual edges their
//! reserved banks execute — in the graph form this is the documented
//! shortcut-operand exemption of `ir::shape`. Softmax in `tinyformer`
//! fuses into the SFU chain like any pointwise activation (one pipeline
//! pass — `ir::ActFn`).

use crate::ir::{Graph, NodeId, Shape};

use super::Network;

/// conv → relu (→ pool) — the standard CNN block, matching the flat
/// `LayerDesc::conv` constructor's always-on ReLU.
#[allow(clippy::too_many_arguments)]
fn conv_block(
    g: &mut Graph,
    src: NodeId,
    name: &str,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
    pool: bool,
) -> NodeId {
    let c = g.conv(name, src, out_ch, k, stride, pad);
    let r = g.relu(&format!("{name}.relu"), c);
    if pool {
        g.pool(&format!("{name}.pool"), r)
    } else {
        r
    }
}

/// depthwise conv → relu.
fn dw_block(g: &mut Graph, src: NodeId, name: &str, k: usize, stride: usize, pad: usize) -> NodeId {
    let c = g.depthwise(name, src, k, stride, pad);
    g.relu(&format!("{name}.relu"), c)
}

/// linear (→ relu).
fn linear_block(g: &mut Graph, src: NodeId, name: &str, out: usize, relu: bool) -> NodeId {
    let l = g.linear(name, src, out);
    if relu {
        g.relu(&format!("{name}.relu"), l)
    } else {
        l
    }
}

/// AlexNet (227×227×3 input), 8 bank stages — the paper's P-vector length.
pub fn alexnet_graph() -> Graph {
    let mut g = Graph::new("alexnet");
    let x = g.input("input", Shape::Map { h: 227, w: 227, c: 3 });
    let mut v = conv_block(&mut g, x, "conv1", 96, 11, 4, 0, true);
    v = conv_block(&mut g, v, "conv2", 256, 5, 1, 2, true);
    v = conv_block(&mut g, v, "conv3", 384, 3, 1, 1, false);
    v = conv_block(&mut g, v, "conv4", 384, 3, 1, 1, false);
    v = conv_block(&mut g, v, "conv5", 256, 3, 1, 1, true);
    v = linear_block(&mut g, v, "fc6", 4096, true);
    v = linear_block(&mut g, v, "fc7", 4096, true);
    linear_block(&mut g, v, "fc8", 1000, false);
    g
}

/// VGG-16 (224×224×3 input), 16 bank stages.
pub fn vgg16_graph() -> Graph {
    let mut g = Graph::new("vgg16");
    let x = g.input("input", Shape::Map { h: 224, w: 224, c: 3 });
    let mut v = x;
    for (name, out_ch, pool) in [
        ("conv1_1", 64usize, false),
        ("conv1_2", 64, true),
        ("conv2_1", 128, false),
        ("conv2_2", 128, true),
        ("conv3_1", 256, false),
        ("conv3_2", 256, false),
        ("conv3_3", 256, true),
        ("conv4_1", 512, false),
        ("conv4_2", 512, false),
        ("conv4_3", 512, true),
        ("conv5_1", 512, false),
        ("conv5_2", 512, false),
        ("conv5_3", 512, true),
    ] {
        v = conv_block(&mut g, v, name, out_ch, 3, 1, 1, pool);
    }
    v = linear_block(&mut g, v, "fc6", 4096, true);
    v = linear_block(&mut g, v, "fc7", 4096, true);
    linear_block(&mut g, v, "fc8", 1000, false);
    g
}

/// ResNet-18 (224×224×3 input): stem + 16 block convs + classifier head.
/// Residual shortcuts are ordinary `add` nodes (Fig 13 dataflow); each
/// lowers to a reserved-bank edge `from 2b into 2b+2`.
pub fn resnet18_graph() -> Graph {
    let mut g = Graph::new("resnet18");
    let x = g.input("input", Shape::Map { h: 224, w: 224, c: 3 });
    let mut v = conv_block(&mut g, x, "conv1", 64, 7, 2, 3, true);
    let stages: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
    for (si, &(ch, stride1)) in stages.iter().enumerate() {
        for block in 0..2 {
            let s = if block == 0 { stride1 } else { 1 };
            let c1 = conv_block(
                &mut g,
                v,
                &format!("l{}b{}c1", si + 1, block + 1),
                ch,
                3,
                s,
                1,
                false,
            );
            let mut c2 = conv_block(
                &mut g,
                c1,
                &format!("l{}b{}c2", si + 1, block + 1),
                ch,
                3,
                1,
                1,
                false,
            );
            // The classifier reads the global average pool of the last
            // block; the GAP fuses into l4b2c2's SFU chain, so the final
            // shortcut adds 512-vector values in its reserved bank.
            if si == 3 && block == 1 {
                c2 = g.global_avg_pool("l4b2c2.gap", c2);
            }
            v = g.add(&format!("l{}b{}add", si + 1, block + 1), v, c2);
        }
    }
    g.linear("fc", v, 1000);
    g
}

/// PimNet: the small quantized CNN the AOT artifacts implement
/// (python/compile/model.py LAYER_DEFS — must stay in sync).
pub fn pimnet_graph() -> Graph {
    let mut g = Graph::new("pimnet");
    let x = g.input("input", Shape::Map { h: 16, w: 16, c: 1 });
    let mut v = conv_block(&mut g, x, "conv1", 16, 3, 1, 1, true);
    v = conv_block(&mut g, v, "conv2", 32, 3, 1, 1, true);
    v = linear_block(&mut g, v, "fc1", 128, true);
    linear_block(&mut g, v, "fc2", 10, false);
    g
}

/// MobileNet-style depthwise-separable CNN (32×32×3 input): stem conv,
/// three depthwise + pointwise pairs, GAP head. Exists to prove the IR's
/// depthwise legalization end-to-end (grouped bank op, `mac_size = K·L`).
pub fn mobilenet_mini_graph() -> Graph {
    let mut g = Graph::new("mobilenet_mini");
    let x = g.input("input", Shape::Map { h: 32, w: 32, c: 3 });
    let mut v = conv_block(&mut g, x, "conv1", 16, 3, 1, 1, true);
    v = dw_block(&mut g, v, "dw1", 3, 1, 1);
    v = conv_block(&mut g, v, "pw1", 32, 1, 1, 0, true);
    v = dw_block(&mut g, v, "dw2", 3, 1, 1);
    v = conv_block(&mut g, v, "pw2", 64, 1, 1, 0, true);
    v = dw_block(&mut g, v, "dw3", 3, 1, 1);
    v = conv_block(&mut g, v, "pw3", 128, 1, 1, 0, false);
    let p = g.global_avg_pool("pw3.gap", v);
    g.linear("fc", p, 10);
    g
}

/// A small transformer block over 16 tokens × 64 features: single-head
/// MatMul attention (`Q·Kᵀ` softmax, `scores·V`), a 4× MLP, and two
/// residual edges. Exists to prove MatMul legalization and graph-edge
/// residuals end-to-end.
pub fn tinyformer_graph() -> Graph {
    let (s, d, f) = (16usize, 64usize, 256usize);
    let mut g = Graph::new("tinyformer");
    let x = g.input("tokens", Shape::Mat { rows: s, cols: d });
    let embed = g.linear("embed", x, d);
    let q = g.linear("q", embed, d);
    let k = g.linear("k", embed, d);
    let v = g.linear("v", embed, d);
    let scores = g.matmul_t("scores", q, k);
    let sm = g.softmax("scores.softmax", scores);
    let ctx = g.matmul("attn", sm, v);
    let proj = g.linear("proj", ctx, d);
    let r1 = g.add("attn.res", embed, proj);
    let m1 = g.linear("mlp1", r1, f);
    let m1r = g.relu("mlp1.relu", m1);
    let m2 = g.linear("mlp2", m1r, d);
    g.add("mlp.res", r1, m2);
    g
}

/// Builtin registry (paper order, the AOT workload, then the generality
/// workloads) — the single place to add a network: `NAMES`, `by_name`,
/// `graph_by_name`, the `api` spec layer and the generated CLI help all
/// derive from this table.
const BUILTINS: [(&str, fn() -> Graph); 6] = [
    ("alexnet", alexnet_graph),
    ("vgg16", vgg16_graph),
    ("resnet18", resnet18_graph),
    ("pimnet", pimnet_graph),
    ("mobilenet_mini", mobilenet_mini_graph),
    ("tinyformer", tinyformer_graph),
];

/// Builtin names `by_name` accepts, in registry order.
pub const NAMES: [&str; 6] = [
    BUILTINS[0].0,
    BUILTINS[1].0,
    BUILTINS[2].0,
    BUILTINS[3].0,
    BUILTINS[4].0,
    BUILTINS[5].0,
];

/// Lower a builtin's graph; builtin graphs are constructed valid, so a
/// lowering failure is a bug in the builder, not user input.
fn lower_builtin(g: &Graph) -> Network {
    crate::ir::lower(g).expect("builtin graph lowers")
}

/// AlexNet, lowered.
pub fn alexnet() -> Network {
    lower_builtin(&alexnet_graph())
}

/// VGG-16, lowered.
pub fn vgg16() -> Network {
    lower_builtin(&vgg16_graph())
}

/// ResNet-18, lowered.
pub fn resnet18() -> Network {
    lower_builtin(&resnet18_graph())
}

/// PimNet, lowered.
pub fn pimnet() -> Network {
    lower_builtin(&pimnet_graph())
}

/// MobileNet-mini, lowered.
pub fn mobilenet_mini() -> Network {
    lower_builtin(&mobilenet_mini_graph())
}

/// Tinyformer, lowered.
pub fn tinyformer() -> Network {
    lower_builtin(&tinyformer_graph())
}

/// The paper's evaluation networks (§V-B), paper order — the Fig 16/17
/// subjects.
pub fn paper_networks() -> Vec<Network> {
    vec![alexnet(), vgg16(), resnet18()]
}

/// Every evaluation workload: the paper trio plus the generality
/// workloads (PimNet stays the AOT driver's network, as before).
pub fn all_networks() -> Vec<Network> {
    vec![alexnet(), vgg16(), resnet18(), mobilenet_mini(), tinyformer()]
}

/// Look up a builtin's operator graph by name.
pub fn graph_by_name(name: &str) -> anyhow::Result<Graph> {
    BUILTINS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, build)| build())
        .ok_or_else(|| {
            anyhow::anyhow!("unknown network `{name}` (try {})", NAMES.join("|"))
        })
}

/// Look up a network by name (CLI entry point), lowered through the IR.
pub fn by_name(name: &str) -> anyhow::Result<Network> {
    graph_by_name(name).map(|g| lower_builtin(&g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{infer_shapes, Shape};
    use crate::workloads::LayerKind;

    fn builtin_graphs() -> Vec<Graph> {
        NAMES.iter().map(|n| graph_by_name(n).unwrap()).collect()
    }

    #[test]
    fn all_chains_validate() {
        for name in NAMES {
            let net = by_name(name).unwrap();
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }

    #[test]
    fn layer_counts() {
        assert_eq!(alexnet().num_layers(), 8);
        assert_eq!(vgg16().num_layers(), 16);
        assert_eq!(resnet18().num_layers(), 18);
        assert_eq!(pimnet().num_layers(), 4);
        assert_eq!(mobilenet_mini().num_layers(), 8);
        assert_eq!(tinyformer().num_layers(), 9);
    }

    /// The satellite shape-inference bar: walk every builtin graph,
    /// infer every edge's shape (inference itself rejects any
    /// producer/consumer disagreement), and cross-check the **lowered**
    /// `LayerDesc` geometry against the inferred shapes. The two sides
    /// are computed independently — `LayerDesc` arithmetic (pool halving,
    /// GAP collapse, matmul dims) vs the IR's per-node inference — so a
    /// bug in either is caught. This is what retires hand-typed shape
    /// tables (the old ResNet stage list).
    #[test]
    fn every_builtin_edge_shape_agrees() {
        for g in builtin_graphs() {
            let shapes = infer_shapes(&g)
                .unwrap_or_else(|e| panic!("{}: {e:#}", g.name));
            let net = crate::ir::lower(&g).unwrap();
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
            let fused = crate::ir::passes::fuse(&g).unwrap();
            for (si, (stage, layer)) in
                fused.stages.iter().zip(&net.layers).enumerate()
            {
                // Input side: the stage's operand shape must be exactly
                // the geometry the bank op was legalized with.
                let in_shape = shapes[g.node(stage.node).inputs[0].0];
                match layer.kind {
                    LayerKind::Conv { in_h, in_w, in_ch, .. } => assert_eq!(
                        in_shape,
                        Shape::Map { h: in_h, w: in_w, c: in_ch },
                        "{}: stage `{}` input geometry",
                        g.name,
                        layer.name
                    ),
                    LayerKind::Linear { in_features, .. } => assert_eq!(
                        in_features,
                        in_shape.elems(),
                        "{}: stage `{}` in_features",
                        g.name,
                        layer.name
                    ),
                    LayerKind::MatMul { m, k, .. } => assert_eq!(
                        in_shape,
                        Shape::Mat { rows: m, cols: k },
                        "{}: stage `{}` streaming operand",
                        g.name,
                        layer.name
                    ),
                }
                // Output side: the value the stage's bank ships (after
                // its fused SFU chain) must have the element count the
                // lowered descriptor prices transfers with.
                let out_node = (0..g.nodes.len())
                    .filter(|&i| fused.carrier[i] == Some(si))
                    .max()
                    .expect("every stage carries at least its compute node");
                assert_eq!(
                    layer.out_elems(),
                    shapes[out_node].elems(),
                    "{}: stage `{}` output elems",
                    g.name,
                    layer.name
                );
            }
        }
    }

    #[test]
    fn alexnet_known_shapes() {
        let net = alexnet();
        assert_eq!(net.layers[0].conv_out_hw(), Some((55, 55)));
        assert_eq!(net.layers[0].out_elems(), 27 * 27 * 96);
        assert_eq!(net.layers[4].out_elems(), 9216);
        assert_eq!(net.layers[1].mac_size(), 5 * 5 * 96);
    }

    #[test]
    fn flop_totals_match_published_ballpark() {
        // Canonical figures: AlexNet ≈ 1.4 GFLOP (2.3 G ungrouped — we
        // model conv2/4/5 without their 2-way grouping, as the mapping
        // treats them), VGG16 ≈ 31 GFLOP, ResNet18 ≈ 3.6 GFLOP.
        let a = alexnet().total_flops() as f64;
        assert!((1.0e9..2.5e9).contains(&a), "alexnet {a}");
        let v = vgg16().total_flops() as f64;
        assert!((2.5e10..3.5e10).contains(&v), "vgg16 {v}");
        let r = resnet18().total_flops() as f64;
        assert!((2.5e9..4.5e9).contains(&r), "resnet18 {r}");
    }

    #[test]
    fn vgg_weights_match_ballpark() {
        // VGG16 ≈ 138 M parameters.
        let w = vgg16().total_weights() as f64;
        assert!((1.3e8..1.45e8).contains(&w), "vgg16 weights {w}");
    }

    #[test]
    fn resnet_residual_edges() {
        let net = resnet18();
        assert_eq!(net.residuals.len(), 8);
        for (b, r) in net.residuals.iter().enumerate() {
            assert_eq!(r.from_layer, 2 * b, "block {b}");
            assert_eq!(r.into_layer, 2 * b + 2, "block {b}");
        }
    }

    #[test]
    fn resnet_gap_feeds_classifier() {
        let net = resnet18();
        let n = net.layers.len();
        assert!(net.layers[n - 2].gap);
        assert_eq!(net.layers[n - 2].out_elems(), 512);
    }

    #[test]
    fn pimnet_matches_manifest_geometry() {
        // Cross-checked against artifacts/manifest.json by the runtime
        // tests; here just the static invariants.
        let net = pimnet();
        assert_eq!(net.layers[0].mac_size(), 9);
        assert_eq!(net.layers[1].mac_size(), 144);
        assert_eq!(net.layers[2].mac_size(), 512);
        assert_eq!(net.layers[3].mac_size(), 128);
    }

    #[test]
    fn mobilenet_depthwise_legalizes_to_grouped_banks() {
        let net = mobilenet_mini();
        let dw = net.layers.iter().find(|l| l.name == "dw2").unwrap();
        assert!(matches!(
            dw.kind,
            LayerKind::Conv { groups: 32, in_ch: 32, out_ch: 32, .. }
        ));
        // Depthwise MACs contract over the kernel window only.
        assert_eq!(dw.mac_size(), 9);
        assert_eq!(dw.weight_elems(), 9 * 32);
        // The pointwise conv stays dense.
        let pw = net.layers.iter().find(|l| l.name == "pw2").unwrap();
        assert_eq!(pw.mac_size(), 32);
        net.validate().unwrap();
    }

    #[test]
    fn tinyformer_attention_legalizes_to_matmuls() {
        let net = tinyformer();
        assert_eq!(net.residuals.len(), 2);
        let scores = net.layers.iter().find(|l| l.name == "scores").unwrap();
        assert!(matches!(scores.kind, LayerKind::MatMul { m: 16, k: 64, n: 16 }));
        assert!(scores.relu, "softmax fuses into the SFU chain");
        let attn = net.layers.iter().find(|l| l.name == "attn").unwrap();
        assert!(matches!(attn.kind, LayerKind::MatMul { m: 16, k: 16, n: 64 }));
        // Per-token linears legalize to matmuls against resident weights.
        let mlp1 = net.layers.iter().find(|l| l.name == "mlp1").unwrap();
        assert!(matches!(mlp1.kind, LayerKind::MatMul { m: 16, k: 64, n: 256 }));
        // Residuals land on the proj and mlp2 stages.
        assert_eq!(net.residuals[0].into_layer, 6);
        assert_eq!(net.residuals[1].from_layer, 6);
        assert_eq!(net.residuals[1].into_layer, 8);
        net.validate().unwrap();
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("vgg16").is_ok());
        assert!(by_name("nope").is_err());
        assert!(graph_by_name("tinyformer").is_ok());
    }

    #[test]
    fn every_registered_name_resolves_to_itself() {
        for name in NAMES {
            assert_eq!(by_name(name).unwrap().name, name);
            assert_eq!(graph_by_name(name).unwrap().name, name);
        }
    }

    #[test]
    fn memory_bound_fc_layers() {
        // Fig 1's premise: FC layers sit far left on the roofline.
        let net = vgg16();
        let fc = net.layers.iter().find(|l| l.name == "fc6").unwrap();
        let conv = net.layers.iter().find(|l| l.name == "conv3_2").unwrap();
        assert!(fc.op_intensity(4) < 1.0, "fc6 OI {}", fc.op_intensity(4));
        assert!(conv.op_intensity(4) > 10.0, "conv OI {}", conv.op_intensity(4));
    }
}
