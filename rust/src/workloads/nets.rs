//! The evaluation networks (§V-B): AlexNet, VGG-16, ResNet-18 — plus
//! PimNet, the runnable AOT workload.
//!
//! Modeling notes (DESIGN.md §2): pooling is the SFU pooling unit, i.e.
//! 2×2/stride-2 with floor division on odd dims (AlexNet's overlapping
//! 3×3/s2 pools produce the same output dims); ResNet-18's downsample 1×1
//! convs are folded into the residual edges their reserved banks execute.

use super::{LayerDesc, Network, Residual};

/// AlexNet (227×227×3 input), 8 layers — the paper's P-vector length.
pub fn alexnet() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1", (227, 227), 3, 96, 11, 4, 0, true),
        LayerDesc::conv("conv2", (27, 27), 96, 256, 5, 1, 2, true),
        LayerDesc::conv("conv3", (13, 13), 256, 384, 3, 1, 1, false),
        LayerDesc::conv("conv4", (13, 13), 384, 384, 3, 1, 1, false),
        LayerDesc::conv("conv5", (13, 13), 384, 256, 3, 1, 1, true),
        LayerDesc::linear("fc6", 9216, 4096, true),
        LayerDesc::linear("fc7", 4096, 4096, true),
        LayerDesc::linear("fc8", 4096, 1000, false),
    ];
    Network { name: "alexnet".into(), layers, residuals: vec![] }
}

/// VGG-16 (224×224×3 input), 16 layers.
pub fn vgg16() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1_1", (224, 224), 3, 64, 3, 1, 1, false),
        LayerDesc::conv("conv1_2", (224, 224), 64, 64, 3, 1, 1, true),
        LayerDesc::conv("conv2_1", (112, 112), 64, 128, 3, 1, 1, false),
        LayerDesc::conv("conv2_2", (112, 112), 128, 128, 3, 1, 1, true),
        LayerDesc::conv("conv3_1", (56, 56), 128, 256, 3, 1, 1, false),
        LayerDesc::conv("conv3_2", (56, 56), 256, 256, 3, 1, 1, false),
        LayerDesc::conv("conv3_3", (56, 56), 256, 256, 3, 1, 1, true),
        LayerDesc::conv("conv4_1", (28, 28), 256, 512, 3, 1, 1, false),
        LayerDesc::conv("conv4_2", (28, 28), 512, 512, 3, 1, 1, false),
        LayerDesc::conv("conv4_3", (28, 28), 512, 512, 3, 1, 1, true),
        LayerDesc::conv("conv5_1", (14, 14), 512, 512, 3, 1, 1, false),
        LayerDesc::conv("conv5_2", (14, 14), 512, 512, 3, 1, 1, false),
        LayerDesc::conv("conv5_3", (14, 14), 512, 512, 3, 1, 1, true),
        LayerDesc::linear("fc6", 25088, 4096, true),
        LayerDesc::linear("fc7", 4096, 4096, true),
        LayerDesc::linear("fc8", 4096, 1000, false),
    ];
    Network { name: "vgg16".into(), layers, residuals: vec![] }
}

/// ResNet-18 (224×224×3 input): stem + 16 block convs + classifier head,
/// residual edges per basic block (Fig 13 dataflow).
pub fn resnet18() -> Network {
    let mut layers = vec![LayerDesc::conv("conv1", (224, 224), 3, 64, 7, 2, 3, true)];
    let stages: [(usize, usize, usize); 4] = [
        // (spatial in, channels, first-conv stride)
        (56, 64, 1),
        (56, 128, 2),
        (28, 256, 2),
        (14, 512, 2),
    ];
    let mut in_ch = 64;
    for (si, &(hw, ch, stride1)) in stages.iter().enumerate() {
        for block in 0..2 {
            let (s, ic, dim) = if block == 0 {
                (stride1, in_ch, hw)
            } else {
                (1, ch, hw / stride1)
            };
            let out_dim = dim / s;
            layers.push(LayerDesc::conv(
                &format!("l{}b{}c1", si + 1, block + 1),
                (dim, dim),
                ic,
                ch,
                3,
                s,
                1,
                false,
            ));
            layers.push(LayerDesc::conv(
                &format!("l{}b{}c2", si + 1, block + 1),
                (out_dim, out_dim),
                ch,
                ch,
                3,
                1,
                1,
                false,
            ));
        }
        in_ch = ch;
    }
    // Global average pool feeds the classifier.
    let last = layers.len() - 1;
    layers[last] = layers[last].clone().with_gap();
    layers.push(LayerDesc::linear("fc", 512, 1000, false));

    // Residual edges: every basic block adds its input to its output.
    let residuals = (0..8)
        .map(|b| Residual { from_layer: 2 * b, into_layer: 2 * b + 2 })
        .collect();
    Network { name: "resnet18".into(), layers, residuals }
}

/// PimNet: the small quantized CNN the AOT artifacts implement
/// (python/compile/model.py LAYER_DEFS — must stay in sync).
pub fn pimnet() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1", (16, 16), 1, 16, 3, 1, 1, true),
        LayerDesc::conv("conv2", (8, 8), 16, 32, 3, 1, 1, true),
        LayerDesc::linear("fc1", 512, 128, true),
        LayerDesc::linear("fc2", 128, 10, false),
    ];
    Network { name: "pimnet".into(), layers, residuals: vec![] }
}

/// All evaluation networks, paper order.
pub fn all_networks() -> Vec<Network> {
    vec![alexnet(), vgg16(), resnet18()]
}

/// Builtin registry (paper order, then the AOT workload) — the single
/// place to add a network: `NAMES`, `by_name`, the `api` spec layer and
/// the generated CLI help all derive from this table.
const BUILTINS: [(&str, fn() -> Network); 4] = [
    ("alexnet", alexnet),
    ("vgg16", vgg16),
    ("resnet18", resnet18),
    ("pimnet", pimnet),
];

/// Builtin names `by_name` accepts, in registry order.
pub const NAMES: [&str; 4] =
    [BUILTINS[0].0, BUILTINS[1].0, BUILTINS[2].0, BUILTINS[3].0];

/// Look up a network by name (CLI entry point).
pub fn by_name(name: &str) -> anyhow::Result<Network> {
    BUILTINS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, build)| build())
        .ok_or_else(|| {
            anyhow::anyhow!("unknown network `{name}` (try {})", NAMES.join("|"))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_chains_validate() {
        for net in [alexnet(), vgg16(), resnet18(), pimnet()] {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }

    #[test]
    fn layer_counts() {
        assert_eq!(alexnet().num_layers(), 8);
        assert_eq!(vgg16().num_layers(), 16);
        assert_eq!(resnet18().num_layers(), 18);
        assert_eq!(pimnet().num_layers(), 4);
    }

    #[test]
    fn alexnet_known_shapes() {
        let net = alexnet();
        assert_eq!(net.layers[0].conv_out_hw(), Some((55, 55)));
        assert_eq!(net.layers[0].out_elems(), 27 * 27 * 96);
        assert_eq!(net.layers[4].out_elems(), 9216);
        assert_eq!(net.layers[1].mac_size(), 5 * 5 * 96);
    }

    #[test]
    fn flop_totals_match_published_ballpark() {
        // Canonical figures: AlexNet ≈ 1.4 GFLOP (2.3 G ungrouped — we
        // model conv2/4/5 without their 2-way grouping, as the mapping
        // treats them), VGG16 ≈ 31 GFLOP, ResNet18 ≈ 3.6 GFLOP.
        let a = alexnet().total_flops() as f64;
        assert!((1.0e9..2.5e9).contains(&a), "alexnet {a}");
        let v = vgg16().total_flops() as f64;
        assert!((2.5e10..3.5e10).contains(&v), "vgg16 {v}");
        let r = resnet18().total_flops() as f64;
        assert!((2.5e9..4.5e9).contains(&r), "resnet18 {r}");
    }

    #[test]
    fn vgg_weights_match_ballpark() {
        // VGG16 ≈ 138 M parameters.
        let w = vgg16().total_weights() as f64;
        assert!((1.3e8..1.45e8).contains(&w), "vgg16 weights {w}");
    }

    #[test]
    fn resnet_residual_edges() {
        let net = resnet18();
        assert_eq!(net.residuals.len(), 8);
        for r in &net.residuals {
            assert!(r.into_layer < net.layers.len());
        }
    }

    #[test]
    fn resnet_gap_feeds_classifier() {
        let net = resnet18();
        let n = net.layers.len();
        assert!(net.layers[n - 2].gap);
        assert_eq!(net.layers[n - 2].out_elems(), 512);
    }

    #[test]
    fn pimnet_matches_manifest_geometry() {
        // Cross-checked against artifacts/manifest.json by the runtime
        // tests; here just the static invariants.
        let net = pimnet();
        assert_eq!(net.layers[0].mac_size(), 9);
        assert_eq!(net.layers[1].mac_size(), 144);
        assert_eq!(net.layers[2].mac_size(), 512);
        assert_eq!(net.layers[3].mac_size(), 128);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("vgg16").is_ok());
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn every_registered_name_resolves_to_itself() {
        for name in NAMES {
            assert_eq!(by_name(name).unwrap().name, name);
        }
    }

    #[test]
    fn memory_bound_fc_layers() {
        // Fig 1's premise: FC layers sit far left on the roofline.
        let net = vgg16();
        let fc = net.layers.iter().find(|l| l.name == "fc6").unwrap();
        let conv = net.layers.iter().find(|l| l.name == "conv3_2").unwrap();
        assert!(fc.op_intensity(4) < 1.0, "fc6 OI {}", fc.op_intensity(4));
        assert!(conv.op_intensity(4) > 10.0, "conv OI {}", conv.op_intensity(4));
    }
}
