//! Workload zoo (DESIGN.md S12): the **lowered per-bank stage form** every
//! network reaches through `crate::ir` — an ordered chain of
//! [`LayerDesc`] bank stages plus [`Residual`] reserved-bank edges — and
//! the builtin networks (the paper's AlexNet/VGG16/ResNet18, PimNet, and
//! the post-paper generality workloads `mobilenet_mini`/`tinyformer`).
//!
//! Networks are *authored* as typed operator graphs (`ir::Graph`) and
//! lowered by the `ir` pass pipeline; this module keeps the lowered form
//! and its constructors as thin shims. Only *shapes* matter for the
//! timing experiments. Every descriptor knows its MAC geometry
//! (`mac_size`, `num_macs`), FLOPs and byte traffic — the quantities the
//! mapper, the PIM simulator, and the GPU roofline baseline all consume.
//!
//! Three bank-op kinds exist after `ir` legalization:
//!   * [`LayerKind::Conv`] — (optionally grouped) convolution; a
//!     depthwise conv is the `groups == in_ch == out_ch` special case.
//!   * [`LayerKind::Linear`] — fully-connected over a flat vector.
//!   * [`LayerKind::MatMul`] — `m×k · k×n` with the `k×n` operand
//!     resident in the bank (attention scores/context, per-token linear).

pub mod nets;

pub use nets::{
    all_networks, alexnet, mobilenet_mini, paper_networks, pimnet, resnet18,
    tinyformer, vgg16,
};

/// One network layer (a PIM bank's worth of work).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
    /// 2×2/stride-2 max-pool after the layer's SFU chain.
    pub pool: bool,
    /// Global average pool before the next (linear) layer — the pooling
    /// unit in running-average mode (ResNet head).
    pub gap: bool,
    /// ReLU in the SFU chain.
    pub relu: bool,
}

/// Layer geometry.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    Conv {
        in_h: usize,
        in_w: usize,
        in_ch: usize,
        out_ch: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        /// Channel groups: each output channel reads `in_ch / groups`
        /// input channels. 1 = dense conv; `groups == in_ch == out_ch` =
        /// depthwise.
        groups: usize,
    },
    Linear { in_features: usize, out_features: usize },
    /// `m×k · k×n` matrix product on the bank multiplication primitive:
    /// the `k×n` operand sits resident in the bank (it is "the weights"
    /// for footprint purposes, even when it is an activation such as the
    /// attention keys), the `m×k` operand streams through.
    MatMul { m: usize, k: usize, n: usize },
}

impl LayerDesc {
    pub fn conv(
        name: &str,
        in_hw: (usize, usize),
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
        pool: bool,
    ) -> Self {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Conv {
                in_h: in_hw.0,
                in_w: in_hw.1,
                in_ch,
                out_ch,
                kh: k,
                kw: k,
                stride,
                pad,
                groups: 1,
            },
            pool,
            gap: false,
            relu: true,
        }
    }

    /// Depthwise convolution: one `k×k` filter per channel
    /// (`groups == in_ch == out_ch`), the MobileNet building block.
    pub fn depthwise(
        name: &str,
        in_hw: (usize, usize),
        ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
        pool: bool,
    ) -> Self {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Conv {
                in_h: in_hw.0,
                in_w: in_hw.1,
                in_ch: ch,
                out_ch: ch,
                kh: k,
                kw: k,
                stride,
                pad,
                groups: ch,
            },
            pool,
            gap: false,
            relu: true,
        }
    }

    /// `m×k · k×n` matrix product with the `k×n` operand bank-resident.
    pub fn matmul(name: &str, m: usize, k: usize, n: usize, relu: bool) -> Self {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::MatMul { m, k, n },
            pool: false,
            gap: false,
            relu,
        }
    }

    pub fn linear(name: &str, in_features: usize, out_features: usize, relu: bool) -> Self {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Linear { in_features, out_features },
            pool: false,
            gap: false,
            relu,
        }
    }

    /// Mark this layer as ending with a global average pool.
    pub fn with_gap(mut self) -> Self {
        self.gap = true;
        self
    }

    /// Output spatial dims for conv layers (pre-pool): the paper's
    /// `((H-K+2p)/s + 1, (W-L+2p)/s + 1)`. The padding is added before
    /// the kernel is subtracted so a kernel larger than the *unpadded*
    /// input (legal when padding compensates, e.g. H=4, K=5, p=1) does
    /// not underflow `usize`; `api::spec` validates `H + 2p >= K` before
    /// any inline network reaches this.
    pub fn conv_out_hw(&self) -> Option<(usize, usize)> {
        match self.kind {
            LayerKind::Conv { in_h, in_w, kh, kw, stride, pad, .. } => Some((
                (in_h + 2 * pad - kh) / stride + 1,
                (in_w + 2 * pad - kw) / stride + 1,
            )),
            LayerKind::Linear { .. } | LayerKind::MatMul { .. } => None,
        }
    }

    /// Multiplications per MAC (§IV-B: `K·L·I/G` for (grouped) conv,
    /// fan-in for linear, the contraction length for matmul).
    pub fn mac_size(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_ch, kh, kw, groups, .. } => kh * kw * (in_ch / groups),
            LayerKind::Linear { in_features, .. } => in_features,
            LayerKind::MatMul { k, .. } => k,
        }
    }

    /// Number of MACs (dot products) in the layer:
    /// conv → `No_of_MAC · no_output_filter`; linear → output neurons;
    /// matmul → output elements.
    pub fn num_macs(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => {
                let (oh, ow) = self.conv_out_hw().unwrap();
                oh * ow * out_ch
            }
            LayerKind::Linear { out_features, .. } => out_features,
            LayerKind::MatMul { m, n, .. } => m * n,
        }
    }

    /// Output element count (post-pool if pooled; channels only after GAP).
    pub fn out_elems(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => {
                if self.gap {
                    return out_ch;
                }
                let (oh, ow) = self.conv_out_hw().unwrap();
                if self.pool {
                    (oh / 2) * (ow / 2) * out_ch
                } else {
                    oh * ow * out_ch
                }
            }
            LayerKind::Linear { out_features, .. } => out_features,
            LayerKind::MatMul { m, n, .. } => m * n,
        }
    }

    /// Input element count (the streaming operand for matmul).
    pub fn in_elems(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_h, in_w, in_ch, .. } => in_h * in_w * in_ch,
            LayerKind::Linear { in_features, .. } => in_features,
            LayerKind::MatMul { m, k, .. } => m * k,
        }
    }

    /// Weight count (the bank-resident operand for matmul).
    pub fn weight_elems(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_ch, out_ch, kh, kw, groups, .. } => {
                kh * kw * (in_ch / groups) * out_ch
            }
            LayerKind::Linear { in_features, out_features } => {
                in_features * out_features
            }
            LayerKind::MatMul { k, n, .. } => k * n,
        }
    }

    /// Multiply-accumulate FLOPs (2 per MAC-mult) for one input.
    pub fn flops(&self) -> u64 {
        2 * self.num_macs() as u64 * self.mac_size() as u64
    }

    /// Byte traffic for one input at `bytes_per_elem` (weights + in + out),
    /// the denominator of the roofline's operational intensity.
    pub fn bytes(&self, bytes_per_elem: usize) -> u64 {
        ((self.weight_elems() + self.in_elems() + self.out_elems())
            * bytes_per_elem) as u64
    }

    /// Operational intensity in FLOP/byte.
    pub fn op_intensity(&self, bytes_per_elem: usize) -> f64 {
        self.flops() as f64 / self.bytes(bytes_per_elem) as f64
    }
}

/// A residual (shortcut) connection: output of `from_layer` is added to the
/// output of `into_layer` (§IV-B residual dataflow, Fig 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Residual {
    pub from_layer: usize,
    pub into_layer: usize,
}

/// A whole network: ordered layers + residual edges.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<LayerDesc>,
    pub residuals: Vec<Residual>,
}

impl Network {
    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops()).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems() as u64).sum()
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Shape-chain validation: each layer's input must match the previous
    /// layer's output element count.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, pair) in self.layers.windows(2).enumerate() {
            let out = pair[0].out_elems();
            let inp = pair[1].in_elems();
            anyhow::ensure!(
                out == inp,
                "{}: layer {} out {} != layer {} in {}",
                self.name,
                i,
                out,
                i + 1,
                inp
            );
        }
        for r in &self.residuals {
            anyhow::ensure!(
                r.from_layer < r.into_layer && r.into_layer < self.layers.len(),
                "{}: bad residual {:?}",
                self.name,
                r
            );
        }
        Ok(())
    }
}
