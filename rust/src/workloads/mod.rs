//! Workload zoo (DESIGN.md S12): layer-shape descriptors for the paper's
//! evaluation networks (AlexNet, VGG16, ResNet18 — §V-B) plus PimNet, the
//! small quantized CNN whose AOT artifacts the end-to-end driver executes.
//!
//! Only *shapes* matter for the timing experiments; they are the public
//! architectures. Every descriptor knows its MAC geometry (`mac_size`,
//! `num_macs`), FLOPs and byte traffic — the quantities the mapper, the
//! PIM simulator, and the GPU roofline baseline all consume.

pub mod nets;

pub use nets::{alexnet, pimnet, resnet18, vgg16, all_networks};

/// One network layer (a PIM bank's worth of work).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
    /// 2×2/stride-2 max-pool after the layer's SFU chain.
    pub pool: bool,
    /// Global average pool before the next (linear) layer — the pooling
    /// unit in running-average mode (ResNet head).
    pub gap: bool,
    /// ReLU in the SFU chain.
    pub relu: bool,
}

/// Layer geometry.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    Conv {
        in_h: usize,
        in_w: usize,
        in_ch: usize,
        out_ch: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    Linear { in_features: usize, out_features: usize },
}

impl LayerDesc {
    pub fn conv(
        name: &str,
        in_hw: (usize, usize),
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
        pool: bool,
    ) -> Self {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Conv {
                in_h: in_hw.0,
                in_w: in_hw.1,
                in_ch,
                out_ch,
                kh: k,
                kw: k,
                stride,
                pad,
            },
            pool,
            gap: false,
            relu: true,
        }
    }

    pub fn linear(name: &str, in_features: usize, out_features: usize, relu: bool) -> Self {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Linear { in_features, out_features },
            pool: false,
            gap: false,
            relu,
        }
    }

    /// Mark this layer as ending with a global average pool.
    pub fn with_gap(mut self) -> Self {
        self.gap = true;
        self
    }

    /// Output spatial dims for conv layers (pre-pool): the paper's
    /// `((H-K+2p)/s + 1, (W-L+2p)/s + 1)`. The padding is added before
    /// the kernel is subtracted so a kernel larger than the *unpadded*
    /// input (legal when padding compensates, e.g. H=4, K=5, p=1) does
    /// not underflow `usize`; `api::spec` validates `H + 2p >= K` before
    /// any inline network reaches this.
    pub fn conv_out_hw(&self) -> Option<(usize, usize)> {
        match self.kind {
            LayerKind::Conv { in_h, in_w, kh, kw, stride, pad, .. } => Some((
                (in_h + 2 * pad - kh) / stride + 1,
                (in_w + 2 * pad - kw) / stride + 1,
            )),
            LayerKind::Linear { .. } => None,
        }
    }

    /// Multiplications per MAC (§IV-B: `K·L·I` for conv, fan-in for linear).
    pub fn mac_size(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_ch, kh, kw, .. } => kh * kw * in_ch,
            LayerKind::Linear { in_features, .. } => in_features,
        }
    }

    /// Number of MACs (dot products) in the layer:
    /// conv → `No_of_MAC · no_output_filter`; linear → output neurons.
    pub fn num_macs(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => {
                let (oh, ow) = self.conv_out_hw().unwrap();
                oh * ow * out_ch
            }
            LayerKind::Linear { out_features, .. } => out_features,
        }
    }

    /// Output element count (post-pool if pooled; channels only after GAP).
    pub fn out_elems(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => {
                if self.gap {
                    return out_ch;
                }
                let (oh, ow) = self.conv_out_hw().unwrap();
                if self.pool {
                    (oh / 2) * (ow / 2) * out_ch
                } else {
                    oh * ow * out_ch
                }
            }
            LayerKind::Linear { out_features, .. } => out_features,
        }
    }

    /// Input element count.
    pub fn in_elems(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_h, in_w, in_ch, .. } => in_h * in_w * in_ch,
            LayerKind::Linear { in_features, .. } => in_features,
        }
    }

    /// Weight count.
    pub fn weight_elems(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_ch, out_ch, kh, kw, .. } => kh * kw * in_ch * out_ch,
            LayerKind::Linear { in_features, out_features } => {
                in_features * out_features
            }
        }
    }

    /// Multiply-accumulate FLOPs (2 per MAC-mult) for one input.
    pub fn flops(&self) -> u64 {
        2 * self.num_macs() as u64 * self.mac_size() as u64
    }

    /// Byte traffic for one input at `bytes_per_elem` (weights + in + out),
    /// the denominator of the roofline's operational intensity.
    pub fn bytes(&self, bytes_per_elem: usize) -> u64 {
        ((self.weight_elems() + self.in_elems() + self.out_elems())
            * bytes_per_elem) as u64
    }

    /// Operational intensity in FLOP/byte.
    pub fn op_intensity(&self, bytes_per_elem: usize) -> f64 {
        self.flops() as f64 / self.bytes(bytes_per_elem) as f64
    }
}

/// A residual (shortcut) connection: output of `from_layer` is added to the
/// output of `into_layer` (§IV-B residual dataflow, Fig 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Residual {
    pub from_layer: usize,
    pub into_layer: usize,
}

/// A whole network: ordered layers + residual edges.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<LayerDesc>,
    pub residuals: Vec<Residual>,
}

impl Network {
    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops()).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems() as u64).sum()
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Shape-chain validation: each layer's input must match the previous
    /// layer's output element count.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, pair) in self.layers.windows(2).enumerate() {
            let out = pair[0].out_elems();
            let inp = pair[1].in_elems();
            anyhow::ensure!(
                out == inp,
                "{}: layer {} out {} != layer {} in {}",
                self.name,
                i,
                out,
                i + 1,
                inp
            );
        }
        for r in &self.residuals {
            anyhow::ensure!(
                r.from_layer < r.into_layer && r.into_layer < self.layers.len(),
                "{}: bad residual {:?}",
                self.name,
                r
            );
        }
        Ok(())
    }
}
