//! CLI argument parsing and subcommand implementations (clap is
//! unavailable offline — DESIGN.md S17).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::circuit::{run_monte_carlo, simulate_and, AndInputs, CircuitParams};
use crate::config;
use crate::coordinator::{MultiDeviceServer, Policy, PoolConfig, SimBackend};
use crate::gpu::{roofline::roofline_points, GpuModel};
use crate::mapping::{map_network, MapConfig};
use crate::plan::ShardPolicy;
use crate::sim::{simulate, SimConfig, SimSession};
use crate::util::rng::Rng;
use crate::util::si;
use crate::util::table::{Align, Table};
use crate::workloads::nets;

/// Parsed command line: subcommand, positionals, `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                args.flags.insert(key.to_string(), val);
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn flag_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got `{v}`")),
        }
    }
}

pub const USAGE: &str = "\
pim-dram — PIM-DRAM system simulator + coordinator (paper reproduction)

USAGE: pim-dram <COMMAND> [flags]

COMMANDS:
  simulate   Run the PIM timing simulator on a network
             --network <alexnet|vgg16|resnet18|pimnet>  --bits <n>  --k <k>
             --preset <paper_favorable|conservative>
             --channels <c>  --ranks <r>  --shard <replicate|layersplit|hybrid:<n>>
  map        Print the Algorithm-1 mapping for a network (same flags)
  optimize   Plan the per-layer parallelism vector (mapping optimizer)
             --network <name>  --bits <n>  --preset <...>  --balanced
  roofline   Fig 1: Titan Xp roofline for a network  --network <name>
  circuit    Fig 14/15: AND transient + Monte Carlo  --samples <n>
  tables     Tables I/II: bank peripheral area & power
  config     Run an experiment from a TOML file: pim-dram config <file>
  serve      Serve batched classification from a multi-device pool
             --backend <sim|pjrt>  --devices <n>  --policy <rr|least|two>
             --images <n>  --batch <b>  (+ simulate flags for sim devices;
             pjrt needs `make artifacts` and a `--features pjrt` build)
  help       Show this help
";

/// Entry point used by main.rs.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "simulate" => cmd_simulate(&args),
        "map" => cmd_map(&args),
        "optimize" => cmd_optimize(&args),
        "roofline" => cmd_roofline(&args),
        "circuit" => cmd_circuit(&args),
        "tables" => cmd_tables(),
        "config" => cmd_config(&args),
        "serve" => cmd_serve(&args),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn sim_config_from(args: &Args) -> Result<SimConfig> {
    let bits = args.flag_usize("bits", 8)?;
    let mut cfg = match args.flag("preset", "paper_favorable").as_str() {
        "paper_favorable" => SimConfig::paper_favorable(bits),
        "conservative" => SimConfig::conservative(bits),
        other => anyhow::bail!("unknown preset `{other}`"),
    };
    cfg.ks = vec![args.flag_usize("k", 1)?.max(1)];
    cfg.geometry.channels = args.flag_usize("channels", cfg.geometry.channels)?;
    cfg.geometry.ranks_per_channel =
        args.flag_usize("ranks", cfg.geometry.ranks_per_channel)?;
    if let Some(s) = args.flags.get("shard") {
        cfg.shard = ShardPolicy::parse(s)?;
    }
    Ok(cfg)
}

fn policy_from(args: &Args) -> Result<Policy> {
    match args.flag("policy", "rr").as_str() {
        "rr" | "roundrobin" => Ok(Policy::RoundRobin),
        "least" | "leastloaded" => Ok(Policy::LeastLoaded),
        "two" | "twochoices" => Ok(Policy::TwoChoices),
        other => anyhow::bail!("unknown policy `{other}` (try rr|least|two)"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let net = nets::by_name(&args.flag("network", "pimnet"))?;
    let cfg = sim_config_from(args)?;
    let r = simulate(&net, &cfg)?;
    let gpu = GpuModel::titan_xp();

    let mut t = Table::new(&[
        "layer", "k", "waves", "multiply", "logic", "restage", "transfer", "stage",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right,
    ]);
    for l in &r.layers {
        t.row(&[
            l.name.clone(),
            l.mapping.k.to_string(),
            l.mapping.waves.to_string(),
            format!("{:.1}us", l.multiply_ns / 1e3),
            format!("{:.1}us", l.logic_ns / 1e3),
            format!("{:.1}us", l.restage_ns / 1e3),
            format!("{:.1}us", l.transfer_ns / 1e3),
            format!("{:.1}us", l.stage_ns() / 1e3),
        ]);
    }
    println!("{}", t.render());
    println!(
        "latency/image: {:.3} ms   steady-state: {:.3} ms/image ({:.1} img/s per replica)",
        r.latency_ns() / 1e6,
        r.pipeline.cycle_ns / 1e6,
        r.replica_throughput_ips()
    );
    println!(
        "bottleneck stage: {}   total AAPs/image: {}   DRAM energy: {:.2} uJ",
        r.pipeline.stages[r.pipeline.bottleneck].name,
        si(r.total_aaps as f64),
        r.total_dram_energy_nj / 1e3
    );
    println!(
        "scale-out: {} → {} replica(s) × {} device(s); aggregate {:.1} img/s{}",
        r.scale_out.policy,
        r.replicas(),
        r.scale_out.devices.len(),
        r.throughput_ips(),
        if r.scale_out.hop_ns_total > 0.0 {
            format!(
                " (inter-channel hops: {:.1} us/img)",
                r.scale_out.hop_ns_total / 1e3
            )
        } else {
            String::new()
        }
    );
    println!(
        "ideal-GPU ({}) time: {:.3} ms  →  PIM speedup: {:.2}x",
        gpu.name,
        gpu.network_time_s(&net, 4) * 1e3,
        r.speedup_vs(&gpu, &net, 4)
    );
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    let net = nets::by_name(&args.flag("network", "pimnet"))?;
    let cfg = sim_config_from(args)?;
    let mc = MapConfig {
        geometry: cfg.geometry.clone(),
        n_bits: cfg.n_bits,
        ks: cfg.ks.clone(),
    };
    let m = map_network(&net, &mc)?;
    let mut t = Table::new(&[
        "layer", "mac_size", "macs", "k", "sub/grp(ideal)", "sub(used)", "waves",
        "util%", "footprint",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right, Align::Right,
    ]);
    for l in &m.layers {
        t.row(&[
            l.name.clone(),
            l.mac_size.to_string(),
            l.macs_total.to_string(),
            l.k.to_string(),
            l.subarrays_ideal.to_string(),
            l.subarrays_used.to_string(),
            l.waves.to_string(),
            format!("{:.1}", l.utilization * 100.0),
            format!("{}b", si(l.footprint_bits as f64)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "banks: {} (+{} residual reserves), mean utilization {:.1}%, resident: {}",
        m.layers.len(),
        m.residual_banks,
        m.mean_utilization() * 100.0,
        m.fully_resident()
    );
    // Device lowering across the channel × rank grid.
    let plan = crate::plan::lower(&net, &mc, cfg.shard)?;
    println!(
        "plan ({}): {} replica(s), {} device(s) on {} channel(s) × {} rank(s)",
        plan.policy,
        plan.replicas,
        plan.devices.len(),
        plan.geometry.channels,
        plan.geometry.ranks_per_channel
    );
    for d in plan.chain(0) {
        let dev = &plan.devices[*d];
        println!(
            "  device {}: channel {}, ranks {}..{}, layers {}..{} \
             (+{} residual reserves, {} banks)",
            dev.id,
            dev.channel,
            dev.ranks.start,
            dev.ranks.end,
            dev.shard.layers.start,
            dev.shard.layers.end,
            dev.shard.residuals.len(),
            dev.banks_used
        );
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    use crate::mapping::optimizer::{plan_ks, Objective};
    let net = nets::by_name(&args.flag("network", "pimnet"))?;
    let cfg = sim_config_from(args)?;
    let objective = if args.flags.contains_key("balanced") {
        Objective::Balanced
    } else {
        Objective::MinResidentK
    };
    let plan = plan_ks(&net, &cfg.geometry, cfg.n_bits, objective);

    let mut t = Table::new(&["layer", "k", "resident"])
        .aligns(&[Align::Left, Align::Right, Align::Right]);
    for (l, &k) in net.layers.iter().zip(&plan.ks) {
        t.row(&[
            l.name.clone(),
            k.to_string(),
            (!plan.overflow_layers.contains(&l.name)).to_string(),
        ]);
    }
    println!("{}", t.render());
    if !plan.overflow_layers.is_empty() {
        println!(
            "overflow (no resident k exists — weights exceed bank capacity): {:?}",
            plan.overflow_layers
        );
    }
    // Simulate the plan vs the naive k=1 vector — one incremental session,
    // so layers whose planned k stays 1 are priced once, not twice.
    let mut session = SimSession::new(&net);
    let naive = session.simulate_full(&cfg)?;
    let planned = session.simulate_full(&cfg.clone().with_ks(plan.ks.clone()))?;
    println!(
        "naive k=1: {:.3} ms/img   planned: {:.3} ms/img ({:+.1}%)",
        naive.pipeline.cycle_ns / 1e6,
        planned.pipeline.cycle_ns / 1e6,
        100.0 * (planned.pipeline.cycle_ns - naive.pipeline.cycle_ns)
            / naive.pipeline.cycle_ns
    );
    Ok(())
}

fn cmd_roofline(args: &Args) -> Result<()> {
    let net = nets::by_name(&args.flag("network", "vgg16"))?;
    let gpu = GpuModel::titan_xp();
    let mut t = Table::new(&["layer", "FLOP/byte", "attainable GF/s", "bound"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Left]);
    for p in roofline_points(&gpu, &net, 4) {
        t.row(&[
            p.layer.clone(),
            format!("{:.2}", p.op_intensity),
            format!("{:.1}", p.attainable_gflops),
            if p.memory_bound { "memory".into() } else { "compute".into() },
        ]);
    }
    println!("{}", t.render());
    println!(
        "{}: peak {} FLOP/s, BW {} B/s, ridge at {:.1} FLOP/byte",
        gpu.name,
        si(gpu.peak_flops),
        si(gpu.mem_bw),
        gpu.ridge_intensity()
    );
    Ok(())
}

fn cmd_circuit(args: &Args) -> Result<()> {
    let p = CircuitParams::cmos65nm();
    println!("== AND transients (Fig 14) ==");
    for inputs in AndInputs::all_cases() {
        let (wf, _) = simulate_and(&p, inputs, None);
        println!(
            "case ({}) -> BL={:.3}V S1={:.3}V S2={:.3}V",
            inputs.label(),
            wf.final_value("BL").unwrap(),
            wf.final_value("S1").unwrap(),
            wf.final_value("S2").unwrap()
        );
    }
    let samples = args.flag_usize("samples", 100_000)?;
    println!("\n== Monte Carlo, {samples} samples/case (Fig 15) ==");
    let mc = run_monte_carlo(&p, samples, 0xC0FFEE);
    for (inputs, s) in &mc.case_summaries {
        println!(
            "case ({}): BL mean {:.4} V  σ {:.4} V",
            inputs.label(),
            s.mean(),
            s.std()
        );
    }
    println!(
        "sense margin: {:.1} mV mean ({} failures, rate {:.2e})",
        mc.sense_margin_v * 1e3,
        mc.failures,
        mc.failure_rate()
    );
    Ok(())
}

fn cmd_tables() -> Result<()> {
    println!("TABLE I: Area Breakdown\n{}", crate::energy::render_area_table(4096));
    println!("TABLE II: Power Breakdown\n{}", crate::energy::render_power_table(4096));
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .context("usage: pim-dram config <file.toml>")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let e = config::load_experiment(&text)?;
    let r = simulate(&e.network, &e.sim)?;
    let gpu = GpuModel::titan_xp();
    println!(
        "{}: latency {:.3} ms, {:.1} img/s ({} replicas), makespan({} imgs) \
         {:.3} ms, speedup {:.2}x",
        e.network.name,
        r.latency_ns() / 1e6,
        r.throughput_ips(),
        r.replicas(),
        e.images,
        r.pipeline.makespan_ns(e.images) / 1e6,
        r.speedup_vs(&gpu, &e.network, 4)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    match args.flag("backend", "sim").as_str() {
        "sim" => cmd_serve_sim(args),
        "pjrt" => cmd_serve_pjrt(args),
        other => anyhow::bail!("unknown backend `{other}` (try sim|pjrt)"),
    }
}

/// Serve synthetic traffic from a pool of *simulated* PIM devices: each
/// worker stands in for one replica of the planned network, priced by the
/// timing model. Hermetic — no artifacts, no PJRT.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    let net = nets::by_name(&args.flag("network", "pimnet"))?;
    let cfg = sim_config_from(args)?;
    // One incremental session prices the plan summary *and* the pool
    // backend; the second derivation is a per-layer cache hit.
    let mut session = SimSession::new(&net);
    let r = session.simulate_full(&cfg)?;
    let devices = args.flag_usize("devices", r.replicas())?.max(1);
    let policy = policy_from(args)?;
    let images = args.flag_usize("images", 64)?;
    let batch = args.flag_usize("batch", 8)?.max(1);

    println!(
        "plan: {} under {} → {} replica(s); serving from {} simulated \
         device(s), policy {:?}, batch {}",
        net.name, r.scale_out.policy, r.replicas(), devices, policy, batch
    );
    let backend = SimBackend::from_session(&mut session, &cfg, batch)?;
    let server = MultiDeviceServer::start(
        PoolConfig {
            devices,
            policy,
            batch_window: std::time::Duration::from_millis(2),
        },
        move |_| Ok(backend.clone()),
    )?;

    let elems = server.image_elems();
    let clients = 4usize;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let server = &server;
        let mut handles = Vec::new();
        for t in 0..clients {
            handles.push(scope.spawn(move || -> Result<()> {
                let mut rng = Rng::new(t as u64);
                for _ in (t..images).step_by(clients) {
                    let img: Vec<i32> =
                        (0..elems).map(|_| rng.int_range(0, 255) as i32).collect();
                    server.classify(img)?;
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        Ok(())
    })?;
    let dt = t0.elapsed();

    println!(
        "{images} synthetic images in {:.1} ms ({:.0} img/s wall-clock)",
        dt.as_secs_f64() * 1e3,
        images as f64 / dt.as_secs_f64()
    );
    println!("coordinator: {}", server.metrics().report());
    println!(
        "timing model: {:.1} img/s aggregate over {} replica(s) \
         ({:.3} ms/img per replica)",
        r.throughput_ips(),
        r.replicas(),
        r.pipeline.cycle_ns / 1e6
    );
    server.shutdown();
    Ok(())
}

/// End-to-end inference over the AOT artifacts (PJRT pool).
#[cfg(feature = "pjrt")]
fn cmd_serve_pjrt(args: &Args) -> Result<()> {
    use crate::coordinator::{InferenceServer, ServerConfig};
    use crate::runtime::{artifacts_dir, ArtifactManifest, DigitsDataset};

    anyhow::ensure!(
        crate::runtime::artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    let dir = artifacts_dir();
    let manifest = ArtifactManifest::load(&dir)?;
    let ds = DigitsDataset::load(&dir, &manifest)?;
    let n = args.flag_usize("images", 64)?.min(ds.count);
    let devices = args.flag_usize("devices", 1)?.max(1);

    println!(
        "starting inference server over {} ({} device(s)) ...",
        dir.display(),
        devices
    );
    let server = InferenceServer::start(ServerConfig {
        devices,
        policy: policy_from(args)?,
        ..ServerConfig::default()
    })?;
    let mut correct = 0;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let (img, lbl) = ds.batch(i, 1);
        let resp = server.classify(img)?;
        if resp.class == lbl[0] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "{n} images in {:.1} ms ({:.1} img/s), accuracy {:.1}% \
         (quantized reference: {:.1}%)",
        dt.as_secs_f64() * 1e3,
        n as f64 / dt.as_secs_f64(),
        100.0 * correct as f64 / n as f64,
        100.0 * manifest.quant_test_accuracy
    );
    println!("{}", server.metrics().report());
    server.shutdown();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_pjrt(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "this build has no PJRT executor — rebuild with `--features pjrt` \
         (and run `make artifacts`), or use `--backend sim`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse("simulate --network vgg16 --bits 4 extra --verbose");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.flag("network", ""), "vgg16");
        assert_eq!(a.flag_usize("bits", 8).unwrap(), 4);
        assert_eq!(a.flag("verbose", "false"), "true");
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn bad_int_flag_errors() {
        let a = parse("simulate --bits abc");
        assert!(a.flag_usize("bits", 8).is_err());
    }

    #[test]
    fn subcommands_run() {
        for cmd in [
            "simulate --network pimnet",
            "simulate --network alexnet --preset conservative --bits 4 --k 2",
            "simulate --network pimnet --preset conservative --channels 2 --ranks 4",
            "simulate --network vgg16 --preset conservative --channels 2 --ranks 2 \
             --shard layersplit",
            "simulate --network alexnet --preset conservative --channels 4 \
             --shard hybrid:2",
            "map --network resnet18",
            "map --network resnet18 --preset conservative --channels 2 --shard layersplit",
            "optimize --network pimnet --preset conservative",
            "optimize --network alexnet --preset conservative --balanced",
            "roofline --network vgg16",
            "circuit --samples 2000",
            "tables",
            "serve --backend sim --network pimnet --preset conservative \
             --devices 2 --images 12 --batch 4",
            "help",
        ] {
            let v: Vec<String> = cmd.split_whitespace().map(String::from).collect();
            run(&v).unwrap_or_else(|e| panic!("`{cmd}` failed: {e:#}"));
        }
    }

    #[test]
    fn unknown_command_errors() {
        let v = vec!["frobnicate".to_string()];
        assert!(run(&v).is_err());
    }
}
