//! CLI argument parsing and subcommand implementations (clap is
//! unavailable offline — DESIGN.md S17).
//!
//! Every spec-driven subcommand resolves its flags into an [`api::Spec`]
//! (`--spec <file.json>` loads one first; individual flags override it)
//! and constructs all simulation/serving work through [`api::Job`] — the
//! per-command flag plumbing of the pre-`api` CLI is gone. Unknown flags
//! are an error that lists the accepted set, and the help text is
//! generated from the spec definitions (builtin networks, presets,
//! policies, shard forms) so it cannot drift from what the API accepts.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::api::{self, Job, ShardSpec, Spec};
use crate::circuit::{run_monte_carlo, simulate_and, AndInputs, CircuitParams};
use crate::gpu::{roofline::roofline_points, GpuModel};
use crate::mapping::{map_network, MapConfig};
use crate::util::rng::Rng;
use crate::util::si;
use crate::util::table::{Align, Table};
use crate::workloads::nets;

/// Parsed command line: subcommand, positionals, `--key value` /
/// `--key=value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

/// Flags that never take a value. Without this list `--print spec.json`
/// would swallow the path as the flag's value instead of leaving it a
/// positional.
const BOOLEAN_FLAGS: &[&str] = &["balanced", "deny-warnings", "json", "print", "report"];

impl Args {
    /// Parse `argv`. Both `--key value` and `--key=value` are accepted;
    /// a value may start with a single `-` (e.g. a negative offset). A
    /// `--key` followed by another `--flag` (or by nothing), or named in
    /// [`BOOLEAN_FLAGS`], is a boolean set to `"true"`. A repeated flag
    /// keeps its last value.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                anyhow::ensure!(!body.is_empty(), "stray `--` in arguments");
                if let Some((key, val)) = body.split_once('=') {
                    anyhow::ensure!(!key.is_empty(), "empty flag name in `{a}`");
                    args.flags.insert(key.to_string(), val.to_string());
                } else {
                    let val = match it.peek() {
                        _ if BOOLEAN_FLAGS.contains(&body) => "true".to_string(),
                        Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                        _ => "true".to_string(),
                    };
                    args.flags.insert(body.to_string(), val);
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn flag_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number, got `{v}`")),
        }
    }

    /// Error on any flag outside `accepted` — a typo'd flag must not
    /// silently fall back to its default.
    pub fn expect_flags(&self, accepted: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !accepted.contains(&key.as_str()) {
                let list = if accepted.is_empty() {
                    "this command takes no flags".to_string()
                } else {
                    format!(
                        "accepted: {}",
                        accepted
                            .iter()
                            .map(|a| format!("--{a}"))
                            .collect::<Vec<_>>()
                            .join(" ")
                    )
                };
                anyhow::bail!("unknown flag `--{key}` for `{}` ({list})", self.command);
            }
        }
        Ok(())
    }
}

/// Flags shared by every spec-driven subcommand.
const SPEC_FLAGS: &[&str] =
    &["spec", "network", "preset", "bits", "k", "channels", "ranks", "shard"];
const OPTIMIZE_FLAGS: &[&str] = &[
    "spec", "network", "preset", "bits", "k", "channels", "ranks", "shard",
    "balanced", "mapper", "beam", "budget", "json",
];
const SERVE_FLAGS: &[&str] = &[
    "spec", "network", "preset", "bits", "k", "channels", "ranks", "shard",
    "backend", "devices", "policy", "images", "batch",
    "deadline-ms", "retries", "queue-cap", "fault-seed", "transient", "load",
    "arrival", "rate", "report",
];
const SPEC_CMD_FLAGS: &[&str] = &["print"];
const CHECK_FLAGS: &[&str] = &["json", "deny-warnings"];
const ROOFLINE_FLAGS: &[&str] = &["network"];
const CIRCUIT_FLAGS: &[&str] = &["samples"];

/// Build the help text from the spec definitions so it cannot drift from
/// what `api::Spec` accepts.
pub fn usage() -> String {
    format!(
        "\
pim-dram — PIM-DRAM system simulator + coordinator (paper reproduction)

USAGE: pim-dram <COMMAND> [flags]

Spec-driven commands (simulate, map, optimize, serve) accept
  --spec <file.json>   load an api::Spec (api_version {version}); other
                       flags override it
  --network <{nets}>
  --preset <{presets}>  --bits <n>  --k <k>
  --channels <c>  --ranks <r>  --shard <{shard}>

COMMANDS:
  simulate   Run the PIM timing simulator on a network
  map        Print the Algorithm-1 mapping and the device plan
  optimize   Plan the per-layer mapping  --balanced  --json
             --mapper <paper|search>  --beam <n>  --budget <n>
             (search explores k x tiling x layout per layer and prints
             the chosen mapping; paper plans the k vector only)
  spec       Validate spec JSON files: pim-dram spec [--print] <file>...
             (--print emits the canonical form examples/specs/ uses)
  check      Static Spec→IR→Plan analysis with coded diagnostics:
             pim-dram check [--json] [--deny-warnings] <file>...
             (exit 1 on any error; --deny-warnings also fails on warnings)
  roofline   Fig 1: Titan Xp roofline for a network  --network <name>
  circuit    Fig 14/15: AND transient + Monte Carlo  --samples <n>
  tables     Tables I/II: bank peripheral area & power
  config     Run an experiment from a TOML or spec-JSON file:
             pim-dram config <file>
  serve      Serve batched classification from a multi-device pool
             --backend <sim|pjrt>  --devices <n|{presets_csv}>
             --policy <{policies}>  --images <n>  --batch <b>
             (+ spec flags for sim devices; a comma-separated --devices
             builds a heterogeneous fleet from presets; pjrt needs
             `make artifacts` and a `--features pjrt` build)
             Resilience: --deadline-ms <ms>  --retries <n>  --queue-cap <n>
             Fault injection: --fault-seed <s>  --transient <p>  --load <f>
             Open loop: --arrival <{arrivals}>  --rate <req/s>
             (submissions paced by the arrival process, never the fleet;
             prints the offered-vs-goodput open-loop report)
             --report prints the deterministic virtual-time fleet SLO
             report (bitwise-reproducible per seed) instead of serving live
  help       Show this help

Unknown flags are an error; the message lists the command's accepted set.
",
        version = api::API_VERSION,
        nets = api::BUILTIN_NETWORKS.join("|"),
        presets = api::PRESETS.join("|"),
        presets_csv = "cloud,edge,...",
        shard = api::SHARD_FORMS,
        policies = api::POLICIES.join("|"),
        arrivals = crate::coordinator::ARRIVALS.join("|"),
    )
}

/// Entry point used by main.rs.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "simulate" => {
            args.expect_flags(SPEC_FLAGS)?;
            cmd_simulate(&args)
        }
        "map" => {
            args.expect_flags(SPEC_FLAGS)?;
            cmd_map(&args)
        }
        "optimize" => {
            args.expect_flags(OPTIMIZE_FLAGS)?;
            cmd_optimize(&args)
        }
        "spec" => {
            args.expect_flags(SPEC_CMD_FLAGS)?;
            cmd_spec(&args)
        }
        "check" => {
            args.expect_flags(CHECK_FLAGS)?;
            cmd_check(&args)
        }
        "roofline" => {
            args.expect_flags(ROOFLINE_FLAGS)?;
            cmd_roofline(&args)
        }
        "circuit" => {
            args.expect_flags(CIRCUIT_FLAGS)?;
            cmd_circuit(&args)
        }
        "tables" => {
            args.expect_flags(&[])?;
            cmd_tables()
        }
        "config" => {
            args.expect_flags(&[])?;
            cmd_config(&args)
        }
        "serve" => {
            args.expect_flags(SERVE_FLAGS)?;
            cmd_serve(&args)
        }
        "help" | "" => {
            println!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown command `{other}`\n\n{}", usage()),
    }
}

/// Resolve the spec-driven flags into an [`api::Spec`]: start from
/// `--spec <file.json>` (or the default spec over `default_network`), then
/// apply individual flag overrides on top.
fn spec_from(args: &Args, default_network: &str) -> Result<Spec> {
    let mut spec = match args.flags.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?;
            Spec::from_json_text(&text)
                .map_err(|e| e.context(format!("parsing {path}")))?
        }
        None => Spec::builtin(default_network),
    };
    if let Some(name) = args.flags.get("network") {
        spec.network = api::NetworkSpec::Builtin(name.clone());
    }
    if let Some(preset) = args.flags.get("preset") {
        spec.device.preset = preset.clone();
    }
    if args.flags.contains_key("bits") {
        spec.run.precision = args.flag_usize("bits", 8)?;
    }
    if args.flags.contains_key("k") {
        spec.run.ks = vec![args.flag_usize("k", 1)?.max(1)];
    }
    if args.flags.contains_key("channels") {
        spec.device.channels = Some(args.flag_usize("channels", 1)?);
    }
    if args.flags.contains_key("ranks") {
        spec.device.ranks_per_channel = Some(args.flag_usize("ranks", 1)?);
    }
    if let Some(s) = args.flags.get("shard") {
        spec.run.shard = ShardSpec::parse(s)?;
    }
    Ok(spec)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let job = Job::new(spec_from(args, "pimnet")?)?;
    let net = job.network();
    let r = job.simulate_full()?;
    let gpu = GpuModel::titan_xp();

    let mut t = Table::new(&[
        "layer", "k", "waves", "multiply", "logic", "restage", "transfer", "stage",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right,
    ]);
    for l in &r.layers {
        t.row(&[
            l.name.clone(),
            l.mapping.k.to_string(),
            l.mapping.waves.to_string(),
            format!("{:.1}us", l.multiply_ns / 1e3),
            format!("{:.1}us", l.logic_ns / 1e3),
            format!("{:.1}us", l.restage_ns / 1e3),
            format!("{:.1}us", l.transfer_ns / 1e3),
            format!("{:.1}us", l.stage_ns() / 1e3),
        ]);
    }
    println!("{}", t.render());
    println!(
        "latency/image: {:.3} ms   steady-state: {:.3} ms/image ({:.1} img/s per replica)",
        r.latency_ns() / 1e6,
        r.pipeline.cycle_ns / 1e6,
        r.replica_throughput_ips()
    );
    println!(
        "bottleneck stage: {}   total AAPs/image: {}   DRAM energy: {:.2} uJ",
        r.pipeline.stages[r.pipeline.bottleneck].name,
        si(r.total_aaps as f64),
        r.total_dram_energy_nj / 1e3
    );
    println!(
        "scale-out: {} → {} replica(s) × {} device(s); aggregate {:.1} img/s{}",
        r.scale_out.policy,
        r.replicas(),
        r.scale_out.devices.len(),
        r.throughput_ips(),
        if r.scale_out.hop_ns_total > 0.0 {
            format!(
                " (inter-channel hops: {:.1} us/img)",
                r.scale_out.hop_ns_total / 1e3
            )
        } else {
            String::new()
        }
    );
    println!(
        "ideal-GPU ({}) time: {:.3} ms  →  PIM speedup: {:.2}x",
        gpu.name,
        gpu.network_time_s(net, 4) * 1e3,
        r.speedup_vs(&gpu, net, 4)
    );
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    let job = Job::new(spec_from(args, "pimnet")?)?;
    let net = job.network();
    let cfg = job.config();
    let mc = MapConfig {
        geometry: cfg.geometry.clone(),
        n_bits: cfg.n_bits,
        ks: cfg.ks.clone(),
    };
    let m = map_network(net, &mc)?;
    let mut t = Table::new(&[
        "layer", "mac_size", "macs", "k", "sub/grp(ideal)", "sub(used)", "waves",
        "util%", "footprint",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right, Align::Right,
    ]);
    for l in &m.layers {
        t.row(&[
            l.name.clone(),
            l.mac_size.to_string(),
            l.macs_total.to_string(),
            l.k.to_string(),
            l.subarrays_ideal.to_string(),
            l.subarrays_used.to_string(),
            l.waves.to_string(),
            format!("{:.1}", l.utilization * 100.0),
            format!("{}b", si(l.footprint_bits as f64)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "banks: {} (+{} residual reserves), mean utilization {:.1}%, resident: {}",
        m.layers.len(),
        m.residual_banks,
        m.mean_utilization() * 100.0,
        m.fully_resident()
    );
    // Device lowering across the channel × rank grid.
    let plan = crate::plan::lower(net, &mc, cfg.shard)?;
    println!(
        "plan ({}): {} replica(s), {} device(s) on {} channel(s) × {} rank(s)",
        plan.policy,
        plan.replicas,
        plan.devices.len(),
        plan.geometry.channels,
        plan.geometry.ranks_per_channel
    );
    for d in plan.chain(0) {
        let dev = &plan.devices[*d];
        println!(
            "  device {}: channel {}, ranks {}..{}, layers {}..{} \
             (+{} residual reserves, {} banks)",
            dev.id,
            dev.channel,
            dev.ranks.start,
            dev.ranks.end,
            dev.shard.layers.start,
            dev.shard.layers.end,
            dev.shard.residuals.len(),
            dev.banks_used
        );
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let mut spec = spec_from(args, "pimnet")?;
    if let Some(m) = args.flags.get("mapper") {
        spec.run.mapper = api::Mapper::parse(m)?;
    }
    if args.flags.contains_key("beam") {
        spec.run.beam = args.flag_usize("beam", spec.run.beam)?;
    }
    if args.flags.contains_key("budget") {
        spec.run.search_budget = args.flag_usize("budget", spec.run.search_budget)?;
    }
    let as_json = args.flags.contains_key("json");
    if spec.run.mapper == api::Mapper::Search {
        cmd_optimize_search(&spec, as_json)
    } else {
        cmd_optimize_paper(args, &spec, as_json)
    }
}

/// The pre-search optimizer: plan the per-layer k vector with
/// Algorithm 1's residency arithmetic and price it against the spec's
/// own ks.
fn cmd_optimize_paper(args: &Args, spec: &Spec, as_json: bool) -> Result<()> {
    use crate::mapping::optimizer::{plan_ks, Objective};
    use crate::util::json::Json;
    let job = Job::new(spec.clone())?;
    let net = job.network();
    let cfg = job.config();
    let objective = if args.flags.contains_key("balanced") {
        Objective::Balanced
    } else {
        Objective::MinResidentK
    };
    let plan = plan_ks(net, &cfg.geometry, cfg.n_bits, objective);

    // Simulate the plan vs the spec's own k vector — one incremental
    // session, so layers whose planned k is unchanged are priced once.
    let mut session = job.session();
    let naive = job.report_variant(&mut session, spec)?;
    let planned = job.report_variant(&mut session, &spec.clone().with_ks(plan.ks.clone()))?;

    if as_json {
        let layers: Vec<Json> = net
            .layers
            .iter()
            .zip(&plan.ks)
            .map(|(l, &k)| {
                let mut o = BTreeMap::new();
                o.insert("k".to_string(), Json::Num(k as f64));
                o.insert("name".to_string(), Json::Str(l.name.clone()));
                o.insert(
                    "resident".to_string(),
                    Json::Bool(!plan.overflow_layers.contains(&l.name)),
                );
                Json::Obj(o)
            })
            .collect();
        let mut cycle = BTreeMap::new();
        cycle.insert("planned".to_string(), Json::Num(planned.cycle_ns));
        cycle.insert("spec".to_string(), Json::Num(naive.cycle_ns));
        let mut o = BTreeMap::new();
        o.insert("cycle_ns".to_string(), Json::Obj(cycle));
        o.insert("layers".to_string(), Json::Arr(layers));
        o.insert("mapper".to_string(), Json::Str("paper".to_string()));
        o.insert("network".to_string(), Json::Str(net.name.clone()));
        print!("{}", Json::Obj(o).pretty());
        return Ok(());
    }

    let mut t = Table::new(&["layer", "k", "resident"])
        .aligns(&[Align::Left, Align::Right, Align::Right]);
    for (l, &k) in net.layers.iter().zip(&plan.ks) {
        t.row(&[
            l.name.clone(),
            k.to_string(),
            (!plan.overflow_layers.contains(&l.name)).to_string(),
        ]);
    }
    println!("{}", t.render());
    if !plan.overflow_layers.is_empty() {
        println!(
            "overflow (no resident k exists — weights exceed bank capacity): {:?}",
            plan.overflow_layers
        );
    }
    println!(
        "spec ks {:?}: {:.3} ms/img   planned: {:.3} ms/img ({:+.1}%)",
        spec.run.ks,
        naive.cycle_ns / 1e6,
        planned.cycle_ns / 1e6,
        100.0 * (planned.cycle_ns - naive.cycle_ns) / naive.cycle_ns
    );
    Ok(())
}

/// The `pim::mapopt` beam search: per-layer chosen mapping (k, tiling,
/// layout) plus the paper-vs-searched end-to-end comparison. `--json`
/// emits the canonical form (`Json::pretty`, byte-stable).
fn cmd_optimize_search(spec: &Spec, as_json: bool) -> Result<()> {
    use crate::mapping::DataLayout;
    use crate::util::json::Json;
    let job = Job::new(spec.clone())?;
    let out = job.search()?;
    let layout_name = |l: DataLayout| match l {
        DataLayout::Sequential => "seq",
        DataLayout::RowAligned => "row",
    };

    if as_json {
        let layers: Vec<Json> = out
            .choices
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("k".to_string(), Json::Num(c.cand.k as f64));
                o.insert(
                    "layout".to_string(),
                    Json::Str(layout_name(c.cand.layout).to_string()),
                );
                o.insert("name".to_string(), Json::Str(c.name.clone()));
                o.insert("paper_stage_ns".to_string(), Json::Num(c.paper_stage_ns));
                o.insert("resident".to_string(), Json::Bool(c.resident));
                o.insert("stage_ns".to_string(), Json::Num(c.stage_ns));
                o.insert("tile".to_string(), Json::Num(c.cand.tile as f64));
                Json::Obj(o)
            })
            .collect();
        let mut latency = BTreeMap::new();
        latency.insert("paper".to_string(), Json::Num(out.paper.latency_ns));
        latency.insert("searched".to_string(), Json::Num(out.searched.latency_ns));
        let mut o = BTreeMap::new();
        o.insert(
            "candidates_priced".to_string(),
            Json::Num(out.candidates_priced as f64),
        );
        o.insert(
            "changed_layers".to_string(),
            Json::Num(out.changed_layers() as f64),
        );
        o.insert("fell_back".to_string(), Json::Bool(out.fell_back));
        o.insert("latency_ns".to_string(), Json::Obj(latency));
        o.insert("layers".to_string(), Json::Arr(layers));
        o.insert("mapper".to_string(), Json::Str("search".to_string()));
        o.insert("network".to_string(), Json::Str(job.network().name.clone()));
        o.insert(
            "pruned_branches".to_string(),
            Json::Num(out.pruned_branches as f64),
        );
        print!("{}", Json::Obj(o).pretty());
        return Ok(());
    }

    let mut t = Table::new(&[
        "layer", "k", "tile", "layout", "resident", "paper", "chosen", "gain%",
    ])
    .aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Left, Align::Right,
        Align::Right, Align::Right, Align::Right,
    ]);
    for c in &out.choices {
        t.row(&[
            c.name.clone(),
            c.cand.k.to_string(),
            if c.cand.tile == 0 { "-".to_string() } else { c.cand.tile.to_string() },
            layout_name(c.cand.layout).to_string(),
            c.resident.to_string(),
            format!("{:.1}us", c.paper_stage_ns / 1e3),
            format!("{:.1}us", c.stage_ns / 1e3),
            format!("{:.1}", 100.0 * (c.paper_stage_ns - c.stage_ns) / c.paper_stage_ns),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper: {:.3} ms/img   searched: {:.3} ms/img ({:+.2}%) — {} of {} \
         layer(s) changed",
        out.paper.latency_ns / 1e6,
        out.searched.latency_ns / 1e6,
        100.0 * (out.searched.latency_ns - out.paper.latency_ns) / out.paper.latency_ns,
        out.changed_layers(),
        out.choices.len()
    );
    println!(
        "search: {} candidate(s) priced, {} branch(es) pruned by the lower \
         bound{}",
        out.candidates_priced,
        out.pruned_branches,
        if out.fell_back {
            " — end-to-end fallback to the paper mapping"
        } else {
            ""
        }
    );
    Ok(())
}

/// Validate spec files and show what they resolve to; `--print` emits the
/// canonical JSON form instead (regenerates `examples/specs/` content).
/// A file that fails validation prints its coded diagnostics and the
/// command exits nonzero — after every file has been processed.
fn cmd_spec(args: &Args) -> Result<()> {
    anyhow::ensure!(
        !args.positional.is_empty(),
        "usage: pim-dram spec [--print] <file.json>..."
    );
    let mut failures = 0usize;
    for path in &args.positional {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let resolved = Spec::from_json_text(&text)
            .map_err(anyhow::Error::from)
            .and_then(|spec| {
                let job = Job::new(spec.clone())?;
                Ok((spec, job))
            });
        let (spec, job) = match resolved {
            Ok(pair) => pair,
            Err(_) => {
                // Re-derive the failure as coded diagnostics (E001-E003,
                // or node-attributed IR errors for inline graphs).
                let findings = crate::analysis::check_text(&text);
                for line in findings.render_text().lines() {
                    println!("{path}: {line}");
                }
                failures += 1;
                continue;
            }
        };
        if args.flags.contains_key("print") {
            print!("{}", spec.to_json_text());
        } else {
            let cfg = job.config();
            println!(
                "{path}: ok — network {} ({} layers), preset {}, {}b, \
                 grid {}x{}, shard {}{}",
                job.network().name,
                job.network().layers.len(),
                spec.device.preset,
                cfg.n_bits,
                cfg.geometry.channels,
                cfg.geometry.ranks_per_channel,
                cfg.shard,
                if spec.serve.is_some() { ", servable" } else { "" }
            );
        }
    }
    if failures > 0 {
        anyhow::bail!(
            "{failures} of {} spec file(s) failed validation",
            args.positional.len()
        );
    }
    Ok(())
}

/// Static Spec → IR → Plan analysis (`pim::analysis`, DESIGN.md §Static
/// analysis) over one or more spec documents. Every finding carries a
/// stable code; errors — or warnings under `--deny-warnings` — fail the
/// command after all files are reported.
fn cmd_check(args: &Args) -> Result<()> {
    anyhow::ensure!(
        !args.positional.is_empty(),
        "usage: pim-dram check [--json] [--deny-warnings] <file.json>..."
    );
    let deny_warnings = args.flags.contains_key("deny-warnings");
    let as_json = args.flags.contains_key("json");
    let (mut errors, mut warnings) = (0usize, 0usize);
    let mut files = BTreeMap::new();
    for path in &args.positional {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let d = crate::analysis::check_text(&text);
        errors += d.error_count();
        warnings += d.warning_count();
        if as_json {
            files.insert(path.clone(), d.to_json());
        } else if d.is_empty() {
            println!("{path}: ok");
        } else {
            for line in d.render_text().lines() {
                println!("{path}: {line}");
            }
        }
    }
    if as_json {
        let mut o = BTreeMap::new();
        o.insert("files".to_string(), crate::util::json::Json::Obj(files));
        o.insert("errors".to_string(), crate::util::json::Json::Num(errors as f64));
        o.insert(
            "warnings".to_string(),
            crate::util::json::Json::Num(warnings as f64),
        );
        print!("{}", crate::util::json::Json::Obj(o).pretty());
    }
    if errors > 0 || (deny_warnings && warnings > 0) {
        anyhow::bail!(
            "check failed: {errors} error(s), {warnings} warning(s) across {} \
             file(s){}",
            args.positional.len(),
            if deny_warnings { " (--deny-warnings)" } else { "" }
        );
    }
    Ok(())
}

fn cmd_roofline(args: &Args) -> Result<()> {
    let net = nets::by_name(&args.flag("network", "vgg16"))?;
    let gpu = GpuModel::titan_xp();
    let mut t = Table::new(&["layer", "FLOP/byte", "attainable GF/s", "bound"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Left]);
    for p in roofline_points(&gpu, &net, 4) {
        t.row(&[
            p.layer.clone(),
            format!("{:.2}", p.op_intensity),
            format!("{:.1}", p.attainable_gflops),
            if p.memory_bound { "memory".into() } else { "compute".into() },
        ]);
    }
    println!("{}", t.render());
    println!(
        "{}: peak {} FLOP/s, BW {} B/s, ridge at {:.1} FLOP/byte",
        gpu.name,
        si(gpu.peak_flops),
        si(gpu.mem_bw),
        gpu.ridge_intensity()
    );
    Ok(())
}

fn cmd_circuit(args: &Args) -> Result<()> {
    let p = CircuitParams::cmos65nm();
    println!("== AND transients (Fig 14) ==");
    for inputs in AndInputs::all_cases() {
        let (wf, _) = simulate_and(&p, inputs, None);
        println!(
            "case ({}) -> BL={:.3}V S1={:.3}V S2={:.3}V",
            inputs.label(),
            wf.final_value("BL").unwrap(),
            wf.final_value("S1").unwrap(),
            wf.final_value("S2").unwrap()
        );
    }
    let samples = args.flag_usize("samples", 100_000)?;
    println!("\n== Monte Carlo, {samples} samples/case (Fig 15) ==");
    let mc = run_monte_carlo(&p, samples, 0xC0FFEE);
    for (inputs, s) in &mc.case_summaries {
        println!(
            "case ({}): BL mean {:.4} V  σ {:.4} V",
            inputs.label(),
            s.mean(),
            s.std()
        );
    }
    println!(
        "sense margin: {:.1} mV mean ({} failures, rate {:.2e})",
        mc.sense_margin_v * 1e3,
        mc.failures,
        mc.failure_rate()
    );
    Ok(())
}

fn cmd_tables() -> Result<()> {
    println!("TABLE I: Area Breakdown\n{}", crate::energy::render_area_table(4096));
    println!("TABLE II: Power Breakdown\n{}", crate::energy::render_power_table(4096));
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .context("usage: pim-dram config <file.toml|file.json>")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let job = if path.ends_with(".json") {
        Job::from_json_text(&text)
    } else {
        Job::from_toml(&text)
    }
    .map_err(|e| e.context(format!("resolving {path}")))?;
    let net = job.network();
    let images = job.spec().images;
    let r = job.simulate_full()?;
    let gpu = GpuModel::titan_xp();
    println!(
        "{}: latency {:.3} ms, {:.1} img/s ({} replicas), makespan({} imgs) \
         {:.3} ms, speedup {:.2}x",
        net.name,
        r.latency_ns() / 1e6,
        r.throughput_ips(),
        r.replicas(),
        images,
        r.pipeline.makespan_ns(images) / 1e6,
        r.speedup_vs(&gpu, net, 4)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    match args.flag("backend", "sim").as_str() {
        "sim" => cmd_serve_sim(args),
        "pjrt" => {
            // The artifact pool ignores the sim-device spec knobs; accepting
            // them would be exactly the silent fallback expect_flags exists
            // to prevent.
            args.expect_flags(&["backend", "devices", "policy", "images"])?;
            cmd_serve_pjrt(args)
        }
        other => anyhow::bail!("unknown backend `{other}` (try sim|pjrt)"),
    }
}

/// `--devices` accepts a worker count (`--devices 4`) or a comma-separated
/// preset list for a heterogeneous fleet (`--devices cloud,edge`), each
/// worker priced for its own geometry.
fn parse_devices(v: &str) -> Result<api::DevicesSpec> {
    if let Ok(n) = v.parse::<usize>() {
        return Ok(api::DevicesSpec::Count(n.max(1)));
    }
    let fleet: Vec<api::DeviceSpec> = v
        .split(',')
        .map(|p| api::DeviceSpec { preset: p.trim().to_string(), ..Default::default() })
        .collect();
    for dev in &fleet {
        anyhow::ensure!(
            api::PRESETS.contains(&dev.preset.as_str()),
            "--devices expects a count or comma-separated presets \
             ({}), got `{v}`",
            api::PRESETS.join("|")
        );
    }
    Ok(api::DevicesSpec::Fleet(fleet))
}

/// Serve synthetic traffic from a pool of *simulated* PIM devices via
/// `Job::serve`: each worker stands in for one replica of the planned
/// network, priced by the timing model. Hermetic — no artifacts, no PJRT.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    let mut spec = spec_from(args, "pimnet")?;
    let mut serve = spec.serve.clone().unwrap_or_default();
    if let Some(v) = args.flags.get("devices") {
        serve.devices = Some(parse_devices(v)?);
    }
    if let Some(p) = args.flags.get("policy") {
        serve.policy = api::parse_policy(p)?;
    }
    if args.flags.contains_key("batch") {
        serve.batch = args.flag_usize("batch", 8)?.max(1);
    }
    // Resilience overrides (start from the spec's section, if any).
    if args.flags.contains_key("deadline-ms")
        || args.flags.contains_key("retries")
        || args.flags.contains_key("queue-cap")
    {
        let mut r = serve.resilience.unwrap_or_default();
        if args.flags.contains_key("deadline-ms") {
            r.deadline_ms = Some(args.flag_usize("deadline-ms", 1)?.max(1) as u64);
        }
        if args.flags.contains_key("retries") {
            r.retries = args.flag_usize("retries", 0)? as u32;
        }
        if args.flags.contains_key("queue-cap") {
            r.queue_cap = args.flag_usize("queue-cap", 1024)?;
        }
        serve.resilience = Some(r);
    }
    // Fault-schedule overrides.
    if args.flags.contains_key("fault-seed") || args.flags.contains_key("transient") {
        let mut f = serve.faults.clone().unwrap_or_default();
        if args.flags.contains_key("fault-seed") {
            f.seed = args.flag_usize("fault-seed", 0)? as u64;
        }
        if args.flags.contains_key("transient") {
            f.transient = args.flag_f64("transient", 0.0)?;
        }
        serve.faults = Some(f);
    }
    if args.flags.contains_key("load") {
        serve.load = Some(args.flag_f64("load", 0.9)?);
    }
    // Open-loop arrival overrides (start from the spec's section, if any).
    if args.flags.contains_key("arrival") || args.flags.contains_key("rate") {
        let mut a = serve.arrival.clone().unwrap_or_default();
        if let Some(p) = args.flags.get("arrival") {
            a.kind = crate::coordinator::parse_arrival(p)?;
        }
        if args.flags.contains_key("rate") {
            a.rate_rps = args.flag_f64("rate", 0.0)?;
        }
        serve.arrival = Some(a);
    }
    let arrival = serve.arrival.clone();
    spec.serve = Some(serve);
    let images = args.flag_usize("images", spec.images)?;
    spec.images = images; // --images drives both live traffic and the fleet replay
    let job = Job::new(spec)?;

    // --report: the deterministic virtual-time fleet replay — same seed,
    // bitwise-identical SLO report — instead of the live thread pool.
    if args.flags.contains_key("report") {
        let fleet = job.fleet_report()?;
        print!("{}", fleet.render());
        return Ok(());
    }

    let handle = job.serve()?;

    println!(
        "plan: {} under {} → {} replica(s); serving from {} simulated \
         device(s), policy {:?}, batch {}",
        job.network().name,
        handle.report.policy,
        handle.report.replicas,
        handle.devices,
        handle.policy,
        handle.batch
    );

    // Open loop: pace submissions by the arrival schedule alone — never by
    // client backpressure — then reconcile the driver's accounting against
    // the pool's metrics (offered == completed + shed + timeouts + failed).
    if let Some(a) = &arrival {
        let interarrival = a.interarrival_ns().unwrap_or_else(|| {
            // No explicit rate: derive one from fleet capacity × load,
            // exactly like the virtual-time replay does.
            let load = job.spec().serve.as_ref().and_then(|s| s.load).unwrap_or(0.9);
            let per_image = handle.report.cycle_ns / handle.devices.max(1) as f64;
            ((per_image / load).round() as u64).max(1)
        });
        let offsets = a.schedule(images as u64, interarrival);
        let report = crate::coordinator::drive(&handle.server, &offsets, 0x5EED);
        report.reconcile(&handle.server.metrics())?;
        print!("{}", report.render());
        println!("coordinator: {}", handle.server.metrics().report());
        handle.server.shutdown();
        return Ok(());
    }

    let server = &handle.server;
    let elems = server.image_elems();
    let clients = 4usize;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for t in 0..clients {
            handles.push(scope.spawn(move || -> Result<()> {
                let mut rng = Rng::new(t as u64);
                for _ in (t..images).step_by(clients) {
                    let img: Vec<i32> =
                        (0..elems).map(|_| rng.int_range(0, 255) as i32).collect();
                    server.classify(img)?;
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        Ok(())
    })?;
    let dt = t0.elapsed();

    println!(
        "{images} synthetic images in {:.1} ms ({:.0} img/s wall-clock)",
        dt.as_secs_f64() * 1e3,
        images as f64 / dt.as_secs_f64()
    );
    println!("coordinator: {}", server.metrics().report());
    println!(
        "timing model: {:.1} img/s aggregate over {} replica(s) \
         ({:.3} ms/img per replica)",
        handle.report.throughput_ips(),
        handle.report.replicas,
        handle.report.cycle_ns / 1e6
    );
    handle.server.shutdown();
    Ok(())
}

/// End-to-end inference over the AOT artifacts (PJRT pool).
#[cfg(feature = "pjrt")]
fn cmd_serve_pjrt(args: &Args) -> Result<()> {
    use crate::coordinator::{InferenceServer, ServerConfig};
    use crate::runtime::{artifacts_dir, ArtifactManifest, DigitsDataset};

    anyhow::ensure!(
        crate::runtime::artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    let dir = artifacts_dir();
    let manifest = ArtifactManifest::load(&dir)?;
    let ds = DigitsDataset::load(&dir, &manifest)?;
    let n = args.flag_usize("images", 64)?.min(ds.count);
    let devices = args.flag_usize("devices", 1)?.max(1);

    println!(
        "starting inference server over {} ({} device(s)) ...",
        dir.display(),
        devices
    );
    let server = InferenceServer::start(ServerConfig {
        devices,
        policy: api::parse_policy(&args.flag("policy", "rr"))?,
        ..ServerConfig::default()
    })?;
    let mut correct = 0;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let (img, lbl) = ds.batch(i, 1);
        let resp = server.classify(img)?;
        if resp.class == lbl[0] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "{n} images in {:.1} ms ({:.1} img/s), accuracy {:.1}% \
         (quantized reference: {:.1}%)",
        dt.as_secs_f64() * 1e3,
        n as f64 / dt.as_secs_f64(),
        100.0 * correct as f64 / n as f64,
        100.0 * manifest.quant_test_accuracy
    );
    println!("{}", server.metrics().report());
    server.shutdown();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_pjrt(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "this build has no PJRT executor — rebuild with `--features pjrt` \
         (and run `make artifacts`), or use `--backend sim`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    fn run_str(s: &str) -> Result<()> {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        run(&v)
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = parse("simulate --network vgg16 --bits 4 extra --verbose");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.flag("network", ""), "vgg16");
        assert_eq!(a.flag_usize("bits", 8).unwrap(), 4);
        assert_eq!(a.flag("verbose", "false"), "true");
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn key_equals_value_and_dashed_values() {
        let a = parse("simulate --network=vgg16 --offset -5 --delta=-7 --flag");
        assert_eq!(a.flag("network", ""), "vgg16");
        assert_eq!(a.flag("offset", ""), "-5");
        assert_eq!(a.flag("delta", ""), "-7");
        assert_eq!(a.flag("flag", "false"), "true");
        // Last value wins on repeats; `=` can carry values with `=` in them.
        let a = parse("simulate --k 1 --k=2 --path=a=b");
        assert_eq!(a.flag("k", ""), "2");
        assert_eq!(a.flag("path", ""), "a=b");
    }

    #[test]
    fn boolean_flags_do_not_swallow_positionals() {
        let a = parse("check spec.json --deny-warnings other.json --json");
        assert_eq!(a.flag("deny-warnings", "false"), "true");
        assert_eq!(a.flag("json", "false"), "true");
        assert_eq!(a.positional, vec!["spec.json", "other.json"]);
        let a = parse("spec --print spec.json");
        assert_eq!(a.flag("print", "false"), "true");
        assert_eq!(a.positional, vec!["spec.json"]);
    }

    #[test]
    fn malformed_flags_rejected() {
        for bad in ["simulate --", "simulate --=3"] {
            let v: Vec<String> = bad.split_whitespace().map(String::from).collect();
            assert!(Args::parse(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn bad_int_flag_errors() {
        let a = parse("simulate --bits abc");
        assert!(a.flag_usize("bits", 8).is_err());
    }

    #[test]
    fn unknown_flag_is_an_error_listing_accepted() {
        let err = run_str("simulate --nework vgg16").unwrap_err().to_string();
        assert!(err.contains("--nework"), "{err}");
        assert!(err.contains("--network"), "{err}");
        let err = run_str("tables --verbose").unwrap_err().to_string();
        assert!(err.contains("no flags"), "{err}");
        // The PJRT pool ignores sim-device knobs, so they are rejected
        // up front rather than silently dropped.
        let err = run_str("serve --backend pjrt --batch 16")
            .unwrap_err()
            .to_string();
        assert!(err.contains("--batch"), "{err}");
    }

    #[test]
    fn serve_devices_flag_rejects_unknown_presets() {
        let err = run_str("serve --backend sim --devices cloud,datacenter --images 4")
            .unwrap_err()
            .to_string();
        assert!(err.contains("--devices"), "{err}");
        assert!(err.contains("edge"), "{err}");
        let err = run_str("serve --backend sim --arrival sine --images 4")
            .unwrap_err()
            .to_string();
        assert!(err.contains("poisson"), "{err}");
    }

    #[test]
    fn subcommands_run() {
        for cmd in [
            "simulate --network pimnet",
            "simulate --network alexnet --preset conservative --bits 4 --k 2",
            "simulate --network=pimnet --preset=conservative --channels 2 --ranks 4",
            "simulate --network vgg16 --preset conservative --channels 2 --ranks 2 \
             --shard layersplit",
            "simulate --network alexnet --preset conservative --channels 4 \
             --shard hybrid:2",
            "map --network resnet18",
            "map --network resnet18 --preset conservative --channels 2 --shard layersplit",
            "optimize --network pimnet --preset conservative",
            "optimize --network alexnet --preset conservative --balanced",
            "optimize --network pimnet --preset conservative --json",
            "optimize --network mobilenet_mini --preset conservative --mapper search",
            "optimize --network tinyformer --preset conservative --mapper search \
             --beam 2 --budget 16 --json",
            "roofline --network vgg16",
            "circuit --samples 2000",
            "tables",
            "serve --backend sim --network pimnet --preset conservative \
             --devices 2 --images 12 --batch 4",
            "serve --backend sim --network pimnet --preset conservative \
             --devices 2 --images 64 --batch 4 --report --fault-seed 7 \
             --transient 0.2 --retries 2 --deadline-ms 50 --load 1.2 \
             --queue-cap 32",
            "serve --backend sim --network pimnet --preset conservative \
             --devices cloud,edge --policy backlog --images 12 --batch 2",
            "serve --backend sim --network pimnet --preset conservative \
             --devices 2 --images 16 --batch 4 --arrival poisson --rate 2000",
            "serve --backend sim --network pimnet --preset conservative \
             --devices cloud,edge --policy backlog --images 64 --batch 4 \
             --arrival bursty --rate 4000 --report",
            "help",
        ] {
            run_str(cmd).unwrap_or_else(|e| panic!("`{cmd}` failed: {e:#}"));
        }
    }

    #[test]
    fn spec_files_drive_the_cli() {
        // Default (paper_favorable) preset: resident everywhere, so the
        // spec survives `check --deny-warnings` below.
        let spec = Spec::builtin("pimnet");
        let path = std::env::temp_dir()
            .join(format!("pim_cli_spec_{}.json", std::process::id()));
        std::fs::write(&path, spec.to_json_text()).unwrap();
        let p = path.display();
        run_str(&format!("spec {p}")).unwrap();
        run_str(&format!("spec --print {p}")).unwrap();
        run_str(&format!("check {p}")).unwrap();
        run_str(&format!("check --json --deny-warnings {p}")).unwrap();
        run_str(&format!("simulate --spec {p}")).unwrap();
        // Flags override the file.
        run_str(&format!("simulate --spec {p} --network alexnet --k 2")).unwrap();
        run_str(&format!("config {p}")).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spec_and_check_fail_on_bad_documents() {
        let dir = std::env::temp_dir();
        let bad = dir.join(format!("pim_cli_bad_{}.json", std::process::id()));
        std::fs::write(&bad, "{\"api_version\": 1").unwrap();
        let good = dir.join(format!("pim_cli_good_{}.json", std::process::id()));
        std::fs::write(&good, Spec::builtin("pimnet").to_json_text()).unwrap();
        let (b, g) = (bad.display(), good.display());

        // `spec` processes every file, then exits nonzero.
        let err = run_str(&format!("spec {g} {b}")).unwrap_err().to_string();
        assert!(err.contains("1 of 2"), "{err}");
        // `check` fails on errors, and --deny-warnings promotes warnings.
        assert!(run_str(&format!("check {b}")).is_err());
        run_str(&format!("check {g}")).unwrap();

        // A spec with a warning (k exceeds pimnet's head outer count)
        // passes by default and fails under --deny-warnings.
        let warn = dir.join(format!("pim_cli_warn_{}.json", std::process::id()));
        let spec = Spec::builtin("pimnet").with_preset("conservative").with_ks(vec![64]);
        std::fs::write(&warn, spec.to_json_text()).unwrap();
        let w = warn.display();
        run_str(&format!("check {w}")).unwrap();
        let err = run_str(&format!("check --deny-warnings {w}"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--deny-warnings"), "{err}");

        for f in [bad, good, warn] {
            std::fs::remove_file(f).ok();
        }
    }

    #[test]
    fn unknown_command_errors() {
        let v = vec!["frobnicate".to_string()];
        assert!(run(&v).is_err());
    }
}
