//! Mapping optimizer: choose the per-layer parallelism vector.
//!
//! §V-B: "Our simulator maps the workload layers to the DRAM based on
//! layer size to optimize performance." The printed Algorithm 1 takes k as
//! an input; this module closes the loop — for each layer it picks the
//! smallest k (most parallelism) whose operand expansion fits the bank's
//! residency budget, optionally balancing the pipeline so no single bank
//! dominates the initiation interval.

use crate::dram::DramGeometry;
use crate::workloads::{LayerDesc, Network};

use super::{map_layer, outer_count, MapConfig};

/// Optimization objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Max parallelism that stays resident (no waves, no restaging).
    MinResidentK,
    /// Balance stage times: allow folding fat layers further as long as the
    /// pipeline bottleneck does not move (saves footprint for free).
    Balanced,
}

/// The chosen per-layer parallelism plan.
#[derive(Debug, Clone, PartialEq)]
pub struct KPlan {
    pub ks: Vec<usize>,
    /// Layers that cannot be made resident at any k ≤ outer (their weights
    /// exceed bank capacity; they will pay waves/restaging regardless).
    pub overflow_layers: Vec<String>,
}

/// Smallest k at which `layer` is fully resident, or None if no k works.
pub fn min_resident_k(
    layer: &LayerDesc,
    geometry: &DramGeometry,
    n_bits: usize,
) -> Option<usize> {
    let mut probe = MapConfig::uniform(geometry.clone(), n_bits, 1);
    min_resident_k_with(&mut probe, layer)
}

/// [`min_resident_k`] over a caller-owned probe config: the binary search
/// only rewrites `probe.ks[0]` between probes instead of re-cloning the
/// geometry for every `fits(k)` evaluation — [`plan_ks`] shares one probe
/// across all layers and probes.
fn min_resident_k_with(probe: &mut MapConfig, layer: &LayerDesc) -> Option<usize> {
    let outer = outer_count(layer);
    let max_pairs = probe.geometry.pairs_per_column(probe.n_bits).max(1);
    // fits(k) is monotone in k → binary search the boundary.
    let mut fits = |k: usize| -> bool {
        probe.ks[0] = k;
        match map_layer(0, 0, layer, probe) {
            Ok(m) => m.fully_resident(),
            Err(_) => false,
        }
    };
    let hi_limit = outer.min(max_pairs);
    if fits(1) {
        return Some(1);
    }
    if !fits(hi_limit) {
        return None;
    }
    let (mut lo, mut hi) = (1usize, hi_limit); // lo fails, hi fits
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Rough per-layer cost proxy used for balancing: sequential rounds ×
/// multiply cost dominates, so rounds(k) = k × waves(k) works.
fn rounds_at(probe: &mut MapConfig, layer: &LayerDesc, k: usize) -> usize {
    probe.ks[0] = k;
    map_layer(0, 0, layer, probe).map(|m| m.rounds()).unwrap_or(usize::MAX)
}

/// Plan the parallelism vector for a network.
pub fn plan_ks(
    net: &Network,
    geometry: &DramGeometry,
    n_bits: usize,
    objective: Objective,
) -> KPlan {
    // One probe config for the whole plan; every probe varies only k.
    let mut probe = MapConfig::uniform(geometry.clone(), n_bits, 1);
    let mut ks = Vec::with_capacity(net.layers.len());
    let mut overflow = Vec::new();
    for layer in &net.layers {
        match min_resident_k_with(&mut probe, layer) {
            Some(k) => ks.push(k),
            None => {
                overflow.push(layer.name.clone());
                ks.push(outer_count(layer).min(geometry.pairs_per_column(n_bits).max(1)));
            }
        }
    }

    if objective == Objective::Balanced {
        // The bottleneck layer's round count sets the pipeline cycle; any
        // other layer may fold further (freeing footprint) while staying
        // at or below that round count.
        let bottleneck_rounds = net
            .layers
            .iter()
            .zip(&ks)
            .map(|(l, &k)| rounds_at(&mut probe, l, k))
            .max()
            .unwrap_or(1);
        for (i, layer) in net.layers.iter().enumerate() {
            let outer = outer_count(layer);
            let mut k = ks[i];
            while k < outer {
                let next = (k * 2).min(outer);
                if rounds_at(&mut probe, layer, next) <= bottleneck_rounds {
                    k = next;
                } else {
                    break;
                }
            }
            ks[i] = k;
        }
    }
    KPlan { ks, overflow_layers: overflow }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::workloads::nets::{alexnet, pimnet, vgg16};

    #[test]
    fn pimnet_resident_plan_on_real_ddr3() {
        // conv2 expands to 74 subarrays of operands at k=1 (> 32/bank), so
        // the optimizer folds it to k=3; everything else stays at k=1.
        let g = DramGeometry::paper_default();
        let plan = plan_ks(&pimnet(), &g, 8, Objective::MinResidentK);
        assert_eq!(plan.ks, vec![1, 3, 1, 1]);
        assert!(plan.overflow_layers.is_empty());
    }

    #[test]
    fn min_resident_k_is_minimal() {
        let g = DramGeometry::paper_default();
        for layer in alexnet().layers.iter() {
            if let Some(k) = min_resident_k(layer, &g, 8) {
                if k > 1 {
                    let cfg = MapConfig::uniform(g.clone(), 8, k - 1);
                    let m = map_layer(0, 0, layer, &cfg).unwrap();
                    assert!(!m.fully_resident(), "{}: k-1 also fits", layer.name);
                }
            }
        }
    }

    #[test]
    fn vgg_fat_layers_overflow_real_ddr3() {
        let g = DramGeometry::paper_default();
        let plan = plan_ks(&vgg16(), &g, 8, Objective::MinResidentK);
        // conv1_2's expansion (1.85 G columns) cannot fit 32 subarrays at
        // any k ≤ 64 — it must be reported as overflow.
        assert!(
            plan.overflow_layers.iter().any(|n| n == "conv1_2"),
            "overflow: {:?}",
            plan.overflow_layers
        );
    }

    #[test]
    fn ideal_geometry_everything_resident() {
        let g = DramGeometry::paper_ideal();
        let plan = plan_ks(&vgg16(), &g, 8, Objective::MinResidentK);
        assert!(plan.overflow_layers.is_empty());
        assert!(plan.ks.iter().all(|&k| k == 1));
    }

    #[test]
    fn balanced_never_slower_than_bottleneck() {
        let g = DramGeometry::paper_default();
        let net = alexnet();
        let base = plan_ks(&net, &g, 8, Objective::MinResidentK);
        let bal = plan_ks(&net, &g, 8, Objective::Balanced);
        let rounds = |ks: &[usize]| -> usize {
            let mut probe = MapConfig::uniform(g.clone(), 8, 1);
            net.layers
                .iter()
                .zip(ks)
                .map(|(l, &k)| rounds_at(&mut probe, l, k))
                .max()
                .unwrap()
        };
        assert!(rounds(&bal.ks) <= rounds(&base.ks));
        // Balanced folds at least as much everywhere.
        for (b, m) in bal.ks.iter().zip(&base.ks) {
            assert!(b >= m);
        }
    }

    #[test]
    fn planned_ks_are_valid_property() {
        crate::testutil::check(12, |rng| {
            let nets = [alexnet(), vgg16(), pimnet()];
            let net = &nets[rng.below(3)];
            let n_bits = [2usize, 4, 8][rng.below(3)];
            let g = DramGeometry::paper_default();
            let plan = plan_ks(net, &g, n_bits, Objective::MinResidentK);
            for (layer, &k) in net.layers.iter().zip(&plan.ks) {
                prop_assert!(k >= 1 && k <= outer_count(layer));
                let cfg = MapConfig::uniform(g.clone(), n_bits, k);
                prop_assert!(map_layer(0, 0, layer, &cfg).is_ok());
            }
            Ok(())
        });
    }
}
