//! Search-candidate mapping: the enlarged per-layer design space the
//! `mapopt` beam search explores (DESIGN.md §Mapping optimizer).
//!
//! The paper's Algorithm 1 exposes one knob (the parallelism divisor k).
//! A candidate adds two more, both about *how operands are staged* rather
//! than how many groups fold:
//!
//!   * **tile** — outer units staged per chunk. The untiled mapper lands
//!     a whole wave of operands before its first multiply round; a tiled
//!     mapping streams tile j+1 over the internal bus while tile j
//!     multiplies, so a re-staging event exposes only one tile's rows.
//!   * **layout** — [`DataLayout`]: sequential packing keeps the paper's
//!     footprint but a tile straddling a subarray boundary costs extra
//!     row activations every group stream; row-aligned placement zeroes
//!     the crossings by starting every tile at a fresh subarray, paying
//!     footprint padding (and possibly extra waves) instead.
//!
//! `tile == 0` IS the paper mapping: [`map_candidate`] then returns
//! `map_layer`'s result untouched, which keeps the default path
//! bitwise-frozen.

use crate::dram::DramGeometry;
use crate::util::ceil_div;
use crate::workloads::LayerDesc;

use super::optimizer::min_resident_k;
use super::{map_layer, outer_count, DataLayout, LayerMapping, MapConfig, MapError};

/// Tiled variants enumerated per (k, layout) branch — the tile ladder is
/// powers of two, so 6 values cover a 64× staging-granularity range.
const MAX_TILE_VALUES: usize = 6;

/// One point of the per-layer search space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerCandidate {
    /// Parallelism divisor (must already be clamped to the outer count).
    pub k: usize,
    /// Staging-tile size in outer units; 0 = untiled (the paper mapping).
    pub tile: usize,
    pub layout: DataLayout,
}

impl LayerCandidate {
    /// The paper mapping at parallelism `k`.
    pub fn paper(k: usize) -> Self {
        LayerCandidate { k, tile: 0, layout: DataLayout::Sequential }
    }

    pub fn is_paper(&self) -> bool {
        self.tile == 0
    }
}

/// Map one layer under a search candidate. `probe.ks[0]` is overwritten
/// with the candidate's k — the sweep reuses one probe config across all
/// candidates, mirroring `optimizer::min_resident_k_with`.
pub fn map_candidate(
    layer_idx: usize,
    bank: usize,
    layer: &LayerDesc,
    probe: &mut MapConfig,
    cand: &LayerCandidate,
) -> Result<LayerMapping, MapError> {
    probe.ks[0] = cand.k;
    let mut m = map_layer(layer_idx, bank, layer, probe)?;
    if cand.tile == 0 {
        return Ok(m);
    }
    let outer = outer_count(layer);
    let macs_per_outer = m.macs_total / outer;
    let outer_per_group = ceil_div(outer, m.k);
    // Tiling needs narrow MACs (a wide MAC already spans whole subarrays)
    // and at least two tiles per group; otherwise the candidate
    // degenerates to the paper mapping.
    if m.macs_per_subarray == 0 || macs_per_outer == 0 || cand.tile >= outer_per_group {
        return Ok(m);
    }
    let g = &probe.geometry;
    let per_sub = m.macs_per_subarray;
    let tile_macs = cand.tile * macs_per_outer;
    m.tile = cand.tile;
    m.layout = cand.layout;
    m.tile_subarrays = ceil_div(tile_macs, per_sub).max(1);
    match cand.layout {
        DataLayout::Sequential => {
            // Packing unchanged; each boundary-straddling tile pays 2n
            // extra row activations, once per group stream per image.
            let crossings = tile_crossings(m.macs_per_group, tile_macs, per_sub);
            m.extra_row_acts = m.k as u64 * crossings * 2 * probe.n_bits as u64;
        }
        DataLayout::RowAligned => {
            // Every tile starts at a fresh subarray: the group footprint
            // pads up to tiles × per-tile span, which can add waves.
            let tiles = ceil_div(outer_per_group, cand.tile);
            m.subarrays_ideal = tiles * m.tile_subarrays;
            m.subarrays_used = m.subarrays_ideal.min(g.subarrays_per_bank);
            m.waves = ceil_div(m.subarrays_ideal, g.subarrays_per_bank).max(1);
            let used_cols = (m.macs_total * m.mac_size) as f64;
            let alloc_cols = (m.subarrays_ideal * g.cols * m.k) as f64;
            m.utilization = (used_cols / alloc_cols).min(1.0);
        }
    }
    Ok(m)
}

/// Subarray boundaries straddled by a group's tiles under sequential
/// packing: MAC j lives in subarray `j / per_sub` (`map_layer`'s
/// consecutive-columns rule), tile i covers MACs `[i·w, (i+1)·w)`, and a
/// tile's crossings are the subarray-index span of its MACs. For w and
/// per_sub coprime this reproduces the GCD periodic analysis — a
/// `(w − gcd(w, per_sub)) / per_sub` fraction of tiles straddle.
pub fn tile_crossings(group_macs: usize, tile_macs: usize, per_sub: usize) -> u64 {
    if tile_macs == 0 || per_sub == 0 {
        return 0;
    }
    let mut crossings = 0u64;
    let mut start = 0usize;
    while start < group_macs {
        let end = (start + tile_macs).min(group_macs);
        crossings += ((end - 1) / per_sub - start / per_sub) as u64;
        start = end;
    }
    crossings
}

/// Whether the tiling knob is searchable for `layer` at parallelism `k`:
/// narrow MACs and more than one outer unit per group. When this is
/// false the search space collapses to the paper default (W051).
pub fn tiling_applicable(layer: &LayerDesc, geometry: &DramGeometry, k: usize) -> bool {
    let outer = outer_count(layer);
    let macs_per_outer = layer.num_macs() / outer;
    layer.mac_size() <= geometry.cols
        && macs_per_outer > 0
        && ceil_div(outer, k.max(1).min(outer)) > 1
}

/// Deterministic candidate-k ladder for one layer: the spec's (clamped)
/// paper k first — ties in the exact pricing then resolve toward the
/// paper choice — then 1, the minimum resident k, and powers of two up
/// to the outer/stack-capacity limit.
pub fn candidate_ks(
    layer: &LayerDesc,
    geometry: &DramGeometry,
    n_bits: usize,
    paper_k: usize,
) -> Vec<usize> {
    let outer = outer_count(layer);
    let hi = outer.min(geometry.pairs_per_column(n_bits).max(1)).max(1);
    let mut ks = vec![paper_k.min(outer).max(1)];
    let mut push = |ks: &mut Vec<usize>, k: usize| {
        if k >= 1 && k <= hi && !ks.contains(&k) {
            ks.push(k);
        }
    };
    push(&mut ks, 1);
    if let Some(k) = min_resident_k(layer, geometry, n_bits) {
        push(&mut ks, k);
    }
    let mut p = 2usize;
    while p <= hi {
        push(&mut ks, p);
        p *= 2;
    }
    push(&mut ks, hi);
    ks
}

/// Deterministic candidates under one k: untiled first, then — when the
/// untiled mapping is not fully resident and tiling is applicable —
/// tiled variants, coarse to fine, Sequential before RowAligned. A
/// resident mapping has nothing to re-stage, so tiling can only add
/// crossing or padding cost and is skipped to save search budget.
pub fn candidates_at_k(
    layer: &LayerDesc,
    probe: &mut MapConfig,
    k: usize,
) -> Vec<LayerCandidate> {
    let mut out = vec![LayerCandidate::paper(k)];
    let Ok(untiled) = map_candidate(0, 0, layer, probe, &out[0]) else {
        return out;
    };
    if untiled.fully_resident() || !tiling_applicable(layer, &probe.geometry, k) {
        return out;
    }
    let outer_per_group = ceil_div(outer_count(layer), k);
    // Tile ladder: powers of two below the group size, coarse to fine.
    let mut tiles = Vec::new();
    let mut t = 1usize;
    while t * 2 <= outer_per_group && tiles.len() < MAX_TILE_VALUES {
        tiles.push(t);
        t *= 2;
    }
    for &tile in tiles.iter().rev() {
        for layout in [DataLayout::Sequential, DataLayout::RowAligned] {
            out.push(LayerCandidate { k, tile, layout });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::{mobilenet_mini, vgg16};

    fn probe() -> MapConfig {
        MapConfig::uniform(DramGeometry::paper_default(), 8, 1)
    }

    #[test]
    fn untiled_candidate_is_bitwise_paper_mapping() {
        let net = mobilenet_mini();
        let mut p = probe();
        for (i, l) in net.layers.iter().enumerate() {
            let cand = LayerCandidate::paper(1);
            let m = map_candidate(i, i, l, &mut p, &cand).unwrap();
            p.ks[0] = 1;
            let paper = map_layer(i, i, l, &p).unwrap();
            assert_eq!(m, paper, "{}", l.name);
        }
    }

    #[test]
    fn crossings_match_gcd_period() {
        // w=3, c=8 over one full period of lcm(3,8)=24 MACs → 8 tiles, of
        // which (w − gcd)/c · tiles = (3−1)/8 · 8 = 2 straddle.
        assert_eq!(tile_crossings(24, 3, 8), 2);
        // Tiles aligned to the subarray never cross.
        assert_eq!(tile_crossings(64, 4, 8), 0);
        // A tile wider than a subarray always crosses.
        assert_eq!(tile_crossings(32, 16, 8), 2);
    }

    #[test]
    fn row_aligned_pads_footprint_and_zeroes_crossings() {
        let net = vgg16();
        // conv1_2 is never resident on real DDR3 — tiling applies.
        let idx = net.layers.iter().position(|l| l.name == "conv1_2").unwrap();
        let l = &net.layers[idx];
        let mut p = probe();
        let seq_cand = LayerCandidate { k: 1, tile: 2, layout: DataLayout::Sequential };
        let row_cand = LayerCandidate { k: 1, tile: 2, layout: DataLayout::RowAligned };
        let seq = map_candidate(idx, idx, l, &mut p, &seq_cand).unwrap();
        let row = map_candidate(idx, idx, l, &mut p, &row_cand).unwrap();
        let untiled = map_candidate(idx, idx, l, &mut p, &LayerCandidate::paper(1)).unwrap();
        assert!(seq.extra_row_acts > 0);
        assert_eq!(seq.subarrays_ideal, untiled.subarrays_ideal);
        assert_eq!(row.extra_row_acts, 0);
        assert!(row.subarrays_ideal >= untiled.subarrays_ideal);
        assert!(row.waves >= untiled.waves);
    }

    #[test]
    fn resident_layers_enumerate_only_paper() {
        let net = mobilenet_mini();
        let mut p = probe();
        // dw1 is resident at k=1 → no tiled candidates.
        let idx = net.layers.iter().position(|l| l.name == "dw1").unwrap();
        let cands = candidates_at_k(&net.layers[idx], &mut p, 1);
        assert_eq!(cands, vec![LayerCandidate::paper(1)]);
    }

    #[test]
    fn candidate_ks_start_with_paper_and_stay_bounded() {
        let net = mobilenet_mini();
        for l in &net.layers {
            let ks = candidate_ks(l, &DramGeometry::paper_default(), 8, 1);
            assert_eq!(ks[0], 1);
            let outer = outer_count(l);
            for &k in &ks {
                assert!(k >= 1 && k <= outer, "{}: k={k} outer={outer}", l.name);
            }
            // Dedup holds.
            let mut sorted = ks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), ks.len());
        }
    }
}
