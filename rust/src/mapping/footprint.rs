//! Memory-footprint formulas (§IV-B): the paper's worst-case expressions
//! and the parallelism ↔ footprint trade-off curve.

use crate::workloads::{LayerDesc, LayerKind};

/// Worst-case footprint of a conv layer in bits (paper §IV-B):
/// `O · ((H-K+2p)/s+1) · ((W-L+2p)/s+1) · (I·L·K) · 2 · n`.
pub fn conv_worstcase_bits(layer: &LayerDesc, n: usize) -> u64 {
    match layer.kind {
        LayerKind::Conv { .. } => {
            // O · OH · OW · (I·L·K) · 2 · n — which is exactly
            // num_macs · mac_size · 2 · n since num_macs = O·OH·OW.
            layer.num_macs() as u64 * layer.mac_size() as u64 * 2 * n as u64
        }
        _ => panic!("conv_worstcase_bits on non-conv layer"),
    }
}

/// Worst-case footprint of a linear layer in bits: `w1 · w2 · 2 · n`.
pub fn linear_worstcase_bits(layer: &LayerDesc, n: usize) -> u64 {
    match layer.kind {
        LayerKind::Linear { in_features, out_features } => {
            (in_features as u64) * (out_features as u64) * 2 * n as u64
        }
        _ => panic!("linear_worstcase_bits on non-linear layer"),
    }
}

/// Footprint at parallelism divisor `k`: operands shared across the k
/// groups stack into the same columns, so resident bits shrink ≈ k×
/// (until restaging kicks in).
pub fn resident_bits_at_k(layer: &LayerDesc, n: usize, k: usize) -> u64 {
    let full = layer.num_macs() as u64 * layer.mac_size() as u64 * 2 * n as u64;
    full.div_ceil(k as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::{alexnet, vgg16};

    #[test]
    fn linear_formula_matches_paper() {
        let net = vgg16();
        let fc7 = net.layers.iter().find(|l| l.name == "fc7").unwrap();
        // w1*w2*2*n = 4096*4096*2*8
        assert_eq!(linear_worstcase_bits(fc7, 8), 4096 * 4096 * 16);
    }

    #[test]
    fn conv_formula_matches_mac_expansion() {
        // The §IV-B conv expression is exactly num_macs · mac_size · 2n.
        let net = alexnet();
        let conv2 = &net.layers[1];
        let want =
            conv2.num_macs() as u64 * conv2.mac_size() as u64 * 2 * 8;
        assert_eq!(conv_worstcase_bits(conv2, 8), want);
    }

    #[test]
    fn parallelism_footprint_tradeoff() {
        // Fig 12 discussion: higher k → smaller resident footprint.
        let net = alexnet();
        let l = &net.layers[1];
        let f1 = resident_bits_at_k(l, 8, 1);
        let f4 = resident_bits_at_k(l, 8, 4);
        assert!(f4 < f1);
        assert_eq!(f1.div_ceil(4), f4);
    }
}
