//! Algorithm 1: mapping a DNN onto PIM-DRAM banks (§IV-B, DESIGN.md S10).
//!
//! Every layer gets one bank. Within a bank, each MAC's multiplications
//! occupy *consecutive columns of a single subarray* (so one adder-tree
//! pass can reduce them); a MAC that would not fit in the remaining columns
//! starts at column 1 of the next subarray and the tail columns are wasted.
//! The parallelism divisor `k` folds the output filters/neurons into `k`
//! groups that reuse the same columns at increasing stack depth — k× less
//! area, k× more sequential rounds (the paper's parallelism ↔ footprint
//! trade-off, and the P1..P4 sweep of Fig 16).
//!
//! Divergences from the printed algorithm (DESIGN.md §7):
//!   * **Wide MACs.** Algorithm 1 loops forever when `MAC_size >
//!     column_size` (every large FC layer, e.g. VGG16 fc6: 25088 > 4096).
//!     Extension: a wide MAC spans `ceil(mac_size/cols)` whole subarrays
//!     and the adder tree reduces it in that many passes.
//!   * **Capacity.** The paper's worst-case footprint exceeds any real
//!     bank for large conv layers at P1 (VGG16 conv1_2 alone needs ≈ 451k
//!     subarrays of operand expansion); the paper's simulator implicitly
//!     assumes capacity. We model both: when a group exceeds the bank's
//!     subarray budget it is processed in sequential `waves` over the
//!     budget, each wave paying an operand re-staging cost. The
//!     `paper_ideal` geometry preset makes the budget effectively
//!     unbounded, reproducing the paper's assumption (Fig 16); the default
//!     geometry shows what a real DDR3 die does (ablation_subarray bench).

pub mod candidates;
pub mod footprint;
pub mod optimizer;

use crate::dram::DramGeometry;
use crate::util::ceil_div;
use crate::workloads::{LayerDesc, LayerKind, Network};

/// Mapping configuration for one network instance.
#[derive(Debug, Clone)]
pub struct MapConfig {
    pub geometry: DramGeometry,
    /// Operand bit width n.
    pub n_bits: usize,
    /// Per-layer parallelism divisors (the paper's P vectors). Length must
    /// equal the layer count, or be a single value broadcast to all.
    pub ks: Vec<usize>,
}

impl MapConfig {
    pub fn uniform(geometry: DramGeometry, n_bits: usize, k: usize) -> Self {
        MapConfig { geometry, n_bits, ks: vec![k] }
    }

    pub fn k_for(&self, layer_idx: usize) -> usize {
        if self.ks.len() == 1 {
            self.ks[0]
        } else {
            self.ks[layer_idx]
        }
    }
}

/// Operand placement of a staging tile's MACs within the subarray row
/// space (DESIGN.md §Mapping optimizer). The paper's mapper always packs
/// sequentially; the search mapper may trade row-aligned padding against
/// the extra row activations that boundary-straddling tiles cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DataLayout {
    /// Tiles packed back-to-back; a tile whose MACs straddle a subarray
    /// boundary pays extra row activations per round (tile-crossing
    /// analysis against the row width).
    #[default]
    Sequential,
    /// Every tile starts at a fresh subarray: zero crossings, but the
    /// per-tile padding inflates the subarray footprint (and possibly the
    /// wave count).
    RowAligned,
}

/// Result of mapping one layer to one bank.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMapping {
    pub layer_idx: usize,
    pub name: String,
    /// Bank index hosting this layer.
    pub bank: usize,
    pub mac_size: usize,
    pub macs_total: usize,
    /// Parallelism divisor (clamped to the outer-loop count).
    pub k: usize,
    /// MACs mapped per group (one sequential round each).
    pub macs_per_group: usize,
    /// MACs that fit one subarray (0 if the MAC is wider than a subarray).
    pub macs_per_subarray: usize,
    /// Subarrays a wide MAC spans (1 if it fits).
    pub subarrays_per_mac: usize,
    /// Subarrays one group *wants* (before capping at the bank budget).
    pub subarrays_ideal: usize,
    /// Subarrays actually used concurrently (≤ bank budget).
    pub subarrays_used: usize,
    /// Sequential waves over the budget to cover one group (≥ 1).
    pub waves: usize,
    /// Operand pairs stacked per column (= k groups, capped by row budget).
    pub stacked_pairs: usize,
    /// Rounds whose operands must be re-staged between rounds because the
    /// column stack capacity is exceeded.
    pub restaged_rounds: usize,
    /// Fraction of allocated columns actually holding operands.
    pub utilization: f64,
    /// Total operand storage in bits (both operands of every mult).
    pub footprint_bits: u64,
    /// Staging-tile size in outer units (0 = the paper's untiled mapping;
    /// the default everywhere outside the search mapper).
    pub tile: usize,
    /// Subarrays one staging tile occupies (0 when untiled) — the unit of
    /// operand traffic a re-staging event exposes under tiled staging.
    pub tile_subarrays: usize,
    /// Operand placement of the staging tiles ([`DataLayout::Sequential`]
    /// for the paper mapping).
    pub layout: DataLayout,
    /// Extra row activations per image charged by tile-crossing analysis
    /// (0 for the paper mapping and for row-aligned tiles).
    pub extra_row_acts: u64,
}

impl LayerMapping {
    /// Total sequential multiply rounds per image: k groups × waves.
    pub fn rounds(&self) -> usize {
        self.k * self.waves
    }

    /// Whether the layer's operand expansion is resident (no waves, no
    /// restaging) — the paper's implicit assumption.
    pub fn fully_resident(&self) -> bool {
        self.waves == 1 && self.restaged_rounds == 0
    }
}

/// Mapping failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapError {
    BankOverflow { net: String, banks: usize, avail: usize },
    KTooLarge { layer: String, k: usize, outer: usize },
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::BankOverflow { net, banks, avail } => write!(
                f,
                "network {net}: needs {banks} banks (layers + residual \
                 reserves) but device has {avail}"
            ),
            MapError::KTooLarge { layer, k, outer } => {
                write!(f, "layer {layer}: k={k} exceeds outer loop count {outer}")
            }
        }
    }
}

impl std::error::Error for MapError {}

/// The outer-loop count k divides (output filters / output neurons /
/// resident-operand columns for matmul).
pub fn outer_count(layer: &LayerDesc) -> usize {
    match layer.kind {
        LayerKind::Conv { out_ch, .. } => out_ch,
        LayerKind::Linear { out_features, .. } => out_features,
        LayerKind::MatMul { n, .. } => n,
    }
}

/// Map one layer onto one bank (Algorithm 1 + the extensions above).
pub fn map_layer(
    layer_idx: usize,
    bank: usize,
    layer: &LayerDesc,
    cfg: &MapConfig,
) -> Result<LayerMapping, MapError> {
    let g = &cfg.geometry;
    let n = cfg.n_bits;
    let k = cfg.k_for(layer_idx);
    let mac_size = layer.mac_size();
    let macs_total = layer.num_macs();
    let outer = outer_count(layer);

    if k > outer {
        return Err(MapError::KTooLarge { layer: layer.name.clone(), k, outer });
    }
    let max_pairs = g.pairs_per_column(n).max(1);

    // Outer units per group → MACs per group.
    let macs_per_outer = macs_total / outer;
    let outer_per_group = ceil_div(outer, k);
    let macs_per_group = outer_per_group * macs_per_outer;

    let (macs_per_subarray, subarrays_per_mac, subarrays_ideal) =
        if mac_size <= g.cols {
            let per_sub = g.cols / mac_size;
            (per_sub, 1, ceil_div(macs_per_group, per_sub))
        } else {
            let span = ceil_div(mac_size, g.cols);
            (0, span, macs_per_group * span)
        };

    let subarrays_used = subarrays_ideal.min(g.subarrays_per_bank);
    let waves = ceil_div(subarrays_ideal, g.subarrays_per_bank).max(1);

    let used_cols = (macs_total * mac_size) as f64;
    let alloc_cols = (subarrays_ideal * g.cols * k) as f64;
    Ok(LayerMapping {
        layer_idx,
        name: layer.name.clone(),
        bank,
        mac_size,
        macs_total,
        k,
        macs_per_group,
        macs_per_subarray,
        subarrays_per_mac,
        subarrays_ideal,
        subarrays_used,
        waves,
        stacked_pairs: k.min(max_pairs),
        restaged_rounds: k.saturating_sub(max_pairs),
        utilization: (used_cols / alloc_cols).min(1.0),
        footprint_bits: 2 * (n as u64) * macs_total as u64 * mac_size as u64,
        tile: 0,
        tile_subarrays: 0,
        layout: DataLayout::Sequential,
        extra_row_acts: 0,
    })
}

/// A full network mapped onto the device: layer-per-bank plus one reserved
/// bank per residual edge (§IV-B, Fig 13).
#[derive(Debug, Clone)]
pub struct NetworkMapping {
    pub net_name: String,
    pub layers: Vec<LayerMapping>,
    /// Reserved banks for residual adds, indexed after the layer banks.
    pub residual_banks: usize,
    pub total_banks: usize,
}

impl NetworkMapping {
    /// Device-level summary: fraction of banks' subarrays in use.
    pub fn mean_utilization(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|m| m.utilization).sum::<f64>()
            / self.layers.len() as f64
    }

    pub fn fully_resident(&self) -> bool {
        self.layers.iter().all(|m| m.fully_resident())
    }
}

pub fn map_network(net: &Network, cfg: &MapConfig) -> Result<NetworkMapping, MapError> {
    let banks_needed = net.layers.len() + net.residuals.len();
    if banks_needed > cfg.geometry.total_banks() {
        return Err(MapError::BankOverflow {
            net: net.name.clone(),
            banks: banks_needed,
            avail: cfg.geometry.total_banks(),
        });
    }
    let layers = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            // Clamp the requested k at the layer's outer count (a uniform
            // P vector like (4,4,…) can exceed a small head layer's
            // channel count).
            let k = cfg.k_for(i).min(outer_count(l));
            let c = MapConfig {
                geometry: cfg.geometry.clone(),
                n_bits: cfg.n_bits,
                ks: vec![k],
            };
            map_layer(i, i, l, &c)
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(NetworkMapping {
        net_name: net.name.clone(),
        layers,
        residual_banks: net.residuals.len(),
        total_banks: banks_needed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::workloads::nets::{alexnet, pimnet, resnet18, vgg16};

    fn cfg(k: usize) -> MapConfig {
        MapConfig::uniform(DramGeometry::paper_default(), 8, k)
    }

    fn ideal_cfg(k: usize) -> MapConfig {
        MapConfig::uniform(DramGeometry::paper_ideal(), 8, k)
    }

    #[test]
    fn pimnet_conv1_mapping() {
        let net = pimnet();
        let m = map_layer(0, 0, &net.layers[0], &cfg(1)).unwrap();
        // mac_size 9 → 455 MACs per 4096-col subarray; 4096 MACs total.
        assert_eq!(m.macs_per_subarray, 455);
        assert_eq!(m.subarrays_ideal, ceil_div(16 * 16 * 16, 455));
        assert_eq!(m.waves, 1);
        assert_eq!(m.stacked_pairs, 1);
        assert!(m.utilization > 0.85);
        assert!(m.fully_resident());
    }

    #[test]
    fn wide_fc_layer_spans_subarrays() {
        // VGG16 fc6: mac_size 25088 > 4096 columns — the printed Algorithm 1
        // cannot place it; our extension spans ceil(25088/4096)=7 subarrays.
        let net = vgg16();
        let fc6 = net.layers.iter().position(|l| l.name == "fc6").unwrap();
        let m = map_layer(fc6, fc6, &net.layers[fc6], &cfg(1)).unwrap();
        assert_eq!(m.subarrays_per_mac, 7);
        assert_eq!(m.macs_per_subarray, 0);
        assert_eq!(m.subarrays_ideal, 4096 * 7);
        // Real bank: 32 subarrays → waves cover the rest sequentially.
        assert_eq!(m.subarrays_used, 32);
        assert_eq!(m.waves, ceil_div(4096 * 7, 32));
    }

    #[test]
    fn ideal_geometry_makes_vgg_resident_at_p1() {
        // The paper's implicit assumption (Fig 16 P1).
        let net = vgg16();
        let mapping = map_network(&net, &ideal_cfg(1)).unwrap();
        assert!(mapping.fully_resident(), "vgg16 not resident on ideal geometry");
    }

    #[test]
    fn k_reduces_subarrays_linearly() {
        let net = alexnet();
        let l = &net.layers[2]; // conv3
        let m1 = map_layer(2, 2, l, &ideal_cfg(1)).unwrap();
        let m4 = map_layer(2, 2, l, &ideal_cfg(4)).unwrap();
        assert!(m4.subarrays_ideal <= ceil_div(m1.subarrays_ideal, 4) + 1);
        assert_eq!(m4.stacked_pairs, 4);
        assert_eq!(m4.rounds(), 4);
    }

    #[test]
    fn k_larger_than_outer_rejected() {
        let net = pimnet();
        let err = map_layer(3, 3, &net.layers[3], &cfg(64)).unwrap_err();
        assert!(matches!(err, MapError::KTooLarge { .. }));
    }

    #[test]
    fn map_network_clamps_uniform_k() {
        // pimnet fc2 has only 10 output neurons; uniform k=16 must clamp.
        let net = pimnet();
        let m = map_network(&net, &cfg(16)).unwrap();
        assert_eq!(m.layers[3].k, 10);
        assert_eq!(m.layers[0].k, 16);
    }

    #[test]
    fn stack_capacity_triggers_restaging() {
        // 256 stacked groups > 255 pairs/column at 8 bits.
        let net = alexnet();
        let l = &net.layers[1]; // conv2: 256 output filters ≥ k
        let m = map_layer(1, 1, l, &ideal_cfg(256)).unwrap();
        assert_eq!(m.stacked_pairs, 255);
        assert_eq!(m.restaged_rounds, 1);
        let m2 = map_layer(1, 1, l, &ideal_cfg(4)).unwrap();
        assert_eq!(m2.restaged_rounds, 0);
    }

    #[test]
    fn all_networks_map_on_both_geometries() {
        for net in [alexnet(), vgg16(), resnet18(), pimnet()] {
            for c in [cfg(1), ideal_cfg(1), cfg(4), ideal_cfg(4)] {
                let m = map_network(&net, &c)
                    .unwrap_or_else(|e| panic!("{}: {e}", net.name));
                assert_eq!(m.layers.len(), net.layers.len());
            }
        }
    }

    #[test]
    fn bank_overflow_detected() {
        let mut g = DramGeometry::paper_default();
        g.banks_per_rank = 2;
        g.ranks_per_channel = 1; // 2 banks total
        let cfg = MapConfig::uniform(g, 8, 1);
        let err = map_network(&vgg16(), &cfg).unwrap_err();
        assert!(matches!(err, MapError::BankOverflow { .. }));
    }

    #[test]
    fn mac_never_split_within_subarray_rule() {
        crate::testutil::check(30, |rng| {
            let mac_size = rng.int_range(1, 4096) as usize;
            let g = DramGeometry::paper_default();
            let per_sub = g.cols / mac_size;
            prop_assert!(per_sub * mac_size <= g.cols);
            Ok(())
        });
    }

    #[test]
    fn footprint_matches_formula() {
        // §IV-B worst-case footprint: macs · mac_size · 2 · n bits.
        let net = alexnet();
        let m = map_layer(0, 0, &net.layers[0], &cfg(1)).unwrap();
        let l = &net.layers[0];
        assert_eq!(
            m.footprint_bits,
            2 * 8 * (l.num_macs() as u64) * (l.mac_size() as u64)
        );
    }

    #[test]
    fn rounds_scale_with_waves_and_k() {
        crate::testutil::check(25, |rng| {
            let nets = [alexnet(), vgg16(), resnet18(), pimnet()];
            let net = &nets[rng.below(4)];
            let li = rng.below(net.layers.len());
            let l = &net.layers[li];
            let k = 1 + rng.below(outer_count(l).min(8));
            let c = MapConfig::uniform(DramGeometry::paper_default(), 8, k);
            let m = map_layer(li, li, l, &c).map_err(|e| e.to_string())?;
            prop_assert!(m.rounds() == m.k * m.waves);
            prop_assert!(m.subarrays_used <= 32);
            prop_assert!(m.waves >= 1);
            Ok(())
        });
    }
}
