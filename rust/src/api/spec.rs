//! Pure-data experiment specifications (DESIGN.md §API).
//!
//! A [`Spec`] is the one typed description of "what to run" shared by the
//! CLI, the TOML config loader, the benches and the serving stack. It is
//! plain data — no handles, no threads, no borrowed state — and it
//! round-trips through JSON under a top-level `"api_version"`:
//!
//! ```json
//! {
//!   "api_version": 1,
//!   "device": { "preset": "conservative", "channels": 2 },
//!   "images": 64,
//!   "network": "vgg16",
//!   "run": { "ks": [1], "precision": 8, "shard": "layersplit" },
//!   "serve": { "batch": 8, "batch_window_ms": 2, "policy": "rr" }
//! }
//! ```
//!
//! * [`NetworkSpec`] — a builtin name, an inline layer list, **or** an
//!   inline operator graph (`{"name": .., "graph": [..]}` — the
//!   `pim::ir` schema: nodes with explicit `inputs` edges, residual adds
//!   as ordinary nodes). The layer-list form stays accepted and converts
//!   to the same lowered chain, so `api_version` stays 1.
//! * [`DeviceSpec`] — timing/geometry preset plus explicit overrides,
//!   including the channels × ranks grid.
//! * [`RunSpec`] / [`ShardSpec`] — parallelism vector, operand precision
//!   and the shard policy lowering uses.
//! * [`ServeSpec`] — pool size, batch, dispatch policy for `Job::serve`.
//!
//! Serialization is **canonical**: object keys are byte-sorted, optional
//! fields are omitted when unset, and [`Spec::to_json_text`] uses
//! [`Json::pretty`] — so parse → serialize is byte-identical for canonical
//! documents (`tests/spec_roundtrip.rs` holds `examples/specs/` to this).
//! Parsing is **strict**: unknown keys, bad types and out-of-range values
//! are errors that name the field and the accepted values, raised before
//! any simulation work runs. Documents with a different `api_version` are
//! rejected outright — schema changes must bump [`API_VERSION`] and teach
//! the parser both shapes.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::config::toml::{Toml, Value};
use crate::coordinator::{
    arrival_name, parse_arrival, CrashSpec, FaultSpec, Policy, ResilienceSpec,
    StormSpec, StragglerSpec, TrafficSpec,
};
use crate::ir::{self, ActFn, Graph, NodeId, Op, Shape};
use crate::plan::ShardPolicy;
use crate::sim::SimConfig;
use crate::util::json::Json;
use crate::workloads::{nets, LayerDesc, LayerKind, Network, Residual};

pub use crate::workloads::nets::NAMES as BUILTIN_NETWORKS;

/// The one spec-schema version this build reads and writes.
pub const API_VERSION: i64 = 1;

/// Device preset names [`DeviceSpec::preset`] accepts. `edge` and `cloud`
/// are serving-fleet aliases for the two timing points: an `edge` device
/// is the conservative DDR3 geometry, a `cloud` device the paper-favorable
/// one — so a heterogeneous `serve.devices` fleet reads naturally.
pub const PRESETS: [&str; 4] = ["paper_favorable", "conservative", "edge", "cloud"];

/// Canonical dispatch-policy spellings [`ServeSpec::policy`] accepts.
pub const POLICIES: [&str; 4] = ["rr", "least", "two", "backlog"];

/// Shard-policy grammar ([`ShardSpec`]).
pub const SHARD_FORMS: &str = "replicate|layersplit|hybrid:<n>";

/// Parse a dispatch-policy spelling (long forms accepted, canonical short
/// forms serialized).
pub fn parse_policy(s: &str) -> Result<Policy> {
    match s {
        "rr" | "roundrobin" => Ok(Policy::RoundRobin),
        "least" | "leastloaded" => Ok(Policy::LeastLoaded),
        "two" | "twochoices" => Ok(Policy::TwoChoices),
        "backlog" => Ok(Policy::Backlog),
        other => anyhow::bail!(
            "unknown policy `{other}` (accepted: {})",
            POLICIES.join("|")
        ),
    }
}

/// The canonical spelling of a dispatch policy.
pub fn policy_name(p: Policy) -> &'static str {
    match p {
        Policy::RoundRobin => "rr",
        Policy::LeastLoaded => "least",
        Policy::TwoChoices => "two",
        Policy::Backlog => "backlog",
    }
}

/// Reject object keys outside `accepted` — a typo'd field must not
/// silently fall back to its default.
fn check_keys(what: &str, obj: &BTreeMap<String, Json>, accepted: &[&str]) -> Result<()> {
    for k in obj.keys() {
        anyhow::ensure!(
            accepted.contains(&k.as_str()),
            "unknown {what} field `{k}` (accepted: {})",
            accepted.join(", ")
        );
    }
    Ok(())
}

fn num(v: usize) -> Json {
    Json::Num(v as f64)
}

// ---- NetworkSpec ----------------------------------------------------------

/// The workload: a builtin evaluation network, an inline layer list, or
/// an inline operator graph.
#[derive(Debug, Clone, PartialEq)]
pub enum NetworkSpec {
    /// One of [`BUILTIN_NETWORKS`]; JSON form is the bare name string.
    Builtin(String),
    /// A custom network described as the lowered layer chain; JSON form
    /// is `{"name": .., "layers": [..], "residuals": [..]}`.
    Inline(Network),
    /// A custom network described as a `pim::ir` operator graph; JSON
    /// form is `{"name": .., "graph": [node, ..]}` where each node is
    /// `{"op": .., "name": .., "inputs": [..], ..params}` and residual
    /// shortcuts are ordinary `add` nodes.
    Graph(Graph),
}

impl NetworkSpec {
    pub fn name(&self) -> &str {
        match self {
            NetworkSpec::Builtin(n) => n,
            NetworkSpec::Inline(net) => &net.name,
            NetworkSpec::Graph(g) => &g.name,
        }
    }

    /// Materialize the network, validating an inline description (shape
    /// chain / graph shape inference, residual bounds, per-layer
    /// geometry) before any work runs. Graphs lower through the full
    /// `ir` pass pipeline here.
    pub fn resolve(&self) -> Result<Network> {
        match self {
            NetworkSpec::Builtin(name) => nets::by_name(name),
            NetworkSpec::Inline(net) => {
                validate_inline(net)?;
                Ok(net.clone())
            }
            NetworkSpec::Graph(g) => ir::lower(g),
        }
    }

    fn from_json(v: &Json) -> Result<NetworkSpec> {
        match v {
            Json::Str(name) => Ok(NetworkSpec::Builtin(name.clone())),
            Json::Obj(obj) if obj.contains_key("graph") => {
                check_keys("network", obj, &["graph", "name"])?;
                let name = v.req_str("name")?.to_string();
                Ok(NetworkSpec::Graph(graph_from_json(&name, v.req_arr("graph")?)?))
            }
            Json::Obj(obj) => {
                check_keys("network", obj, &["layers", "name", "residuals"])?;
                let name = v.req_str("name")?.to_string();
                let layers = v
                    .req_arr("layers")?
                    .iter()
                    .map(layer_from_json)
                    .collect::<Result<Vec<_>>>()?;
                let residuals = match v.get("residuals") {
                    None => Vec::new(),
                    Some(r) => r
                        .as_arr()
                        .context("network `residuals` must be an array")?
                        .iter()
                        .map(residual_from_json)
                        .collect::<Result<Vec<_>>>()?,
                };
                Ok(NetworkSpec::Inline(Network { name, layers, residuals }))
            }
            _ => anyhow::bail!(
                "`network` must be a builtin name ({}), an inline object with \
                 name/layers/residuals, or a graph object with name/graph",
                BUILTIN_NETWORKS.join("|")
            ),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            NetworkSpec::Builtin(name) => Json::Str(name.clone()),
            NetworkSpec::Inline(net) => {
                let mut o = BTreeMap::new();
                o.insert(
                    "layers".to_string(),
                    Json::Arr(net.layers.iter().map(layer_to_json).collect()),
                );
                o.insert("name".to_string(), Json::Str(net.name.clone()));
                o.insert(
                    "residuals".to_string(),
                    Json::Arr(net.residuals.iter().map(residual_to_json).collect()),
                );
                Json::Obj(o)
            }
            NetworkSpec::Graph(g) => {
                let mut o = BTreeMap::new();
                o.insert("graph".to_string(), graph_to_json(g));
                o.insert("name".to_string(), Json::Str(g.name.clone()));
                Json::Obj(o)
            }
        }
    }
}

// ---- graph schema ---------------------------------------------------------

/// Node-op spellings the graph schema accepts, for error messages.
const GRAPH_OPS: &str =
    "input|conv|depthwise|linear|matmul|add|pool|gap|relu|softmax";

/// The common node keys (`inputs`/`name`/`op`) plus the op-specific
/// fields, byte-sorted for `check_keys`.
fn node_keys<'a>(extra: &[&'a str]) -> Vec<&'a str> {
    let mut all: Vec<&'a str> = vec!["inputs", "name", "op"];
    all.extend_from_slice(extra);
    all.sort_unstable();
    all
}

fn shape_from_json(name: &str, v: &Json) -> Result<Shape> {
    let obj = v.as_obj().with_context(|| {
        format!("node `{name}`: `shape` must be an object ({{h,w,c}} | {{n}} | {{rows,cols}})")
    })?;
    let u = |key: &str| -> Result<usize> {
        v.get(key).and_then(Json::as_usize).with_context(|| {
            format!("node `{name}`: shape field `{key}` must be a non-negative integer")
        })
    };
    if obj.contains_key("h") || obj.contains_key("w") || obj.contains_key("c") {
        check_keys("shape", obj, &["c", "h", "w"])?;
        Ok(Shape::Map { h: u("h")?, w: u("w")?, c: u("c")? })
    } else if obj.contains_key("rows") || obj.contains_key("cols") {
        check_keys("shape", obj, &["cols", "rows"])?;
        Ok(Shape::Mat { rows: u("rows")?, cols: u("cols")? })
    } else {
        check_keys("shape", obj, &["n"])?;
        Ok(Shape::Flat { n: u("n")? })
    }
}

fn shape_to_json(s: Shape) -> Json {
    let mut o = BTreeMap::new();
    match s {
        Shape::Map { h, w, c } => {
            o.insert("c".to_string(), num(c));
            o.insert("h".to_string(), num(h));
            o.insert("w".to_string(), num(w));
        }
        Shape::Flat { n } => {
            o.insert("n".to_string(), num(n));
        }
        Shape::Mat { rows, cols } => {
            o.insert("cols".to_string(), num(cols));
            o.insert("rows".to_string(), num(rows));
        }
    }
    Json::Obj(o)
}

/// Parse one graph node. `inputs` entries are node *names* and must refer
/// to already-declared nodes (the schema keeps program order topological,
/// like the builder API).
fn graph_node_from_json(
    v: &Json,
    ids: &BTreeMap<String, NodeId>,
) -> Result<(String, Op, Vec<NodeId>)> {
    let obj = v.as_obj().context("each graph node must be an object")?;
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .context("each graph node needs a `name` string")?
        .to_string();
    let op_name = v
        .get("op")
        .and_then(Json::as_str)
        .with_context(|| format!("node `{name}`: missing `op` ({GRAPH_OPS})"))?;
    let u = |key: &str| -> Result<usize> {
        v.get(key).and_then(Json::as_usize).with_context(|| {
            format!("node `{name}`: field `{key}` must be a non-negative integer")
        })
    };
    let opt_u = |key: &str, default: usize| -> Result<usize> {
        match v.get(key) {
            None => Ok(default),
            Some(_) => u(key),
        }
    };
    let inputs: Vec<NodeId> = match v.get("inputs") {
        None => Vec::new(),
        Some(arr) => arr
            .as_arr()
            .with_context(|| format!("node `{name}`: `inputs` must be an array"))?
            .iter()
            .map(|i| {
                let refname = i.as_str().with_context(|| {
                    format!("node `{name}`: inputs must be node-name strings")
                })?;
                ids.get(refname).copied().ok_or_else(|| {
                    anyhow::anyhow!(
                        "node `{name}`: unknown input `{refname}` (inputs must \
                         be declared earlier in the graph)"
                    )
                })
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let op = match op_name {
        "input" => {
            check_keys("input node", obj, &node_keys(&["shape"]))?;
            let shape = shape_from_json(
                &name,
                v.get("shape").with_context(|| {
                    format!("node `{name}`: input nodes need a `shape`")
                })?,
            )?;
            Op::Input { shape }
        }
        "conv" => {
            check_keys(
                "conv node",
                obj,
                &node_keys(&["kh", "kw", "out_ch", "pad", "stride"]),
            )?;
            Op::Conv {
                out_ch: u("out_ch")?,
                kh: u("kh")?,
                kw: u("kw")?,
                stride: u("stride")?,
                pad: opt_u("pad", 0)?,
            }
        }
        "depthwise" => {
            check_keys(
                "depthwise node",
                obj,
                &node_keys(&["kh", "kw", "pad", "stride"]),
            )?;
            Op::DepthwiseConv {
                kh: u("kh")?,
                kw: u("kw")?,
                stride: u("stride")?,
                pad: opt_u("pad", 0)?,
            }
        }
        "linear" => {
            check_keys("linear node", obj, &node_keys(&["out_features"]))?;
            Op::Linear { out_features: u("out_features")? }
        }
        "matmul" => {
            check_keys("matmul node", obj, &node_keys(&["transpose_rhs"]))?;
            let transpose_rhs = match v.get("transpose_rhs") {
                None => false,
                Some(t) => t.as_bool().with_context(|| {
                    format!("node `{name}`: `transpose_rhs` must be a boolean")
                })?,
            };
            Op::MatMul { transpose_rhs }
        }
        "add" => {
            check_keys("add node", obj, &node_keys(&[]))?;
            Op::ElemwiseAdd
        }
        "pool" => {
            check_keys("pool node", obj, &node_keys(&[]))?;
            Op::Pool
        }
        "gap" => {
            check_keys("gap node", obj, &node_keys(&[]))?;
            Op::GlobalAvgPool
        }
        "relu" => {
            check_keys("relu node", obj, &node_keys(&[]))?;
            Op::Activation { f: ActFn::Relu }
        }
        "softmax" => {
            check_keys("softmax node", obj, &node_keys(&[]))?;
            Op::Activation { f: ActFn::Softmax }
        }
        other => anyhow::bail!(
            "node `{name}`: unknown op `{other}` (accepted: {GRAPH_OPS})"
        ),
    };
    anyhow::ensure!(
        inputs.len() == op.arity(),
        "node `{name}`: op `{op_name}` takes {} input(s), got {}",
        op.arity(),
        inputs.len()
    );
    Ok((name, op, inputs))
}

fn graph_from_json(name: &str, nodes: &[Json]) -> Result<Graph> {
    let mut g = Graph::new(name);
    let mut ids: BTreeMap<String, NodeId> = BTreeMap::new();
    for v in nodes {
        let (node_name, op, inputs) = graph_node_from_json(v, &ids)?;
        let id = g.push(&node_name, op, inputs);
        // A duplicate name overwrites the id binding here, but
        // `validate` rejects the graph before it can be used.
        ids.insert(node_name, id);
    }
    g.validate()?;
    Ok(g)
}

fn graph_to_json(g: &Graph) -> Json {
    let nodes: Vec<Json> = g
        .nodes
        .iter()
        .map(|node| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(node.name.clone()));
            if !node.inputs.is_empty() {
                o.insert(
                    "inputs".to_string(),
                    Json::Arr(
                        node.inputs
                            .iter()
                            .map(|id| Json::Str(g.node(*id).name.clone()))
                            .collect(),
                    ),
                );
            }
            let op = |s: &str| Json::Str(s.to_string());
            match node.op {
                Op::Input { shape } => {
                    o.insert("op".to_string(), op("input"));
                    o.insert("shape".to_string(), shape_to_json(shape));
                }
                Op::Conv { out_ch, kh, kw, stride, pad } => {
                    o.insert("op".to_string(), op("conv"));
                    o.insert("out_ch".to_string(), num(out_ch));
                    o.insert("kh".to_string(), num(kh));
                    o.insert("kw".to_string(), num(kw));
                    o.insert("stride".to_string(), num(stride));
                    o.insert("pad".to_string(), num(pad));
                }
                Op::DepthwiseConv { kh, kw, stride, pad } => {
                    o.insert("op".to_string(), op("depthwise"));
                    o.insert("kh".to_string(), num(kh));
                    o.insert("kw".to_string(), num(kw));
                    o.insert("stride".to_string(), num(stride));
                    o.insert("pad".to_string(), num(pad));
                }
                Op::Linear { out_features } => {
                    o.insert("op".to_string(), op("linear"));
                    o.insert("out_features".to_string(), num(out_features));
                }
                Op::MatMul { transpose_rhs } => {
                    o.insert("op".to_string(), op("matmul"));
                    if transpose_rhs {
                        o.insert("transpose_rhs".to_string(), Json::Bool(true));
                    }
                }
                Op::ElemwiseAdd => {
                    o.insert("op".to_string(), op("add"));
                }
                Op::Pool => {
                    o.insert("op".to_string(), op("pool"));
                }
                Op::GlobalAvgPool => {
                    o.insert("op".to_string(), op("gap"));
                }
                Op::Activation { f: ActFn::Relu } => {
                    o.insert("op".to_string(), op("relu"));
                }
                Op::Activation { f: ActFn::Softmax } => {
                    o.insert("op".to_string(), op("softmax"));
                }
            }
            Json::Obj(o)
        })
        .collect();
    Json::Arr(nodes)
}

/// Inline-network validation: every check that would otherwise surface as
/// a panic or a confusing mapper error deep inside a run.
fn validate_inline(net: &Network) -> Result<()> {
    anyhow::ensure!(!net.name.is_empty(), "inline network needs a non-empty name");
    anyhow::ensure!(
        !net.layers.is_empty(),
        "inline network `{}` needs at least one layer",
        net.name
    );
    for l in &net.layers {
        match l.kind {
            LayerKind::Conv {
                in_h,
                in_w,
                in_ch,
                out_ch,
                kh,
                kw,
                stride,
                pad,
                groups,
            } => {
                anyhow::ensure!(
                    in_h >= 1
                        && in_w >= 1
                        && in_ch >= 1
                        && out_ch >= 1
                        && kh >= 1
                        && kw >= 1
                        && stride >= 1,
                    "layer `{}`: conv dimensions and stride must be >= 1",
                    l.name
                );
                anyhow::ensure!(
                    in_h + 2 * pad >= kh && in_w + 2 * pad >= kw,
                    "layer `{}`: {kh}x{kw} kernel exceeds the padded \
                     {in_h}x{in_w} input",
                    l.name
                );
                anyhow::ensure!(
                    groups >= 1 && in_ch % groups == 0 && out_ch % groups == 0,
                    "layer `{}`: groups ({groups}) must divide in_ch \
                     ({in_ch}) and out_ch ({out_ch})",
                    l.name
                );
            }
            LayerKind::Linear { in_features, out_features } => {
                anyhow::ensure!(
                    in_features >= 1 && out_features >= 1,
                    "layer `{}`: linear features must be >= 1",
                    l.name
                );
            }
            LayerKind::MatMul { m, k, n } => {
                anyhow::ensure!(
                    m >= 1 && k >= 1 && n >= 1,
                    "layer `{}`: matmul dimensions must be >= 1",
                    l.name
                );
                anyhow::ensure!(
                    !l.pool && !l.gap,
                    "layer `{}`: pool/gap need a spatial feature map, which \
                     a matmul does not produce",
                    l.name
                );
            }
        }
    }
    net.validate()
}

fn layer_from_json(v: &Json) -> Result<LayerDesc> {
    let obj = v.as_obj().context("each network layer must be an object")?;
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .context("each network layer needs a `name` string")?
        .to_string();
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .with_context(|| {
            format!("layer `{name}`: missing `kind` (conv|linear|matmul)")
        })?;
    let u = |key: &str| -> Result<usize> {
        v.get(key).and_then(Json::as_usize).with_context(|| {
            format!("layer `{name}`: field `{key}` must be a non-negative integer")
        })
    };
    let b = |key: &str, default: bool| -> Result<bool> {
        match v.get(key) {
            None => Ok(default),
            Some(j) => j
                .as_bool()
                .with_context(|| format!("layer `{name}`: `{key}` must be a boolean")),
        }
    };
    match kind {
        "conv" => {
            check_keys(
                "conv layer",
                obj,
                &[
                    "gap", "groups", "in_ch", "in_h", "in_w", "kh", "kind",
                    "kw", "name", "out_ch", "pad", "pool", "relu", "stride",
                ],
            )?;
            Ok(LayerDesc {
                name: name.clone(),
                kind: LayerKind::Conv {
                    in_h: u("in_h")?,
                    in_w: u("in_w")?,
                    in_ch: u("in_ch")?,
                    out_ch: u("out_ch")?,
                    kh: u("kh")?,
                    kw: u("kw")?,
                    stride: u("stride")?,
                    pad: match v.get("pad") {
                        None => 0,
                        Some(_) => u("pad")?,
                    },
                    groups: match v.get("groups") {
                        None => 1,
                        Some(_) => u("groups")?,
                    },
                },
                pool: b("pool", false)?,
                gap: b("gap", false)?,
                relu: b("relu", true)?,
            })
        }
        "linear" => {
            check_keys(
                "linear layer",
                obj,
                &["in_features", "kind", "name", "out_features", "relu"],
            )?;
            Ok(LayerDesc {
                name: name.clone(),
                kind: LayerKind::Linear {
                    in_features: u("in_features")?,
                    out_features: u("out_features")?,
                },
                pool: false,
                gap: false,
                relu: b("relu", false)?,
            })
        }
        "matmul" => {
            check_keys("matmul layer", obj, &["k", "kind", "m", "n", "name", "relu"])?;
            Ok(LayerDesc {
                name: name.clone(),
                kind: LayerKind::MatMul { m: u("m")?, k: u("k")?, n: u("n")? },
                pool: false,
                gap: false,
                relu: b("relu", false)?,
            })
        }
        other => anyhow::bail!(
            "layer `{name}`: unknown kind `{other}` (accepted: conv, linear, \
             matmul)"
        ),
    }
}

fn layer_to_json(l: &LayerDesc) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(l.name.clone()));
    match l.kind {
        LayerKind::Conv { in_h, in_w, in_ch, out_ch, kh, kw, stride, pad, groups } => {
            o.insert("kind".to_string(), Json::Str("conv".to_string()));
            o.insert("in_h".to_string(), num(in_h));
            o.insert("in_w".to_string(), num(in_w));
            o.insert("in_ch".to_string(), num(in_ch));
            o.insert("out_ch".to_string(), num(out_ch));
            o.insert("kh".to_string(), num(kh));
            o.insert("kw".to_string(), num(kw));
            o.insert("stride".to_string(), num(stride));
            o.insert("pad".to_string(), num(pad));
            // Dense convs omit `groups` so pre-IR documents stay
            // canonical fixed points.
            if groups != 1 {
                o.insert("groups".to_string(), num(groups));
            }
            o.insert("pool".to_string(), Json::Bool(l.pool));
            o.insert("gap".to_string(), Json::Bool(l.gap));
            o.insert("relu".to_string(), Json::Bool(l.relu));
        }
        LayerKind::Linear { in_features, out_features } => {
            o.insert("kind".to_string(), Json::Str("linear".to_string()));
            o.insert("in_features".to_string(), num(in_features));
            o.insert("out_features".to_string(), num(out_features));
            o.insert("relu".to_string(), Json::Bool(l.relu));
        }
        LayerKind::MatMul { m, k, n } => {
            o.insert("kind".to_string(), Json::Str("matmul".to_string()));
            o.insert("m".to_string(), num(m));
            o.insert("k".to_string(), num(k));
            o.insert("n".to_string(), num(n));
            o.insert("relu".to_string(), Json::Bool(l.relu));
        }
    }
    Json::Obj(o)
}

fn residual_from_json(v: &Json) -> Result<Residual> {
    let obj = v
        .as_obj()
        .context("each residual must be an object with `from` and `into`")?;
    check_keys("residual", obj, &["from", "into"])?;
    let idx = |key: &str| -> Result<usize> {
        v.get(key).and_then(Json::as_usize).with_context(|| {
            format!("residual `{key}` must be a layer index (non-negative integer)")
        })
    };
    Ok(Residual { from_layer: idx("from")?, into_layer: idx("into")? })
}

fn residual_to_json(r: &Residual) -> Json {
    let mut o = BTreeMap::new();
    o.insert("from".to_string(), num(r.from_layer));
    o.insert("into".to_string(), num(r.into_layer));
    Json::Obj(o)
}

// ---- DeviceSpec -----------------------------------------------------------

/// The device: a timing/geometry preset plus explicit overrides. `None`
/// fields inherit the preset's value, exactly as the TOML loader and the
/// CLI flags always did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceSpec {
    /// One of [`PRESETS`].
    pub preset: String,
    pub channels: Option<usize>,
    pub ranks_per_channel: Option<usize>,
    pub banks_per_rank: Option<usize>,
    pub subarrays_per_bank: Option<usize>,
    pub rows: Option<usize>,
    pub cols: Option<usize>,
    pub internal_bus_bits: Option<usize>,
    pub adder_inputs: Option<usize>,
    pub tree_per_subarray: Option<bool>,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec {
            preset: "paper_favorable".to_string(),
            channels: None,
            ranks_per_channel: None,
            banks_per_rank: None,
            subarrays_per_bank: None,
            rows: None,
            cols: None,
            internal_bus_bits: None,
            adder_inputs: None,
            tree_per_subarray: None,
        }
    }
}

impl DeviceSpec {
    /// Resolve to a [`SimConfig`] at `n_bits`: preset first, then each
    /// override, then the geometry/arch validity checks — the same
    /// sequence (and therefore the same resulting config, field for
    /// field) as the legacy CLI and TOML paths.
    pub fn resolve(&self, n_bits: usize) -> Result<SimConfig> {
        let mut cfg = match self.preset.as_str() {
            "paper_favorable" | "cloud" => SimConfig::paper_favorable(n_bits),
            "conservative" | "edge" => SimConfig::conservative(n_bits),
            other => anyhow::bail!(
                "unknown device preset `{other}` (accepted: {})",
                PRESETS.join("|")
            ),
        };
        if let Some(v) = self.channels {
            cfg.geometry.channels = v;
        }
        if let Some(v) = self.ranks_per_channel {
            cfg.geometry.ranks_per_channel = v;
        }
        if let Some(v) = self.banks_per_rank {
            cfg.geometry.banks_per_rank = v;
        }
        if let Some(v) = self.subarrays_per_bank {
            cfg.geometry.subarrays_per_bank = v;
        }
        if let Some(v) = self.rows {
            cfg.geometry.rows = v;
        }
        if let Some(v) = self.cols {
            cfg.geometry.cols = v;
        }
        if let Some(v) = self.internal_bus_bits {
            cfg.timing.internal_bus_bits = v;
        }
        if let Some(v) = self.adder_inputs {
            cfg.adder_inputs = v;
        }
        if let Some(v) = self.tree_per_subarray {
            cfg.tree_per_subarray = v;
        }
        cfg.geometry.validate()?;
        anyhow::ensure!(
            cfg.adder_inputs.is_power_of_two(),
            "device.adder_inputs must be a power of two, got {}",
            cfg.adder_inputs
        );
        Ok(cfg)
    }

    fn from_json(v: &Json) -> Result<DeviceSpec> {
        let obj = v.as_obj().context("`device` must be an object")?;
        check_keys(
            "device",
            obj,
            &[
                "adder_inputs", "banks_per_rank", "channels", "cols",
                "internal_bus_bits", "preset", "ranks_per_channel", "rows",
                "subarrays_per_bank", "tree_per_subarray",
            ],
        )?;
        let mut d = DeviceSpec::default();
        if let Some(p) = v.get("preset") {
            d.preset = p
                .as_str()
                .context("device.preset must be a string")?
                .to_string();
        }
        let u = |key: &str| -> Result<Option<usize>> {
            match v.get(key) {
                None => Ok(None),
                Some(j) => j.as_usize().map(Some).with_context(|| {
                    format!("device.{key} must be a non-negative integer")
                }),
            }
        };
        d.channels = u("channels")?;
        d.ranks_per_channel = u("ranks_per_channel")?;
        d.banks_per_rank = u("banks_per_rank")?;
        d.subarrays_per_bank = u("subarrays_per_bank")?;
        d.rows = u("rows")?;
        d.cols = u("cols")?;
        d.internal_bus_bits = u("internal_bus_bits")?;
        d.adder_inputs = u("adder_inputs")?;
        if let Some(t) = v.get("tree_per_subarray") {
            d.tree_per_subarray =
                Some(t.as_bool().context("device.tree_per_subarray must be a boolean")?);
        }
        Ok(d)
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("preset".to_string(), Json::Str(self.preset.clone()));
        let mut opt = |key: &str, v: Option<usize>| {
            if let Some(v) = v {
                o.insert(key.to_string(), num(v));
            }
        };
        opt("channels", self.channels);
        opt("ranks_per_channel", self.ranks_per_channel);
        opt("banks_per_rank", self.banks_per_rank);
        opt("subarrays_per_bank", self.subarrays_per_bank);
        opt("rows", self.rows);
        opt("cols", self.cols);
        opt("internal_bus_bits", self.internal_bus_bits);
        opt("adder_inputs", self.adder_inputs);
        if let Some(t) = self.tree_per_subarray {
            o.insert("tree_per_subarray".to_string(), Json::Bool(t));
        }
        Json::Obj(o)
    }
}

// ---- ShardSpec / RunSpec --------------------------------------------------

/// How the network is sharded across the channel × rank grid. JSON form is
/// the policy spelling (`replicate`, `layersplit`, `hybrid:<n>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardSpec {
    pub policy: ShardPolicy,
}

impl ShardSpec {
    pub fn parse(s: &str) -> Result<ShardSpec> {
        Ok(ShardSpec { policy: ShardPolicy::parse(s)? })
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.policy)
    }
}

/// Which mapping optimizer prices the run. JSON form is the lowercase
/// name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mapper {
    /// Algorithm 1 with the spec's P vector — the frozen default path.
    #[default]
    Paper,
    /// The `mapopt` beam search over k, tiling and data layout; never
    /// worse than the paper mapping under the analytic cost.
    Search,
}

impl Mapper {
    pub fn parse(s: &str) -> Result<Mapper> {
        match s {
            "paper" => Ok(Mapper::Paper),
            "search" => Ok(Mapper::Search),
            other => anyhow::bail!("unknown run.mapper `{other}` (try paper|search)"),
        }
    }
}

impl std::fmt::Display for Mapper {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mapper::Paper => write!(f, "paper"),
            Mapper::Search => write!(f, "search"),
        }
    }
}

/// One simulation run: operand precision, the paper's P vector, sharding,
/// and the (additive) mapping-search knobs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunSpec {
    /// Operand bit width n.
    pub precision: usize,
    /// Per-layer parallelism (broadcast if length 1) — the paper's P factor.
    pub ks: Vec<usize>,
    pub shard: ShardSpec,
    /// Mapping optimizer; `paper` (the default) is bitwise-frozen.
    pub mapper: Mapper,
    /// `mapopt` beam width (k-branches expanded per layer). Values below
    /// 1 are clamped to 1 at search time (diagnostic W052).
    pub beam: usize,
    /// `mapopt` exact-pricing budget per layer beyond the always-priced
    /// paper candidate; 0 degenerates to the paper mapping (W050).
    pub search_budget: usize,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            precision: 8,
            ks: vec![1],
            shard: ShardSpec::default(),
            mapper: Mapper::default(),
            beam: RunSpec::DEFAULT_BEAM,
            search_budget: RunSpec::DEFAULT_SEARCH_BUDGET,
        }
    }
}

impl RunSpec {
    pub const DEFAULT_BEAM: usize = 4;
    pub const DEFAULT_SEARCH_BUDGET: usize = 64;

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (1..=64).contains(&self.precision),
            "run.precision must be in 1..=64 bits, got {}",
            self.precision
        );
        anyhow::ensure!(!self.ks.is_empty(), "run.ks must not be empty");
        anyhow::ensure!(
            self.ks.iter().all(|&k| k >= 1),
            "run.ks entries must be >= 1, got {:?}",
            self.ks
        );
        Ok(())
    }

    fn from_json(v: &Json) -> Result<RunSpec> {
        let obj = v.as_obj().context("`run` must be an object")?;
        check_keys("run", obj, &["beam", "ks", "mapper", "precision", "search_budget", "shard"])?;
        let mut run = RunSpec::default();
        if let Some(k) = v.get("ks") {
            let ints = k.i64_vec().context("run.ks must be an array of integers")?;
            anyhow::ensure!(
                ints.iter().all(|&x| x >= 1),
                "run.ks entries must be >= 1, got {ints:?}"
            );
            run.ks = ints.into_iter().map(|x| x as usize).collect();
        }
        if let Some(p) = v.get("precision") {
            run.precision = p
                .as_usize()
                .context("run.precision must be a positive integer")?;
        }
        if let Some(s) = v.get("shard") {
            run.shard =
                ShardSpec::parse(s.as_str().context("run.shard must be a string")?)?;
        }
        if let Some(m) = v.get("mapper") {
            run.mapper =
                Mapper::parse(m.as_str().context("run.mapper must be a string")?)?;
        }
        if let Some(b) = v.get("beam") {
            run.beam = b.as_usize().context("run.beam must be a non-negative integer")?;
        }
        if let Some(b) = v.get("search_budget") {
            run.search_budget =
                b.as_usize().context("run.search_budget must be a non-negative integer")?;
        }
        Ok(run)
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("ks".to_string(), Json::Arr(self.ks.iter().map(|&k| num(k)).collect()));
        o.insert("precision".to_string(), num(self.precision));
        o.insert("shard".to_string(), Json::Str(self.shard.to_string()));
        // Search knobs are emitted only off their defaults, keeping the
        // pre-search canonical corpus byte-stable.
        if self.mapper != Mapper::Paper {
            o.insert("mapper".to_string(), Json::Str(self.mapper.to_string()));
        }
        if self.beam != RunSpec::DEFAULT_BEAM {
            o.insert("beam".to_string(), num(self.beam));
        }
        if self.search_budget != RunSpec::DEFAULT_SEARCH_BUDGET {
            o.insert("search_budget".to_string(), num(self.search_budget));
        }
        Json::Obj(o)
    }
}

// ---- ServeSpec ------------------------------------------------------------

/// Serving-fleet shape: either a homogeneous worker count (the legacy JSON
/// number form) or an explicit heterogeneous list of per-device presets
/// plus overrides (JSON array of `device` objects).
#[derive(Debug, Clone, PartialEq)]
pub enum DevicesSpec {
    /// `n` identical devices, each running the job's own device config.
    Count(usize),
    /// One entry per device; each resolves its own `SimConfig`, so an
    /// `edge`/`cloud` mix serves with per-device service times.
    Fleet(Vec<DeviceSpec>),
}

impl DevicesSpec {
    /// Number of devices this spec describes.
    pub fn count(&self) -> usize {
        match self {
            DevicesSpec::Count(n) => *n,
            DevicesSpec::Fleet(f) => f.len(),
        }
    }

    /// The per-device specs, when the fleet is heterogeneous.
    pub fn fleet(&self) -> Option<&[DeviceSpec]> {
        match self {
            DevicesSpec::Count(_) => None,
            DevicesSpec::Fleet(f) => Some(f),
        }
    }
}

/// Pool configuration for `Job::serve`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Fleet shape; `None` serves one worker per plan replica.
    pub devices: Option<DevicesSpec>,
    /// Fixed device batch (requests are padded up to it).
    pub batch: usize,
    /// Dispatch policy across devices.
    pub policy: Policy,
    /// Max time a request waits for its batch to fill before a partial
    /// batch is flushed.
    pub batch_window_ms: u64,
    /// Optional deterministic fault schedule (the chaos layer). Absent =
    /// fault-free serving, bit-for-bit the legacy path.
    pub faults: Option<FaultSpec>,
    /// Optional deadline/retry/failover/shedding policy. Absent = the
    /// behavior-preserving defaults.
    pub resilience: Option<ResilienceSpec>,
    /// Offered load (fraction of full-batch fleet capacity) for the
    /// virtual-time fleet report; `Job::fleet_report` defaults to 0.9.
    pub load: Option<f64>,
    /// Optional open-loop arrival process (the traffic layer). Absent =
    /// the legacy uniform capacity-derived arrivals, bit-for-bit.
    pub arrival: Option<TrafficSpec>,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            devices: None,
            batch: 8,
            policy: Policy::RoundRobin,
            batch_window_ms: 2,
            faults: None,
            resilience: None,
            load: None,
            arrival: None,
        }
    }
}

impl ServeSpec {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.batch >= 1, "serve.batch must be >= 1");
        match &self.devices {
            Some(DevicesSpec::Count(n)) => {
                anyhow::ensure!(*n >= 1, "serve.devices must be >= 1");
            }
            Some(DevicesSpec::Fleet(f)) => {
                anyhow::ensure!(!f.is_empty(), "serve.devices fleet must not be empty");
            }
            None => {}
        }
        if let Some(a) = &self.arrival {
            a.validate()?;
        }
        if let Some(f) = &self.faults {
            f.validate()?;
        }
        if let Some(r) = &self.resilience {
            r.validate()?;
        }
        if let Some(l) = self.load {
            anyhow::ensure!(
                l > 0.0 && l.is_finite(),
                "serve.load must be positive, got {l}"
            );
        }
        Ok(())
    }

    fn from_json(v: &Json) -> Result<ServeSpec> {
        let obj = v.as_obj().context("`serve` must be an object")?;
        check_keys(
            "serve",
            obj,
            &[
                "arrival", "batch", "batch_window_ms", "devices", "faults", "load",
                "policy", "resilience",
            ],
        )?;
        let mut s = ServeSpec::default();
        if let Some(d) = v.get("devices") {
            s.devices = Some(match d {
                Json::Arr(items) => DevicesSpec::Fleet(
                    items.iter().map(DeviceSpec::from_json).collect::<Result<Vec<_>>>()?,
                ),
                _ => DevicesSpec::Count(d.as_usize().context(
                    "serve.devices must be a positive integer or an array of \
                     device objects",
                )?),
            });
        }
        if let Some(b) = v.get("batch") {
            s.batch = b.as_usize().context("serve.batch must be a positive integer")?;
        }
        if let Some(p) = v.get("policy") {
            s.policy = parse_policy(p.as_str().context("serve.policy must be a string")?)?;
        }
        if let Some(w) = v.get("batch_window_ms") {
            s.batch_window_ms = w
                .as_usize()
                .context("serve.batch_window_ms must be a non-negative integer")?
                as u64;
        }
        if let Some(f) = v.get("faults") {
            s.faults = Some(faults_from_json(f)?);
        }
        if let Some(r) = v.get("resilience") {
            s.resilience = Some(resilience_from_json(r)?);
        }
        if let Some(l) = v.get("load") {
            s.load = Some(l.as_f64().context("serve.load must be a number")?);
        }
        if let Some(a) = v.get("arrival") {
            s.arrival = Some(arrival_from_json(a)?);
        }
        Ok(s)
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        if let Some(a) = &self.arrival {
            o.insert("arrival".to_string(), arrival_to_json(a));
        }
        o.insert("batch".to_string(), num(self.batch));
        o.insert("batch_window_ms".to_string(), num(self.batch_window_ms as usize));
        match &self.devices {
            Some(DevicesSpec::Count(n)) => {
                o.insert("devices".to_string(), num(*n));
            }
            Some(DevicesSpec::Fleet(f)) => {
                o.insert(
                    "devices".to_string(),
                    Json::Arr(f.iter().map(DeviceSpec::to_json).collect()),
                );
            }
            None => {}
        }
        if let Some(f) = &self.faults {
            o.insert("faults".to_string(), faults_to_json(f));
        }
        if let Some(l) = self.load {
            o.insert("load".to_string(), Json::Num(l));
        }
        o.insert("policy".to_string(), Json::Str(policy_name(self.policy).to_string()));
        if let Some(r) = &self.resilience {
            o.insert("resilience".to_string(), resilience_to_json(r));
        }
        Json::Obj(o)
    }
}

// ---- fault / resilience sections ------------------------------------------

fn faults_from_json(v: &Json) -> Result<FaultSpec> {
    let obj = v.as_obj().context("serve.faults must be an object")?;
    check_keys(
        "serve.faults",
        obj,
        &["crash", "seed", "storm", "straggler", "transient"],
    )?;
    let seed = v
        .get("seed")
        .context("serve.faults.seed is required (one seed reproduces the schedule)")?
        .as_usize()
        .context("serve.faults.seed must be a non-negative integer")? as u64;
    let mut f = FaultSpec { seed, ..FaultSpec::none() };
    if let Some(t) = v.get("transient") {
        f.transient = t.as_f64().context("serve.faults.transient must be a number")?;
    }
    if let Some(s) = v.get("straggler") {
        let so = s.as_obj().context("serve.faults.straggler must be an object")?;
        check_keys("serve.faults.straggler", so, &["factor", "prob"])?;
        f.straggler = Some(StragglerSpec {
            prob: s
                .get("prob")
                .context("serve.faults.straggler.prob is required")?
                .as_f64()
                .context("serve.faults.straggler.prob must be a number")?,
            factor: s
                .get("factor")
                .context("serve.faults.straggler.factor is required")?
                .as_f64()
                .context("serve.faults.straggler.factor must be a number")?,
        });
    }
    if let Some(s) = v.get("storm") {
        let so = s.as_obj().context("serve.faults.storm must be an object")?;
        check_keys("serve.faults.storm", so, &["duty", "factor", "period"])?;
        f.storm = Some(StormSpec {
            period: s
                .get("period")
                .context("serve.faults.storm.period is required")?
                .as_usize()
                .context("serve.faults.storm.period must be a positive integer")?
                as u64,
            duty: s
                .get("duty")
                .context("serve.faults.storm.duty is required")?
                .as_usize()
                .context("serve.faults.storm.duty must be a non-negative integer")?
                as u64,
            factor: s
                .get("factor")
                .context("serve.faults.storm.factor is required")?
                .as_f64()
                .context("serve.faults.storm.factor must be a number")?,
        });
    }
    if let Some(c) = v.get("crash") {
        let arr = c.as_arr().context("serve.faults.crash must be an array")?;
        for e in arr {
            let eo = e.as_obj().context("serve.faults.crash entries must be objects")?;
            check_keys("serve.faults.crash entry", eo, &["after", "device", "down_for"])?;
            f.crash.push(CrashSpec {
                device: e
                    .get("device")
                    .context("serve.faults.crash.device is required")?
                    .as_usize()
                    .context("serve.faults.crash.device must be a non-negative integer")?,
                after: e
                    .get("after")
                    .map(|a| {
                        a.as_usize()
                            .context("serve.faults.crash.after must be a non-negative integer")
                    })
                    .transpose()?
                    .unwrap_or(0) as u64,
                down_for: e
                    .get("down_for")
                    .map(|d| {
                        d.as_usize()
                            .context("serve.faults.crash.down_for must be a positive integer")
                            .map(|n| n as u64)
                    })
                    .transpose()?,
            });
        }
    }
    Ok(f)
}

fn faults_to_json(f: &FaultSpec) -> Json {
    let mut o = BTreeMap::new();
    if !f.crash.is_empty() {
        o.insert(
            "crash".to_string(),
            Json::Arr(
                f.crash
                    .iter()
                    .map(|c| {
                        let mut e = BTreeMap::new();
                        e.insert("after".to_string(), num(c.after as usize));
                        e.insert("device".to_string(), num(c.device));
                        if let Some(d) = c.down_for {
                            e.insert("down_for".to_string(), num(d as usize));
                        }
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
    }
    o.insert("seed".to_string(), num(f.seed as usize));
    if let Some(s) = &f.storm {
        let mut so = BTreeMap::new();
        so.insert("duty".to_string(), num(s.duty as usize));
        so.insert("factor".to_string(), Json::Num(s.factor));
        so.insert("period".to_string(), num(s.period as usize));
        o.insert("storm".to_string(), Json::Obj(so));
    }
    if let Some(s) = &f.straggler {
        let mut so = BTreeMap::new();
        so.insert("factor".to_string(), Json::Num(s.factor));
        so.insert("prob".to_string(), Json::Num(s.prob));
        o.insert("straggler".to_string(), Json::Obj(so));
    }
    o.insert("transient".to_string(), Json::Num(f.transient));
    Json::Obj(o)
}

fn resilience_from_json(v: &Json) -> Result<ResilienceSpec> {
    let obj = v.as_obj().context("serve.resilience must be an object")?;
    check_keys(
        "serve.resilience",
        obj,
        &[
            "backoff_cap_ms",
            "backoff_ms",
            "deadline_ms",
            "probe_after_ms",
            "queue_cap",
            "quarantine_after",
            "retries",
        ],
    )?;
    let mut r = ResilienceSpec::default();
    if let Some(d) = v.get("deadline_ms") {
        r.deadline_ms = Some(
            d.as_usize().context("serve.resilience.deadline_ms must be a positive integer")?
                as u64,
        );
    }
    if let Some(n) = v.get("retries") {
        r.retries = n
            .as_usize()
            .context("serve.resilience.retries must be a non-negative integer")?
            as u32;
    }
    if let Some(n) = v.get("backoff_ms") {
        r.backoff_ms = n
            .as_usize()
            .context("serve.resilience.backoff_ms must be a positive integer")?
            as u64;
    }
    if let Some(n) = v.get("backoff_cap_ms") {
        r.backoff_cap_ms = n
            .as_usize()
            .context("serve.resilience.backoff_cap_ms must be a positive integer")?
            as u64;
    }
    if let Some(n) = v.get("queue_cap") {
        r.queue_cap = n
            .as_usize()
            .context("serve.resilience.queue_cap must be a positive integer")?;
    }
    if let Some(n) = v.get("quarantine_after") {
        r.quarantine_after = n
            .as_usize()
            .context("serve.resilience.quarantine_after must be a non-negative integer")?
            as u32;
    }
    if let Some(n) = v.get("probe_after_ms") {
        r.probe_after_ms = n
            .as_usize()
            .context("serve.resilience.probe_after_ms must be a positive integer")?
            as u64;
    }
    Ok(r)
}

fn resilience_to_json(r: &ResilienceSpec) -> Json {
    let mut o = BTreeMap::new();
    o.insert("backoff_cap_ms".to_string(), num(r.backoff_cap_ms as usize));
    o.insert("backoff_ms".to_string(), num(r.backoff_ms as usize));
    if let Some(d) = r.deadline_ms {
        o.insert("deadline_ms".to_string(), num(d as usize));
    }
    o.insert("probe_after_ms".to_string(), num(r.probe_after_ms as usize));
    o.insert("queue_cap".to_string(), num(r.queue_cap));
    o.insert("quarantine_after".to_string(), num(r.quarantine_after as usize));
    o.insert("retries".to_string(), num(r.retries as usize));
    Json::Obj(o)
}

// ---- arrival section ------------------------------------------------------

fn arrival_from_json(v: &Json) -> Result<TrafficSpec> {
    let obj = v.as_obj().context("serve.arrival must be an object")?;
    check_keys(
        "serve.arrival",
        obj,
        &["amplitude", "duty", "period_ms", "process", "rate", "seed"],
    )?;
    let mut t = TrafficSpec::default();
    if let Some(p) = v.get("process") {
        t.kind =
            parse_arrival(p.as_str().context("serve.arrival.process must be a string")?)?;
    }
    if let Some(r) = v.get("rate") {
        t.rate_rps = r.as_f64().context("serve.arrival.rate must be a number")?;
    }
    if let Some(s) = v.get("seed") {
        t.seed = s
            .as_usize()
            .context("serve.arrival.seed must be a non-negative integer")?
            as u64;
    }
    if let Some(p) = v.get("period_ms") {
        t.period_ms = p
            .as_usize()
            .context("serve.arrival.period_ms must be a positive integer")?
            as u64;
    }
    if let Some(d) = v.get("duty") {
        t.duty = d.as_f64().context("serve.arrival.duty must be a number")?;
    }
    if let Some(a) = v.get("amplitude") {
        t.amplitude = a.as_f64().context("serve.arrival.amplitude must be a number")?;
    }
    Ok(t)
}

/// Canonical arrival JSON: `process` always, every other knob only off its
/// default — specs written before the traffic layer stay byte-stable.
fn arrival_to_json(t: &TrafficSpec) -> Json {
    let d = TrafficSpec::default();
    let mut o = BTreeMap::new();
    if t.amplitude != d.amplitude {
        o.insert("amplitude".to_string(), Json::Num(t.amplitude));
    }
    if t.duty != d.duty {
        o.insert("duty".to_string(), Json::Num(t.duty));
    }
    if t.period_ms != d.period_ms {
        o.insert("period_ms".to_string(), num(t.period_ms as usize));
    }
    o.insert("process".to_string(), Json::Str(arrival_name(t.kind).to_string()));
    if t.rate_rps != d.rate_rps {
        o.insert("rate".to_string(), Json::Num(t.rate_rps));
    }
    if t.seed != d.seed {
        o.insert("seed".to_string(), num(t.seed as usize));
    }
    Json::Obj(o)
}

// ---- Spec -----------------------------------------------------------------

/// The top-level versioned spec: everything `Job` needs to run.
#[derive(Debug, Clone, PartialEq)]
pub struct Spec {
    pub network: NetworkSpec,
    pub device: DeviceSpec,
    pub run: RunSpec,
    /// Present when the spec also describes a serving pool.
    pub serve: Option<ServeSpec>,
    /// Synthetic traffic volume for makespan reporting / serving drivers.
    pub images: usize,
}

impl Spec {
    pub fn new(network: NetworkSpec) -> Spec {
        Spec {
            network,
            device: DeviceSpec::default(),
            run: RunSpec::default(),
            serve: None,
            images: 64,
        }
    }

    /// Spec over a builtin network with all defaults.
    pub fn builtin(name: &str) -> Spec {
        Spec::new(NetworkSpec::Builtin(name.to_string()))
    }

    /// Spec over an inline network description.
    pub fn inline(net: Network) -> Spec {
        Spec::new(NetworkSpec::Inline(net))
    }

    /// Spec over an inline `pim::ir` operator graph.
    pub fn inline_graph(graph: Graph) -> Spec {
        Spec::new(NetworkSpec::Graph(graph))
    }

    pub fn with_preset(mut self, preset: &str) -> Spec {
        self.device.preset = preset.to_string();
        self
    }

    pub fn with_precision(mut self, bits: usize) -> Spec {
        self.run.precision = bits;
        self
    }

    pub fn with_ks(mut self, ks: Vec<usize>) -> Spec {
        self.run.ks = ks;
        self
    }

    /// Resize the device grid (scale-out knob).
    pub fn with_grid(mut self, channels: usize, ranks_per_channel: usize) -> Spec {
        self.device.channels = Some(channels);
        self.device.ranks_per_channel = Some(ranks_per_channel);
        self
    }

    pub fn with_shard(mut self, policy: ShardPolicy) -> Spec {
        self.run.shard = ShardSpec { policy };
        self
    }

    /// Select the mapping path: `Mapper::Paper` (the frozen default) or
    /// `Mapper::Search` (the `pim::mapopt` beam search).
    pub fn with_mapper(mut self, mapper: Mapper) -> Spec {
        self.run.mapper = mapper;
        self
    }

    pub fn with_subarrays_per_bank(mut self, subarrays: usize) -> Spec {
        self.device.subarrays_per_bank = Some(subarrays);
        self
    }

    pub fn with_tree_per_subarray(mut self, tree_per_subarray: bool) -> Spec {
        self.device.tree_per_subarray = Some(tree_per_subarray);
        self
    }

    pub fn with_serve(mut self, serve: ServeSpec) -> Spec {
        self.serve = Some(serve);
        self
    }

    /// Value-level validation (no network resolution). `Job::new` runs
    /// this plus the network-dependent checks.
    pub fn validate(&self) -> Result<()> {
        self.run.validate()?;
        if let Some(serve) = &self.serve {
            serve.validate()?;
        }
        Ok(())
    }

    /// Resolve device + run into the engine's [`SimConfig`].
    pub fn resolve_config(&self) -> Result<SimConfig> {
        self.run.validate()?;
        let mut cfg = self.device.resolve(self.run.precision)?;
        cfg.ks = self.run.ks.clone();
        cfg.shard = self.run.shard.policy;
        Ok(cfg)
    }

    /// Parse a versioned spec document. Rejects any `api_version` other
    /// than [`API_VERSION`] and any unknown field, before resolution.
    pub fn from_json_text(text: &str) -> Result<Spec> {
        let v = Json::parse(text)?;
        let obj = v.as_obj().context("spec must be a JSON object")?;
        check_keys(
            "spec",
            obj,
            &["api_version", "device", "images", "network", "run", "serve"],
        )?;
        let version = v.get("api_version").and_then(Json::as_i64).context(
            "spec is missing `api_version` (this build writes api_version 1)",
        )?;
        anyhow::ensure!(
            version == API_VERSION,
            "unsupported api_version {version}: this build supports \
             api_version {API_VERSION}"
        );
        let network = NetworkSpec::from_json(v.get("network").context(
            "spec is missing `network` (a builtin name or an inline object)",
        )?)?;
        let device = match v.get("device") {
            None => DeviceSpec::default(),
            Some(d) => DeviceSpec::from_json(d)?,
        };
        let run = match v.get("run") {
            None => RunSpec::default(),
            Some(r) => RunSpec::from_json(r)?,
        };
        let serve = match v.get("serve") {
            None => None,
            Some(s) => Some(ServeSpec::from_json(s)?),
        };
        let images = match v.get("images") {
            None => 64,
            Some(i) => i.as_usize().context("`images` must be a non-negative integer")?,
        };
        let spec = Spec { network, device, run, serve, images };
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("api_version".to_string(), Json::Num(API_VERSION as f64));
        o.insert("device".to_string(), self.device.to_json());
        o.insert("images".to_string(), num(self.images));
        o.insert("network".to_string(), self.network.to_json());
        o.insert("run".to_string(), self.run.to_json());
        if let Some(s) = &self.serve {
            o.insert("serve".to_string(), s.to_json());
        }
        Json::Obj(o)
    }

    /// Canonical pretty JSON (the byte-exact form `examples/specs/` uses).
    pub fn to_json_text(&self) -> String {
        self.to_json().pretty()
    }

    /// Deserialize the legacy TOML experiment format into a spec — the
    /// `config` subcommand's shim path. Key names and semantics (including
    /// the `max(1)` clamp on `map.ks`) are unchanged from the pre-`api`
    /// loader.
    pub fn from_toml(text: &str) -> Result<Spec> {
        let t = Toml::parse(text)?;
        let net_name = t.get_str("network", "pimnet").to_string();
        let network = nets::by_name(&net_name)?;
        let mut spec = Spec::builtin(&net_name);
        spec.device.preset = t.get_str("preset", "paper_favorable").to_string();
        spec.run.precision = t.get_usize("n_bits", 8);
        if let Some(ks) = t.get("map.ks").and_then(Value::as_int_array) {
            anyhow::ensure!(
                ks.len() == 1 || ks.len() == network.layers.len(),
                "map.ks must have 1 or {} entries, got {}",
                network.layers.len(),
                ks.len()
            );
            spec.run.ks = ks.iter().map(|&v| v.max(1) as usize).collect();
        }
        if let Some(s) = t.get("shard").and_then(Value::as_str) {
            spec.run.shard = ShardSpec::parse(s)?;
        }
        spec.device.channels = t.get("dram.channels").and_then(Value::as_usize);
        spec.device.ranks_per_channel =
            t.get("dram.ranks_per_channel").and_then(Value::as_usize);
        spec.device.subarrays_per_bank =
            t.get("dram.subarrays_per_bank").and_then(Value::as_usize);
        spec.device.cols = t.get("dram.cols").and_then(Value::as_usize);
        spec.device.rows = t.get("dram.rows").and_then(Value::as_usize);
        spec.device.internal_bus_bits =
            t.get("dram.internal_bus_bits").and_then(Value::as_usize);
        spec.device.adder_inputs = t.get("arch.adder_inputs").and_then(Value::as_usize);
        spec.device.tree_per_subarray =
            t.get("arch.tree_per_subarray").and_then(Value::as_bool);
        spec.images = t.get_usize("images", 64);
        Ok(spec)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests assert by panicking
mod tests {
    use super::*;

    fn tiny_inline() -> Network {
        Network {
            name: "tinynet".to_string(),
            layers: vec![
                LayerDesc::conv("c1", (8, 8), 1, 8, 3, 1, 1, true),
                LayerDesc::linear("fc1", 128, 32, true),
                LayerDesc::linear("fc2", 32, 10, false),
            ],
            residuals: vec![],
        }
    }

    #[test]
    fn builtin_spec_roundtrips() {
        let spec = Spec::builtin("vgg16")
            .with_preset("conservative")
            .with_grid(2, 4)
            .with_shard(ShardPolicy::LayerSplit)
            .with_serve(ServeSpec {
                devices: Some(DevicesSpec::Count(3)),
                policy: Policy::LeastLoaded,
                ..ServeSpec::default()
            });
        let text = spec.to_json_text();
        let parsed = Spec::from_json_text(&text).unwrap();
        assert_eq!(parsed, spec);
        // Canonical: serialize is a fixed point.
        assert_eq!(parsed.to_json_text(), text);
    }

    #[test]
    fn fault_injected_serve_spec_roundtrips() {
        let spec = Spec::builtin("pimnet").with_preset("conservative").with_serve(
            ServeSpec {
                devices: Some(DevicesSpec::Count(4)),
                policy: Policy::TwoChoices,
                faults: Some(FaultSpec {
                    seed: 0xC0FFEE,
                    transient: 0.1,
                    straggler: Some(StragglerSpec { prob: 0.05, factor: 8.0 }),
                    storm: Some(StormSpec { period: 64, duty: 8, factor: 2.5 }),
                    crash: vec![CrashSpec { device: 1, after: 10, down_for: Some(20) }],
                }),
                resilience: Some(ResilienceSpec {
                    deadline_ms: Some(50),
                    retries: 3,
                    quarantine_after: 4,
                    ..ResilienceSpec::default()
                }),
                load: Some(0.8),
                ..ServeSpec::default()
            },
        );
        let text = spec.to_json_text();
        let parsed = Spec::from_json_text(&text).unwrap();
        assert_eq!(parsed, spec);
        // Canonical: serialize is a fixed point.
        assert_eq!(parsed.to_json_text(), text);
        // And the sections carry through intact.
        let s = parsed.serve.unwrap();
        assert_eq!(s.faults.as_ref().unwrap().seed, 0xC0FFEE);
        assert_eq!(s.resilience.unwrap().retries, 3);
    }

    #[test]
    fn fault_section_errors_are_actionable() {
        // Seed is required — the schedule must be reproducible.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet",
                "serve": {"faults": {"transient": 0.1}}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("seed"), "{err}");
        // Unknown fault fields are rejected, not silently defaulted.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet",
                "serve": {"faults": {"seed": 1, "transcient": 0.1}}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("transcient"), "{err}");
        // Out-of-range probabilities fail Job-level validation.
        let spec = Spec::builtin("pimnet").with_serve(ServeSpec {
            faults: Some(FaultSpec { seed: 1, transient: 1.5, ..FaultSpec::none() }),
            ..ServeSpec::default()
        });
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("transient"), "{err}");
        // Unknown resilience fields are rejected too.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet",
                "serve": {"resilience": {"retrys": 2}}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("retrys"), "{err}");
    }

    #[test]
    fn inline_spec_roundtrips_and_resolves() {
        let spec = Spec::inline(tiny_inline()).with_ks(vec![2]);
        let text = spec.to_json_text();
        let parsed = Spec::from_json_text(&text).unwrap();
        assert_eq!(parsed, spec);
        let net = parsed.network.resolve().unwrap();
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.layers[0].out_elems(), 128);
    }

    #[test]
    fn residuals_roundtrip() {
        let mut net = Network {
            name: "res".to_string(),
            layers: vec![
                LayerDesc::conv("c1", (8, 8), 1, 8, 3, 1, 1, false),
                LayerDesc::conv("c2", (8, 8), 8, 8, 3, 1, 1, false),
                LayerDesc::conv("c3", (8, 8), 8, 8, 3, 1, 1, false),
            ],
            residuals: vec![Residual { from_layer: 0, into_layer: 2 }],
        };
        net.validate().unwrap();
        let spec = Spec::inline(net.clone());
        let parsed = Spec::from_json_text(&spec.to_json_text()).unwrap();
        assert_eq!(parsed.network.resolve().unwrap().residuals, net.residuals);
        // A backwards residual is rejected at resolve time.
        net.residuals[0] = Residual { from_layer: 2, into_layer: 1 };
        assert!(Spec::inline(net).network.resolve().is_err());
    }

    #[test]
    fn version_gate() {
        let good = r#"{"api_version": 1, "network": "pimnet"}"#;
        Spec::from_json_text(good).unwrap();
        let err = Spec::from_json_text(r#"{"api_version": 2, "network": "pimnet"}"#)
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("api_version") && msg.contains('2'), "{msg}");
        let err = Spec::from_json_text(r#"{"network": "pimnet"}"#).unwrap_err();
        assert!(err.to_string().contains("api_version"), "{err}");
    }

    #[test]
    fn unknown_fields_are_errors() {
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet", "nets": "x"}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("`nets`"), "{err}");
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet", "run": {"kss": [1]}}"#,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("`kss`") && msg.contains("ks"), "{msg}");
    }

    #[test]
    fn value_errors_are_actionable() {
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet", "run": {"ks": [0]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet", "serve": {"policy": "rand"}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("rr"), "{err}");
        let mut spec = Spec::builtin("pimnet");
        spec.run.precision = 0;
        assert!(spec.resolve_config().is_err());
        spec.run.precision = 8;
        spec.device.adder_inputs = Some(100);
        let err = spec.resolve_config().unwrap_err();
        assert!(err.to_string().contains("power of two"), "{err}");
    }

    #[test]
    fn inline_validation_catches_bad_geometry() {
        // Kernel larger than the padded input would underflow the mapper.
        let net = Network {
            name: "bad".to_string(),
            layers: vec![LayerDesc::conv("c1", (4, 4), 1, 8, 11, 4, 0, false)],
            residuals: vec![],
        };
        let err = NetworkSpec::Inline(net).resolve().unwrap_err();
        assert!(err.to_string().contains("kernel"), "{err}");
        // A kernel wider than the *unpadded* input is fine when padding
        // compensates: H=4, K=5, p=1 → (4 + 2 - 5)/1 + 1 = 2×2 output.
        let net = Network {
            name: "padded".to_string(),
            layers: vec![LayerDesc::conv("c1", (4, 4), 1, 8, 5, 1, 1, false)],
            residuals: vec![],
        };
        let resolved = NetworkSpec::Inline(net).resolve().unwrap();
        assert_eq!(resolved.layers[0].conv_out_hw(), Some((2, 2)));
        assert_eq!(resolved.layers[0].out_elems(), 2 * 2 * 8);
        // Broken shape chain.
        let net = Network {
            name: "bad2".to_string(),
            layers: vec![
                LayerDesc::conv("c1", (8, 8), 1, 8, 3, 1, 1, false),
                LayerDesc::linear("fc", 100, 10, false),
            ],
            residuals: vec![],
        };
        assert!(NetworkSpec::Inline(net).resolve().is_err());
        // Empty layer list.
        let net =
            Network { name: "empty".to_string(), layers: vec![], residuals: vec![] };
        let err = NetworkSpec::Inline(net).resolve().unwrap_err();
        assert!(err.to_string().contains("at least one layer"), "{err}");
    }

    #[test]
    fn terse_layers_default_optionals() {
        let terse = r#"{
            "api_version": 1,
            "network": {
                "name": "t",
                "layers": [
                    {"kind": "conv", "name": "c1", "in_h": 8, "in_w": 8,
                     "in_ch": 1, "out_ch": 8, "kh": 3, "kw": 3, "stride": 1,
                     "pad": 1, "pool": true},
                    {"kind": "linear", "name": "fc", "in_features": 128,
                     "out_features": 10}
                ]
            }
        }"#;
        let spec = Spec::from_json_text(terse).unwrap();
        let net = spec.network.resolve().unwrap();
        assert!(net.layers[0].relu && !net.layers[0].gap);
        assert!(!net.layers[1].relu);
        assert_eq!(spec, Spec::from_json_text(&spec.to_json_text()).unwrap());
    }

    #[test]
    fn toml_resolves_like_the_legacy_loader() {
        let spec = Spec::from_toml(
            "preset = \"conservative\"\nnetwork = \"alexnet\"\nn_bits = 4\n\
             [map]\nks = [2]\n[arch]\nadder_inputs = 1024\n",
        )
        .unwrap();
        assert_eq!(spec.network.name(), "alexnet");
        assert_eq!(spec.run.precision, 4);
        assert_eq!(spec.run.ks, vec![2]);
        let cfg = spec.resolve_config().unwrap();
        assert_eq!(cfg.adder_inputs, 1024);
        assert!(!cfg.tree_per_subarray);
        // Scale-out keys.
        let spec = Spec::from_toml(
            "network = \"pimnet\"\nshard = \"layersplit\"\n\
             [dram]\nchannels = 2\nranks_per_channel = 2\n",
        )
        .unwrap();
        let cfg = spec.resolve_config().unwrap();
        assert_eq!(cfg.geometry.channels, 2);
        assert_eq!(cfg.geometry.ranks_per_channel, 2);
        assert_eq!(cfg.shard, ShardPolicy::LayerSplit);
    }

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("graphnet");
        let x = g.input("x", Shape::Map { h: 8, w: 8, c: 4 });
        let c0 = g.conv("c0", x, 4, 3, 1, 1);
        let d = g.depthwise("dw", c0, 3, 1, 1);
        let r = g.relu("dw.relu", d);
        let a = g.add("res", c0, r);
        let pw = g.conv("pw", a, 8, 1, 1, 0);
        let gp = g.global_avg_pool("pw.gap", pw);
        g.linear("fc", gp, 10);
        g
    }

    #[test]
    fn graph_spec_roundtrips_and_resolves() {
        let spec = Spec::inline_graph(tiny_graph()).with_preset("conservative");
        let text = spec.to_json_text();
        let parsed = Spec::from_json_text(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_json_text(), text, "canonical fixed point");
        let net = parsed.network.resolve().unwrap();
        assert_eq!(net.name, "graphnet");
        assert_eq!(net.layers.len(), 4);
        assert_eq!(net.residuals.len(), 1);
        assert!(net.layers[1].relu && !net.layers[1].gap);
        assert!(net.layers[2].gap);
    }

    #[test]
    fn graph_spec_parse_errors_are_actionable() {
        // Unknown op names the accepted set.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": {"name": "g", "graph": [
                {"name": "x", "op": "tensor"}
            ]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("conv"), "{err}");
        // Forward/unknown input references are rejected at parse time.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": {"name": "g", "graph": [
                {"name": "x", "op": "input", "shape": {"n": 8}},
                {"inputs": ["nope"], "name": "fc", "op": "linear",
                 "out_features": 4}
            ]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("declared earlier"), "{err}");
        // Arity mismatch.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": {"name": "g", "graph": [
                {"name": "x", "op": "input", "shape": {"n": 8}},
                {"inputs": ["x"], "name": "a", "op": "add"}
            ]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("2 input(s)"), "{err}");
        // Unknown node field.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": {"name": "g", "graph": [
                {"name": "x", "op": "input", "shape": {"n": 8}, "extra": 1}
            ]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("`extra`"), "{err}");
    }

    #[test]
    fn grouped_and_matmul_layers_roundtrip() {
        let net = Network {
            name: "g".to_string(),
            layers: vec![
                LayerDesc::depthwise("dw", (8, 8), 4, 3, 1, 1, false),
                LayerDesc::conv("pw", (8, 8), 4, 4, 1, 1, 0, false),
            ],
            residuals: vec![],
        };
        let spec = Spec::inline(net);
        let parsed = Spec::from_json_text(&spec.to_json_text()).unwrap();
        assert_eq!(parsed, spec);
        assert!(spec.to_json_text().contains("\"groups\": 4"));

        let net = Network {
            name: "mm".to_string(),
            layers: vec![
                LayerDesc::matmul("qk", 4, 16, 4, true),
                LayerDesc::matmul("av", 4, 4, 16, false),
            ],
            residuals: vec![],
        };
        let spec = Spec::inline(net);
        let parsed = Spec::from_json_text(&spec.to_json_text()).unwrap();
        assert_eq!(parsed, spec);
        parsed.network.resolve().unwrap();

        // Bad groups are caught at resolve time.
        let net = Network {
            name: "bad".to_string(),
            layers: vec![LayerDesc {
                name: "c".to_string(),
                kind: LayerKind::Conv {
                    in_h: 8,
                    in_w: 8,
                    in_ch: 4,
                    out_ch: 6,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad: 1,
                    groups: 4,
                },
                pool: false,
                gap: false,
                relu: true,
            }],
            residuals: vec![],
        };
        let err = NetworkSpec::Inline(net).resolve().unwrap_err();
        assert!(err.to_string().contains("groups"), "{err}");
    }

    #[test]
    fn builtin_registry_includes_the_generality_workloads() {
        assert!(BUILTIN_NETWORKS.contains(&"mobilenet_mini"));
        assert!(BUILTIN_NETWORKS.contains(&"tinyformer"));
        for name in BUILTIN_NETWORKS {
            Spec::builtin(name).network.resolve().unwrap();
        }
    }

    #[test]
    fn policy_spellings() {
        assert_eq!(parse_policy("rr").unwrap(), Policy::RoundRobin);
        assert_eq!(parse_policy("leastloaded").unwrap(), Policy::LeastLoaded);
        assert_eq!(parse_policy("two").unwrap(), Policy::TwoChoices);
        assert_eq!(parse_policy("backlog").unwrap(), Policy::Backlog);
        assert!(parse_policy("rand").is_err());
        for p in [
            Policy::RoundRobin,
            Policy::LeastLoaded,
            Policy::TwoChoices,
            Policy::Backlog,
        ] {
            assert_eq!(parse_policy(policy_name(p)).unwrap(), p);
        }
    }

    #[test]
    fn edge_and_cloud_presets_alias_the_timing_points() {
        let edge =
            Spec::builtin("pimnet").with_preset("edge").resolve_config().unwrap();
        let cloud =
            Spec::builtin("pimnet").with_preset("cloud").resolve_config().unwrap();
        // `edge` is the conservative point, `cloud` the paper-favorable one.
        assert!(!edge.tree_per_subarray && edge.refresh.is_some());
        assert!(cloud.tree_per_subarray && cloud.refresh.is_none());
        // Unknown presets still name the full accepted set.
        let err = Spec::builtin("pimnet")
            .with_preset("datacenter")
            .resolve_config()
            .unwrap_err();
        assert!(err.to_string().contains("edge"), "{err}");
    }

    #[test]
    fn hetero_fleet_and_arrival_roundtrip() {
        let spec = Spec::builtin("mobilenet_mini").with_serve(ServeSpec {
            devices: Some(DevicesSpec::Fleet(vec![
                DeviceSpec { preset: "cloud".to_string(), ..DeviceSpec::default() },
                DeviceSpec { preset: "edge".to_string(), ..DeviceSpec::default() },
            ])),
            policy: Policy::Backlog,
            arrival: Some(TrafficSpec {
                kind: crate::coordinator::ArrivalKind::Bursty,
                rate_rps: 2000.0,
                duty: 0.25,
                ..TrafficSpec::default()
            }),
            ..ServeSpec::default()
        });
        let text = spec.to_json_text();
        let parsed = Spec::from_json_text(&text).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.to_json_text(), text, "canonical fixed point");
        let s = parsed.serve.unwrap();
        assert_eq!(s.devices.as_ref().unwrap().count(), 2);
        assert_eq!(s.devices.unwrap().fleet().unwrap()[1].preset, "edge");
        // The legacy count form still parses (and stays a number on write).
        let spec = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet", "serve": {"devices": 2}}"#,
        )
        .unwrap();
        assert_eq!(spec.serve.as_ref().unwrap().devices, Some(DevicesSpec::Count(2)));
        assert!(spec.to_json_text().contains("\"devices\": 2"));
    }

    #[test]
    fn arrival_errors_are_actionable() {
        // An unknown process names the accepted set.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet",
                "serve": {"arrival": {"process": "sine"}}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("poisson"), "{err}");
        // Degenerate knobs fail value validation.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet",
                "serve": {"arrival": {"duty": 0}}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("duty"), "{err}");
        // Unknown arrival fields are rejected, not silently defaulted.
        let err = Spec::from_json_text(
            r#"{"api_version": 1, "network": "pimnet",
                "serve": {"arrival": {"rps": 100}}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("`rps`"), "{err}");
    }
}
