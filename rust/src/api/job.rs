//! The [`Job`] facade: one resolved spec, three ways to run it.
//!
//! `Job::new` validates and resolves a [`Spec`] into the existing
//! machinery — the network (builtin or inline), the engine's `SimConfig`,
//! and the serving options — **before any work runs**, so every
//! downstream failure is a real simulation outcome, not a config typo.
//!
//! Read paths:
//!   * [`Job::report`] — the scalar [`SimReport`] sweeps read.
//!   * [`Job::simulate_full`] — the exact [`SimResult`] the legacy free
//!     `sim::simulate()` returns, bitwise (results *and* errors):
//!     `tests/api_equivalence.rs` is the correctness bar.
//!   * [`Job::serve`] — a running `MultiDeviceServer` pool built from the
//!     spec's [`ServeSpec`](super::spec::ServeSpec), priced by the same
//!     session.
//!
//! For sweeps, [`Job::session`] hands out the incremental pricing session
//! (DESIGN.md §8) over the job's network and [`Job::report_variant`]
//! prices spec-level variations through it, reusing the per-layer cache
//! across points exactly like the pre-`api` bench loops did.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{
    simulate_fleet, FaultSpec, FaultyBackend, FleetConfig, FleetReport, MultiDeviceServer,
    Policy, PoolConfig, SimBackend,
};
use crate::mapopt::{self, SearchKnobs, SearchOutcome};
use crate::plan::PlanError;
use crate::sim::{SimConfig, SimReport, SimResult, SimSession};
use crate::workloads::Network;

use super::spec::{DeviceSpec, DevicesSpec, Mapper, RunSpec, ServeSpec, Spec};

/// Search knobs resolved from a spec's run section.
fn search_knobs(run: &RunSpec) -> SearchKnobs {
    SearchKnobs { beam: run.beam, budget: run.search_budget }
}

/// The broadcast rule: a `run.ks` vector is either a single value (applied
/// to every layer) or exactly one entry per layer of `net`.
fn check_ks(net: &Network, ks: &[usize]) -> Result<()> {
    anyhow::ensure!(
        ks.len() == 1 || ks.len() == net.layers.len(),
        "run.ks must have 1 or {} entries (one per layer of `{}`), got {}",
        net.layers.len(),
        net.name,
        ks.len()
    );
    Ok(())
}

/// A validated, resolved spec — the only construction path for simulation
/// and serving work.
pub struct Job {
    spec: Spec,
    net: Network,
    cfg: SimConfig,
}

impl Job {
    /// Validate `spec` and resolve it against the network/device/plan
    /// layers. Every value error (unknown network, bad preset, invalid
    /// geometry, malformed ks vector) surfaces here.
    pub fn new(spec: Spec) -> Result<Job> {
        let net = spec.network.resolve()?;
        check_ks(&net, &spec.run.ks)?;
        let cfg = spec.resolve_config()?;
        if let Some(serve) = &spec.serve {
            serve.validate()?;
        }
        Ok(Job { spec, net, cfg })
    }

    /// Parse a versioned JSON spec document and resolve it.
    pub fn from_json_text(text: &str) -> Result<Job> {
        Job::new(Spec::from_json_text(text)?)
    }

    /// Parse the legacy TOML experiment format and resolve it.
    pub fn from_toml(text: &str) -> Result<Job> {
        Job::new(Spec::from_toml(text)?)
    }

    pub fn spec(&self) -> &Spec {
        &self.spec
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    /// The resolved engine configuration.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// An incremental pricing session over this job's network, for sweeps
    /// (see [`Job::report_variant`]).
    pub fn session(&self) -> SimSession<'_> {
        SimSession::new(&self.net)
    }

    /// Run the static analyzer (`pim::analysis`) over this job: plan
    /// legality, per-layer residency, serve sanity. Warnings never block;
    /// errors carry the exact [`PlanError`] pricing would return.
    pub fn check(&self) -> crate::analysis::Diagnostics {
        crate::analysis::check_job(self)
    }

    /// Scalar report (the sweep read path). One-shot: uses a fresh
    /// session; hold a [`Job::session`] to amortize across calls.
    ///
    /// Fails fast through [`Job::check`]: a statically-provable plan
    /// failure returns *the identical error value* pricing would have
    /// produced, without starting the session.
    ///
    /// With `run.mapper: "search"` this is the searched mapping's report
    /// ([`Job::search`]'s `searched`); the default `"paper"` path is
    /// bitwise-frozen.
    pub fn report(&self) -> Result<SimReport, PlanError> {
        if let Some(e) = self.check().plan_error() {
            return Err(e.clone());
        }
        let mut session = self.session();
        if self.spec.run.mapper == Mapper::Search {
            return Ok(self.search_with(&mut session)?.searched);
        }
        session.report(&self.cfg)
    }

    /// Run the `mapopt` per-layer mapping search for this job (whatever
    /// the spec's `mapper` field says) and return the full outcome —
    /// per-layer choices, the paper baseline report and the searched
    /// report, which is never worse on latency.
    pub fn search(&self) -> Result<SearchOutcome, PlanError> {
        if let Some(e) = self.check().plan_error() {
            return Err(e.clone());
        }
        let mut session = self.session();
        self.search_with(&mut session)
    }

    /// [`Job::search`] through a caller-held session (from
    /// [`Job::session`]) — repeated searches and paper reports share the
    /// per-layer arena, so the sweep is absorbed by the fingerprint
    /// cache.
    pub fn search_with(&self, session: &mut SimSession<'_>) -> Result<SearchOutcome, PlanError> {
        mapopt::optimize(session, &self.cfg, &search_knobs(&self.spec.run))
    }

    /// Full-fidelity result — bitwise-identical to the legacy free
    /// `sim::simulate()` on the same resolved config, including errors.
    pub fn simulate_full(&self) -> Result<SimResult, PlanError> {
        let mut session = self.session();
        session.simulate_full(&self.cfg)
    }

    /// Price a spec variant through a shared session. The variant must
    /// keep this job's network (that is what the session's per-layer
    /// cache is keyed under); device/run knobs are free to change.
    pub fn report_variant(
        &self,
        session: &mut SimSession<'_>,
        spec: &Spec,
    ) -> Result<SimReport> {
        anyhow::ensure!(
            spec.network == self.spec.network,
            "variant spec must keep the job's network `{}` (got `{}`)",
            self.spec.network.name(),
            spec.network.name()
        );
        check_ks(&self.net, &spec.run.ks)?;
        let cfg = spec.resolve_config()?;
        if spec.run.mapper == Mapper::Search {
            let out = mapopt::optimize(session, &cfg, &search_knobs(&spec.run))?;
            return Ok(out.searched);
        }
        Ok(session.report(&cfg)?)
    }

    /// Price several spec variants through **one** session pass — the
    /// batched counterpart of a [`Job::report`] call per variant. Every
    /// variant must keep this job's network (the same rule as
    /// [`Job::report_variant`]); results come back in input order and a
    /// failing variant poisons only its own slot.
    pub fn report_batch(&self, variants: &[Spec]) -> Vec<Result<SimReport>> {
        let mut session = self.session();
        variants
            .iter()
            .map(|spec| self.report_variant(&mut session, spec))
            .collect()
    }

    /// Resolve one heterogeneous-fleet entry against this job's run
    /// section — the same preset + override + ks/shard sequence as
    /// `Spec::resolve_config`, just with the fleet entry's device.
    fn fleet_device_config(&self, dev: &DeviceSpec) -> Result<SimConfig> {
        let mut cfg = dev.resolve(self.spec.run.precision)?;
        cfg.ks = self.spec.run.ks.clone();
        cfg.shard = self.spec.run.shard.policy;
        Ok(cfg)
    }

    /// Per-device serving backends. Homogeneous paper fleets return one
    /// backend cloned per worker (the frozen legacy pricing); a
    /// heterogeneous fleet and/or `run.mapper: "search"` prices each
    /// device's own geometry — searched per device when asked, with the
    /// session's fingerprint cache absorbing the shared layers.
    fn serve_backends(
        &self,
        session: &mut SimSession<'_>,
        opts: &ServeSpec,
        devices: usize,
    ) -> Result<Vec<SimBackend>> {
        let fleet = opts.devices.as_ref().and_then(DevicesSpec::fleet);
        let searched = self.spec.run.mapper == Mapper::Search;
        if fleet.is_none() && !searched {
            let backend = SimBackend::from_session(session, &self.cfg, opts.batch)?;
            return Ok(vec![backend; devices]);
        }
        let image_elems = self.net.layers[0].in_elems();
        let mut backends = Vec::with_capacity(devices);
        for d in 0..devices {
            let cfg = match fleet {
                Some(f) => self.fleet_device_config(&f[d])?,
                None => self.cfg.clone(),
            };
            let report = if searched {
                mapopt::optimize(session, &cfg, &search_knobs(&self.spec.run))?.searched
            } else {
                session.report(&cfg)?
            };
            backends.push(SimBackend::from_report(&report, image_elems, opts.batch));
        }
        Ok(backends)
    }

    /// Start a pool of simulated PIM devices serving this job's plan: one
    /// incremental session prices the plan summary *and* the worker
    /// backends, then `coordinator::PoolConfig`/`MultiDeviceServer` are
    /// built from the spec's serve options (defaults if absent).
    ///
    /// A heterogeneous `serve.devices` fleet prices every device's own
    /// geometry (so the backlog policy can weigh real service times), and
    /// `run.mapper: "search"` serves each device its mapopt-searched plan.
    /// The homogeneous paper path stays bit-for-bit the legacy one.
    pub fn serve(&self) -> Result<ServeHandle> {
        // Same fail-fast as `report()`: don't start worker threads for a
        // plan the analyzer can already prove unpriceable.
        if let Some(e) = self.check().plan_error() {
            return Err(e.clone().into());
        }
        let opts = self.spec.serve.clone().unwrap_or_default();
        let mut session = self.session();
        let report = if self.spec.run.mapper == Mapper::Search {
            self.search_with(&mut session)?.searched
        } else {
            session.report(&self.cfg)?
        };
        let devices = match &opts.devices {
            None => report.replicas.max(1),
            Some(d) => d.count().max(1),
        };
        let backends = self.serve_backends(&mut session, &opts, devices)?;
        // Only a heterogeneous fleet carries per-device weights into the
        // router; uniform fleets keep the legacy unit weights.
        let service_ns = opts
            .devices
            .as_ref()
            .and_then(DevicesSpec::fleet)
            .map(|_| backends.iter().map(SimBackend::service_ns).collect());
        let pool = PoolConfig {
            devices,
            policy: opts.policy,
            batch_window: Duration::from_millis(opts.batch_window_ms),
            resilience: opts.resilience.unwrap_or_default(),
            service_ns,
        };
        // A noop fault section keeps the plain backend — the fault-free
        // serve path stays bit-for-bit the legacy one.
        let faults = opts.faults.clone().filter(|f| !f.is_noop());
        let server = match faults {
            Some(faults) => MultiDeviceServer::start(pool, move |d| {
                Ok(FaultyBackend::new(backends[d].clone(), d, faults.clone()))
            })?,
            None => MultiDeviceServer::start(pool, move |d| Ok(backends[d].clone()))?,
        };
        Ok(ServeHandle {
            server,
            report,
            devices,
            policy: opts.policy,
            batch: opts.batch,
        })
    }

    /// Deterministic degraded-mode SLO report: replay this job's serving
    /// fleet — same devices/policy/batch, same arrival process, same fault
    /// schedule, same resilience policy — as a virtual-time simulation
    /// over `images` offered requests. Same spec → bitwise-identical
    /// [`FleetReport`].
    pub fn fleet_report(&self) -> Result<FleetReport> {
        let opts = self.spec.serve.clone().unwrap_or_default();
        let report = self.report()?;
        let devices = match &opts.devices {
            None => report.replicas.max(1),
            Some(d) => d.count().max(1),
        };
        // A heterogeneous fleet replays with each device's own priced
        // (searched, under `mapper: "search"`) service time.
        let service_ns_per_device = match opts.devices.as_ref().and_then(DevicesSpec::fleet)
        {
            None => None,
            Some(fleet) => {
                let mut session = self.session();
                let searched = self.spec.run.mapper == Mapper::Search;
                let mut v = Vec::with_capacity(fleet.len());
                for dev in fleet {
                    let cfg = self.fleet_device_config(dev)?;
                    let rep = if searched {
                        mapopt::optimize(&mut session, &cfg, &search_knobs(&self.spec.run))?
                            .searched
                    } else {
                        session.report(&cfg)?
                    };
                    v.push(rep.cycle_ns);
                }
                Some(v)
            }
        };
        let cfg = FleetConfig {
            devices,
            service_ns: report.cycle_ns,
            batch: opts.batch,
            policy: opts.policy,
            seed: 0x5EED,
            requests: (self.spec.images as u64).max(1),
            load: opts.load.unwrap_or(0.9),
            faults: opts.faults.unwrap_or_else(FaultSpec::none),
            resilience: opts.resilience.unwrap_or_default(),
            traffic: opts.arrival,
            service_ns_per_device,
        };
        simulate_fleet(&cfg)
    }
}

/// A running pool plus the timing-model report it was priced from.
pub struct ServeHandle {
    pub server: MultiDeviceServer,
    /// The report the pool's service time came from.
    pub report: SimReport,
    /// Workers actually started (spec value, or one per plan replica).
    pub devices: usize,
    pub policy: Policy,
    pub batch: usize,
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests assert by panicking
mod tests {
    use super::*;
    use crate::plan::ShardPolicy;
    use crate::sim::simulate;

    #[test]
    fn job_resolves_builtin_spec() {
        let job = Job::new(Spec::builtin("pimnet").with_preset("conservative")).unwrap();
        assert_eq!(job.network().name, "pimnet");
        assert_eq!(job.config().n_bits, 8);
        assert!(!job.config().tree_per_subarray);
    }

    #[test]
    fn job_report_matches_simulate() {
        let spec = Spec::builtin("alexnet")
            .with_preset("paper_favorable")
            .with_ks(vec![2]);
        let job = Job::new(spec).unwrap();
        let fresh = simulate(job.network(), job.config()).unwrap();
        let rep = job.report().unwrap();
        assert_eq!(rep.cycle_ns.to_bits(), fresh.pipeline.cycle_ns.to_bits());
        let full = job.simulate_full().unwrap();
        assert_eq!(full.total_aaps, fresh.total_aaps);
    }

    #[test]
    fn validation_runs_before_work() {
        // Unknown network names the accepted set.
        let err = Job::new(Spec::builtin("lenet")).unwrap_err();
        assert!(err.to_string().contains("alexnet"), "{err}");
        // Bad preset.
        let err = Job::new(Spec::builtin("pimnet").with_preset("fast")).unwrap_err();
        assert!(err.to_string().contains("paper_favorable"), "{err}");
        // Wrong per-layer ks length (pimnet has 4 layers).
        let err =
            Job::new(Spec::builtin("pimnet").with_ks(vec![1, 2, 4])).unwrap_err();
        assert!(err.to_string().contains("run.ks"), "{err}");
        // Zero parallelism.
        let err = Job::new(Spec::builtin("pimnet").with_ks(vec![0])).unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        // Invalid geometry override.
        let mut spec = Spec::builtin("pimnet");
        spec.device.rows = Some(4);
        let err = Job::new(spec).unwrap_err();
        assert!(err.to_string().contains("rows"), "{err}");
    }

    #[test]
    fn check_fails_fast_with_the_pricing_error() {
        // 16 banks overflow a 1×1 grid: the analyzer proves it, and
        // `report()` returns the carried error without pricing.
        let job = Job::new(
            Spec::builtin("vgg16").with_preset("conservative").with_grid(1, 1),
        )
        .unwrap();
        let d = job.check();
        assert!(d.has_errors());
        let fast = job.report().unwrap_err();
        assert_eq!(Some(&fast), d.plan_error());
        // A healthy job checks clean and still prices; a warnings-only job
        // (conservative pimnet carries a W020 residency wave) prices too —
        // only carried errors block the read path.
        let ok = Job::new(Spec::builtin("pimnet")).unwrap();
        assert!(ok.check().is_empty(), "{}", ok.check().render_text());
        ok.report().unwrap();
        let warned = Job::new(Spec::builtin("pimnet").with_preset("conservative")).unwrap();
        assert!(!warned.check().has_errors());
        warned.report().unwrap();
    }

    #[test]
    fn report_batch_matches_per_variant_jobs() {
        let base = Spec::builtin("vgg16").with_preset("conservative");
        let variants = vec![
            base.clone(),
            base.clone().with_grid(2, 4).with_shard(ShardPolicy::LayerSplit),
            base.clone().with_ks(vec![2]),
            // Fails lowering: 16 banks overflow a 1×1 grid.
            base.clone().with_grid(1, 1),
        ];
        let job = Job::new(base).unwrap();
        let batched = job.report_batch(&variants);
        assert_eq!(batched.len(), variants.len());
        for (spec, got) in variants.iter().zip(&batched) {
            let want = Job::new(spec.clone()).unwrap().report();
            match (want, got) {
                (Ok(want), Ok(got)) => {
                    assert_eq!(&want, got);
                    assert_eq!(want.cycle_ns.to_bits(), got.cycle_ns.to_bits());
                }
                (Err(want), Err(got)) => {
                    assert_eq!(want.to_string(), got.to_string());
                }
                (want, got) => panic!("mismatch: {want:?} vs {got:?}"),
            }
        }
        // A foreign network is rejected per-slot, not a panic.
        let mixed = job.report_batch(&[Spec::builtin("alexnet")]);
        assert!(mixed[0].as_ref().unwrap_err().to_string().contains("network"));
    }

    fn hetero_fleet() -> DevicesSpec {
        DevicesSpec::Fleet(vec![
            DeviceSpec { preset: "cloud".to_string(), ..DeviceSpec::default() },
            DeviceSpec { preset: "edge".to_string(), ..DeviceSpec::default() },
        ])
    }

    #[test]
    fn hetero_fleet_serves_with_per_device_pricing() {
        let spec = Spec::builtin("pimnet").with_serve(ServeSpec {
            devices: Some(hetero_fleet()),
            policy: Policy::Backlog,
            batch: 2,
            batch_window_ms: 1,
            ..ServeSpec::default()
        });
        let job = Job::new(spec).unwrap();
        let handle = job.serve().unwrap();
        assert_eq!(handle.devices, 2);
        let image = vec![1; handle.server.image_elems()];
        for _ in 0..4 {
            let resp = handle.server.classify(image.clone()).unwrap();
            assert_eq!(resp.logits.len(), 10);
        }
        assert_eq!(handle.server.metrics().requests, 4);
        handle.server.shutdown();
    }

    #[test]
    fn hetero_fleet_report_routes_by_device_speed() {
        let spec = Spec::builtin("pimnet").with_serve(ServeSpec {
            devices: Some(hetero_fleet()),
            policy: Policy::Backlog,
            batch: 1,
            ..ServeSpec::default()
        });
        let mut spec = spec;
        spec.images = 512;
        let job = Job::new(spec).unwrap();
        let r = job.fleet_report().unwrap();
        assert_eq!(r.devices, 2);
        assert_eq!(r.completed, r.offered);
        // The cloud device (paper-favorable timing) is strictly faster, so
        // the backlog policy must send it strictly more batches.
        assert!(
            r.per_device_batches[0] > r.per_device_batches[1],
            "cloud={} edge={}",
            r.per_device_batches[0],
            r.per_device_batches[1]
        );
    }

    #[test]
    fn searched_serving_prices_the_searched_plan() {
        let serve = ServeSpec {
            devices: Some(DevicesSpec::Count(2)),
            batch: 2,
            batch_window_ms: 1,
            ..ServeSpec::default()
        };
        let paper = Job::new(
            Spec::builtin("mobilenet_mini").with_serve(serve.clone()),
        )
        .unwrap();
        let searched = Job::new(
            Spec::builtin("mobilenet_mini")
                .with_serve(serve)
                .with_mapper(Mapper::Search),
        )
        .unwrap();
        let p = paper.serve().unwrap();
        let s = searched.serve().unwrap();
        // The searched mapping is never worse under the analytic cost, and
        // the serve handle's report is the one the backends were priced by.
        assert!(s.report.cycle_ns <= p.report.cycle_ns);
        assert_eq!(
            s.report.cycle_ns.to_bits(),
            searched.report().unwrap().cycle_ns.to_bits()
        );
        p.server.shutdown();
        s.server.shutdown();
    }

    #[test]
    fn report_variant_shares_the_cache() {
        let base = Spec::builtin("vgg16").with_preset("conservative");
        let job = Job::new(base.clone()).unwrap();
        let mut session = job.session();
        job.report_variant(&mut session, &base).unwrap();
        let (_, misses) = session.cache_stats();
        for channels in [2usize, 4] {
            job.report_variant(
                &mut session,
                &base.clone().with_grid(channels, 4).with_shard(ShardPolicy::LayerSplit),
            )
            .unwrap();
        }
        let (_, misses_after) = session.cache_stats();
        assert_eq!(misses, misses_after, "grid/shard variants must not re-price");

        // A different network is rejected.
        let err = job
            .report_variant(&mut session, &Spec::builtin("alexnet"))
            .unwrap_err();
        assert!(err.to_string().contains("network"), "{err}");
    }
}
