//! `pim::api` — the one versioned Spec → Job → Report surface
//! (DESIGN.md §API).
//!
//! The paper's pipeline (map → lower onto the channel × rank grid →
//! price → aggregate) used to be reachable through four divergent front
//! doors: free `sim::simulate()`, `SimSession`, the coordinator's
//! `PoolConfig`/`MultiDeviceServer::start`, and the stringly-typed CLI
//! flags plus ad-hoc TOML keys. This module replaces all of them as the
//! *construction* path:
//!
//!   * [`Spec`] and its parts ([`NetworkSpec`], [`DeviceSpec`],
//!     [`ShardSpec`], [`RunSpec`], [`ServeSpec`]) are pure data,
//!     JSON-round-trippable under `"api_version": 1`, validated with
//!     actionable errors before any work runs. A network is a builtin
//!     name, an inline lowered layer list, or an inline `pim::ir`
//!     operator graph (DESIGN.md §IR) — all three resolve to the same
//!     per-bank stage form before pricing.
//!   * [`Job`] resolves a spec into the plan/session machinery:
//!     [`Job::report`] → `SimReport`, [`Job::simulate_full`] →
//!     `SimResult` (bitwise-equal to the legacy path — results and
//!     errors), [`Job::serve`] → a running `MultiDeviceServer` pool.
//!
//! The old entry points remain as thin shims: `sim::simulate` is the
//! engine primitive `Job` delegates to (and the equivalence reference),
//! `config::load_experiment` parses TOML through [`Spec::from_toml`], and
//! `SimBackend::from_sim` stays for callers that already priced a result.
//! Canonical example documents live in `examples/specs/`;
//! `tests/spec_roundtrip.rs` keeps them parseable and byte-stable, and
//! `pim-dram spec` validates or reprints them from the CLI.

// The api layer is the public construction path: callers hand it
// arbitrary documents, so panicking on them (unwrap) or cloning specs to
// pass by value are bugs, not style. CI runs clippy with -D warnings.
#![warn(clippy::needless_pass_by_value)]
#![warn(clippy::unwrap_used)]

pub mod job;
pub mod spec;

pub use job::{Job, ServeHandle};
pub use spec::{
    parse_policy, policy_name, DeviceSpec, DevicesSpec, Mapper, NetworkSpec, RunSpec,
    ServeSpec, ShardSpec, Spec, API_VERSION, BUILTIN_NETWORKS, POLICIES, PRESETS,
    SHARD_FORMS,
};
