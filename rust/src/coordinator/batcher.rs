//! Dynamic batcher: the PJRT artifacts are compiled for a fixed batch B,
//! so the coordinator groups requests into full batches, padding the tail
//! with zero images (results for padding lanes are dropped).

use std::collections::VecDeque;

/// Accumulates items into fixed-size batches.
#[derive(Debug)]
pub struct Batcher<T> {
    batch_size: usize,
    queue: VecDeque<T>,
}

impl<T> Batcher<T> {
    pub fn new(batch_size: usize) -> Self {
        assert!(batch_size > 0);
        Batcher { batch_size, queue: VecDeque::new() }
    }

    pub fn push(&mut self, item: T) {
        self.queue.push_back(item);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Take a full batch if available.
    pub fn pop_full(&mut self) -> Option<Vec<T>> {
        if self.queue.len() >= self.batch_size {
            Some(self.queue.drain(..self.batch_size).collect())
        } else {
            None
        }
    }

    /// Take whatever is queued (≤ batch_size items) — used on flush when
    /// the batching window expires.
    pub fn pop_partial(&mut self) -> Option<Vec<T>> {
        if self.queue.is_empty() {
            None
        } else {
            let n = self.queue.len().min(self.batch_size);
            Some(self.queue.drain(..n).collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batches_fifo() {
        let mut b = Batcher::new(3);
        for i in 0..7 {
            b.push(i);
        }
        assert_eq!(b.pop_full(), Some(vec![0, 1, 2]));
        assert_eq!(b.pop_full(), Some(vec![3, 4, 5]));
        assert_eq!(b.pop_full(), None);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn partial_flush() {
        let mut b = Batcher::new(4);
        b.push("a");
        assert_eq!(b.pop_partial(), Some(vec!["a"]));
        assert_eq!(b.pop_partial(), None);
    }

    #[test]
    fn pops_on_empty_are_none() {
        let mut b = Batcher::<u8>::new(2);
        assert_eq!(b.pop_full(), None);
        assert_eq!(b.pop_partial(), None);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn exact_multiple_drains_to_empty() {
        let mut b = Batcher::new(3);
        for i in 0..6 {
            b.push(i);
        }
        assert_eq!(b.pop_full(), Some(vec![0, 1, 2]));
        assert_eq!(b.pop_full(), Some(vec![3, 4, 5]));
        assert_eq!(b.pop_full(), None);
        assert_eq!(b.pop_partial(), None);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn remainder_flushes_after_full_batches() {
        let mut b = Batcher::new(4);
        for i in 0..9 {
            b.push(i);
        }
        assert_eq!(b.pop_full(), Some(vec![0, 1, 2, 3]));
        assert_eq!(b.pop_full(), Some(vec![4, 5, 6, 7]));
        assert_eq!(b.pop_full(), None);
        assert_eq!(b.pending(), 1);
        assert_eq!(b.pop_partial(), Some(vec![8]));
        assert_eq!(b.pop_partial(), None);
    }

    #[test]
    fn pop_partial_never_exceeds_batch_size() {
        // The shutdown drain pops partials in a loop; each one must stay
        // within the compiled batch size.
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.pop_partial(), Some(vec![0, 1]));
        assert_eq!(b.pop_partial(), Some(vec![2, 3]));
        assert_eq!(b.pop_partial(), Some(vec![4]));
        assert_eq!(b.pop_partial(), None);
    }

    #[test]
    fn batch_size_one_degenerates_to_fifo() {
        let mut b = Batcher::new(1);
        b.push("x");
        b.push("y");
        assert_eq!(b.batch_size(), 1);
        assert_eq!(b.pop_full(), Some(vec!["x"]));
        assert_eq!(b.pop_partial(), Some(vec!["y"]));
        assert_eq!(b.pop_full(), None);
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        Batcher::<u8>::new(0);
    }
}
