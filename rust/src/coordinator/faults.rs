//! Deterministic, seed-driven fault injection for the device fleet.
//!
//! A [`FaultSpec`] is a *schedule*, not a dice roll: every fault decision
//! is a pure function of `(spec, device, batch index)` via the
//! counter-based hash in [`crate::sim::perturb`], so the live thread-pool
//! server and the virtual-time fleet simulation (`coordinator::chaos`) see
//! the **same** faults for the same seed regardless of execution order.
//! Four fault classes cover the failure modes real PIM deployments
//! exhibit (stragglers, refresh storms, transient command errors, device
//! loss):
//!
//!   * **crash** — a device stops answering for a window of its batch
//!     sequence (or permanently); surfaces as [`InjectedFault::DeviceLost`].
//!   * **transient** — one batch execution fails with probability `p`;
//!     surfaces as [`InjectedFault::Transient`] and succeeds on retry.
//!   * **straggler** — one batch runs `factor×` slower with probability
//!     `p` (latency inflation, no error).
//!   * **storm** — a periodic refresh storm slows every batch in the
//!     storm's duty window by `factor×` (deterministic in the batch index,
//!     modeling the refresh interference the analytic price path ignores).
//!
//! [`FaultyBackend`] wraps any [`Backend`] and applies the schedule to the
//! live pool; the chaos simulation applies the same schedule to virtual
//! time.

use std::fmt;

use anyhow::Result;

use crate::sim::perturb::{fault_hash, Perturbation};
use crate::util::rng::Rng;

use super::backend::Backend;

/// One device-loss window in a device's batch sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashSpec {
    /// Device the crash hits.
    pub device: usize,
    /// Batches the device executes before it goes down.
    pub after: u64,
    /// How many batch *attempts* the device stays down (`None` =
    /// permanent). Attempts made while down consume the window, so a
    /// quarantined device recovers after `down_for` failed probes.
    pub down_for: Option<u64>,
}

impl CrashSpec {
    /// Is the device down for its `batch_idx`-th batch attempt?
    pub fn hits(&self, device: usize, batch_idx: u64) -> bool {
        self.device == device
            && batch_idx >= self.after
            && self.down_for.map_or(true, |d| batch_idx < self.after.saturating_add(d))
    }
}

/// Probabilistic per-batch latency inflation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerSpec {
    /// Probability a batch straggles.
    pub prob: f64,
    /// Service-time multiplier for a straggling batch (`>= 1`).
    pub factor: f64,
}

/// Periodic refresh-storm slowdown: batches with
/// `batch_idx % period < duty` run `factor×` slower.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StormSpec {
    /// Storm cycle length in batches.
    pub period: u64,
    /// Leading batches of each cycle inside the storm.
    pub duty: u64,
    /// Service-time multiplier during the storm (`>= 1`).
    pub factor: f64,
}

/// The full deterministic fault schedule for a fleet.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultSpec {
    /// Seed of the schedule; one seed reproduces every decision exactly.
    pub seed: u64,
    /// Per-batch transient-failure probability.
    pub transient: f64,
    pub straggler: Option<StragglerSpec>,
    pub storm: Option<StormSpec>,
    pub crash: Vec<CrashSpec>,
}

/// The fault verdict for one `(device, batch)` coordinate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchFault {
    /// Device is down: the batch fails with [`InjectedFault::DeviceLost`].
    pub crashed: bool,
    /// The batch fails once with [`InjectedFault::Transient`].
    pub transient: bool,
    /// The batch drew straggler latency inflation.
    pub straggler: bool,
    /// The batch falls inside a refresh-storm duty window.
    pub storm: bool,
    /// Combined service-time multiplier (straggler × storm; `>= 1`).
    pub slow: Perturbation,
}

impl BatchFault {
    /// No fault at all on this batch.
    pub fn is_clean(&self) -> bool {
        !self.crashed && !self.transient && self.slow.is_none()
    }
}

impl FaultSpec {
    /// A schedule that injects nothing (the `Default`).
    pub fn none() -> FaultSpec {
        FaultSpec::default()
    }

    /// Does this schedule ever inject anything?
    pub fn is_noop(&self) -> bool {
        self.transient <= 0.0
            && self.straggler.is_none()
            && self.storm.is_none()
            && self.crash.is_empty()
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.transient),
            "faults.transient must be a probability in [0, 1], got {}",
            self.transient
        );
        if let Some(s) = &self.straggler {
            anyhow::ensure!(
                (0.0..=1.0).contains(&s.prob),
                "faults.straggler.prob must be a probability in [0, 1], got {}",
                s.prob
            );
            anyhow::ensure!(
                s.factor >= 1.0,
                "faults.straggler.factor must be >= 1, got {}",
                s.factor
            );
        }
        if let Some(s) = &self.storm {
            anyhow::ensure!(s.period >= 1, "faults.storm.period must be >= 1");
            anyhow::ensure!(
                s.duty <= s.period,
                "faults.storm.duty ({}) must be <= period ({})",
                s.duty,
                s.period
            );
            anyhow::ensure!(
                s.factor >= 1.0,
                "faults.storm.factor must be >= 1, got {}",
                s.factor
            );
        }
        for c in &self.crash {
            anyhow::ensure!(
                c.down_for != Some(0),
                "faults.crash down_for must be >= 1 batch (omit for permanent)"
            );
        }
        Ok(())
    }

    /// The schedule's verdict for device `device` executing its
    /// `batch_idx`-th batch. Pure: no internal state advances, so callers
    /// in any order (threads, virtual time) agree.
    pub fn batch_fault(&self, device: usize, batch_idx: u64) -> BatchFault {
        // Two fixed draws per coordinate keep the mapping stable even when
        // one fault class is disabled.
        let mut rng = Rng::new(fault_hash(self.seed, device as u64, batch_idx));
        let t_draw = rng.uniform();
        let s_draw = rng.uniform();

        let crashed = self.crash.iter().any(|c| c.hits(device, batch_idx));
        let transient = self.transient > 0.0 && t_draw < self.transient;
        let straggler = self.straggler.map_or(false, |s| s.prob > 0.0 && s_draw < s.prob);
        let storm = self.storm.map_or(false, |s| batch_idx % s.period < s.duty);

        let mut slow = Perturbation::none();
        if straggler {
            slow = slow.and(Perturbation::slow(self.straggler.unwrap().factor));
        }
        if storm {
            slow = slow.and(Perturbation::slow(self.storm.unwrap().factor));
        }
        BatchFault { crashed, transient, straggler, storm, slow }
    }
}

/// A fault injected by the schedule — typed, so the server can tell device
/// loss from a transient error and react differently (quarantine vs plain
/// retry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectedFault {
    /// The device is down (crash window active).
    DeviceLost { device: usize, batch: u64 },
    /// One batch execution failed; a retry may succeed.
    Transient { device: usize, batch: u64 },
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InjectedFault::DeviceLost { device, batch } => {
                write!(f, "injected device loss on device {device} (batch {batch})")
            }
            InjectedFault::Transient { device, batch } => {
                write!(f, "injected transient fault on device {device} (batch {batch})")
            }
        }
    }
}

impl std::error::Error for InjectedFault {}

/// A [`Backend`] wrapper that applies a [`FaultSpec`] schedule to the live
/// pool: each `run_batch` call consults the schedule at this device's next
/// batch index, fails with a typed [`InjectedFault`] when the schedule
/// says so, and otherwise (optionally) stretches wall-clock by the drawn
/// slowdown.
#[derive(Debug, Clone)]
pub struct FaultyBackend<B: Backend> {
    inner: B,
    device: usize,
    spec: FaultSpec,
    batch_idx: u64,
    /// Wall-clock ns one *unperturbed* batch models; when > 0, slow
    /// batches sleep the extra `(factor - 1) × stall_ns`. 0 (default)
    /// keeps faults purely logical — no sleeping in tests.
    stall_ns: f64,
}

impl<B: Backend> FaultyBackend<B> {
    pub fn new(inner: B, device: usize, spec: FaultSpec) -> FaultyBackend<B> {
        FaultyBackend { inner, device, spec, batch_idx: 0, stall_ns: 0.0 }
    }

    /// Replay straggler/storm slowdowns in wall-clock on top of a modeled
    /// per-batch service time.
    pub fn with_stall_ns(mut self, stall_ns: f64) -> Self {
        self.stall_ns = stall_ns.max(0.0);
        self
    }

    /// Batches attempted so far on this device (the schedule cursor).
    pub fn batches(&self) -> u64 {
        self.batch_idx
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn image_elems(&self) -> usize {
        self.inner.image_elems()
    }

    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }

    fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>> {
        let batch = self.batch_idx;
        self.batch_idx += 1;
        let fault = self.spec.batch_fault(self.device, batch);
        if fault.crashed {
            return Err(anyhow::Error::new(InjectedFault::DeviceLost {
                device: self.device,
                batch,
            }));
        }
        if fault.transient {
            return Err(anyhow::Error::new(InjectedFault::Transient {
                device: self.device,
                batch,
            }));
        }
        let out = self.inner.run_batch(images)?;
        if !fault.slow.is_none() && self.stall_ns > 0.0 {
            let extra = (fault.slow.factor - 1.0) * self.stall_ns;
            std::thread::sleep(std::time::Duration::from_nanos(extra as u64));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;

    fn spec_with_everything() -> FaultSpec {
        FaultSpec {
            seed: 7,
            transient: 0.2,
            straggler: Some(StragglerSpec { prob: 0.1, factor: 4.0 }),
            storm: Some(StormSpec { period: 8, duty: 2, factor: 2.0 }),
            crash: vec![CrashSpec { device: 1, after: 3, down_for: Some(2) }],
        }
    }

    #[test]
    fn schedule_is_a_pure_function_of_coordinates() {
        let spec = spec_with_everything();
        // Query in two different orders; verdicts must match coordinate-wise.
        let forward: Vec<BatchFault> =
            (0..64).map(|i| spec.batch_fault(i % 4, i / 4)).collect();
        let backward: Vec<BatchFault> =
            (0..64).rev().map(|i| spec.batch_fault(i % 4, i / 4)).collect();
        for (i, f) in forward.iter().enumerate() {
            assert_eq!(*f, backward[63 - i], "coordinate {i}");
        }
    }

    #[test]
    fn different_seeds_give_different_schedules() {
        let mut a = spec_with_everything();
        let mut b = spec_with_everything();
        a.seed = 1;
        b.seed = 2;
        let fa: Vec<bool> = (0..200).map(|i| a.batch_fault(0, i).transient).collect();
        let fb: Vec<bool> = (0..200).map(|i| b.batch_fault(0, i).transient).collect();
        assert_ne!(fa, fb);
    }

    #[test]
    fn crash_window_hits_exactly_its_batches() {
        let spec = spec_with_everything();
        // Device 1 is down for batches 3 and 4, nothing else.
        for batch in 0..8 {
            let f = spec.batch_fault(1, batch);
            assert_eq!(f.crashed, (3..5).contains(&batch), "batch {batch}");
        }
        // Other devices never crash.
        assert!((0..8).all(|b| !spec.batch_fault(0, b).crashed));
        // A permanent crash never ends.
        let perm = FaultSpec {
            crash: vec![CrashSpec { device: 0, after: 2, down_for: None }],
            ..FaultSpec::none()
        };
        assert!(!perm.batch_fault(0, 1).crashed);
        assert!(perm.batch_fault(0, 1_000_000).crashed);
    }

    #[test]
    fn storm_is_periodic_and_stacks_with_stragglers() {
        let spec = FaultSpec {
            seed: 3,
            straggler: Some(StragglerSpec { prob: 1.0, factor: 3.0 }),
            storm: Some(StormSpec { period: 4, duty: 1, factor: 2.0 }),
            ..FaultSpec::none()
        };
        let in_storm = spec.batch_fault(0, 4);
        let outside = spec.batch_fault(0, 5);
        assert!(in_storm.storm && in_storm.straggler);
        assert_eq!(in_storm.slow.factor, 6.0, "straggler × storm stack");
        assert!(!outside.storm && outside.straggler);
        assert_eq!(outside.slow.factor, 3.0);
    }

    #[test]
    fn transient_rate_tracks_probability() {
        let spec = FaultSpec { seed: 11, transient: 0.25, ..FaultSpec::none() };
        let hits = (0..4000).filter(|&b| spec.batch_fault(0, b).transient).count();
        assert!((800..1200).contains(&hits), "rate off: {hits}/4000");
    }

    #[test]
    fn noop_spec_is_always_clean() {
        let spec = FaultSpec::none();
        assert!(spec.is_noop());
        assert!((0..100).all(|b| spec.batch_fault(0, b).is_clean()));
    }

    #[test]
    fn validation_rejects_bad_probabilities_and_factors() {
        let mut s = FaultSpec::none();
        s.transient = 1.5;
        assert!(s.validate().is_err());
        s.transient = 0.0;
        s.straggler = Some(StragglerSpec { prob: 0.1, factor: 0.5 });
        assert!(s.validate().is_err());
        s.straggler = None;
        s.storm = Some(StormSpec { period: 4, duty: 5, factor: 2.0 });
        assert!(s.validate().is_err());
        s.storm = None;
        s.crash = vec![CrashSpec { device: 0, after: 0, down_for: Some(0) }];
        assert!(s.validate().is_err());
        s.crash.clear();
        assert!(s.validate().is_ok());
        assert!(spec_with_everything().validate().is_ok());
    }

    #[test]
    fn faulty_backend_injects_typed_errors_and_recovers() {
        let spec = FaultSpec {
            crash: vec![CrashSpec { device: 0, after: 1, down_for: Some(2) }],
            ..FaultSpec::none()
        };
        let mut b = FaultyBackend::new(SimBackend::new(2, 4, 10), 0, spec);
        let images = vec![1i32; 8];
        assert!(b.run_batch(&images).is_ok(), "batch 0 is before the window");
        for expect_batch in [1u64, 2] {
            let err = b.run_batch(&images).unwrap_err();
            match err.downcast_ref::<InjectedFault>() {
                Some(&InjectedFault::DeviceLost { device: 0, batch }) => {
                    assert_eq!(batch, expect_batch)
                }
                other => panic!("expected DeviceLost, got {other:?}"),
            }
        }
        assert!(b.run_batch(&images).is_ok(), "window over: device recovered");
        assert_eq!(b.batches(), 4);
    }

    #[test]
    fn faulty_backend_matches_schedule_verdicts() {
        let spec = FaultSpec { seed: 5, transient: 0.5, ..FaultSpec::none() };
        let mut b = FaultyBackend::new(SimBackend::new(1, 4, 10), 2, spec.clone());
        let images = vec![0i32; 4];
        for batch in 0..50 {
            let want = spec.batch_fault(2, batch).transient;
            let got = b.run_batch(&images).is_err();
            assert_eq!(got, want, "batch {batch}");
        }
    }

    #[test]
    fn faulty_backend_passes_dimensions_through() {
        let b = FaultyBackend::new(SimBackend::new(4, 8, 10), 0, FaultSpec::none());
        assert_eq!(b.batch_size(), 4);
        assert_eq!(b.image_elems(), 8);
        assert_eq!(b.num_classes(), 10);
    }
}
