//! Resilience policy for the serving fleet: typed per-request errors,
//! deadline/retry/backoff/shedding knobs, and the device health tracker.
//!
//! [`ServeError`] replaces the old stringly batch-failure path: every
//! per-request outcome is a typed, matchable variant that preserves its
//! source (a [`PlanError`] stays a `PlanError`; an injected fault is
//! classified as `DeviceLost`/`Transient` by downcast before it reaches
//! the client).
//!
//! [`ResilienceSpec`] defaults are **behavior-preserving**: no deadline,
//! no retries, the pre-existing 1024-slot device queue, quarantine
//! disabled. A default-configured pool serves exactly like the pre-chaos
//! server (`tests/api_equivalence.rs` freezes this).
//!
//! [`HealthTracker`] is the quarantine state machine, shared by the live
//! pool (wall-clock ns) and the virtual-time fleet simulation (virtual
//! ns):
//!
//! ```text
//!           quarantine_after consecutive failures
//!  Healthy ─────────────────────────────────────────▶ Quarantined
//!     ▲                                                   │
//!     │ probe succeeds                  probe_after_ms up │
//!     └──────────────────────── Probing ◀────────────────┘
//!                                  │ probe fails: window restarts
//!                                  └──▶ Quarantined
//! ```
//!
//! While quarantined a device receives no traffic; after `probe_after_ms`
//! one request is let through as a probe. Success reintegrates the
//! device; failure restarts the quarantine window. Every transition is
//! logged with its timestamp for the report.

use std::fmt;

use anyhow::Result;

use crate::plan::PlanError;

/// Serving resilience knobs. The `Default` reproduces the pre-resilience
/// server bit-for-bit: no deadline, no retries, 1024-deep queues,
/// quarantine off.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceSpec {
    /// Per-request deadline; a request whose deadline passes before its
    /// batch executes fails with [`ServeError::Timeout`]. `None` = never.
    pub deadline_ms: Option<u64>,
    /// Re-dispatch attempts after a retryable failure (0 = fail fast).
    pub retries: u32,
    /// Base backoff before retry `i`: `min(backoff_ms << i, backoff_cap_ms)`.
    pub backoff_ms: u64,
    /// Cap on the exponential backoff.
    pub backoff_cap_ms: u64,
    /// Bounded per-device queue; an admission that finds it full is shed
    /// with [`ServeError::Shed`] instead of blocking.
    pub queue_cap: usize,
    /// Consecutive failures before a device is quarantined (0 disables
    /// health tracking entirely).
    pub quarantine_after: u32,
    /// Quarantine dwell time before a probe request is allowed through.
    pub probe_after_ms: u64,
}

impl Default for ResilienceSpec {
    fn default() -> Self {
        ResilienceSpec {
            deadline_ms: None,
            retries: 0,
            backoff_ms: 1,
            backoff_cap_ms: 64,
            queue_cap: 1024,
            quarantine_after: 0,
            probe_after_ms: 50,
        }
    }
}

impl ResilienceSpec {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.queue_cap >= 1, "resilience.queue_cap must be >= 1");
        anyhow::ensure!(
            self.backoff_cap_ms >= self.backoff_ms,
            "resilience.backoff_cap_ms ({}) must be >= backoff_ms ({})",
            self.backoff_cap_ms,
            self.backoff_ms
        );
        if let Some(d) = self.deadline_ms {
            anyhow::ensure!(d >= 1, "resilience.deadline_ms must be >= 1");
        }
        Ok(())
    }

    /// Capped exponential backoff before retry number `retry` (0-based).
    pub fn backoff_ms_for(&self, retry: u32) -> u64 {
        let shifted = match 1u64.checked_shl(retry) {
            Some(mul) => self.backoff_ms.saturating_mul(mul),
            None => u64::MAX,
        };
        shifted.min(self.backoff_cap_ms)
    }
}

/// Why a request was shed instead of served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The routed device's bounded queue was full.
    QueueFull,
    /// No routable device (every device down or quarantined).
    NoDevice,
    /// The pool is shutting down.
    Shutdown,
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShedReason::QueueFull => write!(f, "queue full"),
            ShedReason::NoDevice => write!(f, "no routable device"),
            ShedReason::Shutdown => write!(f, "shutting down"),
        }
    }
}

/// Typed per-request serving failure. Replaces the old
/// `anyhow!("batch execution failed: ..")` strings: callers can match on
/// the variant and the source error survives (see
/// [`std::error::Error::source`]).
#[derive(Debug)]
pub enum ServeError {
    /// The request's deadline passed before its batch executed.
    Timeout { device: usize },
    /// Load was shed before execution.
    Shed { device: Option<usize>, reason: ShedReason },
    /// The device is lost (injected or real crash); retries exhausted.
    DeviceLost { device: usize },
    /// A transient execution failure; retries exhausted.
    Transient { device: usize },
    /// Plan/pricing failure (building the pool or the report).
    Plan(PlanError),
    /// The request never made it to a device (bad shape, dead server).
    Rejected(String),
    /// Backend execution failed for a reason the injector didn't cause;
    /// the full source chain is preserved in `source`.
    Backend { device: usize, source: std::sync::Arc<anyhow::Error> },
}

impl ServeError {
    /// Wrap a backend execution error, classifying injected faults into
    /// their typed variants. Cheap to clone per batched request (the
    /// source chain is shared).
    pub fn from_backend(device: usize, err: &std::sync::Arc<anyhow::Error>) -> ServeError {
        use super::faults::InjectedFault;
        match err.downcast_ref::<InjectedFault>() {
            Some(InjectedFault::DeviceLost { .. }) => ServeError::DeviceLost { device },
            Some(InjectedFault::Transient { .. }) => ServeError::Transient { device },
            None => ServeError::Backend { device, source: std::sync::Arc::clone(err) },
        }
    }

    /// The device the failure is attributed to, if any.
    pub fn device(&self) -> Option<usize> {
        match self {
            ServeError::Timeout { device }
            | ServeError::DeviceLost { device }
            | ServeError::Transient { device }
            | ServeError::Backend { device, .. } => Some(*device),
            ServeError::Shed { device, .. } => *device,
            ServeError::Plan(_) | ServeError::Rejected(_) => None,
        }
    }

    /// Would re-dispatching (possibly to another device) plausibly help?
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            ServeError::DeviceLost { .. }
                | ServeError::Transient { .. }
                | ServeError::Backend { .. }
                | ServeError::Shed { reason: ShedReason::QueueFull, .. }
                | ServeError::Shed { reason: ShedReason::NoDevice, .. }
        )
    }

    /// Does this failure count against the device's health (quarantine
    /// accounting)? Sheds and timeouts signal overload, not sickness.
    pub fn counts_against_health(&self) -> bool {
        matches!(
            self,
            ServeError::DeviceLost { .. }
                | ServeError::Transient { .. }
                | ServeError::Backend { .. }
        )
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Timeout { device } => {
                write!(f, "request deadline expired on device {device}")
            }
            ServeError::Shed { device: Some(d), reason } => {
                write!(f, "request shed at device {d}: {reason}")
            }
            ServeError::Shed { device: None, reason } => {
                write!(f, "request shed: {reason}")
            }
            ServeError::DeviceLost { device } => {
                write!(f, "device {device} lost")
            }
            ServeError::Transient { device } => {
                write!(f, "transient failure on device {device}")
            }
            ServeError::Plan(e) => write!(f, "plan failure: {e}"),
            ServeError::Rejected(msg) => write!(f, "{msg}"),
            ServeError::Backend { device, source } => {
                write!(f, "batch execution failed on device {device}: {source:#}")
            }
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Plan(e) => Some(e),
            ServeError::Backend { source, .. } => {
                source.root_cause().map(|e| e as &(dyn std::error::Error + 'static))
            }
            _ => None,
        }
    }
}

impl From<PlanError> for ServeError {
    fn from(e: PlanError) -> Self {
        ServeError::Plan(e)
    }
}

/// A logged health-state transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HealthTransition {
    /// Timestamp in ns (wall-clock since pool start, or virtual time).
    pub at_ns: u64,
    pub device: usize,
    /// `false` = quarantined, `true` = reintegrated.
    pub up: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HealthState {
    Healthy,
    Quarantined { since_ns: u64, probing: bool },
}

/// Per-device quarantine state machine (see module docs for the diagram).
/// Time is a caller-supplied monotonic ns counter so the live pool
/// (wall-clock) and the fleet simulation (virtual time) share one
/// implementation.
#[derive(Debug, Clone)]
pub struct HealthTracker {
    quarantine_after: u32,
    probe_after_ns: u64,
    consecutive: Vec<u32>,
    state: Vec<HealthState>,
    transitions: Vec<HealthTransition>,
}

impl HealthTracker {
    pub fn new(devices: usize, spec: &ResilienceSpec) -> HealthTracker {
        HealthTracker {
            quarantine_after: spec.quarantine_after,
            probe_after_ns: spec.probe_after_ms.saturating_mul(1_000_000),
            consecutive: vec![0; devices],
            state: vec![HealthState::Healthy; devices],
            transitions: Vec::new(),
        }
    }

    /// Health tracking is active (quarantine_after > 0).
    pub fn enabled(&self) -> bool {
        self.quarantine_after > 0
    }

    pub fn is_quarantined(&self, device: usize) -> bool {
        matches!(self.state[device], HealthState::Quarantined { .. })
    }

    /// May the router send `device` traffic at `now_ns`? Healthy devices
    /// always; quarantined devices only once their probe window is up and
    /// no probe is already in flight.
    pub fn can_route(&self, device: usize, now_ns: u64) -> bool {
        match self.state[device] {
            HealthState::Healthy => true,
            HealthState::Quarantined { since_ns, probing } => {
                !probing && now_ns >= since_ns.saturating_add(self.probe_after_ns)
            }
        }
    }

    /// Mark the single allowed probe as in flight (call after the router
    /// picks a quarantined device).
    pub fn begin_probe(&mut self, device: usize) {
        if let HealthState::Quarantined { probing, .. } = &mut self.state[device] {
            *probing = true;
        }
    }

    /// Record a successful execution. Returns `true` when this
    /// reintegrated a quarantined device.
    pub fn record_success(&mut self, device: usize, now_ns: u64) -> bool {
        self.consecutive[device] = 0;
        if self.is_quarantined(device) {
            self.state[device] = HealthState::Healthy;
            self.transitions.push(HealthTransition { at_ns: now_ns, device, up: true });
            true
        } else {
            false
        }
    }

    /// Record an execution failure. Returns `true` when this newly
    /// quarantined the device.
    pub fn record_failure(&mut self, device: usize, now_ns: u64) -> bool {
        if !self.enabled() {
            return false;
        }
        self.consecutive[device] = self.consecutive[device].saturating_add(1);
        match self.state[device] {
            HealthState::Quarantined { .. } => {
                // Failed probe: restart the quarantine window.
                self.state[device] =
                    HealthState::Quarantined { since_ns: now_ns, probing: false };
                false
            }
            HealthState::Healthy => {
                if self.consecutive[device] >= self.quarantine_after {
                    self.state[device] =
                        HealthState::Quarantined { since_ns: now_ns, probing: false };
                    self.transitions.push(HealthTransition {
                        at_ns: now_ns,
                        device,
                        up: false,
                    });
                    true
                } else {
                    false
                }
            }
        }
    }

    /// All transitions so far, in the order they happened.
    pub fn transitions(&self) -> &[HealthTransition] {
        &self.transitions
    }

    /// Devices currently quarantined.
    pub fn quarantined(&self) -> usize {
        (0..self.state.len()).filter(|&d| self.is_quarantined(d)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(quarantine_after: u32, probe_after_ms: u64) -> ResilienceSpec {
        ResilienceSpec { quarantine_after, probe_after_ms, ..ResilienceSpec::default() }
    }

    #[test]
    fn default_spec_preserves_legacy_behavior() {
        let r = ResilienceSpec::default();
        assert_eq!(r.deadline_ms, None);
        assert_eq!(r.retries, 0);
        assert_eq!(r.queue_cap, 1024);
        assert_eq!(r.quarantine_after, 0);
        assert!(r.validate().is_ok());
        assert!(!HealthTracker::new(4, &r).enabled());
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let r = ResilienceSpec {
            backoff_ms: 2,
            backoff_cap_ms: 10,
            ..ResilienceSpec::default()
        };
        assert_eq!(r.backoff_ms_for(0), 2);
        assert_eq!(r.backoff_ms_for(1), 4);
        assert_eq!(r.backoff_ms_for(2), 8);
        assert_eq!(r.backoff_ms_for(3), 10, "capped");
        assert_eq!(r.backoff_ms_for(200), 10, "shift overflow stays capped");
    }

    #[test]
    fn validation_catches_inverted_backoff_and_zero_queue() {
        let base = ResilienceSpec::default();
        assert!(ResilienceSpec { queue_cap: 0, ..base }.validate().is_err());
        assert!(ResilienceSpec { backoff_ms: 100, backoff_cap_ms: 10, ..base }
            .validate()
            .is_err());
        assert!(ResilienceSpec { deadline_ms: Some(0), ..base }.validate().is_err());
        assert!(ResilienceSpec { deadline_ms: Some(10), retries: 3, ..base }
            .validate()
            .is_ok());
    }

    #[test]
    fn quarantine_after_consecutive_failures_only() {
        let mut h = HealthTracker::new(2, &spec(3, 10));
        assert!(!h.record_failure(0, 1));
        assert!(!h.record_failure(0, 2));
        // A success resets the streak.
        h.record_success(0, 3);
        assert!(!h.record_failure(0, 4));
        assert!(!h.record_failure(0, 5));
        assert!(h.record_failure(0, 6), "third consecutive quarantines");
        assert!(h.is_quarantined(0));
        assert!(!h.is_quarantined(1));
        assert_eq!(
            h.transitions(),
            &[HealthTransition { at_ns: 6, device: 0, up: false }]
        );
    }

    #[test]
    fn probe_window_gates_routing_and_success_reintegrates() {
        let ms = 1_000_000;
        let mut h = HealthTracker::new(1, &spec(1, 10));
        assert!(h.record_failure(0, 5 * ms));
        // Quarantined: unroutable until the probe window is up.
        assert!(!h.can_route(0, 10 * ms));
        assert!(h.can_route(0, 15 * ms), "5ms + 10ms probe window");
        // One probe at a time.
        h.begin_probe(0);
        assert!(!h.can_route(0, 20 * ms));
        // Probe succeeds: reintegrated and routable again.
        assert!(h.record_success(0, 20 * ms));
        assert!(h.can_route(0, 20 * ms));
        assert_eq!(h.transitions().len(), 2);
        assert!(h.transitions()[1].up);
    }

    #[test]
    fn failed_probe_restarts_the_window() {
        let ms = 1_000_000;
        let mut h = HealthTracker::new(1, &spec(1, 10));
        h.record_failure(0, 0);
        h.begin_probe(0);
        assert!(!h.record_failure(0, 12 * ms), "re-quarantine is not a new transition");
        assert!(!h.can_route(0, 15 * ms), "window restarted at 12ms");
        assert!(h.can_route(0, 22 * ms));
        assert_eq!(h.transitions().len(), 1, "still just the original quarantine");
    }

    #[test]
    fn disabled_tracker_never_quarantines() {
        let mut h = HealthTracker::new(1, &spec(0, 10));
        for t in 0..100 {
            assert!(!h.record_failure(0, t));
        }
        assert!(h.can_route(0, 1000));
        assert!(h.transitions().is_empty());
    }

    #[test]
    fn serve_error_classification_and_sources() {
        use std::sync::Arc;
        let plan_err =
            PlanError::ReplicaTooLarge { needed_ranks: 9, ranks_per_channel: 4 };
        let e = ServeError::from(plan_err.clone());
        assert!(matches!(&e, ServeError::Plan(p) if *p == plan_err));
        // The typed source survives.
        let src = std::error::Error::source(&e).expect("plan source");
        assert_eq!(src.to_string(), plan_err.to_string());
        assert!(!e.is_retryable());

        // Injected faults classify into their variants.
        use crate::coordinator::faults::InjectedFault;
        let lost = Arc::new(anyhow::Error::new(InjectedFault::DeviceLost {
            device: 3,
            batch: 7,
        }));
        let e = ServeError::from_backend(3, &lost);
        assert!(matches!(e, ServeError::DeviceLost { device: 3 }));
        assert!(e.is_retryable() && e.counts_against_health());

        let transient = Arc::new(anyhow::Error::new(InjectedFault::Transient {
            device: 1,
            batch: 0,
        }));
        let e = ServeError::from_backend(1, &transient);
        assert!(matches!(e, ServeError::Transient { device: 1 }));

        // Non-injected backend errors keep their chain.
        let other = Arc::new(anyhow::anyhow!("PJRT launch failed").context("run_batch"));
        let e = ServeError::from_backend(0, &other);
        assert!(matches!(e, ServeError::Backend { device: 0, .. }));
        assert!(e.to_string().contains("PJRT launch failed"), "{e}");

        // Sheds and timeouts never count against health.
        let shed = ServeError::Shed { device: Some(0), reason: ShedReason::QueueFull };
        assert!(shed.is_retryable() && !shed.counts_against_health());
        let timeout = ServeError::Timeout { device: 0 };
        assert!(!timeout.is_retryable() && !timeout.counts_against_health());
        // `?` into anyhow::Result works (ServeError is a std error).
        fn through_anyhow(e: ServeError) -> anyhow::Result<()> {
            Err(e)?
        }
        assert!(through_anyhow(ServeError::Rejected("nope".into())).is_err());
    }
}
