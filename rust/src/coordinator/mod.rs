//! Layer-3 coordinator (DESIGN.md S15): the serving front of PIM-DRAM.
//!
//! The paper's system contribution is the architecture + mapping +
//! dataflow; the coordinator operationalizes it as a request loop: an
//! inference server owns the PJRT executables (one per bank/layer),
//! batches incoming requests to the artifact batch size, executes the
//! bank chain, and reports both measured wall-clock latency and the PIM
//! timing model's per-image cost for the same work.
//!
//! PJRT handles are not `Send`, so the executor lives on a dedicated
//! worker thread; clients talk to it over channels (std::sync::mpsc — the
//! offline registry has no tokio, and a simulator coordinator needs no
//! async I/O).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::Batcher;
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{Device, Policy, Router};
pub use server::{ClassifyResponse, InferenceServer, ServerConfig};
