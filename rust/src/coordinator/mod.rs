//! Layer-3 coordinator (DESIGN.md S15): the serving front of PIM-DRAM.
//!
//! The paper's system contribution is the architecture + mapping +
//! dataflow; the coordinator operationalizes it as a request loop over a
//! *pool* of PIM devices — one worker per replica of a
//! `plan::ExecutionPlan`. The dispatcher routes each request to a device
//! (round-robin / least-loaded / two-choices), the device's worker batches
//! to the artifact batch size, executes its backend, and reports both
//! measured wall-clock latency and per-device dispatch counts alongside
//! the PIM timing model's per-image cost for the same work.
//!
//! Backends (`backend::Backend`) are constructed inside their worker
//! thread — PJRT handles are not `Send` — so clients talk to workers over
//! channels (std::sync::mpsc — the offline registry has no tokio, and a
//! simulator coordinator needs no async I/O). The simulated backend
//! (`backend::SimBackend`) serves without artifacts; the PJRT artifact
//! executor compiles behind `--features pjrt`.

//! Degraded-mode serving (DESIGN.md §Resilience): `faults` injects a
//! deterministic, seed-driven fault schedule into any backend; `resilience`
//! holds the deadline/retry/failover/quarantine policy and typed serving
//! errors; `chaos` replays the whole fleet in virtual time for
//! bitwise-reproducible SLO reports.

pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod faults;
pub mod metrics;
pub mod resilience;
pub mod router;
pub mod server;
pub mod traffic;

pub use backend::{Backend, SimBackend};
pub use batcher::Batcher;
pub use chaos::{simulate_fleet, FleetConfig, FleetReport};
pub use faults::{CrashSpec, FaultSpec, FaultyBackend, InjectedFault, StormSpec, StragglerSpec};
pub use metrics::{LatencyStats, Metrics, MetricsSnapshot};
pub use traffic::{
    arrival_name, drive, parse_arrival, ArrivalKind, OpenLoopReport, TrafficSpec,
    ARRIVALS,
};
pub use resilience::{
    HealthTracker, HealthTransition, ResilienceSpec, ServeError, ShedReason,
};
pub use router::{Device, Policy, Router};
pub use server::{ClassifyResponse, MultiDeviceServer, Pending, PoolConfig};

#[cfg(feature = "pjrt")]
pub use server::{InferenceServer, ServerConfig};
