//! Open-loop traffic generation against the live serving pool.
//!
//! The virtual-time chaos replay (`chaos.rs`) drives uniform arrivals; a
//! real front-end does not. This module generates **seed-deterministic**
//! arrival schedules — uniform, Poisson, bursty (Markov-modulated
//! on/off), and diurnal (sinusoid-modulated rate) — and [`drive`]s them
//! against the real [`MultiDeviceServer`] through its non-blocking
//! `submit`/`Pending` path, open-loop: a slow fleet does not slow the
//! arrival process down, it just grows queues until the shed policy bites.
//!
//! Accounting is exact: every offered request reaches one terminal
//! outcome (completed / shed / timeout / failed), and
//! [`OpenLoopReport::reconcile`] cross-checks the driver's tallies
//! against the pool's own [`Metrics`](super::metrics::Metrics).
//!
//! The schedule (ns offsets from stream start) is pure data, so the same
//! [`TrafficSpec`] also drives the virtual-time fleet replay — live and
//! simulated serving see identical arrival sequences for a given seed.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::metrics::{LatencyStats, MetricsSnapshot};
use super::resilience::ServeError;
use super::server::MultiDeviceServer;

/// Arrival process families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Evenly spaced: one request every interarrival — exactly the legacy
    /// chaos-replay arrivals.
    Uniform,
    /// Memoryless: exponential interarrival gaps at the nominal rate.
    Poisson,
    /// Markov-modulated on/off: exponential gaps at the within-burst rate
    /// during the on-window of each period, silence in the off-window.
    /// The within-burst mean is scaled by `duty` so the long-run offered
    /// rate matches the nominal one.
    Bursty,
    /// Poisson with a sinusoid-modulated instantaneous rate:
    /// `rate · (1 + amplitude · sin(2π t / period))` — a compressed
    /// day/night cycle.
    Diurnal,
}

/// Accepted arrival-process spellings, in canonical order.
pub const ARRIVALS: [&str; 4] = ["uniform", "poisson", "bursty", "diurnal"];

/// Parse an arrival-process name (CLI `--arrival`, spec `serve.arrival.process`).
pub fn parse_arrival(s: &str) -> Result<ArrivalKind> {
    Ok(match s {
        "uniform" => ArrivalKind::Uniform,
        "poisson" => ArrivalKind::Poisson,
        "bursty" => ArrivalKind::Bursty,
        "diurnal" => ArrivalKind::Diurnal,
        other => anyhow::bail!(
            "unknown arrival process '{other}' (expected {})",
            ARRIVALS.join("|")
        ),
    })
}

/// Canonical name of an arrival process.
pub fn arrival_name(kind: ArrivalKind) -> &'static str {
    match kind {
        ArrivalKind::Uniform => "uniform",
        ArrivalKind::Poisson => "poisson",
        ArrivalKind::Bursty => "bursty",
        ArrivalKind::Diurnal => "diurnal",
    }
}

/// An arrival-process specification. `rate_rps == 0` (the default) means
/// "no explicit rate": callers derive the interarrival from fleet
/// capacity and `serve.load`, exactly like the chaos replay.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    pub kind: ArrivalKind,
    /// Offered arrival rate, requests/s; 0 derives the rate from load.
    pub rate_rps: f64,
    /// Schedule seed — same spec and interarrival give a bitwise-identical
    /// schedule.
    pub seed: u64,
    /// Modulation period for bursty/diurnal processes (ms).
    pub period_ms: u64,
    /// Bursty on-fraction of each period, in (0, 1].
    pub duty: f64,
    /// Diurnal rate swing, in [0, 1).
    pub amplitude: f64,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        TrafficSpec {
            kind: ArrivalKind::Poisson,
            rate_rps: 0.0,
            seed: 0x5EED,
            period_ms: 1000,
            duty: 0.5,
            amplitude: 0.5,
        }
    }
}

impl TrafficSpec {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.rate_rps.is_finite() && self.rate_rps >= 0.0,
            "arrival rate must be finite and >= 0, got {}",
            self.rate_rps
        );
        anyhow::ensure!(self.period_ms >= 1, "arrival period_ms must be >= 1");
        anyhow::ensure!(
            self.duty > 0.0 && self.duty <= 1.0,
            "arrival duty must be in (0, 1], got {}",
            self.duty
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.amplitude),
            "arrival amplitude must be in [0, 1), got {}",
            self.amplitude
        );
        Ok(())
    }

    /// Interarrival from the explicit rate, when one is set.
    pub fn interarrival_ns(&self) -> Option<u64> {
        if self.rate_rps > 0.0 {
            Some(((1e9 / self.rate_rps).round() as u64).max(1))
        } else {
            None
        }
    }

    /// Generate `requests` arrival offsets (ns from stream start,
    /// non-decreasing) at a nominal `interarrival_ns` spacing. Pure and
    /// seed-deterministic: the same spec and interarrival are
    /// bitwise-identical on every call.
    pub fn schedule(&self, requests: u64, interarrival_ns: u64) -> Vec<u64> {
        let mean = interarrival_ns.max(1) as f64;
        let period = (self.period_ms.max(1) * 1_000_000) as f64;
        let mut rng = Rng::new(self.seed);
        let mut gap = |mean: f64| -(1.0 - rng.uniform()).ln() * mean;
        let mut out = Vec::with_capacity(requests as usize);
        match self.kind {
            ArrivalKind::Uniform => {
                for i in 0..requests {
                    out.push(i * interarrival_ns);
                }
            }
            ArrivalKind::Poisson => {
                let mut t = 0.0f64;
                for _ in 0..requests {
                    t += gap(mean);
                    out.push(t.round() as u64);
                }
            }
            ArrivalKind::Bursty => {
                let on = period * self.duty;
                let mut t = 0.0f64;
                for _ in 0..requests {
                    // Within-burst rate is 1/duty × nominal, so the
                    // long-run offered rate stays at the nominal one.
                    t += gap(mean * self.duty);
                    let phase = t % period;
                    if phase > on {
                        // Landed in the off-window: the burst source is
                        // silent until the next period starts.
                        t += period - phase;
                    }
                    out.push(t.round() as u64);
                }
            }
            ArrivalKind::Diurnal => {
                let mut t = 0.0f64;
                for _ in 0..requests {
                    let factor = 1.0
                        + self.amplitude * (std::f64::consts::TAU * t / period).sin();
                    t += gap(mean / factor.max(1e-9));
                    out.push(t.round() as u64);
                }
            }
        }
        out
    }
}

/// Outcome accounting of one open-loop run against the live pool.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// Requests the arrival process offered.
    pub offered: u64,
    /// Requests answered with logits.
    pub completed: u64,
    /// Refused at admission or by a dying worker.
    pub shed: u64,
    /// Deadline expired before execution.
    pub timeouts: u64,
    /// Typed backend/device failures.
    pub failed: u64,
    /// End-to-end request latencies of completed requests.
    pub latency: LatencyStats,
    /// Wall-clock from first submit to last terminal outcome.
    pub makespan: Duration,
}

impl OpenLoopReport {
    /// Every offered request reaches exactly one terminal outcome.
    pub fn accounted(&self) -> u64 {
        self.completed + self.shed + self.timeouts + self.failed
    }

    /// Goodput over the makespan, requests/s.
    pub fn goodput_rps(&self) -> f64 {
        let secs = self.makespan.as_secs_f64();
        if secs > 0.0 {
            self.completed as f64 / secs
        } else {
            0.0
        }
    }

    /// Offered rate over the makespan, requests/s.
    pub fn offered_rps(&self) -> f64 {
        let secs = self.makespan.as_secs_f64();
        if secs > 0.0 {
            self.offered as f64 / secs
        } else {
            0.0
        }
    }

    /// Cross-check the driver's accounting against the pool's own
    /// metrics: no request may vanish (`accounted == offered`) and both
    /// sides must agree on what completed (`completed == requests`).
    pub fn reconcile(&self, m: &MetricsSnapshot) -> Result<()> {
        anyhow::ensure!(
            self.accounted() == self.offered,
            "open-loop accounting leak: {} accounted of {} offered",
            self.accounted(),
            self.offered
        );
        anyhow::ensure!(
            self.completed == m.requests,
            "driver saw {} completions but the pool recorded {}",
            self.completed,
            m.requests
        );
        Ok(())
    }

    /// Human-readable summary (the `serve --arrival` output block).
    pub fn render(&self) -> String {
        format!(
            "open-loop: offered={} ({:.0} req/s) completed={} ({:.0} req/s goodput) \
             shed={} timeouts={} failed={}\n\
             latency: mean={:.0} µs p50={:.0} µs p99={:.0} µs over {:.2} ms makespan\n",
            self.offered,
            self.offered_rps(),
            self.completed,
            self.goodput_rps(),
            self.shed,
            self.timeouts,
            self.failed,
            self.latency.mean_us,
            self.latency.p50_us,
            self.latency.p99_us,
            self.makespan.as_secs_f64() * 1e3,
        )
    }
}

fn tally(err: &ServeError, shed: &mut u64, timeouts: &mut u64, failed: &mut u64) {
    match err {
        ServeError::Shed { .. } => *shed += 1,
        ServeError::Timeout { .. } => *timeouts += 1,
        _ => *failed += 1,
    }
}

/// Drive an arrival schedule (ns offsets from stream start) against a
/// live pool, open-loop: submissions are paced by the schedule alone —
/// never by the fleet — via the non-blocking `submit` path, and every
/// admitted request's `Pending` is drained afterwards. `seed` generates
/// the deterministic image payloads.
pub fn drive(server: &MultiDeviceServer, offsets: &[u64], seed: u64) -> OpenLoopReport {
    let elems = server.image_elems();
    let mut rng = Rng::new(seed);
    let mut latencies = Summary::new();
    let (mut shed, mut timeouts, mut failed) = (0u64, 0u64, 0u64);
    let mut admitted = Vec::with_capacity(offsets.len());
    let t0 = Instant::now();
    for &at in offsets {
        let target = Duration::from_nanos(at);
        let elapsed = t0.elapsed();
        if elapsed < target {
            std::thread::sleep(target - elapsed);
        }
        let image: Vec<i32> = (0..elems).map(|_| rng.int_range(0, 255) as i32).collect();
        match server.submit(image) {
            Ok(pending) => admitted.push(pending),
            Err(e) => tally(&e, &mut shed, &mut timeouts, &mut failed),
        }
    }
    for pending in admitted {
        match pending.wait() {
            Ok(resp) => latencies.push(resp.latency.as_secs_f64() * 1e6),
            Err(e) => tally(&e, &mut shed, &mut timeouts, &mut failed),
        }
    }
    OpenLoopReport {
        offered: offsets.len() as u64,
        completed: latencies.len() as u64,
        shed,
        timeouts,
        failed,
        latency: LatencyStats::from_summary_or_zero(&latencies),
        makespan: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, SimBackend};
    use crate::coordinator::resilience::ResilienceSpec;
    use crate::coordinator::router::Policy;
    use crate::coordinator::server::PoolConfig;

    fn spec(kind: ArrivalKind) -> TrafficSpec {
        TrafficSpec { kind, ..TrafficSpec::default() }
    }

    #[test]
    fn same_seed_schedules_are_bitwise_identical() {
        for kind in
            [ArrivalKind::Uniform, ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal]
        {
            let s = spec(kind);
            assert_eq!(s.schedule(500, 1000), s.schedule(500, 1000), "{kind:?}");
            let reseeded = TrafficSpec { seed: 1, ..spec(kind) };
            if kind != ArrivalKind::Uniform {
                assert_ne!(
                    s.schedule(500, 1000),
                    reseeded.schedule(500, 1000),
                    "{kind:?} must consume the seed"
                );
            }
        }
    }

    #[test]
    fn schedules_are_nondecreasing() {
        for kind in
            [ArrivalKind::Uniform, ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal]
        {
            let offs = spec(kind).schedule(2000, 1000);
            assert_eq!(offs.len(), 2000);
            assert!(offs.windows(2).all(|w| w[0] <= w[1]), "{kind:?} went backwards");
        }
    }

    #[test]
    fn uniform_matches_the_legacy_spacing_exactly() {
        let offs = spec(ArrivalKind::Uniform).schedule(5, 1234);
        assert_eq!(offs, vec![0, 1234, 2468, 3702, 4936]);
    }

    #[test]
    fn poisson_empirical_mean_is_close_to_nominal() {
        let n = 20_000u64;
        let offs = spec(ArrivalKind::Poisson).schedule(n, 1000);
        let mean = *offs.last().unwrap() as f64 / n as f64;
        assert!((mean - 1000.0).abs() < 50.0, "empirical mean {mean} vs nominal 1000");
    }

    #[test]
    fn bursty_respects_the_duty_cycle() {
        let s = TrafficSpec {
            kind: ArrivalKind::Bursty,
            period_ms: 1,
            duty: 0.25,
            ..TrafficSpec::default()
        };
        let period = 1_000_000u64;
        let on = (period as f64 * s.duty) as u64;
        for &off in &s.schedule(2000, 1000) {
            assert!(
                off % period <= on + 1,
                "arrival at {off} ns falls {} ns into the off-window",
                off % period
            );
        }
    }

    #[test]
    fn diurnal_concentrates_arrivals_in_the_high_rate_half() {
        let s = TrafficSpec {
            kind: ArrivalKind::Diurnal,
            period_ms: 1,
            amplitude: 0.9,
            ..TrafficSpec::default()
        };
        let period = 1_000_000u64;
        let offs = s.schedule(4000, 1000);
        let first_half =
            offs.iter().filter(|&&o| o % period < period / 2).count();
        let second_half = offs.len() - first_half;
        // ∫(1 + 0.9 sin) over the first half vs the second gives ≈ 3.7×.
        assert!(
            first_half > second_half * 2,
            "sin-modulated rate must skew arrivals: {first_half} vs {second_half}"
        );
    }

    #[test]
    fn validate_rejects_degenerate_specs() {
        assert!(TrafficSpec::default().validate().is_ok());
        assert!(TrafficSpec { rate_rps: f64::NAN, ..TrafficSpec::default() }
            .validate()
            .is_err());
        assert!(TrafficSpec { rate_rps: -1.0, ..TrafficSpec::default() }
            .validate()
            .is_err());
        assert!(TrafficSpec { duty: 0.0, ..TrafficSpec::default() }.validate().is_err());
        assert!(TrafficSpec { amplitude: 1.0, ..TrafficSpec::default() }
            .validate()
            .is_err());
        assert!(TrafficSpec { period_ms: 0, ..TrafficSpec::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn arrival_names_round_trip() {
        for name in ARRIVALS {
            assert_eq!(arrival_name(parse_arrival(name).unwrap()), name);
        }
        let err = parse_arrival("tidal").unwrap_err();
        assert!(err.to_string().contains("poisson"), "{err}");
    }

    /// A backend slow enough that an instantaneous burst overflows the
    /// bounded admission queue.
    struct SlowBackend(SimBackend);

    impl Backend for SlowBackend {
        fn batch_size(&self) -> usize {
            self.0.batch_size()
        }
        fn image_elems(&self) -> usize {
            self.0.image_elems()
        }
        fn num_classes(&self) -> usize {
            self.0.num_classes()
        }
        fn run_batch(&mut self, images: &[i32]) -> anyhow::Result<Vec<f32>> {
            std::thread::sleep(Duration::from_millis(2));
            self.0.run_batch(images)
        }
    }

    #[test]
    fn overloaded_pool_sheds_but_accounts_every_request() {
        let server = MultiDeviceServer::start(
            PoolConfig {
                devices: 1,
                policy: Policy::Backlog,
                batch_window: Duration::from_millis(1),
                resilience: ResilienceSpec { queue_cap: 2, ..ResilienceSpec::default() },
                ..PoolConfig::default()
            },
            |_| Ok(SlowBackend(SimBackend::new(4, 8, 10))),
        )
        .unwrap();
        // An instantaneous burst of 64 requests against a 2-deep queue.
        let offsets = vec![0u64; 64];
        let report = drive(&server, &offsets, 7);
        assert_eq!(report.offered, 64);
        assert!(report.shed > 0, "2-deep queue must shed an instantaneous burst");
        assert!(report.completed > 0, "the queue head must still be served");
        assert!(report.completed <= report.offered, "goodput cannot exceed offered");
        assert_eq!(report.accounted(), report.offered);
        report.reconcile(&server.metrics()).unwrap();
        server.shutdown();
    }

    #[test]
    fn clean_pool_completes_the_whole_schedule() {
        let server = MultiDeviceServer::start(
            PoolConfig {
                devices: 2,
                batch_window: Duration::from_millis(1),
                ..PoolConfig::default()
            },
            |_| Ok(SimBackend::new(4, 8, 10)),
        )
        .unwrap();
        let offsets = spec(ArrivalKind::Poisson).schedule(40, 50_000);
        let report = drive(&server, &offsets, 11);
        assert_eq!(report.completed, 40);
        assert_eq!(report.accounted(), report.offered);
        assert!(report.latency.p50_us > 0.0);
        assert!(report.render().contains("offered=40"), "{}", report.render());
        report.reconcile(&server.metrics()).unwrap();
        server.shutdown();
    }
}
