//! Multi-device request router: when several PIM-DRAM modules (DIMMs) are
//! attached, the coordinator load-balances inference streams across them —
//! the vLLM-router-shaped piece of the L3 layer. Devices here are
//! abstract workers with a known per-image service time (from the timing
//! simulator) and a queue depth; routing is least-loaded with
//! power-of-two-choices sampling for O(1) decisions at scale.

use crate::util::rng::Rng;

/// One attached PIM device (e.g. a DIMM running a pipelined network).
#[derive(Debug, Clone)]
pub struct Device {
    pub name: String,
    /// Steady-state service time per image (ns) from the simulator.
    pub service_ns: f64,
    /// Outstanding images (queue + in flight).
    pub in_flight: u64,
}

impl Device {
    pub fn new(name: &str, service_ns: f64) -> Self {
        Device { name: name.into(), service_ns, in_flight: 0 }
    }

    /// Expected completion delay for a newly-enqueued image.
    pub fn backlog_ns(&self) -> f64 {
        (self.in_flight + 1) as f64 * self.service_ns
    }
}

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    /// Pick the smaller backlog of two uniformly-sampled devices.
    TwoChoices,
    /// Scan all devices for the minimum backlog.
    LeastLoaded,
}

/// The router: owns device states and dispatch accounting.
#[derive(Debug)]
pub struct Router {
    devices: Vec<Device>,
    policy: Policy,
    rr_next: usize,
    rng: Rng,
    pub dispatched: u64,
}

impl Router {
    pub fn new(devices: Vec<Device>, policy: Policy, seed: u64) -> Self {
        assert!(!devices.is_empty(), "router needs at least one device");
        Router { devices, policy, rr_next: 0, rng: Rng::new(seed), dispatched: 0 }
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Route one image; returns the chosen device index.
    pub fn route(&mut self) -> usize {
        let idx = match self.policy {
            Policy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.devices.len();
                i
            }
            Policy::TwoChoices => {
                let a = self.rng.below(self.devices.len());
                let b = self.rng.below(self.devices.len());
                if self.devices[a].backlog_ns() <= self.devices[b].backlog_ns() {
                    a
                } else {
                    b
                }
            }
            Policy::LeastLoaded => self
                .devices
                .iter()
                .enumerate()
                .min_by(|x, y| {
                    x.1.backlog_ns().partial_cmp(&y.1.backlog_ns()).unwrap()
                })
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.devices[idx].in_flight += 1;
        self.dispatched += 1;
        idx
    }

    /// Mark one image completed on `device`.
    pub fn complete(&mut self, device: usize) {
        let d = &mut self.devices[device];
        assert!(d.in_flight > 0, "completion without dispatch on {}", d.name);
        d.in_flight -= 1;
    }

    /// Simulate dispatching `images` with completions as devices drain
    /// (discrete, service-time ordered); returns the makespan in ns.
    pub fn simulate_makespan(&mut self, images: u64) -> f64 {
        let mut finish: Vec<f64> = vec![0.0; self.devices.len()];
        for _ in 0..images {
            let idx = self.route();
            finish[idx] += self.devices[idx].service_ns;
            self.complete(idx);
        }
        finish.into_iter().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;

    fn devs(times: &[f64]) -> Vec<Device> {
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| Device::new(&format!("dimm{i}"), t))
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0]), Policy::RoundRobin, 0);
        assert_eq!((0..6).map(|_| r.route()).collect::<Vec<_>>(), vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_fast_device() {
        let mut r = Router::new(devs(&[100.0, 1.0]), Policy::LeastLoaded, 0);
        let mut counts = [0u64; 2];
        for _ in 0..100 {
            let i = r.route();
            counts[i] += 1;
        }
        assert!(counts[1] > counts[0] * 5, "{counts:?}");
    }

    #[test]
    fn heterogeneous_makespan_beats_round_robin() {
        // A 4x-faster device should absorb proportionally more load.
        let lb = Router::new(devs(&[4.0, 1.0]), Policy::LeastLoaded, 0)
            .simulate_makespan(1000);
        let rr = Router::new(devs(&[4.0, 1.0]), Policy::RoundRobin, 0)
            .simulate_makespan(1000);
        assert!(lb < rr, "least-loaded {lb} vs round-robin {rr}");
    }

    #[test]
    fn completion_without_dispatch_panics() {
        let mut r = Router::new(devs(&[1.0]), Policy::RoundRobin, 0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            r.complete(0);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn two_choices_balances_property() {
        crate::testutil::check(10, |rng| {
            let n = 2 + rng.below(6);
            let mut r = Router::new(
                devs(&vec![1.0; n]),
                Policy::TwoChoices,
                rng.next_u64(),
            );
            for _ in 0..200 {
                r.route();
            }
            let max = r.devices().iter().map(|d| d.in_flight).max().unwrap();
            let min = r.devices().iter().map(|d| d.in_flight).min().unwrap();
            // Two-choices keeps the imbalance logarithmic; generous bound.
            prop_assert!(max - min <= 200 / n as u64 / 2 + 8, "max={max} min={min}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn empty_router_rejected() {
        Router::new(vec![], Policy::RoundRobin, 0);
    }

    #[test]
    fn backlog_accounting_is_consistent_under_every_policy() {
        // route() increments exactly the chosen device's in_flight and
        // complete() decrements it, under an interleaved dispatch/complete
        // stream — for each policy.
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::TwoChoices] {
            let mut r = Router::new(devs(&[1.0, 2.0, 3.0]), policy, 42);
            let mut outstanding = vec![0u64; 3];
            let mut inflight_fifo = Vec::new();
            for step in 0..60 {
                let i = r.route();
                outstanding[i] += 1;
                inflight_fifo.push(i);
                if step % 2 == 1 {
                    let j = inflight_fifo.remove(0);
                    r.complete(j);
                    outstanding[j] -= 1;
                }
                let got: Vec<u64> =
                    r.devices().iter().map(|d| d.in_flight).collect();
                assert_eq!(got, outstanding, "{policy:?} step {step}");
            }
            assert_eq!(r.dispatched, 60, "{policy:?}");
        }
    }

    #[test]
    fn round_robin_is_exactly_fair() {
        let mut r = Router::new(devs(&[5.0, 1.0, 2.0]), Policy::RoundRobin, 9);
        let mut counts = [0u64; 3];
        for _ in 0..99 {
            counts[r.route()] += 1;
        }
        // Round-robin ignores backlog entirely: perfect thirds.
        assert_eq!(counts, [33, 33, 33]);
    }

    #[test]
    fn least_loaded_balances_exactly_with_equal_service() {
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0, 1.0]), Policy::LeastLoaded, 0);
        for _ in 0..103 {
            r.route();
        }
        let inflight: Vec<u64> = r.devices().iter().map(|d| d.in_flight).collect();
        let max = *inflight.iter().max().unwrap();
        let min = *inflight.iter().min().unwrap();
        assert!(max - min <= 1, "least-loaded must stay within 1: {inflight:?}");
    }

    #[test]
    fn least_loaded_prefers_freshly_drained_device() {
        let mut r = Router::new(devs(&[1.0, 1.0]), Policy::LeastLoaded, 0);
        let first = r.route();
        let second = r.route();
        assert_ne!(first, second, "second dispatch must avoid the loaded device");
        // Draining `first` makes it the unique minimum again.
        r.complete(first);
        assert_eq!(r.route(), first);
    }

    #[test]
    fn two_choices_tracks_completions() {
        // With completions flowing, two-choices must not let any device's
        // backlog run away: complete in bursts and re-check the spread.
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0]), Policy::TwoChoices, 7);
        let mut picks = Vec::new();
        for round in 0..20 {
            for _ in 0..6 {
                picks.push(r.route());
            }
            // Drain all but the last round's dispatches.
            for &i in &picks[..picks.len() - 6] {
                r.complete(i);
            }
            picks.drain(..picks.len() - 6);
            let max = r.devices().iter().map(|d| d.in_flight).max().unwrap();
            assert!(max <= 6, "round {round}: runaway backlog {max}");
        }
        let total: u64 = r.devices().iter().map(|d| d.in_flight).sum();
        assert_eq!(total, 6, "exactly the undrained round stays in flight");
    }
}
