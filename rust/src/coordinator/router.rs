//! Multi-device request router: when several PIM-DRAM modules (DIMMs) are
//! attached, the coordinator load-balances inference streams across them —
//! the vLLM-router-shaped piece of the L3 layer. Devices here are
//! abstract workers with a known per-image service time (from the timing
//! simulator) and a queue depth; routing is least-loaded with
//! power-of-two-choices sampling for O(1) decisions at scale.

use anyhow::Result;

use crate::util::rng::Rng;

/// One attached PIM device (e.g. a DIMM running a pipelined network).
#[derive(Debug, Clone)]
pub struct Device {
    pub name: String,
    /// Steady-state service time per image (ns) from the simulator.
    pub service_ns: f64,
    /// Outstanding images (queue + in flight).
    pub in_flight: u64,
}

impl Device {
    pub fn new(name: &str, service_ns: f64) -> Self {
        Device { name: name.into(), service_ns, in_flight: 0 }
    }

    /// Expected completion delay for a newly-enqueued image.
    pub fn backlog_ns(&self) -> f64 {
        (self.in_flight + 1) as f64 * self.service_ns
    }
}

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    /// Pick the smaller backlog of two uniformly-sampled devices.
    TwoChoices,
    /// Scan all devices for the minimum backlog.
    LeastLoaded,
    /// Capability- and backlog-aware: score every available device by its
    /// estimated completion delay — per-image service ns (from the
    /// device's cached simulator price) × queued depth — and take the
    /// minimum. On a heterogeneous fleet this sends proportionally more
    /// traffic to the faster geometry; reintegration probes flagged via
    /// [`Router::set_probe_candidate`] pre-empt the score so a quarantined
    /// fast device is never starved of its comeback request.
    Backlog,
}

/// The router: owns device states and dispatch accounting.
#[derive(Debug)]
pub struct Router {
    devices: Vec<Device>,
    /// Routability mask (health tracker / failover drives this); all
    /// devices start available, so legacy callers see no change.
    available: Vec<bool>,
    /// Reintegration-probe flags: a flagged available device wins the next
    /// [`Policy::Backlog`] decision outright (then the flag clears), so a
    /// quarantined device whose score lost to every healthy peer still
    /// gets its probe request. Legacy policies ignore the flags entirely.
    probe: Vec<bool>,
    policy: Policy,
    rr_next: usize,
    rng: Rng,
    pub dispatched: u64,
}

impl Router {
    pub fn new(devices: Vec<Device>, policy: Policy, seed: u64) -> Self {
        assert!(!devices.is_empty(), "router needs at least one device");
        let available = vec![true; devices.len()];
        let probe = vec![false; devices.len()];
        Router { devices, available, probe, policy, rr_next: 0, rng: Rng::new(seed), dispatched: 0 }
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Mark a device (un)routable. Unavailable devices are skipped by
    /// [`Router::try_route`]; outstanding work still completes normally.
    pub fn set_available(&mut self, device: usize, up: bool) {
        self.available[device] = up;
    }

    pub fn is_available(&self, device: usize) -> bool {
        self.available[device]
    }

    /// Flag (or clear) `device` as a reintegration-probe candidate. Under
    /// [`Policy::Backlog`] the next routing decision sends one request to a
    /// flagged available device before consulting the score, guaranteeing a
    /// freshly-reintegrated fast device cannot be starved of probes by
    /// lower-backlog healthy peers.
    pub fn set_probe_candidate(&mut self, device: usize, probe: bool) {
        self.probe[device] = probe;
    }

    /// Routable devices remaining.
    pub fn available_count(&self) -> usize {
        self.available.iter().filter(|&&u| u).count()
    }

    fn min_backlog_available(&self) -> Option<usize> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(i, _)| self.available[*i])
            .min_by(|x, y| x.1.backlog_ns().total_cmp(&y.1.backlog_ns()))
            .map(|(i, _)| i)
    }

    /// Route one image among the available devices; `None` when every
    /// device is unavailable. With all devices up this makes exactly the
    /// decisions (and RNG draws) [`Router::route`] always made.
    pub fn try_route(&mut self) -> Option<usize> {
        let n = self.devices.len();
        let idx = match self.policy {
            Policy::RoundRobin => {
                // First available device at or after the cursor.
                let i = (0..n)
                    .map(|k| (self.rr_next + k) % n)
                    .find(|&i| self.available[i])?;
                self.rr_next = (i + 1) % n;
                i
            }
            Policy::TwoChoices => {
                // Draw from the full range regardless of availability so
                // the RNG stream is identical to the legacy router.
                let a = self.rng.below(n);
                let b = self.rng.below(n);
                match (self.available[a], self.available[b]) {
                    (true, true) => {
                        if self.devices[a].backlog_ns() <= self.devices[b].backlog_ns() {
                            a
                        } else {
                            b
                        }
                    }
                    (true, false) => a,
                    (false, true) => b,
                    // Both sampled devices are down: fall back to a scan.
                    (false, false) => self.min_backlog_available()?,
                }
            }
            Policy::LeastLoaded => self.min_backlog_available()?,
            Policy::Backlog => {
                // Probe fairness first: a flagged available device takes
                // this request regardless of score, consuming its flag.
                match (0..n).find(|&i| self.probe[i] && self.available[i]) {
                    Some(i) => {
                        self.probe[i] = false;
                        i
                    }
                    None => self.min_backlog_available()?,
                }
            }
        };
        self.devices[idx].in_flight += 1;
        self.dispatched += 1;
        Some(idx)
    }

    /// Route one image; returns the chosen device index. Panics if every
    /// device has been marked unavailable — use [`Router::try_route`] when
    /// failover is in play.
    pub fn route(&mut self) -> usize {
        self.try_route().expect("no routable device")
    }

    /// Mark one image completed on `device`. Errors (instead of corrupting
    /// the backlog accounting) on a completion that was never dispatched.
    pub fn complete(&mut self, device: usize) -> Result<()> {
        let Some(d) = self.devices.get_mut(device) else {
            anyhow::bail!("completion on unknown device index {device}");
        };
        anyhow::ensure!(
            d.in_flight > 0,
            "completion without dispatch on {}",
            d.name
        );
        d.in_flight -= 1;
        Ok(())
    }

    /// Simulate dispatching `images` with completions as devices drain
    /// (discrete, service-time ordered); returns the makespan in ns.
    pub fn simulate_makespan(&mut self, images: u64) -> f64 {
        let mut finish: Vec<f64> = vec![0.0; self.devices.len()];
        for _ in 0..images {
            let idx = self.route();
            finish[idx] += self.devices[idx].service_ns;
            self.complete(idx).expect("routed immediately above");
        }
        finish.into_iter().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;

    fn devs(times: &[f64]) -> Vec<Device> {
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| Device::new(&format!("dimm{i}"), t))
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0]), Policy::RoundRobin, 0);
        assert_eq!((0..6).map(|_| r.route()).collect::<Vec<_>>(), vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_fast_device() {
        let mut r = Router::new(devs(&[100.0, 1.0]), Policy::LeastLoaded, 0);
        let mut counts = [0u64; 2];
        for _ in 0..100 {
            let i = r.route();
            counts[i] += 1;
        }
        assert!(counts[1] > counts[0] * 5, "{counts:?}");
    }

    #[test]
    fn heterogeneous_makespan_beats_round_robin() {
        // A 4x-faster device should absorb proportionally more load.
        let lb = Router::new(devs(&[4.0, 1.0]), Policy::LeastLoaded, 0)
            .simulate_makespan(1000);
        let rr = Router::new(devs(&[4.0, 1.0]), Policy::RoundRobin, 0)
            .simulate_makespan(1000);
        assert!(lb < rr, "least-loaded {lb} vs round-robin {rr}");
    }

    #[test]
    fn completion_without_dispatch_errors() {
        let mut r = Router::new(devs(&[1.0]), Policy::RoundRobin, 0);
        let err = r.complete(0).unwrap_err();
        assert!(err.to_string().contains("completion without dispatch"), "{err:#}");
        // Unknown indices are an error too, not a panic.
        assert!(r.complete(7).is_err());
        // And the error leaves accounting untouched: a real cycle still works.
        let i = r.route();
        r.complete(i).unwrap();
    }

    #[test]
    fn two_choices_balances_property() {
        crate::testutil::check(10, |rng| {
            let n = 2 + rng.below(6);
            let mut r = Router::new(
                devs(&vec![1.0; n]),
                Policy::TwoChoices,
                rng.next_u64(),
            );
            for _ in 0..200 {
                r.route();
            }
            let max = r.devices().iter().map(|d| d.in_flight).max().unwrap();
            let min = r.devices().iter().map(|d| d.in_flight).min().unwrap();
            // Two-choices keeps the imbalance logarithmic; generous bound.
            prop_assert!(max - min <= 200 / n as u64 / 2 + 8, "max={max} min={min}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn empty_router_rejected() {
        Router::new(vec![], Policy::RoundRobin, 0);
    }

    #[test]
    fn backlog_accounting_is_consistent_under_every_policy() {
        // route() increments exactly the chosen device's in_flight and
        // complete() decrements it, under an interleaved dispatch/complete
        // stream — for each policy.
        for policy in
            [Policy::RoundRobin, Policy::LeastLoaded, Policy::TwoChoices, Policy::Backlog]
        {
            let mut r = Router::new(devs(&[1.0, 2.0, 3.0]), policy, 42);
            let mut outstanding = vec![0u64; 3];
            let mut inflight_fifo = Vec::new();
            for step in 0..60 {
                let i = r.route();
                outstanding[i] += 1;
                inflight_fifo.push(i);
                if step % 2 == 1 {
                    let j = inflight_fifo.remove(0);
                    r.complete(j).unwrap();
                    outstanding[j] -= 1;
                }
                let got: Vec<u64> =
                    r.devices().iter().map(|d| d.in_flight).collect();
                assert_eq!(got, outstanding, "{policy:?} step {step}");
            }
            assert_eq!(r.dispatched, 60, "{policy:?}");
        }
    }

    #[test]
    fn round_robin_is_exactly_fair() {
        let mut r = Router::new(devs(&[5.0, 1.0, 2.0]), Policy::RoundRobin, 9);
        let mut counts = [0u64; 3];
        for _ in 0..99 {
            counts[r.route()] += 1;
        }
        // Round-robin ignores backlog entirely: perfect thirds.
        assert_eq!(counts, [33, 33, 33]);
    }

    #[test]
    fn least_loaded_balances_exactly_with_equal_service() {
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0, 1.0]), Policy::LeastLoaded, 0);
        for _ in 0..103 {
            r.route();
        }
        let inflight: Vec<u64> = r.devices().iter().map(|d| d.in_flight).collect();
        let max = *inflight.iter().max().unwrap();
        let min = *inflight.iter().min().unwrap();
        assert!(max - min <= 1, "least-loaded must stay within 1: {inflight:?}");
    }

    #[test]
    fn least_loaded_prefers_freshly_drained_device() {
        let mut r = Router::new(devs(&[1.0, 1.0]), Policy::LeastLoaded, 0);
        let first = r.route();
        let second = r.route();
        assert_ne!(first, second, "second dispatch must avoid the loaded device");
        // Draining `first` makes it the unique minimum again.
        r.complete(first).unwrap();
        assert_eq!(r.route(), first);
    }

    #[test]
    fn try_route_skips_unavailable_devices() {
        for policy in
            [Policy::RoundRobin, Policy::LeastLoaded, Policy::TwoChoices, Policy::Backlog]
        {
            let mut r = Router::new(devs(&[1.0, 1.0, 1.0]), policy, 11);
            r.set_available(1, false);
            assert_eq!(r.available_count(), 2);
            for _ in 0..30 {
                let i = r.try_route().expect("two devices remain");
                assert_ne!(i, 1, "{policy:?} routed to a downed device");
            }
            assert_eq!(r.devices()[1].in_flight, 0, "{policy:?}");
        }
    }

    #[test]
    fn try_route_returns_none_when_fleet_is_down() {
        let mut r = Router::new(devs(&[1.0, 1.0]), Policy::LeastLoaded, 0);
        r.set_available(0, false);
        r.set_available(1, false);
        assert_eq!(r.try_route(), None);
        assert_eq!(r.dispatched, 0, "failed routes must not count dispatches");
        // Reintegration makes the device routable again.
        r.set_available(1, true);
        assert_eq!(r.try_route(), Some(1));
    }

    #[test]
    fn try_route_with_all_devices_up_matches_legacy_route() {
        // The failover-aware path must be decision- and RNG-identical to
        // the legacy router when nothing is down — the no-faults
        // equivalence freeze relies on this.
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::TwoChoices] {
            let mut old = Router::new(devs(&[3.0, 1.0, 2.0, 1.0]), policy, 77);
            let mut new = Router::new(devs(&[3.0, 1.0, 2.0, 1.0]), policy, 77);
            for step in 0..200 {
                let a = old.route();
                let b = new.try_route().unwrap();
                assert_eq!(a, b, "{policy:?} diverged at step {step}");
                if step % 3 == 2 {
                    old.complete(a).unwrap();
                    new.complete(b).unwrap();
                }
            }
        }
    }

    #[test]
    fn round_robin_cursor_resumes_after_recovery() {
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0]), Policy::RoundRobin, 0);
        assert_eq!(r.try_route(), Some(0));
        r.set_available(1, false);
        // Cursor points at 1; the scan skips to 2, then wraps to 0.
        assert_eq!(r.try_route(), Some(2));
        assert_eq!(r.try_route(), Some(0));
        r.set_available(1, true);
        assert_eq!(r.try_route(), Some(1), "recovered device rejoins rotation");
    }

    #[test]
    fn two_choices_tracks_completions() {
        // With completions flowing, two-choices must not let any device's
        // backlog run away: complete in bursts and re-check the spread.
        let mut r = Router::new(devs(&[1.0, 1.0, 1.0]), Policy::TwoChoices, 7);
        let mut picks = Vec::new();
        for round in 0..20 {
            for _ in 0..6 {
                picks.push(r.route());
            }
            // Drain all but the last round's dispatches.
            for &i in &picks[..picks.len() - 6] {
                r.complete(i).unwrap();
            }
            picks.drain(..picks.len() - 6);
            let max = r.devices().iter().map(|d| d.in_flight).max().unwrap();
            assert!(max <= 6, "round {round}: runaway backlog {max}");
        }
        let total: u64 = r.devices().iter().map(|d| d.in_flight).sum();
        assert_eq!(total, 6, "exactly the undrained round stays in flight");
    }

    #[test]
    fn backlog_policy_prefers_the_capable_device() {
        // service 4.0 vs 1.0: the score (in_flight+1)·service_ns must
        // concentrate traffic on the fast device.
        let mut r = Router::new(devs(&[4.0, 1.0]), Policy::Backlog, 0);
        let mut counts = [0u64; 2];
        for _ in 0..100 {
            let i = r.route();
            counts[i] += 1;
            r.complete(i).unwrap();
        }
        // Completions drain instantly, so every decision sees empty queues
        // and the fast device's lower per-image score always wins.
        assert!(counts[1] > counts[0] * 3, "{counts:?}");
    }

    #[test]
    fn backlog_makespan_beats_round_robin_on_mixed_fleet() {
        let bl = Router::new(devs(&[4.0, 1.0]), Policy::Backlog, 0).simulate_makespan(1000);
        let rr = Router::new(devs(&[4.0, 1.0]), Policy::RoundRobin, 0).simulate_makespan(1000);
        assert!(bl < rr, "backlog {bl} vs round-robin {rr}");
    }

    #[test]
    fn probe_candidate_is_not_starved_by_lower_backlog_peers() {
        // Regression: the fastest device gets quarantined while holding a
        // deep queue; its peers drain to idle. A pure score comparison
        // would then route every request to the idle peers and the fast
        // device could never carry the probe that proves it healthy again.
        let mut r = Router::new(devs(&[1.0, 2.0, 2.0]), Policy::Backlog, 0);
        // Load the fast device: with idle peers its per-image score wins
        // most decisions (deterministic trace: 0, 0, 1, 2, 0, 0).
        let picks: Vec<usize> = (0..6).map(|_| r.route()).collect();
        assert_eq!(picks, vec![0, 0, 1, 2, 0, 0]);
        // Quarantine it mid-backlog; the peers drain completely.
        r.set_available(0, false);
        r.complete(1).unwrap();
        r.complete(2).unwrap();
        // Reintegrated but score-loser: backlog 5·1.0 vs idle peers at 2.0.
        r.set_available(0, true);
        assert_eq!(r.try_route(), Some(1), "plain score still starves device 0");
        // The probe flag must win the very next decision — exactly once.
        r.set_probe_candidate(0, true);
        assert_eq!(r.try_route(), Some(0), "probe flag must pre-empt the score");
        assert_ne!(r.try_route(), Some(0), "flag is consumed; score resumes");
    }

    #[test]
    fn probe_flag_is_inert_for_legacy_policies() {
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::TwoChoices] {
            let mut flagged = Router::new(devs(&[1.0, 1.0, 1.0]), policy, 5);
            let mut plain = Router::new(devs(&[1.0, 1.0, 1.0]), policy, 5);
            flagged.set_probe_candidate(2, true);
            for step in 0..50 {
                assert_eq!(flagged.try_route(), plain.try_route(), "{policy:?} step {step}");
            }
        }
    }
}
