//! Virtual-time fleet simulation: the deterministic chaos-report path.
//!
//! The live `MultiDeviceServer` is a real thread pool — wall-clock
//! latencies and OS scheduling make its metrics non-reproducible. This
//! module replays the *same* machinery (the [`Router`] policies, the
//! [`HealthTracker`] state machine, the [`FaultSpec`] schedule, the
//! deadline/retry/backoff/shed policy of [`ResilienceSpec`]) as a
//! single-threaded discrete-event simulation over a virtual ns clock, so
//! **one seed yields a bitwise-identical [`FleetReport`]** — the
//! degraded-mode SLO numbers (p50/p95/p99, goodput vs offered load,
//! shed/retried/failed-over counts, health transitions) the chaos tests
//! and the `resilience_sweep` bench assert on.
//!
//! Model (documented simplifications):
//!   * Open-loop arrivals: one request every
//!     `service_ns / (devices × load)` ns — `load` is offered load as a
//!     fraction of the fleet's full-batch capacity. A [`TrafficSpec`]
//!     swaps the uniform spacing for a seed-deterministic Poisson /
//!     bursty / diurnal schedule (and an explicit rate, when set).
//!   * An idle device starts a batch immediately with whatever is queued
//!     (a zero batch window); fills accumulate while devices are busy.
//!   * A batch (padded to `batch`) takes `batch × service_ns × slow` ns;
//!     crash/transient faults surface after the batch's service time.
//!   * Retry backoff delays re-dispatch by the same capped exponential
//!     the live server sleeps; an expired deadline surfaces as a timeout
//!     when the request's batch is formed (as in the live worker).

use std::collections::{BinaryHeap, VecDeque};

use anyhow::Result;

use crate::util::json::Json;
use crate::util::stats::Summary;

use super::faults::FaultSpec;
use super::metrics::LatencyStats;
use super::resilience::{HealthTracker, HealthTransition, ResilienceSpec};
use super::router::{Device, Policy, Router};
use super::traffic::TrafficSpec;

/// Configuration of one fleet simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Devices in the pool.
    pub devices: usize,
    /// Steady-state per-image service time (ns) from the timing model.
    pub service_ns: f64,
    /// Compiled device batch (requests pad up to it).
    pub batch: usize,
    pub policy: Policy,
    /// Router seed (two-choices sampling).
    pub seed: u64,
    /// Offered requests.
    pub requests: u64,
    /// Offered load as a fraction of full-batch fleet capacity.
    pub load: f64,
    pub faults: FaultSpec,
    pub resilience: ResilienceSpec,
    /// Arrival process. `None` keeps the legacy uniform spacing, bitwise.
    /// With `Some`, the spec's schedule replaces it; an explicit
    /// `rate_rps` overrides the `load`-derived interarrival.
    pub traffic: Option<TrafficSpec>,
    /// Per-device service time per image (ns) for heterogeneous fleets.
    /// `None` keeps the legacy homogeneous fleet (`service_ns` everywhere,
    /// unit router weights), bitwise. With `Some`, the router scores with
    /// real per-device speeds and each device's batches take its own time.
    pub service_ns_per_device: Option<Vec<f64>>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            devices: 1,
            service_ns: 1000.0,
            batch: 8,
            policy: Policy::RoundRobin,
            seed: 0x5EED,
            requests: 256,
            load: 0.9,
            faults: FaultSpec::none(),
            resilience: ResilienceSpec::default(),
            traffic: None,
            service_ns_per_device: None,
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.devices >= 1, "fleet needs at least one device");
        anyhow::ensure!(self.batch >= 1, "fleet batch must be >= 1");
        anyhow::ensure!(self.requests >= 1, "fleet needs at least one request");
        anyhow::ensure!(
            self.service_ns > 0.0 && self.service_ns.is_finite(),
            "fleet service_ns must be positive and finite, got {}",
            self.service_ns
        );
        anyhow::ensure!(
            self.load > 0.0 && self.load.is_finite(),
            "fleet load must be positive, got {}",
            self.load
        );
        self.faults.validate()?;
        self.resilience.validate()?;
        if let Some(t) = &self.traffic {
            t.validate()?;
        }
        if let Some(s) = &self.service_ns_per_device {
            anyhow::ensure!(
                s.len() == self.devices,
                "service_ns_per_device has {} entries for {} devices",
                s.len(),
                self.devices
            );
            anyhow::ensure!(
                s.iter().all(|&v| v.is_finite() && v > 0.0),
                "service_ns_per_device entries must be finite and positive: {s:?}"
            );
        }
        Ok(())
    }

    /// Virtual ns between arrivals: the traffic spec's explicit rate when
    /// set, else derived from the fleet's capacity and `load`.
    fn interarrival_ns(&self) -> u64 {
        if let Some(ns) = self.traffic.as_ref().and_then(|t| t.interarrival_ns()) {
            return ns;
        }
        ((self.service_ns / (self.devices as f64 * self.load)).round() as u64).max(1)
    }

    /// Per-image service time of `device`.
    fn service_ns_for(&self, device: usize) -> f64 {
        self.service_ns_per_device.as_ref().map_or(self.service_ns, |s| s[device])
    }
}

/// Injected-fault tallies (batch granularity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct InjectedCounts {
    pub crashes: u64,
    pub transients: u64,
    pub stragglers: u64,
    pub storms: u64,
}

/// The deterministic degraded-mode SLO report of one fleet simulation.
/// Same config (incl. seeds) → bitwise-identical report.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub devices: usize,
    /// Requests offered by the arrival process.
    pub offered: u64,
    /// Requests answered with logits.
    pub completed: u64,
    /// Completed within deadline (== `completed` when no deadline is set).
    pub goodput: u64,
    /// Completed but past deadline.
    pub late: u64,
    /// Shed (queue full / no routable device), retries exhausted.
    pub shed: u64,
    /// Deadline expired before execution.
    pub timeouts: u64,
    /// Failed with a device-loss or transient fault, retries exhausted.
    pub failed: u64,
    /// Re-dispatch attempts made.
    pub retried: u64,
    /// Re-dispatches that landed on a different device.
    pub failovers: u64,
    pub injected: InjectedCounts,
    /// Quarantine / reintegration event counts.
    pub quarantines: u64,
    pub reintegrations: u64,
    /// Latency SLOs over completed requests, µs (0 when nothing completed).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    /// Virtual time of the last terminal outcome, ms.
    pub makespan_ms: f64,
    /// Offered arrival rate, requests/s.
    pub offered_rps: f64,
    /// Goodput rate over the makespan, requests/s.
    pub goodput_rps: f64,
    /// Batches attempted per device (the fault-schedule cursor).
    pub per_device_batches: Vec<u64>,
    /// Health transitions in virtual-time order.
    pub transitions: Vec<HealthTransition>,
}

impl FleetReport {
    /// Every offered request reaches exactly one terminal outcome — the
    /// no-silent-drop invariant the chaos tests assert.
    pub fn accounted(&self) -> u64 {
        self.completed + self.shed + self.timeouts + self.failed
    }

    /// Canonical JSON (byte-stable for identical reports).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let n = |v: u64| Json::Num(v as f64);
        let mut o = BTreeMap::new();
        o.insert("devices".into(), Json::Num(self.devices as f64));
        o.insert("offered".into(), n(self.offered));
        o.insert("completed".into(), n(self.completed));
        o.insert("goodput".into(), n(self.goodput));
        o.insert("late".into(), n(self.late));
        o.insert("shed".into(), n(self.shed));
        o.insert("timeouts".into(), n(self.timeouts));
        o.insert("failed".into(), n(self.failed));
        o.insert("retried".into(), n(self.retried));
        o.insert("failovers".into(), n(self.failovers));
        o.insert("injected_crashes".into(), n(self.injected.crashes));
        o.insert("injected_transients".into(), n(self.injected.transients));
        o.insert("injected_stragglers".into(), n(self.injected.stragglers));
        o.insert("injected_storms".into(), n(self.injected.storms));
        o.insert("quarantines".into(), n(self.quarantines));
        o.insert("reintegrations".into(), n(self.reintegrations));
        o.insert("p50_us".into(), Json::Num(self.p50_us));
        o.insert("p95_us".into(), Json::Num(self.p95_us));
        o.insert("p99_us".into(), Json::Num(self.p99_us));
        o.insert("mean_us".into(), Json::Num(self.mean_us));
        o.insert("makespan_ms".into(), Json::Num(self.makespan_ms));
        o.insert("offered_rps".into(), Json::Num(self.offered_rps));
        o.insert("goodput_rps".into(), Json::Num(self.goodput_rps));
        o.insert(
            "per_device_batches".into(),
            Json::Arr(self.per_device_batches.iter().map(|&b| n(b)).collect()),
        );
        o.insert(
            "transitions".into(),
            Json::Arr(
                self.transitions
                    .iter()
                    .map(|t| {
                        let mut e = BTreeMap::new();
                        e.insert("at_ns".into(), n(t.at_ns));
                        e.insert("device".into(), Json::Num(t.device as f64));
                        e.insert("up".into(), Json::Bool(t.up));
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    /// Human-readable summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "fleet: {} devices, offered {} req @ {:.0} req/s\n",
            self.devices, self.offered, self.offered_rps
        ));
        s.push_str(&format!(
            "outcome: completed={} (goodput={} late={}) shed={} timeout={} failed={}\n",
            self.completed, self.goodput, self.late, self.shed, self.timeouts,
            self.failed
        ));
        s.push_str(&format!(
            "latency: p50={:.1} µs p95={:.1} µs p99={:.1} µs mean={:.1} µs\n",
            self.p50_us, self.p95_us, self.p99_us, self.mean_us
        ));
        s.push_str(&format!(
            "resilience: retried={} failovers={} quarantines={} reintegrations={}\n",
            self.retried, self.failovers, self.quarantines, self.reintegrations
        ));
        s.push_str(&format!(
            "injected: crashes={} transients={} stragglers={} storms={}\n",
            self.injected.crashes,
            self.injected.transients,
            self.injected.stragglers,
            self.injected.storms
        ));
        s.push_str(&format!(
            "goodput rate: {:.0} req/s over {:.2} ms makespan\n",
            self.goodput_rps, self.makespan_ms
        ));
        s
    }
}

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
enum EvKind {
    /// A request (re-)arrives for dispatch.
    Arrive(usize),
    /// Device finished its running batch.
    Ready(usize),
}

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Ev {
    t: u64,
    /// Push order: total, deterministic tie-break at equal times.
    seq: u64,
    kind: EvKind,
}

struct Req {
    arrival_ns: u64,
    /// Dispatch attempts so far (0 = first).
    attempts: u32,
    last_device: Option<usize>,
}

struct Dev {
    queue: VecDeque<usize>,
    busy: bool,
    /// Batch-schedule cursor (the fault index).
    batch_idx: u64,
    /// Requests in the running batch + its fault verdict.
    running: Vec<usize>,
    running_fault: Option<super::faults::BatchFault>,
}

struct Fleet<'a> {
    cfg: &'a FleetConfig,
    heap: BinaryHeap<std::cmp::Reverse<Ev>>,
    seq: u64,
    reqs: Vec<Req>,
    devs: Vec<Dev>,
    router: Router,
    health: HealthTracker,
    deadline_ns: Option<u64>,
    // outcome accounting
    completed: u64,
    goodput: u64,
    late: u64,
    shed: u64,
    timeouts: u64,
    failed: u64,
    retried: u64,
    failovers: u64,
    injected: InjectedCounts,
    latencies_us: Summary,
    end_ns: u64,
}

impl<'a> Fleet<'a> {
    fn push(&mut self, t: u64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(std::cmp::Reverse(Ev { t, seq: self.seq, kind }));
    }

    fn expired(&self, req: usize, now: u64) -> bool {
        self.deadline_ns
            .map_or(false, |d| now > self.reqs[req].arrival_ns.saturating_add(d))
    }

    /// Terminal outcome bookkeeping happens at `now`.
    fn finish_at(&mut self, now: u64) {
        self.end_ns = self.end_ns.max(now);
    }

    /// Route + enqueue one request, honoring health, queue caps, and the
    /// retry budget. Mirrors the live `classify` attempt loop.
    fn dispatch(&mut self, req: usize, now: u64) {
        if self.health.enabled() {
            for d in 0..self.cfg.devices {
                let up = self.health.can_route(d, now);
                self.router.set_available(d, up);
                // Mirror the live dispatcher: an open probe window lets the
                // backlog policy pre-empt the score for the probe request.
                self.router.set_probe_candidate(d, up && self.health.is_quarantined(d));
            }
        }
        let routed = self.router.try_route();
        let Some(device) = routed else {
            self.retry_or(req, now, Outcome::Shed);
            return;
        };
        if self.devs[device].queue.len() >= self.cfg.resilience.queue_cap {
            self.router
                .complete(device)
                .expect("routed immediately above");
            self.retry_or(req, now, Outcome::Shed);
            return;
        }
        if self.health.is_quarantined(device) {
            self.health.begin_probe(device);
        }
        if self.reqs[req].attempts > 0 {
            self.retried += 1;
            if self.reqs[req].last_device.map_or(false, |p| p != device) {
                self.failovers += 1;
            }
        }
        self.reqs[req].last_device = Some(device);
        self.devs[device].queue.push_back(req);
        if !self.devs[device].busy {
            self.start_batch(device, now);
        }
    }

    /// A failed attempt: consume a retry (with backoff) or settle on the
    /// terminal `outcome`.
    fn retry_or(&mut self, req: usize, now: u64, outcome: Outcome) {
        if self.reqs[req].attempts < self.cfg.resilience.retries {
            let retry = self.reqs[req].attempts;
            self.reqs[req].attempts += 1;
            let backoff_ns =
                self.cfg.resilience.backoff_ms_for(retry).saturating_mul(1_000_000);
            self.push(now.saturating_add(backoff_ns), EvKind::Arrive(req));
            return;
        }
        match outcome {
            Outcome::Shed => self.shed += 1,
            Outcome::Failed => self.failed += 1,
        }
        self.finish_at(now);
    }

    /// Form and launch the next batch on an idle device.
    fn start_batch(&mut self, device: usize, now: u64) {
        loop {
            let mut live = Vec::new();
            while live.len() < self.cfg.batch {
                let Some(req) = self.devs[device].queue.pop_front() else { break };
                if self.expired(req, now) {
                    // The live worker replies Timeout when the batch pops
                    // an expired request; terminal (no retry).
                    self.router.complete(device).expect("queued implies routed");
                    self.timeouts += 1;
                    self.finish_at(now);
                } else {
                    live.push(req);
                }
            }
            if live.is_empty() {
                if self.devs[device].queue.is_empty() {
                    self.devs[device].busy = false;
                    return;
                }
                continue; // everything popped was expired; try again
            }
            let fault =
                self.cfg.faults.batch_fault(device, self.devs[device].batch_idx);
            self.devs[device].batch_idx += 1;
            if fault.crashed {
                self.injected.crashes += 1;
            }
            if fault.transient {
                self.injected.transients += 1;
            }
            if fault.straggler {
                self.injected.stragglers += 1;
            }
            if fault.storm {
                self.injected.storms += 1;
            }
            let service =
                fault.slow.apply_ns(self.cfg.service_ns_for(device) * self.cfg.batch as f64);
            let dur = (service.round() as u64).max(1);
            self.devs[device].running = live;
            self.devs[device].running_fault = Some(fault);
            self.devs[device].busy = true;
            self.push(now.saturating_add(dur), EvKind::Ready(device));
            return;
        }
    }

    /// A batch finished (successfully or with an injected fault).
    fn finish_batch(&mut self, device: usize, now: u64) {
        let batch = std::mem::take(&mut self.devs[device].running);
        let fault = self.devs[device].running_fault.take().expect("batch was launched");
        if fault.crashed || fault.transient {
            // One execution failure per request in the failed batch — the
            // live classify loop records health per request too.
            for req in batch {
                let _ = self.router.complete(device);
                self.health.record_failure(device, now);
                self.retry_or(req, now, Outcome::Failed);
            }
        } else {
            self.health.record_success(device, now);
            for req in batch {
                let _ = self.router.complete(device);
                let latency_ns = now - self.reqs[req].arrival_ns;
                self.completed += 1;
                if self.deadline_ns.map_or(true, |d| latency_ns <= d) {
                    self.goodput += 1;
                } else {
                    self.late += 1;
                }
                self.latencies_us.push(latency_ns as f64 / 1000.0);
                self.finish_at(now);
            }
        }
        if self.devs[device].queue.is_empty() {
            self.devs[device].busy = false;
        } else {
            self.start_batch(device, now);
        }
    }
}

enum Outcome {
    Shed,
    Failed,
}

/// Run the fleet simulation to completion and report. Deterministic:
/// identical `cfg` (including both seeds) gives a bitwise-identical
/// report.
pub fn simulate_fleet(cfg: &FleetConfig) -> Result<FleetReport> {
    cfg.validate()?;
    let interarrival = cfg.interarrival_ns();
    // Legacy homogeneous fleets keep unit router weights (backlog ==
    // queue depth, bitwise-frozen); heterogeneous fleets hand the router
    // real per-device speeds so capability-aware policies can score.
    let devices = (0..cfg.devices)
        .map(|d| {
            let weight = cfg.service_ns_per_device.as_ref().map_or(1.0, |s| s[d]);
            Device::new(&format!("sim{d}"), weight)
        })
        .collect();
    let mut fleet = Fleet {
        cfg,
        heap: BinaryHeap::new(),
        seq: 0,
        reqs: Vec::with_capacity(cfg.requests as usize),
        devs: (0..cfg.devices)
            .map(|_| Dev {
                queue: VecDeque::new(),
                busy: false,
                batch_idx: 0,
                running: Vec::new(),
                running_fault: None,
            })
            .collect(),
        router: Router::new(devices, cfg.policy, cfg.seed),
        health: HealthTracker::new(cfg.devices, &cfg.resilience),
        deadline_ns: cfg.resilience.deadline_ms.map(|ms| ms.saturating_mul(1_000_000)),
        completed: 0,
        goodput: 0,
        late: 0,
        shed: 0,
        timeouts: 0,
        failed: 0,
        retried: 0,
        failovers: 0,
        injected: InjectedCounts::default(),
        latencies_us: Summary::new(),
        end_ns: 0,
    };
    match &cfg.traffic {
        // Legacy arrivals stay byte-for-byte: one request every
        // `interarrival` ns starting at t=0.
        None => {
            for i in 0..cfg.requests {
                fleet.reqs.push(Req {
                    arrival_ns: i * interarrival,
                    attempts: 0,
                    last_device: None,
                });
                fleet.push(i * interarrival, EvKind::Arrive(i as usize));
            }
        }
        Some(traffic) => {
            for (i, at) in traffic.schedule(cfg.requests, interarrival).into_iter().enumerate()
            {
                fleet.reqs.push(Req { arrival_ns: at, attempts: 0, last_device: None });
                fleet.push(at, EvKind::Arrive(i));
            }
        }
    }
    while let Some(std::cmp::Reverse(ev)) = fleet.heap.pop() {
        match ev.kind {
            EvKind::Arrive(req) => fleet.dispatch(req, ev.t),
            EvKind::Ready(device) => fleet.finish_batch(device, ev.t),
        }
    }

    // completed == 0 ⇔ no latency samples, so the shared zero-on-empty
    // convention reproduces the legacy zeroed percentiles bitwise.
    let lat = LatencyStats::from_summary_or_zero(&fleet.latencies_us);
    let makespan_ms = fleet.end_ns as f64 / 1e6;
    let goodput_rps = if fleet.end_ns == 0 {
        0.0
    } else {
        fleet.goodput as f64 * 1e9 / fleet.end_ns as f64
    };
    let transitions = fleet.health.transitions().to_vec();
    let quarantines = transitions.iter().filter(|t| !t.up).count() as u64;
    let reintegrations = transitions.iter().filter(|t| t.up).count() as u64;
    Ok(FleetReport {
        devices: cfg.devices,
        offered: cfg.requests,
        completed: fleet.completed,
        goodput: fleet.goodput,
        late: fleet.late,
        shed: fleet.shed,
        timeouts: fleet.timeouts,
        failed: fleet.failed,
        retried: fleet.retried,
        failovers: fleet.failovers,
        injected: fleet.injected,
        quarantines,
        reintegrations,
        p50_us: lat.p50_us,
        p95_us: lat.p95_us,
        p99_us: lat.p99_us,
        mean_us: lat.mean_us,
        makespan_ms,
        offered_rps: 1e9 / interarrival as f64,
        goodput_rps,
        per_device_batches: fleet.devs.iter().map(|d| d.batch_idx).collect(),
        transitions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::{CrashSpec, StragglerSpec, StormSpec};

    fn base() -> FleetConfig {
        FleetConfig { devices: 4, requests: 400, ..FleetConfig::default() }
    }

    #[test]
    fn clean_fleet_completes_everything() {
        let r = simulate_fleet(&base()).unwrap();
        assert_eq!(r.completed, 400);
        assert_eq!(r.goodput, 400);
        assert_eq!(r.accounted(), r.offered);
        assert_eq!(r.shed + r.timeouts + r.failed + r.retried + r.failovers, 0);
        assert!(r.p50_us > 0.0 && r.p99_us >= r.p95_us && r.p95_us >= r.p50_us);
        assert!(r.makespan_ms > 0.0);
    }

    #[test]
    fn same_config_is_bitwise_identical() {
        let cfg = FleetConfig {
            faults: FaultSpec {
                seed: 99,
                transient: 0.15,
                straggler: Some(StragglerSpec { prob: 0.1, factor: 4.0 }),
                storm: Some(StormSpec { period: 16, duty: 4, factor: 2.0 }),
                crash: vec![CrashSpec { device: 1, after: 4, down_for: Some(3) }],
            },
            resilience: ResilienceSpec {
                retries: 2,
                deadline_ms: Some(50),
                quarantine_after: 2,
                ..ResilienceSpec::default()
            },
            ..base()
        };
        let a = simulate_fleet(&cfg).unwrap();
        let b = simulate_fleet(&cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
        // And latency floats are bit-equal, not just PartialEq-equal.
        assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits());
    }

    #[test]
    fn transient_faults_are_absorbed_by_retries() {
        let faults = FaultSpec { seed: 21, transient: 0.3, ..FaultSpec::none() };
        let fragile = simulate_fleet(&FleetConfig {
            faults: faults.clone(),
            ..base()
        })
        .unwrap();
        let resilient = simulate_fleet(&FleetConfig {
            faults,
            resilience: ResilienceSpec { retries: 4, ..ResilienceSpec::default() },
            ..base()
        })
        .unwrap();
        assert!(fragile.failed > 0, "30% transients must fail a fragile fleet");
        assert!(resilient.retried > 0);
        assert!(
            resilient.completed > fragile.completed,
            "retries must recover completions: {} vs {}",
            resilient.completed,
            fragile.completed
        );
        assert_eq!(resilient.accounted(), resilient.offered);
    }

    #[test]
    fn stragglers_and_storms_inflate_tail_latency() {
        let clean = simulate_fleet(&base()).unwrap();
        let slow = simulate_fleet(&FleetConfig {
            faults: FaultSpec {
                seed: 5,
                straggler: Some(StragglerSpec { prob: 0.2, factor: 8.0 }),
                storm: Some(StormSpec { period: 8, duty: 2, factor: 3.0 }),
                ..FaultSpec::none()
            },
            ..base()
        })
        .unwrap();
        assert!(slow.injected.stragglers > 0 && slow.injected.storms > 0);
        assert!(
            slow.p99_us > clean.p99_us,
            "tail must inflate: {} vs {}",
            slow.p99_us,
            clean.p99_us
        );
        assert_eq!(slow.completed, slow.offered, "slowdowns lose nothing");
    }

    #[test]
    fn deadline_converts_stragglers_into_timeouts_or_late() {
        let r = simulate_fleet(&FleetConfig {
            faults: FaultSpec {
                seed: 13,
                straggler: Some(StragglerSpec { prob: 0.3, factor: 200.0 }),
                ..FaultSpec::none()
            },
            resilience: ResilienceSpec {
                deadline_ms: Some(1),
                ..ResilienceSpec::default()
            },
            ..base()
        })
        .unwrap();
        assert!(r.timeouts + r.late > 0, "extreme stragglers must blow deadlines");
        assert_eq!(r.accounted(), r.offered);
        assert!(r.goodput < r.offered);
    }

    #[test]
    fn queue_cap_sheds_under_overload() {
        let r = simulate_fleet(&FleetConfig {
            devices: 1,
            load: 50.0, // way past capacity
            requests: 600,
            resilience: ResilienceSpec { queue_cap: 4, ..ResilienceSpec::default() },
            ..FleetConfig::default()
        })
        .unwrap();
        assert!(r.shed > 0, "bounded queue must shed under 50× overload");
        assert_eq!(r.accounted(), r.offered);
    }

    #[test]
    fn report_json_is_canonical_and_complete() {
        let r = simulate_fleet(&base()).unwrap();
        let text = r.to_json().pretty();
        for key in ["goodput", "p99_us", "transitions", "per_device_batches"] {
            assert!(text.contains(&format!("\"{key}\"")), "missing {key} in {text}");
        }
        assert!(r.render().contains("goodput"));
    }

    #[test]
    fn validation_rejects_degenerate_fleets() {
        assert!(simulate_fleet(&FleetConfig { devices: 0, ..base() }).is_err());
        assert!(simulate_fleet(&FleetConfig { batch: 0, ..base() }).is_err());
        assert!(simulate_fleet(&FleetConfig { requests: 0, ..base() }).is_err());
        assert!(simulate_fleet(&FleetConfig { load: 0.0, ..base() }).is_err());
        assert!(
            simulate_fleet(&FleetConfig { service_ns: f64::NAN, ..base() }).is_err()
        );
        assert!(simulate_fleet(&FleetConfig {
            service_ns_per_device: Some(vec![1000.0]),
            ..base()
        })
        .is_err());
    }

    #[test]
    fn uniform_traffic_matches_the_legacy_arrivals_bitwise() {
        use crate::coordinator::traffic::ArrivalKind;
        let legacy = simulate_fleet(&base()).unwrap();
        let uniform = simulate_fleet(&FleetConfig {
            traffic: Some(TrafficSpec { kind: ArrivalKind::Uniform, ..TrafficSpec::default() }),
            ..base()
        })
        .unwrap();
        assert_eq!(legacy, uniform);
        assert_eq!(legacy.to_json().pretty(), uniform.to_json().pretty());
    }

    #[test]
    fn poisson_traffic_is_deterministic_and_fully_accounted() {
        use crate::coordinator::traffic::ArrivalKind;
        let cfg = FleetConfig {
            traffic: Some(TrafficSpec {
                kind: ArrivalKind::Poisson,
                rate_rps: 500_000.0,
                ..TrafficSpec::default()
            }),
            resilience: ResilienceSpec { queue_cap: 64, ..ResilienceSpec::default() },
            ..base()
        };
        let a = simulate_fleet(&cfg).unwrap();
        let b = simulate_fleet(&cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.accounted(), a.offered);
        // The explicit rate (500k req/s = one per 2 µs) overrides load.
        assert!((a.offered_rps - 500_000.0).abs() < 1.0, "{}", a.offered_rps);
    }

    #[test]
    fn backlog_policy_beats_round_robin_on_a_mixed_fleet() {
        // A 500 ns/image device paired with a 4000 ns/image device under a
        // deadline: round-robin drowns the slow device's queue while the
        // backlog score steers traffic to the fast one.
        let mixed = |policy| FleetConfig {
            devices: 2,
            batch: 1,
            requests: 2000,
            policy,
            service_ns_per_device: Some(vec![500.0, 4000.0]),
            resilience: ResilienceSpec {
                deadline_ms: Some(1),
                ..ResilienceSpec::default()
            },
            ..FleetConfig::default()
        };
        let rr = simulate_fleet(&mixed(Policy::RoundRobin)).unwrap();
        let bl = simulate_fleet(&mixed(Policy::Backlog)).unwrap();
        assert_eq!(rr.accounted(), rr.offered);
        assert_eq!(bl.accounted(), bl.offered);
        assert!(
            bl.goodput > rr.goodput,
            "backlog goodput {} must beat round-robin {}",
            bl.goodput,
            rr.goodput
        );
    }
}
