//! Serving metrics: request counts, latency distribution, batch fill.

use std::time::Duration;

use crate::util::stats::Summary;

/// Shared latency percentile summary (µs): the one computation both the
/// live pool's [`MetricsSnapshot`] and the virtual-time fleet replay's
/// [`FleetReport`](super::chaos::FleetReport) build their latency fields
/// from, so live and replay numbers can never drift to different
/// percentile conventions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl LatencyStats {
    /// Summarize a sample set, inheriting [`Summary`]'s NaN-on-empty
    /// convention (the live `Metrics` contract).
    pub fn from_summary(s: &Summary) -> LatencyStats {
        LatencyStats {
            mean_us: s.mean(),
            p50_us: s.percentile(50.0),
            p95_us: s.percentile(95.0),
            p99_us: s.percentile(99.0),
        }
    }

    /// Like [`LatencyStats::from_summary`] but all-zero on an empty
    /// sample set — the fleet-replay convention (its JSON report has no
    /// NaN representation).
    pub fn from_summary_or_zero(s: &Summary) -> LatencyStats {
        if s.is_empty() {
            return LatencyStats { mean_us: 0.0, p50_us: 0.0, p95_us: 0.0, p99_us: 0.0 };
        }
        LatencyStats::from_summary(s)
    }
}

/// Mutable metrics accumulator (lives behind the server's mutex).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_lanes: u64,
    /// Requests refused because a queue or the fleet was full/down.
    pub shed: u64,
    /// Requests whose deadline expired before execution.
    pub timeouts: u64,
    /// Re-dispatch attempts after a retryable failure.
    pub retries: u64,
    /// Re-dispatches that landed on a different device.
    pub failovers: u64,
    /// Requests that exhausted retries on execution failures.
    pub failures: u64,
    /// Devices quarantined / reintegrated by the health tracker.
    pub quarantines: u64,
    pub reintegrations: u64,
    latencies_us: Summary,
    batch_exec_us: Summary,
    /// Requests dispatched per device (multi-device pool).
    per_device: Vec<u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        self.requests += 1;
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
    }

    /// Count one request routed to `device`.
    pub fn record_dispatch(&mut self, device: usize) {
        if self.per_device.len() <= device {
            self.per_device.resize(device + 1, 0);
        }
        self.per_device[device] += 1;
    }

    pub fn record_batch(&mut self, exec: Duration, fill: usize, batch_size: usize) {
        self.batches += 1;
        self.padded_lanes += (batch_size - fill) as u64;
        self.batch_exec_us.push(exec.as_secs_f64() * 1e6);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = LatencyStats::from_summary(&self.latencies_us);
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            padded_lanes: self.padded_lanes,
            shed: self.shed,
            timeouts: self.timeouts,
            retries: self.retries,
            failovers: self.failovers,
            failures: self.failures,
            quarantines: self.quarantines,
            reintegrations: self.reintegrations,
            latency_p50_us: lat.p50_us,
            latency_p95_us: lat.p95_us,
            latency_p99_us: lat.p99_us,
            latency_mean_us: lat.mean_us,
            batch_exec_mean_us: self.batch_exec_us.mean(),
            per_device: self.per_device.clone(),
        }
    }
}

/// Immutable metrics view returned to clients.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_lanes: u64,
    pub shed: u64,
    pub timeouts: u64,
    pub retries: u64,
    pub failovers: u64,
    pub failures: u64,
    pub quarantines: u64,
    pub reintegrations: u64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub batch_exec_mean_us: f64,
    /// Requests dispatched per device (empty for pre-pool accumulators).
    pub per_device: Vec<u64>,
}

impl MetricsSnapshot {
    /// Any degraded-mode activity at all? When false the report stays in
    /// its legacy shape.
    pub fn degraded(&self) -> bool {
        self.shed != 0
            || self.timeouts != 0
            || self.retries != 0
            || self.failovers != 0
            || self.failures != 0
            || self.quarantines != 0
            || self.reintegrations != 0
    }

    pub fn report(&self) -> String {
        let devices = if self.per_device.is_empty() {
            String::new()
        } else {
            format!(" per_device={:?}", self.per_device)
        };
        let resilience = if self.degraded() {
            format!(
                " shed={} timeouts={} retries={} failovers={} failures={} \
                 quarantines={} reintegrations={}",
                self.shed,
                self.timeouts,
                self.retries,
                self.failovers,
                self.failures,
                self.quarantines,
                self.reintegrations,
            )
        } else {
            String::new()
        };
        format!(
            "requests={} batches={} padded={} latency(mean/p50/p95/p99)=\
             {:.0}/{:.0}/{:.0}/{:.0} µs batch_exec_mean={:.0} µs{}{}",
            self.requests,
            self.batches,
            self.padded_lanes,
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p95_us,
            self.latency_p99_us,
            self.batch_exec_mean_us,
            devices,
            resilience,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_snapshots() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(100));
        m.record_request(Duration::from_micros(300));
        m.record_batch(Duration::from_micros(250), 6, 8);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.padded_lanes, 2);
        assert!((s.latency_mean_us - 200.0).abs() < 1e-9);
        assert!(s.report().contains("requests=2"));
    }

    #[test]
    fn resilience_counters_appear_only_when_degraded() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(100));
        assert!(!m.snapshot().degraded());
        assert!(!m.snapshot().report().contains("shed="));
        m.shed += 2;
        m.retries += 3;
        m.quarantines += 1;
        let s = m.snapshot();
        assert!(s.degraded());
        let r = s.report();
        assert!(r.contains("shed=2") && r.contains("retries=3"), "{r}");
        assert!(r.contains("quarantines=1"), "{r}");
    }

    #[test]
    fn snapshot_reports_p95() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500] {
            m.record_request(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert!(s.latency_p50_us <= s.latency_p95_us);
        assert!(s.latency_p95_us <= s.latency_p99_us);
        assert!(s.report().contains("p95") || s.report().contains("/"));
    }

    #[test]
    fn empty_snapshot_is_nan_latency() {
        let s = Metrics::new().snapshot();
        assert!(s.latency_mean_us.is_nan());
        assert_eq!(s.requests, 0);
    }

    #[test]
    fn latency_stats_conventions_differ_only_when_empty() {
        let empty = Summary::new();
        assert!(LatencyStats::from_summary(&empty).p99_us.is_nan());
        let z = LatencyStats::from_summary_or_zero(&empty);
        assert_eq!((z.mean_us, z.p50_us, z.p95_us, z.p99_us), (0.0, 0.0, 0.0, 0.0));

        let s = Summary::from_values(vec![100.0, 200.0, 300.0]);
        assert_eq!(LatencyStats::from_summary(&s), LatencyStats::from_summary_or_zero(&s));
        assert!((LatencyStats::from_summary(&s).mean_us - 200.0).abs() < 1e-9);
    }
}
