//! Serving metrics: request counts, latency distribution, batch fill.

use std::time::Duration;

use crate::util::stats::Summary;

/// Mutable metrics accumulator (lives behind the server's mutex).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_lanes: u64,
    latencies_us: Summary,
    batch_exec_us: Summary,
    /// Requests dispatched per device (multi-device pool).
    per_device: Vec<u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        self.requests += 1;
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
    }

    /// Count one request routed to `device`.
    pub fn record_dispatch(&mut self, device: usize) {
        if self.per_device.len() <= device {
            self.per_device.resize(device + 1, 0);
        }
        self.per_device[device] += 1;
    }

    pub fn record_batch(&mut self, exec: Duration, fill: usize, batch_size: usize) {
        self.batches += 1;
        self.padded_lanes += (batch_size - fill) as u64;
        self.batch_exec_us.push(exec.as_secs_f64() * 1e6);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests,
            batches: self.batches,
            padded_lanes: self.padded_lanes,
            latency_p50_us: self.latencies_us.percentile(50.0),
            latency_p99_us: self.latencies_us.percentile(99.0),
            latency_mean_us: self.latencies_us.mean(),
            batch_exec_mean_us: self.batch_exec_us.mean(),
            per_device: self.per_device.clone(),
        }
    }
}

/// Immutable metrics view returned to clients.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_lanes: u64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub batch_exec_mean_us: f64,
    /// Requests dispatched per device (empty for pre-pool accumulators).
    pub per_device: Vec<u64>,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let devices = if self.per_device.is_empty() {
            String::new()
        } else {
            format!(" per_device={:?}", self.per_device)
        };
        format!(
            "requests={} batches={} padded={} latency(mean/p50/p99)=\
             {:.0}/{:.0}/{:.0} µs batch_exec_mean={:.0} µs{}",
            self.requests,
            self.batches,
            self.padded_lanes,
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p99_us,
            self.batch_exec_mean_us,
            devices,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_snapshots() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(100));
        m.record_request(Duration::from_micros(300));
        m.record_batch(Duration::from_micros(250), 6, 8);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.padded_lanes, 2);
        assert!((s.latency_mean_us - 200.0).abs() < 1e-9);
        assert!(s.report().contains("requests=2"));
    }

    #[test]
    fn empty_snapshot_is_nan_latency() {
        let s = Metrics::new().snapshot();
        assert!(s.latency_mean_us.is_nan());
        assert_eq!(s.requests, 0);
    }
}
