//! Device execution backends for the multi-device coordinator.
//!
//! A [`Backend`] is what one pool worker drives: it owns one PIM device's
//! executable state and runs padded batches. Two implementations exist:
//!
//!   * [`SimBackend`] (always available) — a simulated device priced by
//!     the timing model. Logits are a fixed deterministic function of the
//!     image (the coordinator's dispatch/batching logic is what's under
//!     test, not numerics), and the device can optionally replay its
//!     DRAM-model service time in wall-clock for demos.
//!   * `PjrtBackend` (behind `--features pjrt`, in `server.rs`) — the AOT
//!     artifact executor; real numerics via PJRT.
//!
//! Backends are constructed *inside* their worker thread (the PJRT handles
//! are not `Send`), so the trait itself needs no `Send` bound.

use anyhow::Result;

use crate::plan::PlanError;
use crate::sim::{SimConfig, SimReport, SimResult, SimSession};
use crate::workloads::Network;

use super::batcher::Batcher;

/// One device's executable state, driven by a single pool worker.
pub trait Backend {
    /// Fixed batch the device executes (requests are padded up to it).
    fn batch_size(&self) -> usize;
    /// Elements in one input image.
    fn image_elems(&self) -> usize;
    /// Logit count per image.
    fn num_classes(&self) -> usize;
    /// Run one padded batch (`batch_size × image_elems` elements);
    /// returns row-major logits `[batch_size × num_classes]`.
    fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>>;
}

/// A simulated PIM device: deterministic logits + a timing-model service
/// time it can replay in wall-clock.
#[derive(Debug, Clone)]
pub struct SimBackend {
    batch: usize,
    image_elems: usize,
    classes: usize,
    /// Steady-state per-image service time from the simulator (ns).
    service_ns_per_image: f64,
    /// Wall-clock replay factor: 0 (default) disables sleeping, 1 replays
    /// the DRAM-model time in real time.
    time_scale: f64,
}

impl SimBackend {
    pub fn new(batch: usize, image_elems: usize, classes: usize) -> Self {
        assert!(batch > 0 && image_elems > 0 && classes > 0);
        SimBackend {
            batch,
            image_elems,
            classes,
            service_ns_per_image: 0.0,
            time_scale: 0.0,
        }
    }

    /// Build a device priced by a simulation result: one pool worker
    /// stands in for one replica of `result`'s plan, serving `net` images.
    pub fn from_sim(result: &SimResult, net: &Network, batch: usize) -> Self {
        let mut b = SimBackend::new(batch, net.layers[0].in_elems(), 10);
        b.service_ns_per_image = result.pipeline.cycle_ns;
        b
    }

    /// Build a device priced through an incremental [`SimSession`]: the
    /// serving path reuses the session's cached per-layer pricing instead
    /// of re-running `simulate()` from scratch, and repricing a pool after
    /// a `ks`/shard/grid change is a cache hit away.
    pub fn from_session(
        session: &mut SimSession<'_>,
        cfg: &SimConfig,
        batch: usize,
    ) -> Result<Self> {
        let report = session.report(cfg)?;
        let net = session.network();
        let mut b = SimBackend::new(batch, net.layers[0].in_elems(), 10);
        b.service_ns_per_image = report.cycle_ns;
        Ok(b)
    }

    /// Build a device from an already-priced report — the searched-plan
    /// dispatch path: the caller ran `mapopt` for this device's geometry
    /// and hands over the winning plan's report, so the worker serves at
    /// the searched (not paper) service time.
    pub fn from_report(report: &SimReport, image_elems: usize, batch: usize) -> Self {
        let mut b = SimBackend::new(batch, image_elems, 10);
        b.service_ns_per_image = report.cycle_ns;
        b
    }

    /// Price a whole admission batch through **one** session pass — the
    /// batched serve-pricing path. Each request keeps its own `Result`
    /// (a failing plan poisons only its own slot) and its report is
    /// bitwise-identical to a per-request [`SimBackend::from_session`]
    /// pricing, but the per-layer cache fill is shared across the batch
    /// instead of repeated per request.
    pub fn price_batch(
        session: &mut SimSession<'_>,
        cfgs: &[SimConfig],
    ) -> Vec<Result<SimReport, PlanError>> {
        session.report_batch(cfgs)
    }

    /// Drain `batcher` (full batches first, then the partial tail) and
    /// price every admitted request in one batched session pass.
    /// Admission order is preserved in the result.
    pub fn price_drained(
        session: &mut SimSession<'_>,
        batcher: &mut Batcher<SimConfig>,
    ) -> Vec<Result<SimReport, PlanError>> {
        let mut cfgs: Vec<SimConfig> = Vec::with_capacity(batcher.pending());
        while let Some(batch) = batcher.pop_full() {
            cfgs.extend(batch);
        }
        if let Some(tail) = batcher.pop_partial() {
            cfgs.extend(tail);
        }
        session.report_batch(&cfgs)
    }

    /// [`SimBackend::from_session`] over a whole admission batch: one
    /// session pass prices every backend.
    pub fn from_session_batch(
        session: &mut SimSession<'_>,
        cfgs: &[SimConfig],
        batch: usize,
    ) -> Vec<Result<Self>> {
        let net = session.network();
        session
            .report_batch(cfgs)
            .into_iter()
            .map(|r| {
                let report = r?;
                let mut b = SimBackend::new(batch, net.layers[0].in_elems(), 10);
                b.service_ns_per_image = report.cycle_ns;
                Ok(b)
            })
            .collect()
    }

    /// Replay the device's modeled service time in wall-clock (scaled).
    pub fn with_time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale.max(0.0);
        self
    }

    /// The modeled per-image service time (ns).
    pub fn service_ns(&self) -> f64 {
        self.service_ns_per_image
    }

    /// Deterministic pseudo-weight for (class, element) — fixed stripes so
    /// every device classifies identically and repeatably.
    fn weight(class: usize, elem: usize) -> f32 {
        ((elem.wrapping_mul(31) + class.wrapping_mul(17) + 7) % 13) as f32 - 6.0
    }
}

impl Backend for SimBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn image_elems(&self) -> usize {
        self.image_elems
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            images.len() == self.batch * self.image_elems,
            "batch must be {}x{} elements, got {}",
            self.batch,
            self.image_elems,
            images.len()
        );
        if self.time_scale > 0.0 {
            let ns = self.service_ns_per_image * self.batch as f64 * self.time_scale;
            std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
        }
        let mut logits = Vec::with_capacity(self.batch * self.classes);
        for b in 0..self.batch {
            let img = &images[b * self.image_elems..(b + 1) * self.image_elems];
            for c in 0..self.classes {
                let score: f32 = img
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v as f32 * Self::weight(c, i))
                    .sum();
                logits.push(score / self.image_elems as f32);
            }
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_is_deterministic_across_instances() {
        let mut a = SimBackend::new(2, 16, 10);
        let mut b = SimBackend::new(2, 16, 10);
        let images: Vec<i32> = (0..32).map(|i| (i * 7) % 256).collect();
        assert_eq!(a.run_batch(&images).unwrap(), b.run_batch(&images).unwrap());
    }

    #[test]
    fn logit_rows_have_class_count() {
        let mut b = SimBackend::new(3, 8, 10);
        let out = b.run_batch(&vec![1; 24]).unwrap();
        assert_eq!(out.len(), 30);
    }

    #[test]
    fn wrong_batch_shape_rejected() {
        let mut b = SimBackend::new(2, 8, 10);
        assert!(b.run_batch(&[0; 7]).is_err());
    }

    #[test]
    fn from_sim_prices_service_time() {
        use crate::sim::{simulate, SimConfig};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let r = simulate(&net, &SimConfig::conservative(8)).unwrap();
        let b = SimBackend::from_sim(&r, &net, 8);
        assert_eq!(b.image_elems(), net.layers[0].in_elems());
        assert!(b.service_ns() > 0.0);
        assert_eq!(b.batch_size(), 8);
    }

    #[test]
    fn price_batch_matches_per_request_sessions() {
        use crate::plan::ShardPolicy;
        use crate::sim::{SimConfig, SimSession};
        use crate::workloads::nets::vgg16;
        let net = vgg16();
        let cfgs = [
            SimConfig::conservative(8),
            SimConfig::conservative(8)
                .with_grid(2, 4)
                .with_shard(ShardPolicy::LayerSplit),
            // 16 layer banks overflow a 1×1 grid — a per-request error.
            SimConfig::conservative(8).with_grid(1, 1),
        ];
        let mut session = SimSession::new(&net);
        let batched = SimBackend::price_batch(&mut session, &cfgs);
        assert_eq!(batched.len(), cfgs.len());
        for (cfg, got) in cfgs.iter().zip(&batched) {
            let mut fresh = SimSession::new(&net);
            assert_eq!(&fresh.report(cfg), got);
        }
        assert!(batched[2].is_err());
    }

    #[test]
    fn price_drained_empties_the_batcher_in_order() {
        use crate::coordinator::Batcher;
        use crate::sim::{SimConfig, SimSession};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let mut batcher = Batcher::new(2);
        for bits in [4usize, 8, 16] {
            batcher.push(SimConfig::conservative(bits));
        }
        let mut session = SimSession::new(&net);
        let reports = SimBackend::price_drained(&mut session, &mut batcher);
        assert_eq!(batcher.pending(), 0);
        assert_eq!(reports.len(), 3);
        let bits: Vec<usize> =
            reports.iter().map(|r| r.as_ref().unwrap().n_bits).collect();
        assert_eq!(bits, vec![4, 8, 16]);
    }

    #[test]
    fn from_session_batch_matches_per_request_backends() {
        use crate::sim::{SimConfig, SimSession};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let cfgs = [
            SimConfig::conservative(8),
            SimConfig::paper_favorable(8),
        ];
        let mut session = SimSession::new(&net);
        let batched = SimBackend::from_session_batch(&mut session, &cfgs, 4);
        assert_eq!(batched.len(), 2);
        for (cfg, got) in cfgs.iter().zip(batched) {
            let mut fresh = SimSession::new(&net);
            let want = SimBackend::from_session(&mut fresh, cfg, 4).unwrap();
            let got = got.unwrap();
            assert_eq!(got.service_ns().to_bits(), want.service_ns().to_bits());
            assert_eq!(got.batch_size(), want.batch_size());
            assert_eq!(got.image_elems(), want.image_elems());
        }
    }

    #[test]
    fn from_report_matches_from_session() {
        use crate::sim::{SimConfig, SimSession};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let cfg = SimConfig::conservative(8);
        let mut session = SimSession::new(&net);
        let report = session.report(&cfg).unwrap();
        let b = SimBackend::from_report(&report, net.layers[0].in_elems(), 4);
        let mut fresh = SimSession::new(&net);
        let want = SimBackend::from_session(&mut fresh, &cfg, 4).unwrap();
        assert_eq!(b.service_ns().to_bits(), want.service_ns().to_bits());
        assert_eq!(b.image_elems(), want.image_elems());
        assert_eq!(b.batch_size(), 4);
    }

    #[test]
    fn from_session_matches_from_sim() {
        use crate::sim::{simulate, SimConfig, SimSession};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let cfg = SimConfig::conservative(8);
        let fresh = SimBackend::from_sim(&simulate(&net, &cfg).unwrap(), &net, 4);
        let mut session = SimSession::new(&net);
        let cached = SimBackend::from_session(&mut session, &cfg, 4).unwrap();
        assert_eq!(cached.service_ns().to_bits(), fresh.service_ns().to_bits());
        assert_eq!(cached.image_elems(), fresh.image_elems());
        // Repricing the same pool is a pure cache hit.
        SimBackend::from_session(&mut session, &cfg, 4).unwrap();
        let (hits, _) = session.cache_stats();
        assert!(hits >= net.layers.len() as u64);
    }
}
