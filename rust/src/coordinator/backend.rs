//! Device execution backends for the multi-device coordinator.
//!
//! A [`Backend`] is what one pool worker drives: it owns one PIM device's
//! executable state and runs padded batches. Two implementations exist:
//!
//!   * [`SimBackend`] (always available) — a simulated device priced by
//!     the timing model. Logits are a fixed deterministic function of the
//!     image (the coordinator's dispatch/batching logic is what's under
//!     test, not numerics), and the device can optionally replay its
//!     DRAM-model service time in wall-clock for demos.
//!   * `PjrtBackend` (behind `--features pjrt`, in `server.rs`) — the AOT
//!     artifact executor; real numerics via PJRT.
//!
//! Backends are constructed *inside* their worker thread (the PJRT handles
//! are not `Send`), so the trait itself needs no `Send` bound.

use anyhow::Result;

use crate::sim::{SimConfig, SimResult, SimSession};
use crate::workloads::Network;

/// One device's executable state, driven by a single pool worker.
pub trait Backend {
    /// Fixed batch the device executes (requests are padded up to it).
    fn batch_size(&self) -> usize;
    /// Elements in one input image.
    fn image_elems(&self) -> usize;
    /// Logit count per image.
    fn num_classes(&self) -> usize;
    /// Run one padded batch (`batch_size × image_elems` elements);
    /// returns row-major logits `[batch_size × num_classes]`.
    fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>>;
}

/// A simulated PIM device: deterministic logits + a timing-model service
/// time it can replay in wall-clock.
#[derive(Debug, Clone)]
pub struct SimBackend {
    batch: usize,
    image_elems: usize,
    classes: usize,
    /// Steady-state per-image service time from the simulator (ns).
    service_ns_per_image: f64,
    /// Wall-clock replay factor: 0 (default) disables sleeping, 1 replays
    /// the DRAM-model time in real time.
    time_scale: f64,
}

impl SimBackend {
    pub fn new(batch: usize, image_elems: usize, classes: usize) -> Self {
        assert!(batch > 0 && image_elems > 0 && classes > 0);
        SimBackend {
            batch,
            image_elems,
            classes,
            service_ns_per_image: 0.0,
            time_scale: 0.0,
        }
    }

    /// Build a device priced by a simulation result: one pool worker
    /// stands in for one replica of `result`'s plan, serving `net` images.
    pub fn from_sim(result: &SimResult, net: &Network, batch: usize) -> Self {
        let mut b = SimBackend::new(batch, net.layers[0].in_elems(), 10);
        b.service_ns_per_image = result.pipeline.cycle_ns;
        b
    }

    /// Build a device priced through an incremental [`SimSession`]: the
    /// serving path reuses the session's cached per-layer pricing instead
    /// of re-running `simulate()` from scratch, and repricing a pool after
    /// a `ks`/shard/grid change is a cache hit away.
    pub fn from_session(
        session: &mut SimSession<'_>,
        cfg: &SimConfig,
        batch: usize,
    ) -> Result<Self> {
        let report = session.report(cfg)?;
        let net = session.network();
        let mut b = SimBackend::new(batch, net.layers[0].in_elems(), 10);
        b.service_ns_per_image = report.cycle_ns;
        Ok(b)
    }

    /// Replay the device's modeled service time in wall-clock (scaled).
    pub fn with_time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale.max(0.0);
        self
    }

    /// The modeled per-image service time (ns).
    pub fn service_ns(&self) -> f64 {
        self.service_ns_per_image
    }

    /// Deterministic pseudo-weight for (class, element) — fixed stripes so
    /// every device classifies identically and repeatably.
    fn weight(class: usize, elem: usize) -> f32 {
        ((elem.wrapping_mul(31) + class.wrapping_mul(17) + 7) % 13) as f32 - 6.0
    }
}

impl Backend for SimBackend {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn image_elems(&self) -> usize {
        self.image_elems
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            images.len() == self.batch * self.image_elems,
            "batch must be {}x{} elements, got {}",
            self.batch,
            self.image_elems,
            images.len()
        );
        if self.time_scale > 0.0 {
            let ns = self.service_ns_per_image * self.batch as f64 * self.time_scale;
            std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
        }
        let mut logits = Vec::with_capacity(self.batch * self.classes);
        for b in 0..self.batch {
            let img = &images[b * self.image_elems..(b + 1) * self.image_elems];
            for c in 0..self.classes {
                let score: f32 = img
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v as f32 * Self::weight(c, i))
                    .sum();
                logits.push(score / self.image_elems as f32);
            }
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_is_deterministic_across_instances() {
        let mut a = SimBackend::new(2, 16, 10);
        let mut b = SimBackend::new(2, 16, 10);
        let images: Vec<i32> = (0..32).map(|i| (i * 7) % 256).collect();
        assert_eq!(a.run_batch(&images).unwrap(), b.run_batch(&images).unwrap());
    }

    #[test]
    fn logit_rows_have_class_count() {
        let mut b = SimBackend::new(3, 8, 10);
        let out = b.run_batch(&vec![1; 24]).unwrap();
        assert_eq!(out.len(), 30);
    }

    #[test]
    fn wrong_batch_shape_rejected() {
        let mut b = SimBackend::new(2, 8, 10);
        assert!(b.run_batch(&[0; 7]).is_err());
    }

    #[test]
    fn from_sim_prices_service_time() {
        use crate::sim::{simulate, SimConfig};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let r = simulate(&net, &SimConfig::conservative(8)).unwrap();
        let b = SimBackend::from_sim(&r, &net, 8);
        assert_eq!(b.image_elems(), net.layers[0].in_elems());
        assert!(b.service_ns() > 0.0);
        assert_eq!(b.batch_size(), 8);
    }

    #[test]
    fn from_session_matches_from_sim() {
        use crate::sim::{simulate, SimConfig, SimSession};
        use crate::workloads::nets::pimnet;
        let net = pimnet();
        let cfg = SimConfig::conservative(8);
        let fresh = SimBackend::from_sim(&simulate(&net, &cfg).unwrap(), &net, 4);
        let mut session = SimSession::new(&net);
        let cached = SimBackend::from_session(&mut session, &cfg, 4).unwrap();
        assert_eq!(cached.service_ns().to_bits(), fresh.service_ns().to_bits());
        assert_eq!(cached.image_elems(), fresh.image_elems());
        // Repricing the same pool is a pure cache hit.
        SimBackend::from_session(&mut session, &cfg, 4).unwrap();
        let (hits, _) = session.cache_stats();
        assert!(hits >= net.layers.len() as u64);
    }
}
