//! The inference server: a per-device worker pool over [`Backend`]s.
//!
//! Every planned device gets one worker thread that owns its backend
//! (constructed *inside* the thread — PJRT handles are not `Send`) and
//! runs the batching loop over the shared [`Batcher`]: fill to the
//! artifact batch size within a bounded window, pad the tail, execute,
//! reply. The dispatcher routes each request to a device up front
//! (round-robin / least-loaded / two-choices, mirroring
//! `coordinator::router`), so replicas of a `plan::ExecutionPlan` serve
//! disjoint request streams exactly like the timing model assumes.
//!
//! [`MultiDeviceServer`] is backend-generic and always compiled; the
//! artifact-executing [`InferenceServer`] (a pool of PJRT devices) sits on
//! top behind `--features pjrt`.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::backend::Backend;
use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use super::router::{Device, Policy, Router};

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker/device count (e.g. the plan's replica count).
    pub devices: usize,
    /// Dispatch policy across devices.
    pub policy: Policy,
    /// Max time a request waits for its device's batch to fill before a
    /// partial batch is flushed.
    pub batch_window: Duration,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            devices: 1,
            policy: Policy::RoundRobin,
            batch_window: Duration::from_millis(5),
        }
    }
}

/// Result of one classify request.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassifyResponse {
    pub class: usize,
    pub logits: Vec<f32>,
    /// End-to-end wall-clock latency of the request (queue + execute).
    pub latency: Duration,
    /// Device that served the request.
    pub device: usize,
}

struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    resp: Sender<Result<ClassifyResponse>>,
}

enum Control {
    Req(Request),
    Shutdown,
}

struct Worker {
    tx: SyncSender<Control>,
    handle: Option<JoinHandle<()>>,
}

/// Handle to a running device pool. Dispatch decisions delegate to the
/// existing [`Router`] (each worker is one routed [`Device`]), so the
/// offline router simulations and the live pool share one policy
/// implementation.
pub struct MultiDeviceServer {
    workers: Vec<Worker>,
    metrics: Arc<Mutex<Metrics>>,
    router: Mutex<Router>,
    image_elems: usize,
    batch: usize,
}

impl MultiDeviceServer {
    /// Start one worker per device; `factory(device_id)` builds each
    /// backend on its own thread. All workers spawn first and readiness is
    /// collected afterwards, so N slow backend constructions (e.g. PJRT
    /// artifact compiles) overlap instead of paying `sum(compile)`.
    pub fn start<B, F>(cfg: PoolConfig, factory: F) -> Result<MultiDeviceServer>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + Clone + 'static,
    {
        anyhow::ensure!(cfg.devices > 0, "pool needs at least one device");
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let mut workers = Vec::with_capacity(cfg.devices);
        let mut ready_rxs = Vec::with_capacity(cfg.devices);

        for device in 0..cfg.devices {
            let (tx, rx) = mpsc::sync_channel::<Control>(1024);
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
            let worker_factory = factory.clone();
            let worker_metrics = Arc::clone(&metrics);
            let window = cfg.batch_window;
            let handle = std::thread::Builder::new()
                .name(format!("pim-serve-{device}"))
                .spawn(move || {
                    worker_main(device, worker_factory, rx, worker_metrics, window, ready_tx)
                })
                .context("spawning device worker")?;
            workers.push(Worker { tx, handle: Some(handle) });
            ready_rxs.push(ready_rx);
        }

        let mut dims: Option<(usize, usize)> = None;
        for ready_rx in ready_rxs {
            let got = ready_rx
                .recv()
                .context("device worker died during startup")??;
            if let Some(prev) = dims {
                anyhow::ensure!(
                    prev == got,
                    "heterogeneous backends in one pool: {prev:?} vs {got:?}"
                );
            }
            dims = Some(got);
        }

        let (image_elems, batch) = dims.expect("devices > 0");
        // Workers are homogeneous, so unit service time makes the router's
        // backlog estimate proportional to plain queue depth.
        let devices = (0..cfg.devices)
            .map(|d| Device::new(&format!("worker{d}"), 1.0))
            .collect();
        Ok(MultiDeviceServer {
            workers,
            metrics,
            router: Mutex::new(Router::new(devices, cfg.policy, 0x5EED)),
            image_elems,
            batch,
        })
    }

    pub fn devices(&self) -> usize {
        self.workers.len()
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn image_elems(&self) -> usize {
        self.image_elems
    }

    /// Blocking single-image classification, dispatched to one device.
    pub fn classify(&self, image: Vec<i32>) -> Result<ClassifyResponse> {
        anyhow::ensure!(
            image.len() == self.image_elems,
            "image must have {} elements, got {}",
            self.image_elems,
            image.len()
        );
        let device = self.router.lock().unwrap().route();
        self.metrics.lock().unwrap().record_dispatch(device);
        let result = self.dispatch_to(device, image);
        self.router.lock().unwrap().complete(device);
        result
    }

    fn dispatch_to(&self, device: usize, image: Vec<i32>) -> Result<ClassifyResponse> {
        let (resp_tx, resp_rx) = mpsc::channel();
        self.workers[device]
            .tx
            .send(Control::Req(Request {
                image,
                enqueued: Instant::now(),
                resp: resp_tx,
            }))
            .map_err(|_| anyhow::anyhow!("server is down"))?;
        resp_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Control::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for MultiDeviceServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Index of the max logit in one row.
fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Execute one popped batch on the worker's backend and reply.
fn execute_batch<B: Backend>(
    backend: &mut B,
    device: usize,
    reqs: Vec<Request>,
    metrics: &Mutex<Metrics>,
) {
    let batch_size = backend.batch_size();
    let image_elems = backend.image_elems();
    let fill = reqs.len();

    // Pad to the compiled batch size.
    let mut images = Vec::with_capacity(batch_size * image_elems);
    for r in &reqs {
        images.extend_from_slice(&r.image);
    }
    images.resize(batch_size * image_elems, 0);

    let t0 = Instant::now();
    let result = backend.run_batch(&images);
    let exec_time = t0.elapsed();

    match result {
        Ok(logits) => {
            let ncls = backend.num_classes();
            let mut m = metrics.lock().unwrap();
            m.record_batch(exec_time, fill, batch_size);
            for (i, r) in reqs.into_iter().enumerate() {
                let latency = r.enqueued.elapsed();
                m.record_request(latency);
                let row = logits[i * ncls..(i + 1) * ncls].to_vec();
                let _ = r.resp.send(Ok(ClassifyResponse {
                    class: argmax(&row),
                    logits: row,
                    latency,
                    device,
                }));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e:#}");
            for r in reqs {
                let _ = r.resp.send(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

fn worker_main<B, F>(
    device: usize,
    factory: F,
    rx: Receiver<Control>,
    metrics: Arc<Mutex<Metrics>>,
    window: Duration,
    ready: Sender<Result<(usize, usize)>>,
) where
    B: Backend,
    F: Fn(usize) -> Result<B>,
{
    // Build the backend on this thread (PJRT handles stay here).
    let mut backend = match factory(device) {
        Ok(b) => {
            let _ = ready.send(Ok((b.image_elems(), b.batch_size())));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let batch_size = backend.batch_size();
    let mut batcher: Batcher<Request> = Batcher::new(batch_size);
    let mut open = true;

    while open {
        // Block for the first request of the next batch.
        match rx.recv() {
            Ok(Control::Req(r)) => batcher.push(r),
            Ok(Control::Shutdown) | Err(_) => break,
        }
        // Fill within the window.
        let deadline = Instant::now() + window;
        while batcher.pending() < batch_size {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Control::Req(r)) => batcher.push(r),
                Ok(Control::Shutdown) => {
                    open = false;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // Flush everything queued (all full batches + the tail).
        while let Some(reqs) = batcher.pop_full() {
            execute_batch(&mut backend, device, reqs, &metrics);
        }
        if let Some(reqs) = batcher.pop_partial() {
            execute_batch(&mut backend, device, reqs, &metrics);
        }
    }
    // Drain requests that raced the shutdown.
    while let Some(reqs) = batcher.pop_full().or_else(|| batcher.pop_partial()) {
        execute_batch(&mut backend, device, reqs, &metrics);
    }
}

// ---- PJRT artifact server (feature `pjrt`) --------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_server {
    use std::path::{Path, PathBuf};

    use super::*;
    use crate::runtime::{artifacts_dir, PimNetExecutor, Runtime};

    /// Artifact-server configuration.
    #[derive(Debug, Clone)]
    pub struct ServerConfig {
        pub artifacts: PathBuf,
        /// Max time a request waits for the batch to fill before a partial
        /// batch is flushed.
        pub batch_window: Duration,
        /// Use the per-layer chain (true, the bank pipeline) or the fused
        /// full-model module (false).
        pub per_layer_chain: bool,
        /// PJRT device workers in the pool.
        pub devices: usize,
        pub policy: Policy,
    }

    impl Default for ServerConfig {
        fn default() -> Self {
            ServerConfig {
                artifacts: artifacts_dir(),
                batch_window: Duration::from_millis(5),
                per_layer_chain: true,
                devices: 1,
                policy: Policy::RoundRobin,
            }
        }
    }

    /// One PJRT device: a compiled copy of the AOT artifacts.
    pub struct PjrtBackend {
        exec: PimNetExecutor,
        per_layer_chain: bool,
        image_elems: usize,
    }

    impl PjrtBackend {
        pub fn load(dir: &Path, per_layer_chain: bool) -> Result<PjrtBackend> {
            let rt = Runtime::cpu()?;
            let exec = PimNetExecutor::load(&rt, dir)?;
            let image_elems =
                exec.manifest.layers[0].in_shape.iter().skip(1).product();
            Ok(PjrtBackend { exec, per_layer_chain, image_elems })
        }
    }

    impl Backend for PjrtBackend {
        fn batch_size(&self) -> usize {
            self.exec.batch_size()
        }

        fn image_elems(&self) -> usize {
            self.image_elems
        }

        fn num_classes(&self) -> usize {
            10
        }

        fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>> {
            let images = images.to_vec();
            let logits = if self.per_layer_chain {
                self.exec.run_chain(images)?
            } else {
                self.exec.run_full(images)?
            };
            Ok(logits.as_f32()?.to_vec())
        }
    }

    /// The artifact-serving front: a pool of PJRT devices.
    pub struct InferenceServer {
        inner: MultiDeviceServer,
    }

    impl InferenceServer {
        /// Start the worker pool and wait until every device compiled the
        /// artifacts.
        pub fn start(cfg: ServerConfig) -> Result<InferenceServer> {
            let artifacts = cfg.artifacts.clone();
            let per_layer_chain = cfg.per_layer_chain;
            let inner = MultiDeviceServer::start(
                PoolConfig {
                    devices: cfg.devices,
                    policy: cfg.policy,
                    batch_window: cfg.batch_window,
                },
                move |_| PjrtBackend::load(&artifacts, per_layer_chain),
            )?;
            Ok(InferenceServer { inner })
        }

        pub fn batch_size(&self) -> usize {
            self.inner.batch_size()
        }

        /// Blocking single-image classification.
        pub fn classify(&self, image: Vec<i32>) -> Result<ClassifyResponse> {
            self.inner.classify(image)
        }

        pub fn metrics(&self) -> MetricsSnapshot {
            self.inner.metrics()
        }

        pub fn shutdown(self) {
            self.inner.shutdown();
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_server::{InferenceServer, PjrtBackend, ServerConfig};

// Integration tests: simulated devices in rust/tests/scaleout_serve.rs
// (default features); artifact-backed in rust/tests/serve_integration.rs
// (requires `pjrt` + `make artifacts`).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;

    fn pool(devices: usize, policy: Policy) -> MultiDeviceServer {
        MultiDeviceServer::start(
            PoolConfig { devices, policy, batch_window: Duration::from_millis(2) },
            |_| Ok(SimBackend::new(4, 8, 10)),
        )
        .unwrap()
    }

    #[test]
    fn single_device_round_trip() {
        let s = pool(1, Policy::RoundRobin);
        let resp = s.classify(vec![3; 8]).unwrap();
        assert_eq!(resp.device, 0);
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.class < 10);
        let m = s.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.per_device, vec![1]);
        s.shutdown();
    }

    #[test]
    fn round_robin_touches_every_device() {
        let s = pool(3, Policy::RoundRobin);
        for i in 0..6 {
            let resp = s.classify(vec![i as i32; 8]).unwrap();
            assert_eq!(resp.device, i % 3);
        }
        let m = s.metrics();
        assert_eq!(m.per_device, vec![2, 2, 2]);
        s.shutdown();
    }

    #[test]
    fn wrong_image_size_rejected() {
        let s = pool(1, Policy::RoundRobin);
        assert!(s.classify(vec![0; 3]).is_err());
        s.shutdown();
    }

    #[test]
    fn failing_factory_fails_start() {
        let err = MultiDeviceServer::start(PoolConfig::default(), |d| {
            Err::<SimBackend, _>(anyhow::anyhow!("device {d} has no DIMM"))
        })
        .unwrap_err();
        assert!(err.to_string().contains("no DIMM"));
    }

    #[test]
    fn zero_devices_rejected() {
        let cfg = PoolConfig { devices: 0, ..PoolConfig::default() };
        assert!(
            MultiDeviceServer::start(cfg, |_| Ok(SimBackend::new(1, 1, 2))).is_err()
        );
    }
}
