//! The inference server: a worker thread owns the PJRT executor (PJRT
//! handles are not Send); clients submit requests over a channel and block
//! on per-request response channels. Requests are batched to the artifact
//! batch size within a bounded window.

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use crate::runtime::{artifacts_dir, PimNetExecutor, Runtime};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts: PathBuf,
    /// Max time a request waits for the batch to fill before a partial
    /// batch is flushed.
    pub batch_window: Duration,
    /// Use the per-layer chain (true, the bank pipeline) or the fused
    /// full-model module (false).
    pub per_layer_chain: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts: artifacts_dir(),
            batch_window: Duration::from_millis(5),
            per_layer_chain: true,
        }
    }
}

/// Result of one classify request.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassifyResponse {
    pub class: usize,
    pub logits: Vec<f32>,
    /// End-to-end wall-clock latency of the request (queue + execute).
    pub latency: Duration,
}

struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    resp: Sender<Result<ClassifyResponse>>,
}

enum Control {
    Req(Request),
    Shutdown,
}

/// Handle to the running server.
pub struct InferenceServer {
    tx: SyncSender<Control>,
    metrics: Arc<Mutex<Metrics>>,
    worker: Option<JoinHandle<()>>,
    image_elems: usize,
    batch: usize,
}

impl InferenceServer {
    /// Start the worker and wait until the artifacts are compiled.
    pub fn start(cfg: ServerConfig) -> Result<InferenceServer> {
        let (tx, rx) = mpsc::sync_channel::<Control>(1024);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();

        let worker = std::thread::Builder::new()
            .name("pim-serve".into())
            .spawn(move || {
                worker_main(cfg, rx, metrics_worker, ready_tx);
            })
            .context("spawning server worker")?;

        let (image_elems, batch) = ready_rx
            .recv()
            .context("server worker died during startup")??;
        Ok(InferenceServer {
            tx,
            metrics,
            worker: Some(worker),
            image_elems,
            batch,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Blocking single-image classification.
    pub fn classify(&self, image: Vec<i32>) -> Result<ClassifyResponse> {
        anyhow::ensure!(
            image.len() == self.image_elems,
            "image must have {} elements, got {}",
            self.image_elems,
            image.len()
        );
        let (resp_tx, resp_rx) = mpsc::channel();
        self.tx
            .send(Control::Req(Request {
                image,
                enqueued: Instant::now(),
                resp: resp_tx,
            }))
            .map_err(|_| anyhow::anyhow!("server is down"))?;
        resp_rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Control::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Control::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_main(
    cfg: ServerConfig,
    rx: Receiver<Control>,
    metrics: Arc<Mutex<Metrics>>,
    ready: Sender<Result<(usize, usize)>>,
) {
    // Compile everything on the worker (PJRT handles stay on this thread).
    let exec = match Runtime::cpu()
        .and_then(|rt| PimNetExecutor::load(&rt, &cfg.artifacts))
    {
        Ok(e) => {
            let elems: usize =
                e.manifest.layers[0].in_shape.iter().skip(1).product();
            let _ = ready.send(Ok((elems, e.batch_size())));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let batch_size = exec.batch_size();
    let image_elems: usize =
        exec.manifest.layers[0].in_shape.iter().skip(1).product();
    let mut batcher: Batcher<Request> = Batcher::new(batch_size);
    let mut open = true;

    while open {
        // Fill the batch or time out on the window.
        let deadline = Instant::now() + cfg.batch_window;
        while batcher.pending() < batch_size {
            let now = Instant::now();
            let timeout = deadline.saturating_duration_since(now);
            match rx.recv_timeout(timeout) {
                Ok(Control::Req(r)) => batcher.push(r),
                Ok(Control::Shutdown) => {
                    open = false;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
            if batcher.pending() == 0 {
                // Nothing queued: keep waiting without burning the window.
                continue;
            }
        }

        let Some(reqs) = batcher
            .pop_full()
            .or_else(|| batcher.pop_partial())
        else {
            continue;
        };

        // Pad to the compiled batch size.
        let fill = reqs.len();
        let mut images = Vec::with_capacity(batch_size * image_elems);
        for r in &reqs {
            images.extend_from_slice(&r.image);
        }
        images.resize(batch_size * image_elems, 0);

        let t0 = Instant::now();
        let result = if cfg.per_layer_chain {
            exec.run_chain(images)
        } else {
            exec.run_full(images)
        };
        let exec_time = t0.elapsed();

        match result.and_then(|logits| {
            let classes = PimNetExecutor::classify(&logits)?;
            let flat = logits.as_f32()?.to_vec();
            let ncls = flat.len() / batch_size;
            Ok((classes, flat, ncls))
        }) {
            Ok((classes, flat, ncls)) => {
                let mut m = metrics.lock().unwrap();
                m.record_batch(exec_time, fill, batch_size);
                for (i, r) in reqs.into_iter().enumerate() {
                    let latency = r.enqueued.elapsed();
                    m.record_request(latency);
                    let _ = r.resp.send(Ok(ClassifyResponse {
                        class: classes[i],
                        logits: flat[i * ncls..(i + 1) * ncls].to_vec(),
                        latency,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for r in reqs {
                    let _ = r.resp.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

// Integration tests (need artifacts) live in rust/tests/serve_integration.rs.
