//! The inference server: a per-device worker pool over [`Backend`]s.
//!
//! Every planned device gets one worker thread that owns its backend
//! (constructed *inside* the thread — PJRT handles are not `Send`) and
//! runs the batching loop over the shared [`Batcher`]: fill to the
//! artifact batch size within a bounded window, pad the tail, execute,
//! reply. The dispatcher routes each request to a device up front
//! (round-robin / least-loaded / two-choices, mirroring
//! `coordinator::router`), so replicas of a `plan::ExecutionPlan` serve
//! disjoint request streams exactly like the timing model assumes.
//!
//! [`MultiDeviceServer`] is backend-generic and always compiled; the
//! artifact-executing [`InferenceServer`] (a pool of PJRT devices) sits on
//! top behind `--features pjrt`.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::backend::Backend;
use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use super::resilience::{HealthTracker, HealthTransition, ResilienceSpec, ServeError, ShedReason};
use super::router::{Device, Policy, Router};

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker/device count (e.g. the plan's replica count).
    pub devices: usize,
    /// Dispatch policy across devices.
    pub policy: Policy,
    /// Max time a request waits for its device's batch to fill before a
    /// partial batch is flushed.
    pub batch_window: Duration,
    /// Deadline / retry / failover / shedding policy. The default is
    /// behavior-preserving: no deadline, no retries, the legacy queue
    /// depth, health tracking off.
    pub resilience: ResilienceSpec,
    /// Per-device estimated service time per image (ns), from each
    /// device's cached simulator price. `None` (the default) keeps the
    /// legacy homogeneous assumption — unit service time, so backlog
    /// scoring reduces to plain queue depth. Heterogeneous fleets set this
    /// so capability-aware policies can weigh queue depth by device speed.
    pub service_ns: Option<Vec<f64>>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            devices: 1,
            policy: Policy::RoundRobin,
            batch_window: Duration::from_millis(5),
            resilience: ResilienceSpec::default(),
            service_ns: None,
        }
    }
}

/// Result of one classify request.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassifyResponse {
    pub class: usize,
    pub logits: Vec<f32>,
    /// End-to-end wall-clock latency of the request (queue + execute).
    pub latency: Duration,
    /// Device that served the request.
    pub device: usize,
}

struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    /// Absolute deadline; expired requests are answered with a typed
    /// [`ServeError::Timeout`] when their batch forms.
    deadline: Option<Instant>,
    resp: Sender<Result<ClassifyResponse, ServeError>>,
}

enum Control {
    Req(Request),
    Shutdown,
}

struct Worker {
    tx: SyncSender<Control>,
    handle: Option<JoinHandle<()>>,
}

/// Routing state: the policy router plus the health tracker that drives
/// its availability mask. One mutex for both, so a route decision and the
/// quarantine snapshot it uses are atomic (lock order is always
/// `dispatch` before `metrics`, never the reverse).
struct Dispatch {
    router: Router,
    health: HealthTracker,
}

/// Handle to a running device pool. Dispatch decisions delegate to the
/// existing [`Router`] (each worker is one routed [`Device`]), so the
/// offline router simulations and the live pool share one policy
/// implementation.
pub struct MultiDeviceServer {
    workers: Vec<Worker>,
    metrics: Arc<Mutex<Metrics>>,
    dispatch: Mutex<Dispatch>,
    resilience: ResilienceSpec,
    /// Epoch for the health tracker's monotonic clock.
    t0: Instant,
    image_elems: usize,
    batch: usize,
}

/// An admitted in-flight request (from [`MultiDeviceServer::submit`]).
/// Dropping it without waiting still releases the routed backlog slot.
pub struct Pending<'a> {
    server: &'a MultiDeviceServer,
    rx: Receiver<Result<ClassifyResponse, ServeError>>,
    device: usize,
}

impl Pending<'_> {
    /// Device the request was routed to.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Block for the response. A worker that dies before replying counts
    /// as a shutdown shed — never a silent drop.
    pub fn wait(self) -> Result<ClassifyResponse, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Shed {
                device: Some(self.device),
                reason: ShedReason::Shutdown,
            }),
        }
    }
}

impl Drop for Pending<'_> {
    fn drop(&mut self) {
        // Admission routed us; completion must balance it even if the
        // caller never waited (the reply channel just goes dead).
        let _ = self.server.dispatch.lock().unwrap().router.complete(self.device);
    }
}

impl MultiDeviceServer {
    /// Start one worker per device; `factory(device_id)` builds each
    /// backend on its own thread. All workers spawn first and readiness is
    /// collected afterwards, so N slow backend constructions (e.g. PJRT
    /// artifact compiles) overlap instead of paying `sum(compile)`.
    pub fn start<B, F>(cfg: PoolConfig, factory: F) -> Result<MultiDeviceServer>
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Send + Sync + Clone + 'static,
    {
        anyhow::ensure!(cfg.devices > 0, "pool needs at least one device");
        cfg.resilience.validate()?;
        if let Some(s) = &cfg.service_ns {
            anyhow::ensure!(
                s.len() == cfg.devices,
                "service_ns has {} entries for {} devices",
                s.len(),
                cfg.devices
            );
            anyhow::ensure!(
                s.iter().all(|&v| v.is_finite() && v > 0.0),
                "service_ns entries must be finite and positive: {s:?}"
            );
        }
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let mut workers = Vec::with_capacity(cfg.devices);
        let mut ready_rxs = Vec::with_capacity(cfg.devices);

        for device in 0..cfg.devices {
            let (tx, rx) = mpsc::sync_channel::<Control>(cfg.resilience.queue_cap);
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
            let worker_factory = factory.clone();
            let worker_metrics = Arc::clone(&metrics);
            let window = cfg.batch_window;
            let handle = std::thread::Builder::new()
                .name(format!("pim-serve-{device}"))
                .spawn(move || {
                    worker_main(device, worker_factory, rx, worker_metrics, window, ready_tx)
                })
                .context("spawning device worker")?;
            workers.push(Worker { tx, handle: Some(handle) });
            ready_rxs.push(ready_rx);
        }

        let mut dims: Option<(usize, usize)> = None;
        for ready_rx in ready_rxs {
            let got = ready_rx
                .recv()
                .context("device worker died during startup")??;
            if let Some(prev) = dims {
                anyhow::ensure!(
                    prev == got,
                    "heterogeneous backends in one pool: {prev:?} vs {got:?}"
                );
            }
            dims = Some(got);
        }

        let (image_elems, batch) = dims.expect("devices > 0");
        // Without per-device prices the workers are assumed homogeneous:
        // unit service time makes the router's backlog estimate
        // proportional to plain queue depth. Heterogeneous fleets pass the
        // simulator's per-device service estimates instead.
        let devices = (0..cfg.devices)
            .map(|d| {
                let service = cfg.service_ns.as_ref().map_or(1.0, |s| s[d]);
                Device::new(&format!("worker{d}"), service)
            })
            .collect();
        Ok(MultiDeviceServer {
            workers,
            metrics,
            dispatch: Mutex::new(Dispatch {
                router: Router::new(devices, cfg.policy, 0x5EED),
                health: HealthTracker::new(cfg.devices, &cfg.resilience),
            }),
            resilience: cfg.resilience,
            t0: Instant::now(),
            image_elems,
            batch,
        })
    }

    /// Monotonic ns since the pool started (the health tracker's clock).
    fn now_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    pub fn devices(&self) -> usize {
        self.workers.len()
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn image_elems(&self) -> usize {
        self.image_elems
    }

    /// Blocking single-image classification under the pool's resilience
    /// policy: deadline, retry with capped exponential backoff, failover
    /// to another device, explicit shedding. With the default
    /// [`ResilienceSpec`] this is exactly the legacy one-shot dispatch.
    pub fn classify(&self, image: Vec<i32>) -> Result<ClassifyResponse, ServeError> {
        if image.len() != self.image_elems {
            return Err(ServeError::Rejected(format!(
                "image must have {} elements, got {}",
                self.image_elems,
                image.len()
            )));
        }
        let retries = self.resilience.retries;
        let mut image = image;
        let mut last_device: Option<usize> = None;
        let mut attempt: u32 = 0;
        loop {
            // Clone only while a later retry could still need the image;
            // the zero-retry hot path moves it, allocation-free.
            let img = if attempt < retries {
                image.clone()
            } else {
                std::mem::take(&mut image)
            };
            let err = match self.submit_attempt(img, attempt, last_device) {
                Ok(pending) => {
                    let device = pending.device();
                    last_device = Some(device);
                    match pending.wait() {
                        Ok(resp) => {
                            self.record_health(device, true);
                            return Ok(resp);
                        }
                        Err(e) => {
                            if e.counts_against_health() {
                                self.record_health(device, false);
                            }
                            e
                        }
                    }
                }
                Err(e) => e,
            };
            if attempt < retries && err.is_retryable() {
                let backoff = self.resilience.backoff_ms_for(attempt);
                attempt += 1;
                std::thread::sleep(Duration::from_millis(backoff));
                continue;
            }
            if matches!(
                err,
                ServeError::DeviceLost { .. }
                    | ServeError::Transient { .. }
                    | ServeError::Backend { .. }
            ) {
                self.metrics.lock().unwrap().failures += 1;
            }
            return Err(err);
        }
    }

    /// Admit one image without blocking on the response: route, enqueue
    /// (or shed), and return a [`Pending`] handle. No retries — callers
    /// that want the full resilience policy use
    /// [`MultiDeviceServer::classify`].
    pub fn submit(&self, image: Vec<i32>) -> Result<Pending<'_>, ServeError> {
        if image.len() != self.image_elems {
            return Err(ServeError::Rejected(format!(
                "image must have {} elements, got {}",
                self.image_elems,
                image.len()
            )));
        }
        self.submit_attempt(image, 0, None)
    }

    /// One admission attempt: sync the router's availability mask with the
    /// health tracker, route, and enqueue with explicit load-shedding.
    fn submit_attempt(
        &self,
        image: Vec<i32>,
        attempt: u32,
        last_device: Option<usize>,
    ) -> Result<Pending<'_>, ServeError> {
        let device = {
            let mut d = self.dispatch.lock().unwrap();
            if d.health.enabled() {
                let now = self.now_ns();
                for dev in 0..self.workers.len() {
                    let up = d.health.can_route(dev, now);
                    d.router.set_available(dev, up);
                    // A quarantined device whose probe window opened is
                    // routable exactly once; under the backlog policy the
                    // probe flag lets it pre-empt lower-score peers.
                    d.router.set_probe_candidate(dev, up && d.health.is_quarantined(dev));
                }
            }
            let Some(device) = d.router.try_route() else {
                self.metrics.lock().unwrap().shed += 1;
                return Err(ServeError::Shed { device: None, reason: ShedReason::NoDevice });
            };
            if d.health.is_quarantined(device) {
                // Routed to a quarantined device past its probe window:
                // this request is the (single) reintegration probe.
                d.health.begin_probe(device);
            }
            device
        };
        let (resp_tx, resp_rx) = mpsc::channel();
        let enqueued = Instant::now();
        let req = Request {
            image,
            enqueued,
            deadline: self
                .resilience
                .deadline_ms
                .map(|ms| enqueued + Duration::from_millis(ms)),
            resp: resp_tx,
        };
        match self.workers[device].tx.try_send(Control::Req(req)) {
            Ok(()) => {
                let mut m = self.metrics.lock().unwrap();
                m.record_dispatch(device);
                if attempt > 0 {
                    m.retries += 1;
                    if last_device.map_or(false, |p| p != device) {
                        m.failovers += 1;
                    }
                }
                Ok(Pending { server: self, rx: resp_rx, device })
            }
            Err(err) => {
                let _ = self.dispatch.lock().unwrap().router.complete(device);
                let reason = match err {
                    TrySendError::Full(_) => ShedReason::QueueFull,
                    TrySendError::Disconnected(_) => ShedReason::Shutdown,
                };
                self.metrics.lock().unwrap().shed += 1;
                Err(ServeError::Shed { device: Some(device), reason })
            }
        }
    }

    /// Record a request outcome with the health tracker and surface its
    /// quarantine / reintegration transitions in the metrics.
    fn record_health(&self, device: usize, ok: bool) {
        let mut d = self.dispatch.lock().unwrap();
        if !d.health.enabled() {
            return;
        }
        let now = self.now_ns();
        if ok {
            if d.health.record_success(device, now) {
                self.metrics.lock().unwrap().reintegrations += 1;
            }
        } else if d.health.record_failure(device, now) {
            self.metrics.lock().unwrap().quarantines += 1;
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Health transitions (quarantines and reintegrations) so far, in
    /// wall-clock order.
    pub fn health_transitions(&self) -> Vec<HealthTransition> {
        self.dispatch.lock().unwrap().health.transitions().to_vec()
    }

    /// Devices currently quarantined.
    pub fn quarantined_devices(&self) -> usize {
        self.dispatch.lock().unwrap().health.quarantined()
    }

    pub fn resilience(&self) -> &ResilienceSpec {
        &self.resilience
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Control::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for MultiDeviceServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Index of the max logit in one row (`total_cmp`: a NaN logit must not
/// panic the worker thread and poison the pool).
fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Execute one popped batch on the worker's backend and reply.
fn execute_batch<B: Backend>(
    backend: &mut B,
    device: usize,
    reqs: Vec<Request>,
    metrics: &Mutex<Metrics>,
) {
    // Deadline enforcement happens as the batch forms: expired requests
    // get a typed Timeout reply instead of burning a batch lane.
    let now = Instant::now();
    let (live, expired): (Vec<Request>, Vec<Request>) =
        reqs.into_iter().partition(|r| r.deadline.map_or(true, |d| now <= d));
    if !expired.is_empty() {
        metrics.lock().unwrap().timeouts += expired.len() as u64;
        for r in expired {
            let _ = r.resp.send(Err(ServeError::Timeout { device }));
        }
    }
    if live.is_empty() {
        return;
    }
    let reqs = live;

    let batch_size = backend.batch_size();
    let image_elems = backend.image_elems();
    let fill = reqs.len();

    // Pad to the compiled batch size.
    let mut images = Vec::with_capacity(batch_size * image_elems);
    for r in &reqs {
        images.extend_from_slice(&r.image);
    }
    images.resize(batch_size * image_elems, 0);

    let t0 = Instant::now();
    let result = backend.run_batch(&images);
    let exec_time = t0.elapsed();

    match result {
        Ok(logits) => {
            let ncls = backend.num_classes();
            let mut m = metrics.lock().unwrap();
            m.record_batch(exec_time, fill, batch_size);
            for (i, r) in reqs.into_iter().enumerate() {
                let latency = r.enqueued.elapsed();
                m.record_request(latency);
                let row = logits[i * ncls..(i + 1) * ncls].to_vec();
                let _ = r.resp.send(Ok(ClassifyResponse {
                    class: argmax(&row),
                    logits: row,
                    latency,
                    device,
                }));
            }
        }
        Err(e) => {
            // One shared source chain, one typed error per request — an
            // injected DeviceLost/Transient stays distinguishable from a
            // real backend failure.
            let shared = Arc::new(e);
            for r in reqs {
                let _ = r.resp.send(Err(ServeError::from_backend(device, &shared)));
            }
        }
    }
}

fn worker_main<B, F>(
    device: usize,
    factory: F,
    rx: Receiver<Control>,
    metrics: Arc<Mutex<Metrics>>,
    window: Duration,
    ready: Sender<Result<(usize, usize)>>,
) where
    B: Backend,
    F: Fn(usize) -> Result<B>,
{
    // Build the backend on this thread (PJRT handles stay here).
    let mut backend = match factory(device) {
        Ok(b) => {
            let _ = ready.send(Ok((b.image_elems(), b.batch_size())));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let batch_size = backend.batch_size();
    let mut batcher: Batcher<Request> = Batcher::new(batch_size);
    let mut open = true;

    while open {
        // Block for the first request of the next batch.
        match rx.recv() {
            Ok(Control::Req(r)) => batcher.push(r),
            Ok(Control::Shutdown) | Err(_) => break,
        }
        // Fill within the window.
        let deadline = Instant::now() + window;
        while batcher.pending() < batch_size {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Control::Req(r)) => batcher.push(r),
                Ok(Control::Shutdown) => {
                    open = false;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // Flush everything queued (all full batches + the tail).
        while let Some(reqs) = batcher.pop_full() {
            execute_batch(&mut backend, device, reqs, &metrics);
        }
        if let Some(reqs) = batcher.pop_partial() {
            execute_batch(&mut backend, device, reqs, &metrics);
        }
    }
    // Drain: everything already admitted executes (or times out, typed) —
    // an in-flight request is never silently dropped by shutdown.
    while let Some(reqs) = batcher.pop_full().or_else(|| batcher.pop_partial()) {
        execute_batch(&mut backend, device, reqs, &metrics);
    }
    // `stop` has exclusive access, so Shutdown is the channel's last
    // message and this loop should find nothing; defensively, anything
    // that somehow raced in is reported shed, not dropped.
    while let Ok(ctl) = rx.try_recv() {
        if let Control::Req(r) = ctl {
            metrics.lock().unwrap().shed += 1;
            let _ = r.resp.send(Err(ServeError::Shed {
                device: Some(device),
                reason: ShedReason::Shutdown,
            }));
        }
    }
}

// ---- PJRT artifact server (feature `pjrt`) --------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_server {
    use std::path::{Path, PathBuf};

    use super::*;
    use crate::runtime::{artifacts_dir, PimNetExecutor, Runtime};

    /// Artifact-server configuration.
    #[derive(Debug, Clone)]
    pub struct ServerConfig {
        pub artifacts: PathBuf,
        /// Max time a request waits for the batch to fill before a partial
        /// batch is flushed.
        pub batch_window: Duration,
        /// Use the per-layer chain (true, the bank pipeline) or the fused
        /// full-model module (false).
        pub per_layer_chain: bool,
        /// PJRT device workers in the pool.
        pub devices: usize,
        pub policy: Policy,
    }

    impl Default for ServerConfig {
        fn default() -> Self {
            ServerConfig {
                artifacts: artifacts_dir(),
                batch_window: Duration::from_millis(5),
                per_layer_chain: true,
                devices: 1,
                policy: Policy::RoundRobin,
            }
        }
    }

    /// One PJRT device: a compiled copy of the AOT artifacts.
    pub struct PjrtBackend {
        exec: PimNetExecutor,
        per_layer_chain: bool,
        image_elems: usize,
    }

    impl PjrtBackend {
        pub fn load(dir: &Path, per_layer_chain: bool) -> Result<PjrtBackend> {
            let rt = Runtime::cpu()?;
            let exec = PimNetExecutor::load(&rt, dir)?;
            let image_elems =
                exec.manifest.layers[0].in_shape.iter().skip(1).product();
            Ok(PjrtBackend { exec, per_layer_chain, image_elems })
        }
    }

    impl Backend for PjrtBackend {
        fn batch_size(&self) -> usize {
            self.exec.batch_size()
        }

        fn image_elems(&self) -> usize {
            self.image_elems
        }

        fn num_classes(&self) -> usize {
            10
        }

        fn run_batch(&mut self, images: &[i32]) -> Result<Vec<f32>> {
            let images = images.to_vec();
            let logits = if self.per_layer_chain {
                self.exec.run_chain(images)?
            } else {
                self.exec.run_full(images)?
            };
            Ok(logits.as_f32()?.to_vec())
        }
    }

    /// The artifact-serving front: a pool of PJRT devices.
    pub struct InferenceServer {
        inner: MultiDeviceServer,
    }

    impl InferenceServer {
        /// Start the worker pool and wait until every device compiled the
        /// artifacts.
        pub fn start(cfg: ServerConfig) -> Result<InferenceServer> {
            let artifacts = cfg.artifacts.clone();
            let per_layer_chain = cfg.per_layer_chain;
            let inner = MultiDeviceServer::start(
                PoolConfig {
                    devices: cfg.devices,
                    policy: cfg.policy,
                    batch_window: cfg.batch_window,
                    resilience: ResilienceSpec::default(),
                    service_ns: None,
                },
                move |_| PjrtBackend::load(&artifacts, per_layer_chain),
            )?;
            Ok(InferenceServer { inner })
        }

        pub fn batch_size(&self) -> usize {
            self.inner.batch_size()
        }

        /// Blocking single-image classification (typed serving errors;
        /// `?` still converts into `anyhow::Result` contexts).
        pub fn classify(&self, image: Vec<i32>) -> Result<ClassifyResponse, ServeError> {
            self.inner.classify(image)
        }

        pub fn metrics(&self) -> MetricsSnapshot {
            self.inner.metrics()
        }

        pub fn shutdown(self) {
            self.inner.shutdown();
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_server::{InferenceServer, PjrtBackend, ServerConfig};

// Integration tests: simulated devices in rust/tests/scaleout_serve.rs
// (default features); artifact-backed in rust/tests/serve_integration.rs
// (requires `pjrt` + `make artifacts`).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;

    fn pool(devices: usize, policy: Policy) -> MultiDeviceServer {
        MultiDeviceServer::start(
            PoolConfig {
                devices,
                policy,
                batch_window: Duration::from_millis(2),
                ..PoolConfig::default()
            },
            |_| Ok(SimBackend::new(4, 8, 10)),
        )
        .unwrap()
    }

    #[test]
    fn single_device_round_trip() {
        let s = pool(1, Policy::RoundRobin);
        let resp = s.classify(vec![3; 8]).unwrap();
        assert_eq!(resp.device, 0);
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.class < 10);
        let m = s.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.per_device, vec![1]);
        s.shutdown();
    }

    #[test]
    fn round_robin_touches_every_device() {
        let s = pool(3, Policy::RoundRobin);
        for i in 0..6 {
            let resp = s.classify(vec![i as i32; 8]).unwrap();
            assert_eq!(resp.device, i % 3);
        }
        let m = s.metrics();
        assert_eq!(m.per_device, vec![2, 2, 2]);
        s.shutdown();
    }

    #[test]
    fn wrong_image_size_rejected() {
        let s = pool(1, Policy::RoundRobin);
        let err = s.classify(vec![0; 3]).unwrap_err();
        assert!(matches!(err, ServeError::Rejected(_)), "{err}");
        s.shutdown();
    }

    #[test]
    fn submit_and_wait_round_trip() {
        let s = pool(2, Policy::RoundRobin);
        let a = s.submit(vec![1; 8]).unwrap();
        let b = s.submit(vec![2; 8]).unwrap();
        assert_eq!((a.device(), b.device()), (0, 1));
        let ra = a.wait().unwrap();
        let rb = b.wait().unwrap();
        assert_eq!((ra.device, rb.device), (0, 1));
        assert_eq!(s.metrics().requests, 2);
        s.shutdown();
    }

    #[test]
    fn dropping_pending_releases_the_backlog_slot() {
        let s = pool(1, Policy::LeastLoaded);
        for _ in 0..5 {
            // Admit and abandon: the reply is discarded, but the router's
            // in_flight accounting must drain back to zero each time.
            let p = s.submit(vec![7; 8]).unwrap();
            drop(p);
        }
        assert_eq!(s.dispatch.lock().unwrap().router.devices()[0].in_flight, 0);
        // The pool still serves normally afterwards.
        assert!(s.classify(vec![1; 8]).is_ok());
        s.shutdown();
    }

    #[test]
    fn default_resilience_reports_no_degraded_activity() {
        let s = pool(2, Policy::TwoChoices);
        for i in 0..8 {
            s.classify(vec![i; 8]).unwrap();
        }
        let m = s.metrics();
        assert!(!m.degraded(), "clean serving must stay in the legacy shape");
        assert_eq!(m.requests, 8);
        s.shutdown();
    }

    #[test]
    fn backend_error_is_typed_with_source_chain() {
        struct Broken;
        impl Backend for Broken {
            fn batch_size(&self) -> usize {
                2
            }
            fn image_elems(&self) -> usize {
                4
            }
            fn num_classes(&self) -> usize {
                10
            }
            fn run_batch(&mut self, _images: &[i32]) -> Result<Vec<f32>> {
                Err(anyhow::anyhow!("bank short-circuit").context("device fault"))
            }
        }
        let s = MultiDeviceServer::start(PoolConfig::default(), |_| Ok(Broken)).unwrap();
        let err = s.classify(vec![0; 4]).unwrap_err();
        match &err {
            ServeError::Backend { device, source } => {
                assert_eq!(*device, 0);
                assert!(format!("{source:#}").contains("bank short-circuit"));
            }
            other => panic!("expected Backend error, got {other}"),
        }
        assert_eq!(s.metrics().failures, 1);
        s.shutdown();
    }

    #[test]
    fn failing_factory_fails_start() {
        let err = MultiDeviceServer::start(PoolConfig::default(), |d| {
            Err::<SimBackend, _>(anyhow::anyhow!("device {d} has no DIMM"))
        })
        .unwrap_err();
        assert!(err.to_string().contains("no DIMM"));
    }

    #[test]
    fn zero_devices_rejected() {
        let cfg = PoolConfig { devices: 0, ..PoolConfig::default() };
        assert!(
            MultiDeviceServer::start(cfg, |_| Ok(SimBackend::new(1, 1, 2))).is_err()
        );
    }

    #[test]
    fn backlog_policy_weighs_per_device_service_times() {
        // service 4.0 vs 1.0 ns/image: submits held in flight, so the
        // backlog score steers most traffic to the fast device
        // (deterministic trace: 1, 1, 1, 0, 1, 1).
        let s = MultiDeviceServer::start(
            PoolConfig {
                devices: 2,
                policy: Policy::Backlog,
                batch_window: Duration::from_millis(2),
                service_ns: Some(vec![4.0, 1.0]),
                ..PoolConfig::default()
            },
            |_| Ok(SimBackend::new(4, 8, 10)),
        )
        .unwrap();
        let pendings: Vec<_> =
            (0..6).map(|i| s.submit(vec![i; 8]).unwrap()).collect();
        for p in pendings {
            p.wait().unwrap();
        }
        let m = s.metrics();
        assert_eq!(m.requests, 6);
        assert!(
            m.per_device[1] > m.per_device[0] * 3,
            "fast device should absorb most traffic: {:?}",
            m.per_device
        );
        s.shutdown();
    }

    #[test]
    fn mismatched_service_ns_length_rejected() {
        let cfg = PoolConfig {
            devices: 2,
            service_ns: Some(vec![1.0]),
            ..PoolConfig::default()
        };
        let err =
            MultiDeviceServer::start(cfg, |_| Ok(SimBackend::new(1, 1, 2))).unwrap_err();
        assert!(err.to_string().contains("service_ns"), "{err:#}");
    }
}
