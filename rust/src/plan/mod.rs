//! Device-scoped execution plans (DESIGN.md S20): lowering a network
//! mapping onto the `channels × ranks_per_channel` grid.
//!
//! The paper maps one layer per bank inside a single module and stops
//! there; its own geometry already describes channels and ranks the
//! original `simulate()` never exploited. This module closes that gap with
//! a device-agnostic IR between the mapper and the pricing engine:
//!
//!   * [`PimDevice`] — one *module slot*: a group of ranks on one channel
//!     that owns a shard's layer-per-bank mapping and pipeline. Transfers
//!     inside a device ride the module's internal bus; activations leaving
//!     a device cross the external channel interface (priced by
//!     `DramTiming::interchannel_copy_ns`, always dearer).
//!   * [`ShardAssignment`] — the contiguous slice of pipeline stages (and
//!     the residual reserve banks) a device hosts.
//!   * [`ExecutionPlan`] — the full lowering: devices, replica chains and
//!     the shared per-layer mapping template. Produced by [`lower`],
//!     priced by `sim::simulate` (plan → price → aggregate), and served by
//!     the coordinator's multi-device pool.
//!
//! Sharding policies:
//!   * [`ShardPolicy::Replicate`] — every replica hosts the whole network
//!     in `ceil(banks / banks_per_rank)` ranks of one channel; the grid
//!     packs as many replicas as fit. Replicas are independent (their bank
//!     chains never share a bus segment), so steady-state throughput
//!     scales linearly with the replica count.
//!   * [`ShardPolicy::LayerSplit`] — one pipeline split into contiguous,
//!     compute-balanced segments across the channels. Capacity scales (a
//!     segment only needs its own banks) and each channel's internal bus
//!     carries only its segment's transfers, but every segment boundary
//!     pays an inter-channel hop on latency.
//!   * [`ShardPolicy::Hybrid`] — `replicas` groups of channels, each group
//!     running one layer-split pipeline: the two axes composed.

use std::ops::Range;

use crate::dram::DramGeometry;
use crate::mapping::{map_network, MapConfig, MapError, NetworkMapping};
use crate::util::ceil_div;
use crate::workloads::Network;

/// How a network is sharded across the channel × rank grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardPolicy {
    /// Pack as many full-network replicas as the grid holds.
    #[default]
    Replicate,
    /// Split one pipeline into contiguous segments, one per channel.
    LayerSplit,
    /// `replicas` layer-split pipelines over disjoint channel groups.
    Hybrid { replicas: usize },
}

impl ShardPolicy {
    /// Parse a CLI/config spelling: `replicate`, `layersplit` (or
    /// `layer_split`/`split`), `hybrid:<replicas>`.
    pub fn parse(s: &str) -> anyhow::Result<ShardPolicy> {
        match s {
            "replicate" => Ok(ShardPolicy::Replicate),
            "layersplit" | "layer_split" | "split" => Ok(ShardPolicy::LayerSplit),
            other => {
                if let Some(n) = other.strip_prefix("hybrid:") {
                    let replicas: usize = n.parse().map_err(|_| {
                        anyhow::anyhow!("bad hybrid replica count `{n}`")
                    })?;
                    Ok(ShardPolicy::Hybrid { replicas })
                } else {
                    anyhow::bail!(
                        "unknown shard policy `{other}` \
                         (try replicate|layersplit|hybrid:<n>)"
                    )
                }
            }
        }
    }
}

impl std::fmt::Display for ShardPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardPolicy::Replicate => write!(f, "replicate"),
            ShardPolicy::LayerSplit => write!(f, "layersplit"),
            ShardPolicy::Hybrid { replicas } => write!(f, "hybrid:{replicas}"),
        }
    }
}

/// The slice of the network a device hosts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardAssignment {
    /// Layer indices `[start, end)` of the pipeline segment.
    pub layers: Range<usize>,
    /// Indices into `net.residuals` whose reserved bank lives here (a
    /// residual lands with the device hosting its `into_layer`).
    pub residuals: Vec<usize>,
}

/// One module slot: a rank group on one channel owning a shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PimDevice {
    pub id: usize,
    /// Replica (pipeline group) this device belongs to.
    pub replica: usize,
    pub channel: usize,
    /// Ranks occupied within the channel, `[start, end)`.
    pub ranks: Range<usize>,
    pub shard: ShardAssignment,
    /// Banks in use: shard layers + resident residual reserves.
    pub banks_used: usize,
}

impl PimDevice {
    /// Bank budget of the rank group.
    pub fn banks_avail(&self, g: &DramGeometry) -> usize {
        self.ranks.len() * g.banks_per_rank
    }
}

/// A network lowered onto the device grid.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub net_name: String,
    pub policy: ShardPolicy,
    pub geometry: DramGeometry,
    /// Per-layer mapping template (identical in every replica: a layer's
    /// subarray placement depends only on bank-internal geometry).
    pub mapping: NetworkMapping,
    pub devices: Vec<PimDevice>,
    /// Independent full-network pipelines in the plan.
    pub replicas: usize,
    /// Device ids of each replica's chain, pipeline order.
    pub chains: Vec<Vec<usize>>,
}

impl ExecutionPlan {
    /// Devices forming one replica's pipeline, in order.
    pub fn chain(&self, replica: usize) -> &[usize] {
        &self.chains[replica]
    }

    /// Inter-channel hops one image pays end-to-end (per replica).
    pub fn hops_per_image(&self) -> usize {
        self.chains.first().map(|c| c.len() - 1).unwrap_or(0)
    }

    /// Device id hosting `layer` within `replica`'s chain.
    pub fn device_hosting(&self, replica: usize, layer: usize) -> Option<usize> {
        self.chains[replica]
            .iter()
            .copied()
            .find(|&id| self.devices[id].shard.layers.contains(&layer))
    }
}

/// The grid geometry of a plan — devices, chains, replica count — without
/// the per-layer mapping it will carry. This is the part of lowering that
/// changes when the grid or shard policy changes, and it is cheap: the
/// incremental pricing session ([`crate::sim::SimSession`]) recomputes it
/// per call while reusing cached per-layer mapping/pricing.
#[derive(Debug, Clone, Default)]
pub struct PlanLayout {
    pub devices: Vec<PimDevice>,
    /// Independent full-network pipelines in the layout.
    pub replicas: usize,
    /// Flat chain arena: every replica's device ids back-to-back, so
    /// re-lowering into an existing layout ([`layout_into`]) allocates
    /// nothing once the vectors have grown to size. Replica `r`'s chain is
    /// `chain_devices[chain_bounds[r]..chain_bounds[r + 1]]`.
    chain_devices: Vec<usize>,
    chain_bounds: Vec<usize>,
}

impl PlanLayout {
    /// Empty the layout for re-lowering, keeping the allocations.
    fn reset(&mut self) {
        self.devices.clear();
        self.replicas = 0;
        self.chain_devices.clear();
        self.chain_bounds.clear();
        self.chain_bounds.push(0);
    }

    /// Close the chain under construction: everything pushed onto
    /// `chain_devices` since the last seal becomes one replica's chain.
    fn seal_chain(&mut self) {
        self.chain_bounds.push(self.chain_devices.len());
        self.replicas += 1;
    }

    /// Devices forming one replica's pipeline, in order.
    pub fn chain(&self, replica: usize) -> &[usize] {
        &self.chain_devices[self.chain_bounds[replica]..self.chain_bounds[replica + 1]]
    }

    /// The chains as the owned per-replica vectors [`ExecutionPlan`]
    /// carries.
    pub fn chains_vec(&self) -> Vec<Vec<usize>> {
        (0..self.replicas).map(|r| self.chain(r).to_vec()).collect()
    }

    /// Device id hosting `layer` within `replica`'s chain.
    pub fn device_hosting(&self, replica: usize, layer: usize) -> Option<usize> {
        self.chain(replica)
            .iter()
            .copied()
            .find(|&id| self.devices[id].shard.layers.contains(&layer))
    }
}

/// Plan-lowering failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// The underlying Algorithm-1 mapping failed.
    Map(MapError),
    /// A full-network replica does not fit inside one channel.
    ReplicaTooLarge { needed_ranks: usize, ranks_per_channel: usize },
    /// A layer-split segment exceeds its channel's bank budget.
    SegmentOverflow { channel: usize, banks: usize, budget: usize },
    /// Hybrid replica count is zero or exceeds the channel count.
    BadHybrid { replicas: usize, channels: usize },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Map(e) => write!(f, "{e}"),
            PlanError::ReplicaTooLarge { needed_ranks, ranks_per_channel } => {
                write!(
                    f,
                    "replica needs {needed_ranks} ranks but a channel has \
                     {ranks_per_channel}; use --shard layersplit to span \
                     channels"
                )
            }
            PlanError::SegmentOverflow { channel, banks, budget } => write!(
                f,
                "layer-split segment on channel {channel} needs {banks} \
                 banks but the channel has {budget}"
            ),
            PlanError::BadHybrid { replicas, channels } => write!(
                f,
                "hybrid:{replicas} needs 1..={channels} replicas \
                 ({channels} channels available)"
            ),
        }
    }
}

impl std::error::Error for PlanError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PlanError::Map(e) => Some(e),
            _ => None,
        }
    }
}

impl From<MapError> for PlanError {
    fn from(e: MapError) -> Self {
        PlanError::Map(e)
    }
}

/// Lower a network onto the device grid under `policy`.
pub fn lower(
    net: &Network,
    cfg: &MapConfig,
    policy: ShardPolicy,
) -> Result<ExecutionPlan, PlanError> {
    let mapping = map_network(net, cfg)?;
    lower_mapped(net, &cfg.geometry, mapping, policy)
}

/// Lower a network whose mapping is already built — the search mapper's
/// path: the chosen per-layer mappings (tiling and layout included)
/// replace Algorithm-1's defaults, and the split-balancing weights come
/// from the *chosen* round counts, so a row-aligned candidate that pays
/// extra waves also shifts the layer-split boundaries it implies.
pub fn lower_mapped(
    net: &Network,
    geometry: &DramGeometry,
    mapping: NetworkMapping,
    policy: ShardPolicy,
) -> Result<ExecutionPlan, PlanError> {
    let weights: Vec<u64> = mapping.layers.iter().map(|m| m.rounds() as u64).collect();
    let l = layout(net, &weights, mapping.total_banks, geometry, policy)?;
    let chains = l.chains_vec();
    Ok(ExecutionPlan {
        net_name: net.name.clone(),
        policy,
        geometry: geometry.clone(),
        mapping,
        devices: l.devices,
        replicas: l.replicas,
        chains,
    })
}

/// Compute the grid layout under `policy` from the per-layer sequential
/// round counts (`layer_rounds`, the split-balancing weights) and the bank
/// demand — everything lowering needs short of the mapping itself.
pub fn layout(
    net: &Network,
    layer_rounds: &[u64],
    banks_needed: usize,
    g: &DramGeometry,
    policy: ShardPolicy,
) -> Result<PlanLayout, PlanError> {
    let mut out = PlanLayout::default();
    layout_into(net, layer_rounds, banks_needed, g, policy, &mut out)?;
    Ok(out)
}

/// [`layout`] into a caller-owned [`PlanLayout`], reusing its
/// allocations. This is the sweep hot path: the incremental pricing
/// session re-lowers on every probe, and after the first call the layout
/// vectors are already sized. On error the layout holds a partial
/// lowering and must not be read — the next `layout_into` resets it.
pub fn layout_into(
    net: &Network,
    layer_rounds: &[u64],
    banks_needed: usize,
    g: &DramGeometry,
    policy: ShardPolicy,
    out: &mut PlanLayout,
) -> Result<(), PlanError> {
    out.reset();

    match policy {
        ShardPolicy::Replicate => {
            let needed_ranks = ceil_div(banks_needed, g.banks_per_rank);
            if needed_ranks > g.ranks_per_channel {
                return Err(PlanError::ReplicaTooLarge {
                    needed_ranks,
                    ranks_per_channel: g.ranks_per_channel,
                });
            }
            let per_channel = g.ranks_per_channel / needed_ranks;
            for channel in 0..g.channels {
                for slot in 0..per_channel {
                    let id = out.devices.len();
                    out.devices.push(PimDevice {
                        id,
                        replica: id,
                        channel,
                        ranks: slot * needed_ranks..(slot + 1) * needed_ranks,
                        shard: ShardAssignment {
                            layers: 0..net.layers.len(),
                            residuals: (0..net.residuals.len()).collect(),
                        },
                        banks_used: banks_needed,
                    });
                    out.chain_devices.push(id);
                    out.seal_chain();
                }
            }
        }
        ShardPolicy::LayerSplit => {
            split_group_into(net, layer_rounds, g, 0..g.channels, 0, out)?;
        }
        ShardPolicy::Hybrid { replicas } => {
            if replicas == 0 || replicas > g.channels {
                return Err(PlanError::BadHybrid { replicas, channels: g.channels });
            }
            // Equal channel groups; remainder channels stay idle.
            let group = g.channels / replicas;
            for r in 0..replicas {
                let chs = r * group..(r + 1) * group;
                split_group_into(net, layer_rounds, g, chs, r, out)?;
            }
        }
    }

    Ok(())
}

/// Split one pipeline across `channels`, one contiguous segment per
/// channel, balanced by the per-layer sequential-round count (the same
/// proxy the k-optimizer uses). The new devices become one sealed chain
/// of `out`.
fn split_group_into(
    net: &Network,
    weights: &[u64],
    g: &DramGeometry,
    channels: Range<usize>,
    replica: usize,
    out: &mut PlanLayout,
) -> Result<(), PlanError> {
    let segments = split_by_weight(weights, channels.len());
    let budget = g.ranks_per_channel * g.banks_per_rank;

    // A single-channel group degenerates to a whole-network device and
    // must additionally fit the channel (mirrors the Replicate check).
    for (si, seg) in segments.iter().enumerate() {
        let channel = channels.start + si;
        let residuals: Vec<usize> = net
            .residuals
            .iter()
            .enumerate()
            .filter(|(_, r)| seg.contains(&r.into_layer))
            .map(|(i, _)| i)
            .collect();
        let banks_used = seg.len() + residuals.len();
        if banks_used > budget {
            return Err(PlanError::SegmentOverflow { channel, banks: banks_used, budget });
        }
        let ranks_used = ceil_div(banks_used, g.banks_per_rank);
        let id = out.devices.len();
        out.devices.push(PimDevice {
            id,
            replica,
            channel,
            ranks: 0..ranks_used,
            shard: ShardAssignment { layers: seg.clone(), residuals },
            banks_used,
        });
        out.chain_devices.push(id);
    }
    out.seal_chain();
    Ok(())
}

/// Contiguous partition of `weights` into at most `segments` non-empty
/// ranges with near-equal weight: cut j lands at the first prefix ≥
/// `total·j/segments`, clamped so every remaining segment keeps ≥ 1 item.
fn split_by_weight(weights: &[u64], segments: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    let segs = segments.clamp(1, n.max(1));
    if n == 0 {
        return vec![0..0];
    }
    let cum: Vec<u64> = weights
        .iter()
        .scan(0u64, |acc, &w| {
            *acc += w;
            Some(*acc)
        })
        .collect();
    let total = (*cum.last().unwrap()).max(1);

    let mut cuts = vec![0usize];
    for j in 1..segs {
        let target = total.saturating_mul(j as u64) / segs as u64;
        let raw = cum
            .iter()
            .position(|&c| c >= target)
            .map(|i| i + 1)
            .unwrap_or(n);
        let prev = *cuts.last().unwrap();
        let cut = raw.clamp(prev + 1, n - (segs - j));
        cuts.push(cut);
    }
    cuts.push(n);
    cuts.windows(2).map(|w| w[0]..w[1]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::{alexnet, pimnet, resnet18, vgg16};

    fn cfg(g: DramGeometry) -> MapConfig {
        MapConfig::uniform(g, 8, 1)
    }

    #[test]
    fn replicate_packs_the_grid() {
        // pimnet needs 4 banks → 1 rank; paper_default has 1 ch × 4 ranks.
        let plan = lower(
            &pimnet(),
            &cfg(DramGeometry::paper_default()),
            ShardPolicy::Replicate,
        )
        .unwrap();
        assert_eq!(plan.replicas, 4);
        assert_eq!(plan.devices.len(), 4);
        assert!(plan.chains.iter().all(|c| c.len() == 1));
        assert_eq!(plan.hops_per_image(), 0);

        let mut g2 = DramGeometry::paper_default();
        g2.channels = 2;
        let plan2 = lower(&pimnet(), &cfg(g2), ShardPolicy::Replicate).unwrap();
        assert_eq!(plan2.replicas, 8);
        // Slots must be disjoint: distinct (channel, rank range) pairs.
        let mut slots: Vec<(usize, usize)> = plan2
            .devices
            .iter()
            .map(|d| (d.channel, d.ranks.start))
            .collect();
        slots.sort_unstable();
        slots.dedup();
        assert_eq!(slots.len(), 8);
    }

    #[test]
    fn replicate_spanning_multiple_ranks() {
        // resnet18: 18 layers + 8 residuals = 26 banks → all 4 ranks.
        let plan = lower(
            &resnet18(),
            &cfg(DramGeometry::paper_default()),
            ShardPolicy::Replicate,
        )
        .unwrap();
        assert_eq!(plan.replicas, 1);
        assert_eq!(plan.devices[0].ranks, 0..4);
        assert_eq!(plan.devices[0].banks_used, 26);
    }

    #[test]
    fn replica_too_large_for_one_channel() {
        let mut g = DramGeometry::paper_default();
        g.channels = 4;
        g.ranks_per_channel = 1;
        g.banks_per_rank = 2; // 2 banks per channel < pimnet's 4
        let err = lower(&pimnet(), &cfg(g), ShardPolicy::Replicate).unwrap_err();
        assert!(matches!(err, PlanError::ReplicaTooLarge { needed_ranks: 2, .. }));
    }

    #[test]
    fn layer_split_covers_all_layers_once() {
        let mut g = DramGeometry::paper_default();
        g.channels = 2;
        let net = resnet18();
        let plan = lower(&net, &cfg(g), ShardPolicy::LayerSplit).unwrap();
        assert_eq!(plan.replicas, 1);
        assert_eq!(plan.devices.len(), 2);
        assert_eq!(plan.hops_per_image(), 1);
        // Coverage + contiguity.
        let mut covered = vec![false; net.layers.len()];
        for d in &plan.devices {
            for l in d.shard.layers.clone() {
                assert!(!covered[l], "layer {l} assigned twice");
                covered[l] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // Residual reserves land with their into_layer's device.
        for d in &plan.devices {
            for &ri in &d.shard.residuals {
                assert!(d.shard.layers.contains(&net.residuals[ri].into_layer));
            }
        }
        let res_total: usize =
            plan.devices.iter().map(|d| d.shard.residuals.len()).sum();
        assert_eq!(res_total, net.residuals.len());
    }

    #[test]
    fn layer_split_balances_by_rounds() {
        let mut g = DramGeometry::paper_default();
        g.channels = 2;
        let net = vgg16();
        let plan = lower(&net, &cfg(g), ShardPolicy::LayerSplit).unwrap();
        let rounds_of = |d: &PimDevice| -> u64 {
            d.shard
                .layers
                .clone()
                .map(|i| plan.mapping.layers[i].rounds() as u64)
                .sum()
        };
        let a = rounds_of(&plan.devices[0]);
        let b = rounds_of(&plan.devices[1]);
        let total = a + b;
        // Contiguous split can't be perfect; demand better than 80/20.
        assert!(a * 5 >= total && b * 5 >= total, "split {a} vs {b}");
    }

    #[test]
    fn hybrid_composes_split_and_replicas() {
        let mut g = DramGeometry::paper_default();
        g.channels = 4;
        let plan = lower(
            &alexnet(),
            &cfg(g),
            ShardPolicy::Hybrid { replicas: 2 },
        )
        .unwrap();
        assert_eq!(plan.replicas, 2);
        assert_eq!(plan.devices.len(), 4);
        assert_eq!(plan.chains[0].len(), 2);
        assert_eq!(plan.chains[1].len(), 2);
        // Each replica's devices sit on its own channel group.
        let chans: Vec<usize> =
            plan.chains[1].iter().map(|&id| plan.devices[id].channel).collect();
        assert_eq!(chans, vec![2, 3]);
    }

    #[test]
    fn hybrid_validates_replica_count() {
        let mut g = DramGeometry::paper_default();
        g.channels = 2;
        for bad in [0usize, 3] {
            let err = lower(
                &pimnet(),
                &cfg(g.clone()),
                ShardPolicy::Hybrid { replicas: bad },
            )
            .unwrap_err();
            assert!(matches!(err, PlanError::BadHybrid { .. }), "{bad}");
        }
    }

    #[test]
    fn segment_overflow_detected() {
        let mut g = DramGeometry::paper_default();
        g.channels = 2;
        g.ranks_per_channel = 1;
        g.banks_per_rank = 4; // 4 banks per channel; vgg16 needs 8 per half
        let err = lower(&vgg16(), &cfg(g), ShardPolicy::LayerSplit).unwrap_err();
        assert!(matches!(err, PlanError::SegmentOverflow { .. }));
    }

    #[test]
    fn policy_parsing_round_trips() {
        for (s, p) in [
            ("replicate", ShardPolicy::Replicate),
            ("layersplit", ShardPolicy::LayerSplit),
            ("layer_split", ShardPolicy::LayerSplit),
            ("hybrid:3", ShardPolicy::Hybrid { replicas: 3 }),
        ] {
            assert_eq!(ShardPolicy::parse(s).unwrap(), p);
        }
        assert_eq!(ShardPolicy::parse("replicate").unwrap().to_string(), "replicate");
        assert_eq!(
            ShardPolicy::Hybrid { replicas: 2 }.to_string(),
            "hybrid:2"
        );
        assert!(ShardPolicy::parse("nope").is_err());
        assert!(ShardPolicy::parse("hybrid:x").is_err());
    }

    #[test]
    fn layout_into_reuses_allocations_across_calls() {
        let net = resnet18();
        let mut g2 = DramGeometry::paper_default();
        g2.channels = 2;
        let mapping = map_network(&net, &cfg(g2.clone())).unwrap();
        let weights: Vec<u64> =
            mapping.layers.iter().map(|m| m.rounds() as u64).collect();
        let banks = mapping.total_banks;

        let mut out = PlanLayout::default();
        layout_into(&net, &weights, banks, &g2, ShardPolicy::LayerSplit, &mut out)
            .unwrap();
        assert_eq!(out.replicas, 1);
        assert_eq!(out.chain(0).len(), 2);

        // Re-lowering in place must agree with a fresh layout exactly.
        layout_into(&net, &weights, banks, &g2, ShardPolicy::Replicate, &mut out)
            .unwrap();
        let fresh =
            layout(&net, &weights, banks, &g2, ShardPolicy::Replicate).unwrap();
        assert_eq!(out.devices, fresh.devices);
        assert_eq!(out.replicas, fresh.replicas);
        assert_eq!(out.chains_vec(), fresh.chains_vec());

        // A failed lowering leaves the layout reusable: the next call
        // resets it.
        let mut small = g2.clone();
        small.ranks_per_channel = 1;
        assert!(layout_into(
            &net,
            &weights,
            banks,
            &small,
            ShardPolicy::Replicate,
            &mut out
        )
        .is_err());
        layout_into(&net, &weights, banks, &g2, ShardPolicy::LayerSplit, &mut out)
            .unwrap();
        assert_eq!(out.replicas, 1);
        assert_eq!(
            out.devices.len(),
            out.chain(0).len(),
            "reset must drop stale devices"
        );
    }

    #[test]
    fn split_by_weight_properties() {
        crate::testutil::check(40, |rng| {
            let n = 1 + rng.below(24);
            let weights: Vec<u64> =
                (0..n).map(|_| 1 + rng.below(1000) as u64).collect();
            let segs = 1 + rng.below(8);
            let parts = split_by_weight(&weights, segs);
            crate::prop_assert!(parts.len() == segs.min(n).max(1));
            crate::prop_assert!(parts[0].start == 0);
            crate::prop_assert!(parts.last().unwrap().end == n);
            for w in parts.windows(2) {
                crate::prop_assert!(w[0].end == w[1].start);
                crate::prop_assert!(!w[0].is_empty() && !w[1].is_empty());
            }
            Ok(())
        });
    }
}
