//! End-to-end PIM-DRAM timing/energy simulation.
//!
//! Composes: Algorithm-1 mapping → plan lowering onto the channel × rank
//! grid (`crate::plan`) → in-subarray multiply cost (the paper's AAP
//! closed forms) → adder-tree / SFU cycle models → inter-bank RowClone
//! transfers → residual reserved banks → the layer-per-bank image
//! pipeline, per device, aggregated across replicas.
//!
//! [`simulate`] runs three stages:
//!   1. **plan** — [`crate::plan::lower`] shards the mapped network across
//!      the `channels × ranks_per_channel` grid under
//!      [`SimConfig::shard`].
//!   2. **price** — [`price_layers`] charges every layer's bank once (the
//!      template is identical in every replica), then each device of the
//!      chain gets its stage list: boundary layers swap their internal-bus
//!      transfer for the dearer inter-channel hop, residual reserves land
//!      with their `into_layer`'s device (cross-device shortcuts pay the
//!      hop premium too).
//!   3. **aggregate** — per-device `dataflow::schedule` reports combine:
//!      latency is the chain sum (hops included), the steady-state cycle
//!      is the slowest device (each channel owns its internal bus), and
//!      replicas multiply throughput — they never share a bus segment.
//!
//! Two stances, selected by [`SimConfig`] presets (DESIGN.md §7):
//!   * `paper_favorable(n)` — the assumptions under which the paper's
//!     Fig 16 numbers are reachable: operand expansion fully resident
//!     (`DramGeometry::paper_ideal`), per-subarray adder-tree taps, and
//!     row-wide inter-bank links. Reproduces the *shape* of Fig 16.
//!   * `conservative(n)` — a real DDR3-1600 die: 32 subarrays/bank, one
//!     tree per bank, 64-bit internal bus. Shows where the claim breaks
//!     (ablation_subarray bench, EXPERIMENTS.md discussion).

use crate::arch::adder_tree::AdderTree;
use crate::dataflow::transfer::transfer_rows;
use crate::dataflow::{residual_cost_ns, schedule, transfer_ns, PipelineReport, StageCost};
use crate::dram::{DramGeometry, DramTiming};
use crate::energy;
use crate::gpu::GpuModel;
use crate::mapping::{LayerMapping, MapConfig, NetworkMapping};
use crate::plan::{self, ExecutionPlan, PlanError, ShardPolicy};
use crate::primitives::{mul_aaps, CostModel};
use crate::util::ceil_div;
use crate::workloads::{LayerDesc, Network, Residual};

/// Full simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub geometry: DramGeometry,
    pub timing: DramTiming,
    /// Operand bit width n.
    pub n_bits: usize,
    /// Parallelism vector (broadcast if length 1) — the paper's P factor.
    pub ks: Vec<usize>,
    /// Adder-tree row-buffer width.
    pub adder_inputs: usize,
    pub cost_model: CostModel,
    /// One adder tree drains each subarray concurrently (paper-favorable)
    /// vs a single tree per bank (conservative).
    pub tree_per_subarray: bool,
    /// Adjacent banks have dedicated links so a stage's outbound RowClone
    /// overlaps other stages' compute (paper-favorable) vs one shared
    /// internal bus serializing all transfers (conservative).
    pub overlapped_transfers: bool,
    /// Model refresh interference (tREFI/tRFC) on the multiply stream —
    /// a real-DRAM cost the paper omits. None disables (paper stance).
    pub refresh: Option<crate::dram::RefreshParams>,
    /// How the network is sharded across the channel × rank grid.
    pub shard: ShardPolicy,
}

impl SimConfig {
    /// Real-DDR3 stance.
    pub fn conservative(n_bits: usize) -> Self {
        SimConfig {
            geometry: DramGeometry::paper_default(),
            timing: DramTiming::ddr3_1600(),
            n_bits,
            ks: vec![1],
            adder_inputs: AdderTree::PAPER_INPUTS,
            cost_model: CostModel::Paper,
            tree_per_subarray: false,
            overlapped_transfers: false,
            refresh: Some(crate::dram::RefreshParams::ddr3_1600()),
            shard: ShardPolicy::Replicate,
        }
    }

    /// The assumptions that make the paper's headline reachable.
    pub fn paper_favorable(n_bits: usize) -> Self {
        let geometry = DramGeometry::paper_ideal();
        let mut timing = DramTiming::ddr3_1600();
        timing.internal_bus_bits = geometry.cols; // row-wide links
        SimConfig {
            geometry,
            timing,
            n_bits,
            ks: vec![1],
            adder_inputs: AdderTree::PAPER_INPUTS,
            cost_model: CostModel::Paper,
            tree_per_subarray: true,
            overlapped_transfers: true,
            refresh: None, // the paper never accounts for refresh
            shard: ShardPolicy::Replicate,
        }
    }

    pub fn with_ks(mut self, ks: Vec<usize>) -> Self {
        self.ks = ks;
        self
    }

    pub fn with_shard(mut self, shard: ShardPolicy) -> Self {
        self.shard = shard;
        self
    }

    /// Resize the device grid (scale-out knob).
    pub fn with_grid(mut self, channels: usize, ranks_per_channel: usize) -> Self {
        self.geometry.channels = channels;
        self.geometry.ranks_per_channel = ranks_per_channel;
        self
    }

    /// Requested parallelism for `layer_idx` (`ks` broadcast if a single
    /// value) — the same convention as `MapConfig::k_for`.
    pub fn k_for(&self, layer_idx: usize) -> usize {
        if self.ks.len() == 1 {
            self.ks[0]
        } else {
            self.ks[layer_idx]
        }
    }

    fn map_config(&self) -> MapConfig {
        MapConfig {
            geometry: self.geometry.clone(),
            n_bits: self.n_bits,
            ks: self.ks.clone(),
        }
    }
}

/// Per-layer simulation breakdown.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub name: String,
    pub mapping: LayerMapping,
    /// In-subarray multiply time (all subarrays in parallel; rounds serial).
    pub multiply_ns: f64,
    /// Adder tree + SFU + transpose drain time.
    pub logic_ns: f64,
    /// Operand re-staging time (waves / stack overflow).
    pub restage_ns: f64,
    /// Residual-edge time attributed to this layer (reserved bank).
    pub residual_ns: f64,
    /// Outbound activation transfer.
    pub transfer_ns: f64,
    /// Total AAP-class DRAM commands issued by this bank per image.
    pub aaps: u64,
    /// DRAM energy (nJ) per image for this bank.
    pub dram_energy_nj: f64,
}

impl LayerSim {
    pub fn compute_ns(&self) -> f64 {
        self.multiply_ns + self.logic_ns + self.restage_ns + self.residual_ns
    }

    pub fn stage_ns(&self) -> f64 {
        self.compute_ns() + self.transfer_ns
    }
}

/// One device's priced pipeline segment (the **price** stage output).
#[derive(Debug, Clone)]
pub struct DeviceSim {
    /// Device id within the execution plan.
    pub device: usize,
    pub channel: usize,
    /// Pipeline report over this device's own internal bus. Its stages
    /// are this device's layer slice (boundary transfer already swapped
    /// for the inter-channel hop) plus its residual reserves.
    pub pipeline: PipelineReport,
    /// Outbound inter-channel hop to the next device (0 for the tail).
    pub hop_ns: f64,
}

/// The **aggregate** stage output: how the plan performs as a fleet.
#[derive(Debug, Clone)]
pub struct ScaleOutReport {
    pub policy: ShardPolicy,
    /// Independent full-network pipelines.
    pub replicas: usize,
    /// Replica 0's priced chain (all replicas are identical).
    pub devices: Vec<DeviceSim>,
    /// Per-image inter-channel transfer time across the chain (ns).
    pub hop_ns_total: f64,
}

impl ScaleOutReport {
    /// Devices across all replicas.
    pub fn devices_total(&self) -> usize {
        self.replicas * self.devices.len()
    }
}

/// Whole-network result.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub net_name: String,
    pub n_bits: usize,
    pub layers: Vec<LayerSim>,
    /// One replica's pipeline: every layer stage plus the residual
    /// reserves, latency summed over the device chain (hops included),
    /// cycle set by the slowest device.
    pub pipeline: PipelineReport,
    pub total_aaps: u64,
    pub total_dram_energy_nj: f64,
    /// Peripheral logic energy (nJ) per image (power × busy time).
    pub logic_energy_nj: f64,
    /// The lowered device plan this result priced.
    pub plan: ExecutionPlan,
    pub scale_out: ScaleOutReport,
}

impl SimResult {
    /// Per-image latency (pipeline fill, inter-channel hops included) in ns.
    pub fn latency_ns(&self) -> f64 {
        self.pipeline.latency_ns
    }

    /// Aggregate steady-state throughput (images/s): replicas serve
    /// disjoint request streams, so the plan multiplies the per-replica
    /// rate.
    pub fn throughput_ips(&self) -> f64 {
        self.scale_out.replicas as f64 * self.pipeline.throughput_ips()
    }

    /// Steady-state throughput of a single replica (images/s).
    pub fn replica_throughput_ips(&self) -> f64 {
        self.pipeline.throughput_ips()
    }

    /// Replicas in the plan.
    pub fn replicas(&self) -> usize {
        self.scale_out.replicas
    }

    /// Fig 16 metric: single-module speedup over the ideal GPU — the
    /// GPU's per-image time divided by one replica's steady-state
    /// initiation interval. `gpu_bytes_per_elem` sets the GPU baseline's
    /// operand width (4 = the paper's fp32 comparison); it was a buried
    /// constant before.
    pub fn speedup_vs(&self, gpu: &GpuModel, net: &Network, gpu_bytes_per_elem: usize) -> f64 {
        let gpu_s = gpu.network_time_s(net, gpu_bytes_per_elem);
        gpu_s / (self.pipeline.cycle_ns * 1e-9)
    }
}

/// Shared sub-expressions of per-layer pricing, hoisted out of the layer
/// loop. Building one per pricing run (rather than per layer) keeps the
/// arithmetic identical between `price_layers` and the incremental
/// session's per-layer cache fills.
pub(crate) struct PriceCtx {
    tree: AdderTree,
    aap_ns: f64,
    logic_cycle: f64,
    planes: u64,
    mul_cost: u64,
}

impl PriceCtx {
    pub(crate) fn new(cfg: &SimConfig) -> Self {
        PriceCtx {
            tree: AdderTree::new(cfg.adder_inputs),
            aap_ns: cfg.timing.aap_ns(),
            logic_cycle: energy::logic_cycle_ns(),
            planes: 2 * cfg.n_bits as u64,
            mul_cost: mul_aaps(cfg.cost_model, cfg.n_bits as u64),
        }
    }
}

/// Price one layer's bank for one image (the unit the session caches).
pub(crate) fn price_layer(
    layer: &LayerDesc,
    m: &LayerMapping,
    cfg: &SimConfig,
    ctx: &PriceCtx,
) -> LayerSim {
    price_layer_owned(layer, m.clone(), cfg, ctx)
}

/// [`price_layer`] taking ownership of the mapping — the session's miss
/// path, which builds a fresh `LayerMapping` per cache fill and would
/// otherwise clone it only to drop the original.
pub(crate) fn price_layer_owned(
    layer: &LayerDesc,
    m: LayerMapping,
    cfg: &SimConfig,
    ctx: &PriceCtx,
) -> LayerSim {
    let n = cfg.n_bits;
    let rounds = m.rounds() as f64;
    let mut multiply_ns = rounds * ctx.mul_cost as f64 * ctx.aap_ns;
    if let Some(refresh) = &cfg.refresh {
        multiply_ns = refresh.stretch_ns(multiply_ns);
    }

    // Tree drain: every used subarray's row buffer is streamed through
    // a tree once per product bit-plane, per round.
    let trees = if cfg.tree_per_subarray { m.subarrays_used.max(1) } else { 1 };
    let passes_per_plane = ceil_div(cfg.geometry.cols, cfg.adder_inputs)
        * ceil_div(m.subarrays_used.max(1), trees);
    let passes_per_round = passes_per_plane as u64 * ctx.planes;
    let drain = ctx.tree.levels() as u64 + 8; // SFU + transpose pipeline drain
    let logic_cycles = rounds as u64 * (ctx.tree.cycles(passes_per_round as usize) + drain);
    let logic_ns = logic_cycles as f64 * ctx.logic_cycle;

    // Re-staging: each extra wave / overflowed stack round rewrites the
    // active subarrays' operand rows over the internal bus.
    let restage_events = (m.waves - 1) + m.restaged_rounds;
    let rows_per_subarray = 2 * n;
    let restage_ns = if m.tile > 0 {
        // Tiled staging (search mapper only): tile j+1 streams in over
        // the otherwise-idle internal bus while tile j multiplies, so a
        // re-staging event exposes only the first tile's rows. Sequential
        // tiles additionally pay the crossing row activations counted by
        // the tile-crossing analysis at mapping time.
        let exposed = m.tile_subarrays.max(1).min(m.subarrays_used.max(1));
        restage_events as f64
            * exposed as f64
            * rows_per_subarray as f64
            * cfg.timing.interbank_copy_ns(cfg.geometry.cols)
            + m.extra_row_acts as f64 * ctx.aap_ns
    } else {
        restage_events as f64
            * m.subarrays_used as f64
            * rows_per_subarray as f64
            * cfg.timing.interbank_copy_ns(cfg.geometry.cols)
    };

    // Residual edges execute in their own reserved banks (Fig 13) —
    // they become separate pipeline stages below; nothing lands here.
    let residual_ns = 0.0;

    let transfer = transfer_ns(
        layer.out_elems(),
        n,
        cfg.geometry.cols,
        &cfg.timing,
    );

    let mut aaps = m.rounds() as u64 * ctx.mul_cost * m.subarrays_used as u64;
    let mut dram_energy_nj = aaps as f64
        * (cfg.timing.act_pre_energy_nj + cfg.timing.multi_act_energy(3))
        + crate::dataflow::transfer::transfer_bits(
            layer.out_elems(),
            n,
            cfg.geometry.cols,
        ) as f64
            * cfg.timing.bus_energy_pj_per_bit
            / 1000.0;
    if m.extra_row_acts > 0 {
        // Crossing activations are plain ACT/PRE pairs, not triple-row
        // AAP multiplies (search mapper only; 0 on the paper path).
        aaps += m.extra_row_acts;
        dram_energy_nj += m.extra_row_acts as f64 * cfg.timing.act_pre_energy_nj;
    }

    LayerSim {
        name: layer.name.clone(),
        mapping: m,
        multiply_ns,
        logic_ns,
        restage_ns,
        residual_ns,
        transfer_ns: transfer,
        aaps,
        dram_energy_nj,
    }
}

/// **Price** stage, part 1: charge every layer's bank for one image. The
/// result is a template shared by all replicas — a layer's in-bank cost
/// depends only on bank-internal geometry, never on which grid slot the
/// bank sits in.
pub fn price_layers(net: &Network, mapping: &NetworkMapping, cfg: &SimConfig) -> Vec<LayerSim> {
    let ctx = PriceCtx::new(cfg);
    net.layers
        .iter()
        .zip(&mapping.layers)
        .map(|(layer, m)| price_layer(layer, m, cfg, &ctx))
        .collect()
}

/// Monotone lower bound on `stage_ns` for **any** search candidate of
/// this layer at the mapping's parallelism: the refresh-stretched
/// multiply term plus the outbound transfer, computed with the exact
/// arithmetic of [`price_layer_owned`]. Soundness (DESIGN.md §Mapping
/// optimizer): pass the *untiled* mapping at k — sequential tiling never
/// changes its round count and row-aligned tiling only pads the wave
/// count upward, and every other stage-cost term is nonnegative, so
/// pruning a k-branch whose bound already exceeds the best exact price
/// cannot discard the optimum.
pub(crate) fn stage_lower_bound_ns(
    layer: &LayerDesc,
    m: &LayerMapping,
    cfg: &SimConfig,
    ctx: &PriceCtx,
) -> f64 {
    let mut multiply_ns = m.rounds() as f64 * ctx.mul_cost as f64 * ctx.aap_ns;
    if let Some(refresh) = &cfg.refresh {
        multiply_ns = refresh.stretch_ns(multiply_ns);
    }
    multiply_ns + transfer_ns(layer.out_elems(), cfg.n_bits, cfg.geometry.cols, &cfg.timing)
}

/// Inter-channel hop time for `values` n-bit activations.
pub(crate) fn hop_ns_for(values: usize, cfg: &SimConfig) -> f64 {
    transfer_rows(values, cfg.n_bits, cfg.geometry.cols) as f64
        * cfg.timing.interchannel_copy_ns(cfg.geometry.cols)
}

/// Residual reserved-bank cost (Fig 13) as `(compute_ns, transfer_ns)`.
/// The shortcut/result copies are its transfers; the in-DRAM add its
/// compute. A shortcut arriving from a device on another channel pays the
/// hop premium on its copy-in.
pub(crate) fn residual_cost(
    net: &Network,
    r: &Residual,
    cfg: &SimConfig,
    cross_device: bool,
) -> (f64, f64) {
    let n = cfg.n_bits;
    let elems = net.layers[r.into_layer].out_elems();
    let copy = transfer_ns(elems, n, cfg.geometry.cols, &cfg.timing);
    let total = residual_cost_ns(elems, n, cfg.geometry.cols, &cfg.timing);
    let mut transfer = 3.0 * copy;
    if cross_device {
        let rows = transfer_rows(elems, n, cfg.geometry.cols) as f64;
        transfer += rows
            * (cfg.timing.interchannel_copy_ns(cfg.geometry.cols)
                - cfg.timing.interbank_copy_ns(cfg.geometry.cols));
    }
    (total - 3.0 * copy, transfer)
}

/// Residual reserved-bank stage (Fig 13), named for the report.
fn residual_stage(net: &Network, r: &Residual, cfg: &SimConfig, cross_device: bool) -> StageCost {
    let (compute_ns, transfer_ns) = residual_cost(net, r, cfg, cross_device);
    StageCost {
        name: format!("res:{}", net.layers[r.into_layer].name),
        compute_ns,
        transfer_ns,
    }
}

/// **Price** stage, part 2: one device's stage list and pipeline report.
fn price_device(
    net: &Network,
    plan: &ExecutionPlan,
    layers: &[LayerSim],
    device_id: usize,
    is_chain_tail: bool,
    cfg: &SimConfig,
) -> DeviceSim {
    let d = &plan.devices[device_id];
    let mut stages: Vec<StageCost> =
        Vec::with_capacity(d.shard.layers.len() + d.shard.residuals.len());
    stages.extend(d.shard.layers.clone().map(|i| StageCost {
        name: layers[i].name.clone(),
        compute_ns: layers[i].compute_ns(),
        transfer_ns: layers[i].transfer_ns,
    }));

    // The boundary layer's activations leave the module over the channel
    // interface instead of the internal bus.
    let hop_ns = if is_chain_tail {
        0.0
    } else {
        let boundary = d.shard.layers.end - 1;
        let hop = hop_ns_for(net.layers[boundary].out_elems(), cfg);
        if let Some(last) = stages.last_mut() {
            last.transfer_ns = hop;
        }
        hop
    };

    for &ri in &d.shard.residuals {
        let r = &net.residuals[ri];
        let cross = plan.device_hosting(d.replica, r.from_layer) != Some(device_id);
        stages.push(residual_stage(net, r, cfg, cross));
    }

    // The pipeline report owns the stage list — no defensive copy.
    let pipeline = schedule(stages, cfg.overlapped_transfers);
    DeviceSim { device: device_id, channel: d.channel, pipeline, hop_ns }
}

/// **Aggregate** stage: combine a chain of device pipelines into one
/// replica-level report. Latency is the chain sum (each device's fill,
/// hops included in boundary transfers); the steady-state cycle is the
/// slowest device — every channel drives its own internal bus, and hop
/// links are dedicated per channel pair.
fn combine_chain(devices: &[DeviceSim]) -> PipelineReport {
    let total: usize = devices.iter().map(|d| d.pipeline.stages.len()).sum();
    let mut stages: Vec<StageCost> = Vec::with_capacity(total);
    for d in devices {
        stages.extend_from_slice(&d.pipeline.stages);
    }
    let latency_ns = devices.iter().map(|d| d.pipeline.latency_ns).sum();
    let cycle_ns = devices
        .iter()
        .map(|d| d.pipeline.cycle_ns)
        .fold(f64::NEG_INFINITY, f64::max);
    let bottleneck = stages
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.compute_ns.partial_cmp(&b.1.compute_ns).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    PipelineReport { stages, latency_ns, cycle_ns, bottleneck }
}

/// Simulate one network under `cfg`: plan → price → aggregate.
pub fn simulate(net: &Network, cfg: &SimConfig) -> Result<SimResult, PlanError> {
    // Plan: lower the mapping onto the channel × rank grid.
    let plan = plan::lower(net, &cfg.map_config(), cfg.shard)?;

    // Price: per-layer template (identical in every replica).
    let layers = price_layers(net, &plan.mapping, cfg);
    Ok(finish_simulation(net, cfg, plan, layers))
}

/// **Price** part 2 + **aggregate**: turn a lowered plan and a priced
/// layer template into the full result. Shared verbatim by [`simulate`]
/// and the incremental session so their reports stay bitwise identical.
pub(crate) fn finish_simulation(
    net: &Network,
    cfg: &SimConfig,
    plan: ExecutionPlan,
    layers: Vec<LayerSim>,
) -> SimResult {
    // Price replica 0's device chain (replicas are identical by
    // construction). Long layer-split chains fan out across cores —
    // device pricing is independent per device and `par_sweep` preserves
    // index order, so the output is identical either way. Short chains
    // (the common case) stay sequential: thread spawn costs more than the
    // pricing itself.
    const PAR_CHAIN_MIN_DEVICES: usize = 8;
    let chain = plan.chain(0);
    let price_one = |pos: usize| {
        price_device(net, &plan, &layers, chain[pos], pos + 1 == chain.len(), cfg)
    };
    let devices: Vec<DeviceSim> = if chain.len() >= PAR_CHAIN_MIN_DEVICES {
        crate::bench_harness::par_sweep(chain.len(), price_one)
    } else {
        (0..chain.len()).map(price_one).collect()
    };

    // Aggregate.
    let pipeline = combine_chain(&devices);
    let hop_ns_total = devices.iter().map(|d| d.hop_ns).sum();

    let total_aaps = layers.iter().map(|l| l.aaps).sum();
    let total_dram_energy_nj: f64 = layers.iter().map(|l| l.dram_energy_nj).sum();
    let bank_power_nw: f64 = energy::bank_components(cfg.adder_inputs)
        .iter()
        .map(|c| c.power_nw)
        .sum();
    let logic_busy_s: f64 = layers.iter().map(|l| l.logic_ns).sum::<f64>() * 1e-9;
    let logic_energy_nj = bank_power_nw * logic_busy_s; // nW × s = nJ

    let scale_out = ScaleOutReport {
        policy: cfg.shard,
        replicas: plan.replicas,
        devices,
        hop_ns_total,
    };

    SimResult {
        net_name: net.name.clone(),
        n_bits: cfg.n_bits,
        layers,
        pipeline,
        total_aaps,
        total_dram_energy_nj,
        logic_energy_nj,
        plan,
        scale_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::{alexnet, pimnet, resnet18, vgg16};

    #[test]
    fn pimnet_simulates_on_conservative() {
        let r = simulate(&pimnet(), &SimConfig::conservative(8)).unwrap();
        assert_eq!(r.layers.len(), 4);
        assert!(r.latency_ns() > 0.0);
        assert!(r.throughput_ips() > 0.0);
        assert!(r.total_aaps > 0);
    }

    #[test]
    fn all_networks_simulate_on_both_presets() {
        for net in [alexnet(), vgg16(), resnet18(), pimnet()] {
            for cfg in [SimConfig::conservative(8), SimConfig::paper_favorable(8)] {
                let r = simulate(&net, &cfg)
                    .unwrap_or_else(|e| panic!("{}: {e}", net.name));
                assert!(r.latency_ns().is_finite() && r.latency_ns() > 0.0);
            }
        }
    }

    #[test]
    fn paper_favorable_is_faster_than_conservative() {
        let net = vgg16();
        let fav = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        let con = simulate(&net, &SimConfig::conservative(8)).unwrap();
        assert!(
            fav.pipeline.cycle_ns < con.pipeline.cycle_ns,
            "favorable {} vs conservative {}",
            fav.pipeline.cycle_ns,
            con.pipeline.cycle_ns
        );
    }

    #[test]
    fn paper_favorable_beats_gpu_shape() {
        // The reproduction target: PIM wins over the ideal GPU under the
        // paper's assumptions (exact factor depends on bit width).
        let gpu = GpuModel::titan_xp();
        for net in [alexnet(), vgg16(), resnet18()] {
            let r = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
            let s = r.speedup_vs(&gpu, &net, 4);
            assert!(s > 1.0, "{}: speedup {s}", net.name);
        }
    }

    #[test]
    fn speedup_scales_with_gpu_operand_width() {
        // The (formerly buried) GPU operand width moves the baseline: a
        // wider element costs the GPU more bytes, so PIM's ratio grows.
        let gpu = GpuModel::titan_xp();
        let net = vgg16();
        let r = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        assert!(r.speedup_vs(&gpu, &net, 8) > r.speedup_vs(&gpu, &net, 4));
    }

    #[test]
    fn higher_k_lowers_throughput() {
        // Fig 16's parallelism knob: k folds groups → more serial rounds.
        let net = alexnet();
        let r1 = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        let r4 = simulate(
            &net,
            &SimConfig::paper_favorable(8).with_ks(vec![4]),
        )
        .unwrap();
        assert!(r4.pipeline.cycle_ns > r1.pipeline.cycle_ns);
    }

    #[test]
    fn precision_sweep_monotone() {
        // Fig 17's shape: multiply rounds grow ~cubically with n.
        let net = alexnet();
        let mut prev = 0.0;
        for n in [2, 4, 8, 16] {
            let r = simulate(&net, &SimConfig::paper_favorable(n)).unwrap();
            let mult: f64 = r.layers.iter().map(|l| l.multiply_ns).sum();
            assert!(mult > prev, "n={n}");
            prev = mult;
        }
    }

    #[test]
    fn residual_edges_become_reserved_bank_stages() {
        let net = resnet18();
        let r = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        assert_eq!(
            r.pipeline.stages.len(),
            net.layers.len() + net.residuals.len()
        );
        let res_stages: Vec<_> = r
            .pipeline
            .stages
            .iter()
            .filter(|s| s.name.starts_with("res:"))
            .collect();
        assert_eq!(res_stages.len(), 8);
        for s in res_stages {
            assert!(s.compute_ns > 0.0 && s.transfer_ns > 0.0, "{}", s.name);
        }
    }

    #[test]
    fn conservative_vgg_pays_restaging() {
        let r = simulate(&vgg16(), &SimConfig::conservative(8)).unwrap();
        let restage: f64 = r.layers.iter().map(|l| l.restage_ns).sum();
        assert!(restage > 0.0, "real capacity must force restaging");
    }

    #[test]
    fn refresh_stretches_conservative_multiplies() {
        let net = pimnet();
        let mut no_ref = SimConfig::conservative(8);
        no_ref.refresh = None;
        let with_ref = SimConfig::conservative(8);
        let a = simulate(&net, &no_ref).unwrap();
        let b = simulate(&net, &with_ref).unwrap();
        let ma: f64 = a.layers.iter().map(|l| l.multiply_ns).sum();
        let mb: f64 = b.layers.iter().map(|l| l.multiply_ns).sum();
        assert!(mb > ma, "refresh must add time");
        assert!(mb < ma * 1.05, "refresh duty is ~2%");
    }

    #[test]
    fn optimizer_plan_feeds_simulator() {
        use crate::mapping::optimizer::{plan_ks, Objective};
        let net = pimnet();
        let cfg0 = SimConfig::conservative(8);
        let plan = plan_ks(&net, &cfg0.geometry, 8, Objective::MinResidentK);
        let planned = simulate(&net, &cfg0.clone().with_ks(plan.ks)).unwrap();
        // The plan removes all waves/restaging.
        assert!(planned.layers.iter().all(|l| l.mapping.fully_resident()));
        // And should not be slower than the naive k=1 map.
        let naive = simulate(&net, &cfg0).unwrap();
        assert!(planned.pipeline.cycle_ns <= naive.pipeline.cycle_ns * 1.01);
    }

    #[test]
    fn energy_totals_positive_and_decomposed() {
        let r = simulate(&pimnet(), &SimConfig::paper_favorable(8)).unwrap();
        assert!(r.total_dram_energy_nj > 0.0);
        assert!(r.logic_energy_nj > 0.0);
    }

    // ---- plan → price → aggregate (scale-out) ---------------------------

    #[test]
    fn replicate_reports_aggregate_throughput() {
        // pimnet needs 1 rank; the default 1-channel × 4-rank grid packs 4
        // replicas whose aggregate rate is exactly 4× one replica's.
        let r = simulate(&pimnet(), &SimConfig::conservative(8)).unwrap();
        assert_eq!(r.replicas(), 4);
        let per = r.replica_throughput_ips();
        assert!((r.throughput_ips() - 4.0 * per).abs() < 1e-6 * per);

        // A grid with exactly one slot is the single-module baseline: the
        // same per-replica cycle, a quarter of the aggregate.
        let single = simulate(
            &pimnet(),
            &SimConfig::conservative(8).with_grid(1, 1),
        )
        .unwrap();
        assert_eq!(single.replicas(), 1);
        assert!((single.pipeline.cycle_ns - r.pipeline.cycle_ns).abs() < 1e-9);
        assert!((r.throughput_ips() / single.throughput_ips() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn replicate_scales_linearly_with_channels() {
        let base = simulate(&resnet18(), &SimConfig::conservative(8)).unwrap();
        assert_eq!(base.replicas(), 1); // 26 banks fill all 4 ranks
        for channels in [2usize, 4, 8] {
            let r = simulate(
                &resnet18(),
                &SimConfig::conservative(8).with_grid(channels, 4),
            )
            .unwrap();
            assert_eq!(r.replicas(), channels);
            assert!((r.pipeline.cycle_ns - base.pipeline.cycle_ns).abs() < 1e-9);
            let ratio = r.throughput_ips() / base.throughput_ips();
            assert!(
                (ratio - channels as f64).abs() < 1e-9 * channels as f64,
                "channels={channels}: ratio {ratio}"
            );
        }
    }

    #[test]
    fn layer_split_pays_interchannel_hops_on_latency() {
        // Same total banks: 1 ch × 4 ranks (single module) vs 2 ch × 2
        // ranks split. Per-layer costs are identical; the split swaps one
        // internal-bus transfer for a channel hop, so fill latency is
        // strictly higher while no stage disappears.
        let net = vgg16();
        let single = simulate(
            &net,
            &SimConfig::conservative(8).with_grid(1, 4),
        )
        .unwrap();
        let split = simulate(
            &net,
            &SimConfig::conservative(8)
                .with_grid(2, 2)
                .with_shard(ShardPolicy::LayerSplit),
        )
        .unwrap();
        assert_eq!(split.replicas(), 1);
        assert_eq!(split.scale_out.devices.len(), 2);
        assert!(split.scale_out.hop_ns_total > 0.0);
        assert_eq!(split.pipeline.stages.len(), single.pipeline.stages.len());
        assert!(
            split.latency_ns() > single.latency_ns(),
            "split {} must exceed single {}",
            split.latency_ns(),
            single.latency_ns()
        );
        // The entire latency difference is priced inter-channel transfer:
        // hop minus the internal-bus transfer it replaced.
        let boundary = split.plan.devices[split.scale_out.devices[0].device]
            .shard
            .layers
            .end
            - 1;
        let replaced = single.layers[boundary].transfer_ns;
        let expect = split.scale_out.hop_ns_total - replaced;
        let got = split.latency_ns() - single.latency_ns();
        assert!(
            (got - expect).abs() < 1e-6 * expect.max(1.0),
            "latency delta {got} vs priced hop delta {expect}"
        );
    }

    #[test]
    fn layer_split_relieves_the_shared_bus() {
        // Conservative stance serializes every transfer on one internal
        // bus; splitting across channels halves each bus's traffic, so
        // the steady-state cycle cannot get worse by much and usually
        // improves. (Latency is the price — see the previous test.)
        let net = vgg16();
        let single = simulate(&net, &SimConfig::conservative(8).with_grid(1, 4)).unwrap();
        let split = simulate(
            &net,
            &SimConfig::conservative(8)
                .with_grid(2, 2)
                .with_shard(ShardPolicy::LayerSplit),
        )
        .unwrap();
        assert!(split.pipeline.cycle_ns <= single.pipeline.cycle_ns * 1.001);
    }

    #[test]
    fn hybrid_multiplies_split_pipelines() {
        let net = alexnet();
        let split2 = SimConfig::conservative(8)
            .with_grid(4, 4)
            .with_shard(ShardPolicy::Hybrid { replicas: 2 });
        let r = simulate(&net, &split2).unwrap();
        assert_eq!(r.replicas(), 2);
        assert_eq!(r.scale_out.devices.len(), 2);
        assert_eq!(r.scale_out.devices_total(), 4);
        assert!(
            (r.throughput_ips() - 2.0 * r.replica_throughput_ips()).abs()
                < 1e-9 * r.throughput_ips()
        );
    }

    #[test]
    fn long_split_chains_price_in_parallel_identically() {
        // An 8-device layer-split chain crosses finish_simulation's
        // parallel-pricing threshold; the session's scalar fold is
        // strictly sequential, so bitwise agreement proves the fan-out
        // changes nothing about the numbers.
        let net = vgg16();
        let cfg = SimConfig::conservative(8)
            .with_grid(8, 4)
            .with_shard(ShardPolicy::LayerSplit);
        let fresh = simulate(&net, &cfg).unwrap();
        assert_eq!(fresh.scale_out.devices.len(), 8);
        let mut session = crate::sim::SimSession::new(&net);
        let rep = session.report(&cfg).unwrap();
        assert_eq!(rep.latency_ns.to_bits(), fresh.pipeline.latency_ns.to_bits());
        assert_eq!(rep.cycle_ns.to_bits(), fresh.pipeline.cycle_ns.to_bits());
        assert_eq!(rep.bottleneck, fresh.pipeline.bottleneck);
        assert_eq!(rep.hop_ns_total.to_bits(), fresh.scale_out.hop_ns_total.to_bits());
    }

    #[test]
    fn residual_crossing_devices_pays_hop_premium() {
        // resnet18 split over 2 channels: at least one shortcut edge spans
        // the boundary, so the residual-stage transfer total must exceed
        // the single-module pricing of the same stages.
        let net = resnet18();
        let single = simulate(&net, &SimConfig::conservative(8).with_grid(1, 4)).unwrap();
        let split = simulate(
            &net,
            &SimConfig::conservative(8)
                .with_grid(2, 4)
                .with_shard(ShardPolicy::LayerSplit),
        )
        .unwrap();
        let res_transfer = |r: &SimResult| -> f64 {
            r.pipeline
                .stages
                .iter()
                .filter(|s| s.name.starts_with("res:"))
                .map(|s| s.transfer_ns)
                .sum()
        };
        let a = res_transfer(&single);
        let b = res_transfer(&split);
        let crosses = net
            .residuals
            .iter()
            .any(|e| {
                split.plan.device_hosting(0, e.from_layer)
                    != split.plan.device_hosting(0, e.into_layer)
            });
        if crosses {
            assert!(b > a, "cross-device shortcut must cost extra: {b} vs {a}");
        } else {
            assert!((b - a).abs() < 1e-9);
        }
    }
}
