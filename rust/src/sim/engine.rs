//! End-to-end PIM-DRAM timing/energy simulation.
//!
//! Composes: Algorithm-1 mapping → in-subarray multiply cost (the paper's
//! AAP closed forms) → adder-tree / SFU cycle models → inter-bank RowClone
//! transfers → residual reserved banks → the layer-per-bank image pipeline.
//!
//! Two stances, selected by [`SimConfig`] presets (DESIGN.md §7):
//!   * `paper_favorable(n)` — the assumptions under which the paper's
//!     Fig 16 numbers are reachable: operand expansion fully resident
//!     (`DramGeometry::paper_ideal`), per-subarray adder-tree taps, and
//!     row-wide inter-bank links. Reproduces the *shape* of Fig 16.
//!   * `conservative(n)` — a real DDR3-1600 die: 32 subarrays/bank, one
//!     tree per bank, 64-bit internal bus. Shows where the claim breaks
//!     (ablation_subarray bench, EXPERIMENTS.md discussion).

use crate::arch::adder_tree::AdderTree;
use crate::dataflow::{residual_cost_ns, schedule, transfer_ns, PipelineReport, StageCost};
use crate::dram::{DramGeometry, DramTiming};
use crate::energy;
use crate::gpu::GpuModel;
use crate::mapping::{map_network, LayerMapping, MapConfig, MapError};
use crate::primitives::{mul_aaps, CostModel};
use crate::util::ceil_div;
use crate::workloads::Network;

/// Full simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub geometry: DramGeometry,
    pub timing: DramTiming,
    /// Operand bit width n.
    pub n_bits: usize,
    /// Parallelism vector (broadcast if length 1) — the paper's P factor.
    pub ks: Vec<usize>,
    /// Adder-tree row-buffer width.
    pub adder_inputs: usize,
    pub cost_model: CostModel,
    /// One adder tree drains each subarray concurrently (paper-favorable)
    /// vs a single tree per bank (conservative).
    pub tree_per_subarray: bool,
    /// Adjacent banks have dedicated links so a stage's outbound RowClone
    /// overlaps other stages' compute (paper-favorable) vs one shared
    /// internal bus serializing all transfers (conservative).
    pub overlapped_transfers: bool,
    /// Model refresh interference (tREFI/tRFC) on the multiply stream —
    /// a real-DRAM cost the paper omits. None disables (paper stance).
    pub refresh: Option<crate::dram::RefreshParams>,
}

impl SimConfig {
    /// Real-DDR3 stance.
    pub fn conservative(n_bits: usize) -> Self {
        SimConfig {
            geometry: DramGeometry::paper_default(),
            timing: DramTiming::ddr3_1600(),
            n_bits,
            ks: vec![1],
            adder_inputs: AdderTree::PAPER_INPUTS,
            cost_model: CostModel::Paper,
            tree_per_subarray: false,
            overlapped_transfers: false,
            refresh: Some(crate::dram::RefreshParams::ddr3_1600()),
        }
    }

    /// The assumptions that make the paper's headline reachable.
    pub fn paper_favorable(n_bits: usize) -> Self {
        let geometry = DramGeometry::paper_ideal();
        let mut timing = DramTiming::ddr3_1600();
        timing.internal_bus_bits = geometry.cols; // row-wide links
        SimConfig {
            geometry,
            timing,
            n_bits,
            ks: vec![1],
            adder_inputs: AdderTree::PAPER_INPUTS,
            cost_model: CostModel::Paper,
            tree_per_subarray: true,
            overlapped_transfers: true,
            refresh: None, // the paper never accounts for refresh
        }
    }

    pub fn with_ks(mut self, ks: Vec<usize>) -> Self {
        self.ks = ks;
        self
    }

    fn map_config(&self) -> MapConfig {
        MapConfig {
            geometry: self.geometry.clone(),
            n_bits: self.n_bits,
            ks: self.ks.clone(),
        }
    }
}

/// Per-layer simulation breakdown.
#[derive(Debug, Clone)]
pub struct LayerSim {
    pub name: String,
    pub mapping: LayerMapping,
    /// In-subarray multiply time (all subarrays in parallel; rounds serial).
    pub multiply_ns: f64,
    /// Adder tree + SFU + transpose drain time.
    pub logic_ns: f64,
    /// Operand re-staging time (waves / stack overflow).
    pub restage_ns: f64,
    /// Residual-edge time attributed to this layer (reserved bank).
    pub residual_ns: f64,
    /// Outbound activation transfer.
    pub transfer_ns: f64,
    /// Total AAP-class DRAM commands issued by this bank per image.
    pub aaps: u64,
    /// DRAM energy (nJ) per image for this bank.
    pub dram_energy_nj: f64,
}

impl LayerSim {
    pub fn compute_ns(&self) -> f64 {
        self.multiply_ns + self.logic_ns + self.restage_ns + self.residual_ns
    }

    pub fn stage_ns(&self) -> f64 {
        self.compute_ns() + self.transfer_ns
    }
}

/// Whole-network result.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub net_name: String,
    pub n_bits: usize,
    pub layers: Vec<LayerSim>,
    pub pipeline: PipelineReport,
    pub total_aaps: u64,
    pub total_dram_energy_nj: f64,
    /// Peripheral logic energy (nJ) per image (power × busy time).
    pub logic_energy_nj: f64,
}

impl SimResult {
    /// Per-image latency (pipeline fill) in ns.
    pub fn latency_ns(&self) -> f64 {
        self.pipeline.latency_ns
    }

    /// Steady-state throughput (images/s).
    pub fn throughput_ips(&self) -> f64 {
        self.pipeline.throughput_ips()
    }

    /// Fig 16 metric: speedup over the ideal GPU at matched batch — the
    /// GPU's per-image time divided by the PIM pipeline's steady-state
    /// initiation interval.
    pub fn speedup_vs(&self, gpu: &GpuModel, net: &Network) -> f64 {
        let gpu_s = gpu.network_time_s(net, 4);
        gpu_s / (self.pipeline.cycle_ns * 1e-9)
    }
}

/// Simulate one network under `cfg`.
pub fn simulate(net: &Network, cfg: &SimConfig) -> Result<SimResult, MapError> {
    let mapping = map_network(net, &cfg.map_config())?;
    let tree = AdderTree::new(cfg.adder_inputs);
    let aap_ns = cfg.timing.aap_ns();
    let logic_cycle = energy::logic_cycle_ns();
    let n = cfg.n_bits;
    let planes = 2 * n as u64;
    let mul_cost = mul_aaps(cfg.cost_model, n as u64);

    let mut layers = Vec::with_capacity(net.layers.len());
    for (idx, (layer, m)) in net.layers.iter().zip(&mapping.layers).enumerate() {
        let rounds = m.rounds() as f64;
        let mut multiply_ns = rounds * mul_cost as f64 * aap_ns;
        if let Some(refresh) = &cfg.refresh {
            multiply_ns = refresh.stretch_ns(multiply_ns);
        }

        // Tree drain: every used subarray's row buffer is streamed through
        // a tree once per product bit-plane, per round.
        let trees = if cfg.tree_per_subarray { m.subarrays_used.max(1) } else { 1 };
        let passes_per_plane = ceil_div(cfg.geometry.cols, cfg.adder_inputs)
            * ceil_div(m.subarrays_used.max(1), trees);
        let passes_per_round = passes_per_plane as u64 * planes;
        let drain = tree.levels() as u64 + 8; // SFU + transpose pipeline drain
        let logic_cycles = rounds as u64 * (tree.cycles(passes_per_round as usize) + drain);
        let logic_ns = logic_cycles as f64 * logic_cycle;

        // Re-staging: each extra wave / overflowed stack round rewrites the
        // active subarrays' operand rows over the internal bus.
        let restage_events = (m.waves - 1) + m.restaged_rounds;
        let rows_per_subarray = 2 * n;
        let restage_ns = restage_events as f64
            * m.subarrays_used as f64
            * rows_per_subarray as f64
            * cfg.timing.interbank_copy_ns(cfg.geometry.cols);

        // Residual edges execute in their own reserved banks (Fig 13) —
        // they become separate pipeline stages below; nothing lands here.
        let residual_ns = 0.0;
        let _ = idx;

        let transfer = transfer_ns(
            layer.out_elems(),
            n,
            cfg.geometry.cols,
            &cfg.timing,
        );

        let aaps = m.rounds() as u64 * mul_cost * m.subarrays_used as u64;
        let dram_energy_nj = aaps as f64
            * (cfg.timing.act_pre_energy_nj + cfg.timing.multi_act_energy(3))
            + crate::dataflow::transfer::transfer_bits(
                layer.out_elems(),
                n,
                cfg.geometry.cols,
            ) as f64
                * cfg.timing.bus_energy_pj_per_bit
                / 1000.0;

        layers.push(LayerSim {
            name: layer.name.clone(),
            mapping: m.clone(),
            multiply_ns,
            logic_ns,
            restage_ns,
            residual_ns,
            transfer_ns: transfer,
            aaps,
            dram_energy_nj,
        });
    }

    let mut stages: Vec<StageCost> = layers
        .iter()
        .map(|l| StageCost {
            name: l.name.clone(),
            compute_ns: l.compute_ns(),
            transfer_ns: l.transfer_ns,
        })
        .collect();
    // Residual reserved banks: one pipeline stage per edge (Fig 13). The
    // shortcut/result copies are its transfers; the in-DRAM add its compute.
    for r in &net.residuals {
        let elems = net.layers[r.into_layer].out_elems();
        let copy = transfer_ns(elems, n, cfg.geometry.cols, &cfg.timing);
        let total = residual_cost_ns(elems, n, cfg.geometry.cols, &cfg.timing);
        stages.push(StageCost {
            name: format!("res:{}", net.layers[r.into_layer].name),
            compute_ns: total - 3.0 * copy,
            transfer_ns: 3.0 * copy,
        });
    }
    let pipeline = schedule(stages, cfg.overlapped_transfers);

    let total_aaps = layers.iter().map(|l| l.aaps).sum();
    let total_dram_energy_nj: f64 = layers.iter().map(|l| l.dram_energy_nj).sum();
    let bank_power_nw: f64 = energy::bank_components(cfg.adder_inputs)
        .iter()
        .map(|c| c.power_nw)
        .sum();
    let logic_busy_s: f64 = layers.iter().map(|l| l.logic_ns).sum::<f64>() * 1e-9;
    let logic_energy_nj = bank_power_nw * logic_busy_s; // nW × s = nJ

    Ok(SimResult {
        net_name: net.name.clone(),
        n_bits: n,
        layers,
        pipeline,
        total_aaps,
        total_dram_energy_nj,
        logic_energy_nj,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets::{alexnet, pimnet, resnet18, vgg16};

    #[test]
    fn pimnet_simulates_on_conservative() {
        let r = simulate(&pimnet(), &SimConfig::conservative(8)).unwrap();
        assert_eq!(r.layers.len(), 4);
        assert!(r.latency_ns() > 0.0);
        assert!(r.throughput_ips() > 0.0);
        assert!(r.total_aaps > 0);
    }

    #[test]
    fn all_networks_simulate_on_both_presets() {
        for net in [alexnet(), vgg16(), resnet18(), pimnet()] {
            for cfg in [SimConfig::conservative(8), SimConfig::paper_favorable(8)] {
                let r = simulate(&net, &cfg)
                    .unwrap_or_else(|e| panic!("{}: {e}", net.name));
                assert!(r.latency_ns().is_finite() && r.latency_ns() > 0.0);
            }
        }
    }

    #[test]
    fn paper_favorable_is_faster_than_conservative() {
        let net = vgg16();
        let fav = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        let con = simulate(&net, &SimConfig::conservative(8)).unwrap();
        assert!(
            fav.pipeline.cycle_ns < con.pipeline.cycle_ns,
            "favorable {} vs conservative {}",
            fav.pipeline.cycle_ns,
            con.pipeline.cycle_ns
        );
    }

    #[test]
    fn paper_favorable_beats_gpu_shape() {
        // The reproduction target: PIM wins over the ideal GPU under the
        // paper's assumptions (exact factor depends on bit width).
        let gpu = GpuModel::titan_xp();
        for net in [alexnet(), vgg16(), resnet18()] {
            let r = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
            let s = r.speedup_vs(&gpu, &net);
            assert!(s > 1.0, "{}: speedup {s}", net.name);
        }
    }

    #[test]
    fn higher_k_lowers_throughput() {
        // Fig 16's parallelism knob: k folds groups → more serial rounds.
        let net = alexnet();
        let r1 = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        let r4 = simulate(
            &net,
            &SimConfig::paper_favorable(8).with_ks(vec![4]),
        )
        .unwrap();
        assert!(r4.pipeline.cycle_ns > r1.pipeline.cycle_ns);
    }

    #[test]
    fn precision_sweep_monotone() {
        // Fig 17's shape: multiply rounds grow ~cubically with n.
        let net = alexnet();
        let mut prev = 0.0;
        for n in [2, 4, 8, 16] {
            let r = simulate(&net, &SimConfig::paper_favorable(n)).unwrap();
            let mult: f64 = r.layers.iter().map(|l| l.multiply_ns).sum();
            assert!(mult > prev, "n={n}");
            prev = mult;
        }
    }

    #[test]
    fn residual_edges_become_reserved_bank_stages() {
        let net = resnet18();
        let r = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        assert_eq!(
            r.pipeline.stages.len(),
            net.layers.len() + net.residuals.len()
        );
        let res_stages: Vec<_> = r
            .pipeline
            .stages
            .iter()
            .filter(|s| s.name.starts_with("res:"))
            .collect();
        assert_eq!(res_stages.len(), 8);
        for s in res_stages {
            assert!(s.compute_ns > 0.0 && s.transfer_ns > 0.0, "{}", s.name);
        }
    }

    #[test]
    fn conservative_vgg_pays_restaging() {
        let r = simulate(&vgg16(), &SimConfig::conservative(8)).unwrap();
        let restage: f64 = r.layers.iter().map(|l| l.restage_ns).sum();
        assert!(restage > 0.0, "real capacity must force restaging");
    }

    #[test]
    fn refresh_stretches_conservative_multiplies() {
        let net = pimnet();
        let mut no_ref = SimConfig::conservative(8);
        no_ref.refresh = None;
        let with_ref = SimConfig::conservative(8);
        let a = simulate(&net, &no_ref).unwrap();
        let b = simulate(&net, &with_ref).unwrap();
        let ma: f64 = a.layers.iter().map(|l| l.multiply_ns).sum();
        let mb: f64 = b.layers.iter().map(|l| l.multiply_ns).sum();
        assert!(mb > ma, "refresh must add time");
        assert!(mb < ma * 1.05, "refresh duty is ~2%");
    }

    #[test]
    fn optimizer_plan_feeds_simulator() {
        use crate::mapping::optimizer::{plan_ks, Objective};
        let net = pimnet();
        let cfg0 = SimConfig::conservative(8);
        let plan = plan_ks(&net, &cfg0.geometry, 8, Objective::MinResidentK);
        let planned = simulate(&net, &cfg0.clone().with_ks(plan.ks)).unwrap();
        // The plan removes all waves/restaging.
        assert!(planned.layers.iter().all(|l| l.mapping.fully_resident()));
        // And should not be slower than the naive k=1 map.
        let naive = simulate(&net, &cfg0).unwrap();
        assert!(planned.pipeline.cycle_ns <= naive.pipeline.cycle_ns * 1.01);
    }

    #[test]
    fn energy_totals_positive_and_decomposed() {
        let r = simulate(&pimnet(), &SimConfig::paper_favorable(8)).unwrap();
        assert!(r.total_dram_energy_nj > 0.0);
        assert!(r.logic_energy_nj > 0.0);
    }
}
