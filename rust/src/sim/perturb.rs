//! Deterministic latency-perturbation substrate for the serving layer.
//!
//! Fault injection (coordinator::faults) and the virtual-time fleet
//! simulation (coordinator::chaos) both need per-(device, batch) decisions
//! that are **order-independent**: the live pool executes batches from
//! concurrent worker threads while the fleet simulation replays them in
//! virtual-time order, and the two must see the same schedule. The trick
//! is counter-based randomness — every decision draws from an `Rng` seeded
//! by a hash of `(seed, device, tick)` instead of consuming a shared
//! stream, so the draw for batch 17 on device 3 is the same no matter how
//! many other batches ran first.
//!
//! [`Perturbation`] is the composable output: a multiplicative factor on a
//! modeled service time (straggler inflation × refresh-storm slowdown ×
//! anything a future model stacks on top).

/// SplitMix64-style avalanche of `(seed, device, tick)` into one 64-bit
/// stream seed. Distinct inputs land in distinct, well-mixed states, so
/// `Rng::new(fault_hash(..))` behaves like an independent generator per
/// (device, batch) coordinate.
pub fn fault_hash(seed: u64, device: u64, tick: u64) -> u64 {
    let mut z = seed
        .wrapping_add(device.wrapping_mul(0x9E3779B97F4A7C15))
        .wrapping_add(tick.wrapping_mul(0xBF58476D1CE4E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A multiplicative slowdown applied to a modeled service time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Perturbation {
    /// `>= 1.0`; 1.0 is the unperturbed service time.
    pub factor: f64,
}

impl Perturbation {
    /// The identity perturbation (no slowdown).
    pub fn none() -> Perturbation {
        Perturbation { factor: 1.0 }
    }

    /// A slowdown by `factor` (clamped below at 1.0 — perturbations model
    /// interference, never speedups).
    pub fn slow(factor: f64) -> Perturbation {
        Perturbation { factor: factor.max(1.0) }
    }

    /// Stack another perturbation on top (factors multiply: a straggler
    /// inside a refresh storm pays both).
    pub fn and(self, other: Perturbation) -> Perturbation {
        Perturbation { factor: self.factor * other.factor }
    }

    pub fn is_none(&self) -> bool {
        self.factor == 1.0
    }

    /// Apply to a service time in ns.
    pub fn apply_ns(&self, ns: f64) -> f64 {
        ns * self.factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_hash_is_deterministic_and_coordinate_sensitive() {
        assert_eq!(fault_hash(7, 3, 17), fault_hash(7, 3, 17));
        assert_ne!(fault_hash(7, 3, 17), fault_hash(7, 3, 18));
        assert_ne!(fault_hash(7, 3, 17), fault_hash(7, 4, 17));
        assert_ne!(fault_hash(7, 3, 17), fault_hash(8, 3, 17));
    }

    #[test]
    fn fault_hash_mixes_small_inputs() {
        // Neighbouring coordinates must not land in neighbouring states.
        let a = fault_hash(0, 0, 0);
        let b = fault_hash(0, 0, 1);
        let c = fault_hash(0, 1, 0);
        assert!(a.abs_diff(b) > 1 << 32, "{a} vs {b}");
        assert!(a.abs_diff(c) > 1 << 32, "{a} vs {c}");
    }

    #[test]
    fn perturbations_compose_multiplicatively() {
        let p = Perturbation::slow(4.0).and(Perturbation::slow(2.5));
        assert_eq!(p.factor, 10.0);
        assert_eq!(p.apply_ns(100.0), 1000.0);
        assert!(Perturbation::none().is_none());
        assert!(!p.is_none());
    }

    #[test]
    fn perturbations_never_speed_up() {
        assert_eq!(Perturbation::slow(0.25).factor, 1.0);
        assert_eq!(Perturbation::slow(-3.0).factor, 1.0);
    }
}
