//! Incremental pricing sessions (DESIGN.md §8).
//!
//! Sweeps are the experiment unit: Fig 16/17, the design-space studies
//! and the serving backend call the analytical model dozens-to-hundreds
//! of times while varying only `ks`, the shard policy, or the
//! channels × ranks grid. A fresh [`super::simulate`] re-runs Algorithm-1
//! mapping and re-prices every layer from scratch on each call even
//! though none of those knobs touch a layer's in-bank cost.
//!
//! [`SimSession`] materializes the three stages `simulate()` documents as
//! reusable artifacts:
//!
//!   * **map + price, cached** — each layer's [`LayerSim`] (mapping +
//!     pricing) lives in a session-owned **arena** (`Vec<LayerSim>`),
//!     keyed by `(fingerprint, layer, k)` → arena slot, where the
//!     fingerprint hashes every map/price input: bank-internal geometry,
//!     timing, operand bits, cost model, adder width, tree stance and
//!     refresh. The grid, the shard policy and the `ks` vector are
//!     deliberately **excluded** — they only steer lowering/aggregation,
//!     so changing them reuses the cache.
//!   * **lower + aggregate, per call** — [`crate::plan::layout_into`] and
//!     the chain folds are recomputed every call; they are the cheap
//!     stages, and they run in session-owned scratch (the slot/weight
//!     vectors and the [`crate::plan::PlanLayout`]) so a warm probe
//!     allocates nothing at all.
//!
//! Read paths:
//!   * [`SimSession::simulate_full`] rebuilds the exact [`SimResult`]
//!     `simulate()` returns (shared `finish_simulation` tail), for
//!     callers that need per-stage detail (CLI tables, serving setup).
//!   * [`SimSession::report`] returns the scalar [`SimReport`] the sweeps
//!     read, skipping every per-stage vector. Its folds run in the same
//!     order as `simulate()`'s, so equality is exact, not approximate —
//!     `tests/session_equivalence.rs` is the correctness bar.
//!   * [`SimSession::report_batch`] prices a whole admission batch (the
//!     serve path's unit) through one session pass: request *i*'s result
//!     is bitwise-identical to an isolated `report()` call, but every
//!     request after the first amortizes the shared cache fill.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::Hasher;

use crate::gpu::GpuModel;
use crate::mapping::candidates::{map_candidate, LayerCandidate};
use crate::mapping::{map_layer, outer_count, DataLayout, MapConfig, MapError, NetworkMapping};
use crate::plan::{self, ExecutionPlan, PlanError, PlanLayout, ShardPolicy};
use crate::primitives::CostModel;
use crate::workloads::Network;

use super::engine::{finish_simulation, hop_ns_for, price_layer_owned, residual_cost};
use super::engine::{LayerSim, PriceCtx, SimConfig, SimResult};

/// Hash every `SimConfig` field the **map** and **price** stages read.
/// `channels`, `ranks_per_channel`, `banks_per_rank`, `ks`, `shard` and
/// `overlapped_transfers` are excluded: they only steer the lowering /
/// aggregation stages, which the session recomputes per call.
pub(crate) fn price_fingerprint(cfg: &SimConfig) -> u64 {
    fn f(h: &mut DefaultHasher, v: f64) {
        h.write_u64(v.to_bits());
    }
    let mut h = DefaultHasher::new();
    let g = &cfg.geometry;
    h.write_usize(g.subarrays_per_bank);
    h.write_usize(g.rows);
    h.write_usize(g.cols);
    h.write_usize(g.compute_rows);
    h.write_usize(cfg.n_bits);
    h.write_usize(cfg.adder_inputs);
    h.write_u8(match cfg.cost_model {
        CostModel::Paper => 0,
        CostModel::Derived => 1,
    });
    h.write_u8(cfg.tree_per_subarray as u8);
    let t = &cfg.timing;
    f(&mut h, t.tck_ns);
    f(&mut h, t.trcd_ns);
    f(&mut h, t.tras_ns);
    f(&mut h, t.trp_ns);
    f(&mut h, t.tcas_ns);
    h.write_usize(t.internal_bus_bits);
    h.write_usize(t.channel_bus_bits);
    f(&mut h, t.act_pre_energy_nj);
    f(&mut h, t.multi_act_energy_nj);
    f(&mut h, t.bus_energy_pj_per_bit);
    match &cfg.refresh {
        None => h.write_u8(0),
        Some(r) => {
            h.write_u8(1);
            f(&mut h, r.trefi_ns);
            f(&mut h, r.trfc_ns);
        }
    }
    h.finish()
}

/// Cache key for one layer's mapped + priced artifact. `tile` and
/// `layout` are the search mapper's extra knobs; the paper path always
/// keys `(tile: 0, layout: 0)`, so searched candidates share the arena
/// with — but never collide with — the default mapping.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct LayerKey {
    fingerprint: u64,
    layer: usize,
    k: usize,
    tile: usize,
    layout: u8,
}

impl LayerKey {
    fn paper(fingerprint: u64, layer: usize, k: usize) -> Self {
        LayerKey { fingerprint, layer, k, tile: 0, layout: 0 }
    }

    fn for_candidate(fingerprint: u64, layer: usize, cand: &LayerCandidate) -> Self {
        LayerKey {
            fingerprint,
            layer,
            k: cand.k,
            tile: cand.tile,
            layout: match cand.layout {
                DataLayout::Sequential => 0,
                DataLayout::RowAligned => 1,
            },
        }
    }
}

/// Scalar view of one simulation — everything the sweeps read, none of
/// the per-stage vectors [`SimResult`] carries. Every field is produced
/// by the same fold order as `simulate()`, so comparing against the full
/// report is exact `==`, not an epsilon check.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub net_name: String,
    pub n_bits: usize,
    pub policy: ShardPolicy,
    /// Independent full-network pipelines in the plan.
    pub replicas: usize,
    /// Devices in one replica's chain.
    pub devices_per_replica: usize,
    /// Per-image latency (pipeline fill, inter-channel hops included).
    pub latency_ns: f64,
    /// Steady-state initiation interval of one replica.
    pub cycle_ns: f64,
    /// Per-image inter-channel transfer time across the chain.
    pub hop_ns_total: f64,
    pub total_aaps: u64,
    pub total_dram_energy_nj: f64,
    pub logic_energy_nj: f64,
    /// Bottleneck stage index in the flattened chain
    /// (`SimResult::pipeline.bottleneck`).
    pub bottleneck: usize,
    /// All layers resident (no waves, no restaging) under this config.
    pub fully_resident: bool,
}

impl SimReport {
    /// Aggregate steady-state throughput (images/s) across replicas.
    pub fn throughput_ips(&self) -> f64 {
        self.replicas as f64 * (1e9 / self.cycle_ns)
    }

    /// Steady-state throughput of a single replica (images/s).
    pub fn replica_throughput_ips(&self) -> f64 {
        1e9 / self.cycle_ns
    }

    /// Devices across all replicas.
    pub fn devices_total(&self) -> usize {
        self.replicas * self.devices_per_replica
    }

    /// Fig 16 metric — see [`SimResult::speedup_vs`].
    pub fn speedup_vs(&self, gpu: &GpuModel, net: &Network, gpu_bytes_per_elem: usize) -> f64 {
        let gpu_s = gpu.network_time_s(net, gpu_bytes_per_elem);
        gpu_s / (self.cycle_ns * 1e-9)
    }
}

/// An incremental simulation session over one network: map once, price
/// per `(config-fingerprint, layer, k)`, re-lower and re-aggregate per
/// call. See the module docs for the caching contract.
///
/// All per-call state lives in session-owned arenas and scratch vectors:
/// a warm [`SimSession::report`] probe performs no heap allocation beyond
/// the report's own `net_name` string.
pub struct SimSession<'a> {
    net: &'a Network,
    /// Arena of priced per-layer artifacts; cache values are slots here.
    /// Entries are append-only until [`SimSession::clear`].
    arena: Vec<LayerSim>,
    cache: HashMap<LayerKey, u32>,
    /// Scratch, reused across calls: the active config's arena slot per
    /// layer, the layout-balancing round counts, and the grid layout.
    slots: Vec<u32>,
    weights: Vec<u64>,
    layout: PlanLayout,
    hits: u64,
    misses: u64,
}

impl<'a> SimSession<'a> {
    pub fn new(net: &'a Network) -> Self {
        SimSession {
            net,
            arena: Vec::new(),
            cache: HashMap::new(),
            slots: Vec::new(),
            weights: Vec::new(),
            layout: PlanLayout::default(),
            hits: 0,
            misses: 0,
        }
    }

    /// The network this session prices.
    pub fn network(&self) -> &'a Network {
        self.net
    }

    /// `(hits, misses)` of the per-layer cache since construction.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Distinct `(fingerprint, layer, k)` artifacts currently cached.
    pub fn cached_layers(&self) -> usize {
        self.cache.len()
    }

    /// Drop all cached artifacts (stats survive).
    pub fn clear(&mut self) {
        self.cache.clear();
        self.arena.clear();
    }

    /// The effective per-layer parallelism under `cfg` — the same clamp
    /// `map_network` applies.
    fn k_for(&self, cfg: &SimConfig, layer_idx: usize) -> usize {
        cfg.k_for(layer_idx).min(outer_count(&self.net.layers[layer_idx]))
    }

    /// Mirror `map_network`'s up-front bank budget check so the session
    /// fails with the identical error before touching the cache.
    fn check_banks(&self, cfg: &SimConfig) -> Result<usize, PlanError> {
        let banks_needed = self.net.layers.len() + self.net.residuals.len();
        if banks_needed > cfg.geometry.total_banks() {
            return Err(PlanError::Map(MapError::BankOverflow {
                net: self.net.name.clone(),
                banks: banks_needed,
                avail: cfg.geometry.total_banks(),
            }));
        }
        Ok(banks_needed)
    }

    /// Fill the arena for every layer missing under `(fp, k)`.
    fn ensure_priced(&mut self, cfg: &SimConfig, fp: u64) -> Result<(), PlanError> {
        let net = self.net;
        let mut ctx: Option<PriceCtx> = None;
        // One probe MapConfig serves every miss: `map_layer` broadcasts a
        // single-entry `ks`, so only `ks[0]` changes between layers.
        let mut probe: Option<MapConfig> = None;
        for (i, layer) in net.layers.iter().enumerate() {
            let key = LayerKey::paper(fp, i, self.k_for(cfg, i));
            if self.cache.contains_key(&key) {
                self.hits += 1;
                continue;
            }
            self.misses += 1;
            let c = probe.get_or_insert_with(|| MapConfig {
                geometry: cfg.geometry.clone(),
                n_bits: cfg.n_bits,
                ks: vec![key.k],
            });
            c.ks[0] = key.k;
            let m = map_layer(i, i, layer, c).map_err(PlanError::Map)?;
            let ctx = ctx.get_or_insert_with(|| PriceCtx::new(cfg));
            let slot = self.arena.len() as u32;
            self.arena.push(price_layer_owned(layer, m, cfg, ctx));
            self.cache.insert(key, slot);
        }
        Ok(())
    }

    /// Resolve the active config's arena slots and layout-balancing
    /// weights into the session scratch. Infallible after a successful
    /// [`SimSession::ensure_priced`] under the same `(cfg, fp)`.
    fn resolve_slots(&mut self, cfg: &SimConfig, fp: u64) {
        let net = self.net;
        self.slots.clear();
        self.weights.clear();
        for i in 0..net.layers.len() {
            let key = LayerKey::paper(fp, i, self.k_for(cfg, i));
            let slot = self.cache[&key];
            let rounds = self.arena[slot as usize].mapping.rounds() as u64;
            self.slots.push(slot);
            self.weights.push(rounds);
        }
    }

    /// Full fidelity: the same [`SimResult`] `simulate()` returns, built
    /// from cached per-layer artifacts and a fresh lowering. The result
    /// owns every per-stage vector, so this path clones out of the arena
    /// by design; sweeps should read [`SimSession::report`].
    pub fn simulate_full(&mut self, cfg: &SimConfig) -> Result<SimResult, PlanError> {
        let banks_needed = self.check_banks(cfg)?;
        let fp = price_fingerprint(cfg);
        self.ensure_priced(cfg, fp)?;
        self.resolve_slots(cfg, fp);

        let layers: Vec<LayerSim> = self
            .slots
            .iter()
            .map(|&s| self.arena[s as usize].clone())
            .collect();
        let mapping = NetworkMapping {
            net_name: self.net.name.clone(),
            layers: layers.iter().map(|l| l.mapping.clone()).collect(),
            residual_banks: self.net.residuals.len(),
            total_banks: banks_needed,
        };
        let l =
            plan::layout(self.net, &self.weights, banks_needed, &cfg.geometry, cfg.shard)?;
        let chains = l.chains_vec();
        let plan = ExecutionPlan {
            net_name: self.net.name.clone(),
            policy: cfg.shard,
            geometry: cfg.geometry.clone(),
            mapping,
            devices: l.devices,
            replicas: l.replicas,
            chains,
        };
        Ok(finish_simulation(self.net, cfg, plan, layers))
    }

    /// Sweep hot path: lower + aggregate over cached layer pricing,
    /// producing the scalar [`SimReport`] without building any per-stage
    /// vector. Folds run in `simulate()`'s order so the numbers match the
    /// full report exactly.
    pub fn report(&mut self, cfg: &SimConfig) -> Result<SimReport, PlanError> {
        let banks_needed = self.check_banks(cfg)?;
        let fp = price_fingerprint(cfg);
        self.ensure_priced(cfg, fp)?;
        self.resolve_slots(cfg, fp);
        self.fold_report(cfg, banks_needed)
    }

    /// Lower + aggregate over the already-resolved `slots`/`weights`
    /// scratch — the shared tail of [`SimSession::report`] and
    /// [`SimSession::report_with`]. Folds run in `simulate()`'s order so
    /// the numbers match the full report exactly.
    fn fold_report(
        &mut self,
        cfg: &SimConfig,
        banks_needed: usize,
    ) -> Result<SimReport, PlanError> {
        // Lower: grid layout from the cached per-layer round counts, into
        // the session-owned layout scratch.
        plan::layout_into(
            self.net,
            &self.weights,
            banks_needed,
            &cfg.geometry,
            cfg.shard,
            &mut self.layout,
        )?;

        let arena = &self.arena;
        let slots = &self.slots;
        let layer_at = |i: usize| -> &LayerSim { &arena[slots[i] as usize] };

        // Aggregate replica 0's chain, mirroring `price_device` +
        // `combine_chain` fold-for-fold (see module docs).
        let layout = &self.layout;
        let chain = layout.chain(0);
        let mut latency_ns = 0.0f64;
        let mut cycle_ns = f64::NEG_INFINITY;
        let mut hop_ns_total = 0.0f64;
        let mut bottleneck = 0usize;
        let mut best_compute = f64::NEG_INFINITY;
        let mut flat_idx = 0usize;

        for (pos, &dev_id) in chain.iter().enumerate() {
            let d = &layout.devices[dev_id];
            let is_tail = pos + 1 == chain.len();
            let boundary = d.shard.layers.end - 1;
            let hop_ns = if is_tail {
                0.0
            } else {
                hop_ns_for(self.net.layers[boundary].out_elems(), cfg)
            };

            let mut dev_latency = 0.0f64;
            let mut max_stage = f64::NEG_INFINITY; // compute + transfer
            let mut max_compute = f64::NEG_INFINITY;
            let mut sum_transfer = 0.0f64;
            let mut fold = |compute: f64, transfer: f64| {
                dev_latency += compute + transfer;
                max_stage = max_stage.max(compute + transfer);
                max_compute = max_compute.max(compute);
                sum_transfer += transfer;
                // combine_chain's max_by keeps the *last* maximal stage.
                if compute >= best_compute {
                    best_compute = compute;
                    bottleneck = flat_idx;
                }
                flat_idx += 1;
            };
            for i in d.shard.layers.clone() {
                let compute = layer_at(i).compute_ns();
                let transfer = if !is_tail && i == boundary {
                    hop_ns
                } else {
                    layer_at(i).transfer_ns
                };
                fold(compute, transfer);
            }
            for &ri in &d.shard.residuals {
                let r = &self.net.residuals[ri];
                let cross = layout.device_hosting(d.replica, r.from_layer) != Some(dev_id);
                let (compute, transfer) = residual_cost(self.net, r, cfg, cross);
                fold(compute, transfer);
            }

            let dev_cycle = if cfg.overlapped_transfers {
                max_stage
            } else {
                max_compute + sum_transfer
            };
            latency_ns += dev_latency;
            cycle_ns = cycle_ns.max(dev_cycle);
            hop_ns_total += hop_ns;
        }

        // Layer-template totals, in `finish_simulation`'s fold order.
        let n_layers = self.net.layers.len();
        let total_aaps: u64 = (0..n_layers).map(|i| layer_at(i).aaps).sum();
        let total_dram_energy_nj: f64 =
            (0..n_layers).map(|i| layer_at(i).dram_energy_nj).sum();
        let bank_power_nw: f64 = crate::energy::bank_components(cfg.adder_inputs)
            .iter()
            .map(|c| c.power_nw)
            .sum();
        let logic_busy_s: f64 =
            (0..n_layers).map(|i| layer_at(i).logic_ns).sum::<f64>() * 1e-9;
        let logic_energy_nj = bank_power_nw * logic_busy_s; // nW × s = nJ
        let fully_resident = (0..n_layers).all(|i| layer_at(i).mapping.fully_resident());

        Ok(SimReport {
            net_name: self.net.name.clone(),
            n_bits: cfg.n_bits,
            policy: cfg.shard,
            replicas: layout.replicas,
            devices_per_replica: chain.len(),
            latency_ns,
            cycle_ns,
            hop_ns_total,
            total_aaps,
            total_dram_energy_nj,
            logic_energy_nj,
            bottleneck,
            fully_resident,
        })
    }

    /// Price one layer under an explicit search candidate, filling the
    /// arena on miss. `probe.ks[0]` is clobbered.
    fn ensure_candidate(
        &mut self,
        cfg: &SimConfig,
        fp: u64,
        probe: &mut MapConfig,
        ctx: &PriceCtx,
        layer_idx: usize,
        cand: &LayerCandidate,
    ) -> Result<u32, PlanError> {
        let key = LayerKey::for_candidate(fp, layer_idx, cand);
        if let Some(&slot) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(slot);
        }
        self.misses += 1;
        let layer = &self.net.layers[layer_idx];
        let m = map_candidate(layer_idx, layer_idx, layer, probe, cand).map_err(PlanError::Map)?;
        let slot = self.arena.len() as u32;
        self.arena.push(price_layer_owned(layer, m, cfg, ctx));
        self.cache.insert(key, slot);
        Ok(slot)
    }

    /// Exact pricing of one layer under a search candidate — the mapopt
    /// beam search's surviving-candidate path. Returns the arena slot
    /// (stable until [`SimSession::clear`]); the search holds slots, not
    /// references, so it can keep pricing new candidates while comparing
    /// earlier ones via [`SimSession::layer_sim`]. Candidates differing
    /// only in the searched knobs share the fingerprint, so a sweep is
    /// one cache fill per distinct candidate, ever.
    pub fn candidate_slot(
        &mut self,
        cfg: &SimConfig,
        layer_idx: usize,
        cand: &LayerCandidate,
    ) -> Result<u32, PlanError> {
        let fp = price_fingerprint(cfg);
        let mut probe = MapConfig {
            geometry: cfg.geometry.clone(),
            n_bits: cfg.n_bits,
            ks: vec![cand.k],
        };
        let ctx = PriceCtx::new(cfg);
        self.ensure_candidate(cfg, fp, &mut probe, &ctx, layer_idx, cand)
    }

    /// Read a priced artifact by arena slot.
    pub fn layer_sim(&self, slot: u32) -> &LayerSim {
        &self.arena[slot as usize]
    }

    /// Price the network under an explicit per-layer candidate assignment
    /// (the search mapper's chosen mapping): the same lower + aggregate
    /// folds as [`SimSession::report`], so a searched report is exactly
    /// comparable to the paper report. `cands` must cover every layer.
    pub fn report_with(
        &mut self,
        cfg: &SimConfig,
        cands: &[LayerCandidate],
    ) -> Result<SimReport, PlanError> {
        assert_eq!(cands.len(), self.net.layers.len(), "one candidate per layer");
        let banks_needed = self.check_banks(cfg)?;
        let fp = price_fingerprint(cfg);
        let mut probe = MapConfig {
            geometry: cfg.geometry.clone(),
            n_bits: cfg.n_bits,
            ks: vec![1],
        };
        let ctx = PriceCtx::new(cfg);
        self.slots.clear();
        self.weights.clear();
        for (i, cand) in cands.iter().enumerate() {
            let slot = self.ensure_candidate(cfg, fp, &mut probe, &ctx, i, cand)?;
            let rounds = self.arena[slot as usize].mapping.rounds() as u64;
            self.slots.push(slot);
            self.weights.push(rounds);
        }
        self.fold_report(cfg, banks_needed)
    }

    /// Price a whole admission batch through one session pass — the serve
    /// path's batched entry point ([`crate::coordinator::SimBackend`]
    /// wraps it for `Batcher` batches). Each request keeps its own
    /// `Result`, so a failing plan poisons only its own slot, and request
    /// *i*'s report is bitwise-identical to an isolated
    /// [`SimSession::report`] call under the same config. The win is
    /// amortization: requests sharing a pricing fingerprint (the common
    /// serve case — same die, different grid/shard/ks knobs) are one
    /// cache fill plus per-request scalar folds, instead of the
    /// per-request fresh-session loop `Job::report()` implies.
    pub fn report_batch(
        &mut self,
        cfgs: &[SimConfig],
    ) -> Vec<Result<SimReport, PlanError>> {
        cfgs.iter().map(|cfg| self.report(cfg)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate;
    use crate::workloads::nets::{pimnet, resnet18, vgg16};

    #[test]
    fn session_matches_fresh_simulate_exactly() {
        let net = resnet18();
        let cfg = SimConfig::conservative(8);
        let fresh = simulate(&net, &cfg).unwrap();
        let mut session = SimSession::new(&net);
        let full = session.simulate_full(&cfg).unwrap();
        let rep = session.report(&cfg).unwrap();

        assert_eq!(full.pipeline.latency_ns.to_bits(), fresh.pipeline.latency_ns.to_bits());
        assert_eq!(full.pipeline.cycle_ns.to_bits(), fresh.pipeline.cycle_ns.to_bits());
        assert_eq!(full.total_aaps, fresh.total_aaps);
        assert_eq!(rep.latency_ns.to_bits(), fresh.pipeline.latency_ns.to_bits());
        assert_eq!(rep.cycle_ns.to_bits(), fresh.pipeline.cycle_ns.to_bits());
        assert_eq!(rep.bottleneck, fresh.pipeline.bottleneck);
        assert_eq!(rep.total_aaps, fresh.total_aaps);
        assert_eq!(
            rep.throughput_ips().to_bits(),
            fresh.throughput_ips().to_bits()
        );
    }

    #[test]
    fn grid_and_shard_changes_reuse_the_layer_cache() {
        let net = vgg16();
        let mut session = SimSession::new(&net);
        session.report(&SimConfig::conservative(8)).unwrap();
        let (_, misses_after_first) = session.cache_stats();
        assert_eq!(misses_after_first, net.layers.len() as u64);

        // Grid + shard sweeps: pure hits.
        for channels in [2usize, 4, 8] {
            let cfg = SimConfig::conservative(8).with_grid(channels, 4);
            session.report(&cfg).unwrap();
            let split = cfg.with_shard(ShardPolicy::LayerSplit);
            session.report(&split).unwrap();
        }
        let (hits, misses) = session.cache_stats();
        assert_eq!(misses, misses_after_first, "grid/shard must not re-price");
        assert_eq!(hits, 6 * net.layers.len() as u64);

        // A new k re-prices each layer once, then hits again.
        session.report(&SimConfig::conservative(8).with_ks(vec![2])).unwrap();
        let (_, misses_k2) = session.cache_stats();
        assert_eq!(misses_k2, misses_after_first + net.layers.len() as u64);
        session.report(&SimConfig::conservative(8).with_ks(vec![2])).unwrap();
        let (_, misses_again) = session.cache_stats();
        assert_eq!(misses_again, misses_k2);
    }

    #[test]
    fn fingerprint_separates_pricing_configs() {
        let a = SimConfig::conservative(8);
        let b = SimConfig::paper_favorable(8);
        let c = SimConfig::conservative(4);
        let fa = price_fingerprint(&a);
        assert_ne!(fa, price_fingerprint(&b));
        assert_ne!(fa, price_fingerprint(&c));
        // Grid / shard / ks do not move the fingerprint.
        assert_eq!(fa, price_fingerprint(&a.clone().with_grid(8, 2)));
        assert_eq!(
            fa,
            price_fingerprint(&a.with_ks(vec![4]).with_shard(ShardPolicy::LayerSplit))
        );
    }

    #[test]
    fn report_batch_matches_isolated_reports_including_errors() {
        let net = vgg16();
        let batch = [
            SimConfig::conservative(8),
            // 16 layer banks overflow a 1×1 grid's 8 — a per-request error.
            SimConfig::conservative(8).with_grid(1, 1),
            SimConfig::conservative(8)
                .with_grid(2, 4)
                .with_shard(ShardPolicy::LayerSplit),
        ];

        let mut session = SimSession::new(&net);
        let batched = session.report_batch(&batch);
        assert_eq!(batched.len(), 3);
        for (cfg, got) in batch.iter().zip(&batched) {
            let mut isolated = SimSession::new(&net);
            match (isolated.report(cfg), got) {
                (Ok(want), Ok(got)) => {
                    assert_eq!(&want, got);
                    assert_eq!(want.cycle_ns.to_bits(), got.cycle_ns.to_bits());
                }
                (Err(want), Err(got)) => assert_eq!(&want, got),
                (want, got) => panic!("mismatch: {want:?} vs {got:?}"),
            }
        }
        // The whole batch shares one pricing pass.
        let (hits, misses) = session.cache_stats();
        assert_eq!(misses, net.layers.len() as u64);
        assert_eq!(hits, net.layers.len() as u64);
    }

    #[test]
    fn bank_overflow_error_matches_simulate() {
        let net = vgg16();
        let mut cfg = SimConfig::conservative(8);
        cfg.geometry.ranks_per_channel = 1;
        cfg.geometry.banks_per_rank = 2;
        let fresh = simulate(&net, &cfg).unwrap_err();
        let mut session = SimSession::new(&net);
        assert_eq!(session.simulate_full(&cfg).unwrap_err(), fresh);
        assert_eq!(session.report(&cfg).unwrap_err(), fresh);
    }

    #[test]
    fn report_carries_residency() {
        let net = pimnet();
        let mut session = SimSession::new(&net);
        let ideal = session.report(&SimConfig::paper_favorable(8)).unwrap();
        assert!(ideal.fully_resident);
        let r = session.report(&SimConfig::conservative(8)).unwrap();
        let fresh = simulate(&net, &SimConfig::conservative(8)).unwrap();
        assert_eq!(
            r.fully_resident,
            fresh.layers.iter().all(|l| l.mapping.fully_resident())
        );
    }
}
