//! The system-level PIM-DRAM simulator (DESIGN.md S11): maps a network,
//! lowers it onto the channel × rank device grid (`crate::plan`), prices
//! every bank's compute/transfer phases per device, and aggregates the
//! replica pipelines into the report the paper's Fig 16/17 and the
//! scale-out benches are built from.

pub mod engine;
pub mod perturb;
pub mod session;
pub mod trace;

pub use engine::{
    price_layers, simulate, DeviceSim, LayerSim, ScaleOutReport, SimConfig, SimResult,
};
pub use perturb::{fault_hash, Perturbation};
pub use session::{SimReport, SimSession};
