//! The system-level PIM-DRAM simulator (DESIGN.md S11): maps a network,
//! prices every bank's compute/transfer phases, and produces the pipeline
//! report plus the GPU comparison the paper's Fig 16/17 are built from.

pub mod engine;
pub mod trace;

pub use engine::{simulate, LayerSim, SimConfig, SimResult};
