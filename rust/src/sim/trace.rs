//! Pipeline timeline tracer: renders the §IV-B dataflow as an ASCII Gantt
//! chart (banks × time) so mapping/schedule decisions are inspectable, and
//! exports a CSV for plotting.

use crate::sim::SimResult;

/// One traced interval on a bank's timeline. Borrows its bank label from
/// the [`SimResult`] it was traced from — a timeline is a *view* of a
/// result, and the layer-name strings never need copying.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span<'a> {
    pub bank: &'a str,
    pub start_ns: f64,
    pub end_ns: f64,
    pub kind: SpanKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Multiply,
    Logic,
    Restage,
    Transfer,
}

impl SpanKind {
    fn glyph(self) -> char {
        match self {
            SpanKind::Multiply => 'M',
            SpanKind::Logic => 'L',
            SpanKind::Restage => 'R',
            SpanKind::Transfer => 't',
        }
    }
}

/// Build the single-image (pipeline-fill) timeline from a sim result:
/// stage i starts when stage i-1's transfer lands.
pub fn fill_timeline(result: &SimResult) -> Vec<Span<'_>> {
    let mut spans = Vec::new();
    let mut clock = 0.0;
    for l in &result.layers {
        let phases = [
            (SpanKind::Multiply, l.multiply_ns),
            (SpanKind::Logic, l.logic_ns),
            (SpanKind::Restage, l.restage_ns),
            (SpanKind::Transfer, l.transfer_ns),
        ];
        for (kind, dur) in phases {
            if dur > 0.0 {
                spans.push(Span {
                    bank: &l.name,
                    start_ns: clock,
                    end_ns: clock + dur,
                    kind,
                });
                clock += dur;
            }
        }
    }
    spans
}

/// ASCII Gantt: one row per bank, `width` character columns over the fill.
pub fn ascii_gantt(spans: &[Span<'_>], width: usize) -> String {
    if spans.is_empty() {
        return String::new();
    }
    let total = spans.last().unwrap().end_ns.max(1e-9);
    let mut banks: Vec<&str> = Vec::new();
    for s in spans {
        if banks.last() != Some(&s.bank) {
            banks.push(s.bank);
        }
    }
    let name_w = banks.iter().map(|b| b.len()).max().unwrap_or(4).max(4);
    let mut out = String::new();
    for bank in &banks {
        let mut row = vec![b' '; width];
        for s in spans.iter().filter(|s| s.bank == *bank) {
            let a = ((s.start_ns / total) * width as f64) as usize;
            let b = (((s.end_ns / total) * width as f64).ceil() as usize).min(width);
            for cell in row.iter_mut().take(b).skip(a) {
                *cell = s.kind.glyph() as u8;
            }
        }
        out.push_str(&format!(
            "{:>name_w$} |{}|\n",
            bank,
            String::from_utf8(row).unwrap(),
            name_w = name_w
        ));
    }
    out.push_str(&format!(
        "{:>name_w$}  0 ns {:>w$.1} ns  (M=multiply L=tree/SFU R=restage t=transfer)\n",
        "",
        total,
        name_w = name_w,
        w = width.saturating_sub(8)
    ));
    out
}

/// CSV export: `bank,kind,start_ns,end_ns`.
pub fn to_csv(spans: &[Span<'_>]) -> String {
    let mut out = String::from("bank,kind,start_ns,end_ns\n");
    for s in spans {
        out.push_str(&format!(
            "{},{:?},{:.1},{:.1}\n",
            s.bank, s.kind, s.start_ns, s.end_ns
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimConfig};
    use crate::workloads::nets::{pimnet, vgg16};

    #[test]
    fn timeline_is_contiguous_and_ordered() {
        let r = simulate(&pimnet(), &SimConfig::paper_favorable(8)).unwrap();
        let spans = fill_timeline(&r);
        assert!(!spans.is_empty());
        for w in spans.windows(2) {
            assert!(w[0].end_ns <= w[1].start_ns + 1e-9);
        }
        let total: f64 = r
            .layers
            .iter()
            .map(|l| l.compute_ns() + l.transfer_ns)
            .sum();
        assert!((spans.last().unwrap().end_ns - total).abs() < 1e-6);
    }

    #[test]
    fn gantt_renders_every_bank() {
        let r = simulate(&pimnet(), &SimConfig::paper_favorable(8)).unwrap();
        let g = ascii_gantt(&fill_timeline(&r), 60);
        for l in &r.layers {
            assert!(g.contains(&l.name), "missing {}", l.name);
        }
        assert!(g.contains('M'));
    }

    #[test]
    fn restage_spans_appear_on_conservative_vgg() {
        let r = simulate(&vgg16(), &SimConfig::conservative(8)).unwrap();
        let spans = fill_timeline(&r);
        assert!(spans.iter().any(|s| s.kind == SpanKind::Restage));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = simulate(&pimnet(), &SimConfig::paper_favorable(8)).unwrap();
        let spans = fill_timeline(&r);
        let csv = to_csv(&spans);
        assert!(csv.starts_with("bank,kind,"));
        assert_eq!(csv.lines().count(), spans.len() + 1);
    }

    #[test]
    fn empty_spans_render_empty() {
        assert_eq!(ascii_gantt(&[], 40), "");
    }
}
