//! Minimal recursive-descent JSON parser (serde is unavailable offline).
//!
//! Supports the full JSON grammar the artifact manifest and test-vector
//! files use: objects, arrays, strings (with escapes), numbers, booleans,
//! null. Numbers are kept as `f64` plus an exact `i64` when integral.

// This parser faces arbitrary caller documents: every malformed input
// must come back as a `JsonError`, never a panic. CI runs clippy with
// -D warnings.
#![warn(clippy::needless_pass_by_value)]
#![warn(clippy::unwrap_used)]

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` chained with i64 extraction, with an error message for context.
    pub fn req_i64(&self, key: &str) -> anyhow::Result<i64> {
        self.get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid int field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid num field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid str field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    /// Extract `[i64]` from an array value.
    pub fn i64_vec(&self) -> anyhow::Result<Vec<i64>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_i64().ok_or_else(|| anyhow::anyhow!("expected int")))
            .collect()
    }

    /// Canonical pretty form: 2-space indent, object keys in `BTreeMap`
    /// (byte-sorted) order, scalar-only arrays inline, one trailing
    /// newline. Deterministic — re-rendering a parsed document reproduces
    /// it byte-for-byte, which is the property `tests/spec_roundtrip.rs`
    /// holds `examples/specs/` to.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        use std::fmt::Write;
        fn indent(out: &mut String, depth: usize) {
            for _ in 0..depth {
                out.push_str("  ");
            }
        }
        match self {
            Json::Arr(a)
                if a.iter().any(|v| matches!(v, Json::Arr(_) | Json::Obj(_))) =>
            {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    let _ = write!(out, "{v}");
                }
                out.push(']');
            }
            Json::Obj(o) if o.is_empty() => out.push_str("{}"),
            Json::Obj(o) => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    out.push_str(&escape_json_string(k));
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            scalar => {
                let _ = write!(out, "{scalar}");
            }
        }
    }
}

/// Escape a string as a JSON string literal (quotes included). Unlike
/// Rust's `{:?}` debug form, control characters get *JSON* escapes
/// (`\u00XX`), so the output always re-parses.
pub fn escape_json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => f.write_str(&escape_json_string(s)),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape_json_string(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unexpected end of string"))?;
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests assert by panicking
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-3.5").unwrap().as_f64(), Some(-3.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"\\ A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo µm²\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo µm²"));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn i64_vec_extraction() {
        let v = Json::parse("[1, -2, 3]").unwrap();
        assert_eq!(v.i64_vec().unwrap(), vec![1, -2, 3]);
        assert!(Json::parse("[1, \"x\"]").unwrap().i64_vec().is_err());
    }

    #[test]
    fn req_accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "t", "f": 1.5, "a": [1]}"#).unwrap();
        assert_eq!(v.req_i64("n").unwrap(), 5);
        assert_eq!(v.req_str("s").unwrap(), "t");
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert!(v.req_i64("missing").is_err());
        assert!(v.req_str("n").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let doc = r#"{"a":[1,true,"x"],"b":null}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_is_canonical() {
        let doc = r#"{"b": [1, 2], "a": {"x": true}, "c": [], "d": [{"k": 1}]}"#;
        let v = Json::parse(doc).unwrap();
        let text = v.pretty();
        assert_eq!(
            text,
            "{\n  \"a\": {\n    \"x\": true\n  },\n  \"b\": [1, 2],\n  \
             \"c\": [],\n  \"d\": [\n    {\n      \"k\": 1\n    }\n  ]\n}\n"
        );
        // Parse → pretty is a fixed point.
        let reparsed = Json::parse(&text).unwrap();
        assert_eq!(reparsed, v);
        assert_eq!(reparsed.pretty(), text);
    }

    #[test]
    fn pretty_scalars_and_empties() {
        assert_eq!(Json::parse("3").unwrap().pretty(), "3\n");
        assert_eq!(Json::parse("{}").unwrap().pretty(), "{}\n");
        assert_eq!(Json::parse("[1.5, null]").unwrap().pretty(), "[1.5, null]\n");
    }

    #[test]
    fn control_characters_re_emit_as_valid_json() {
        // Rust debug escapes (`\u{8}`) are not JSON; both render paths
        // must emit JSON escapes that re-parse.
        let v = Json::parse("\"a\\u0008b\\u001fc\"").unwrap();
        assert_eq!(v.as_str(), Some("a\u{0008}b\u{001f}c"));
        assert_eq!(v.to_string(), "\"a\\bb\\u001fc\"");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(v.pretty().trim_end()).unwrap(), v);
        // Quotes, backslashes, and keys round-trip too.
        let q = Json::Str("say \"hi\" \\ done".to_string());
        assert_eq!(Json::parse(&q.to_string()).unwrap(), q);
        let obj = Json::parse("{\"k\\n\": 1}").unwrap();
        assert_eq!(Json::parse(&obj.to_string()).unwrap(), obj);
        assert_eq!(Json::parse(obj.pretty().trim_end()).unwrap(), obj);
    }
}
