//! ASCII table formatter for benches and CLI reports — every paper
//! table/figure bench prints its rows through this so outputs align.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// Simple monospace table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            aligns: header.iter().map(|_| Align::Right).collect(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Set all alignments at once (must match header length).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+\n";
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                let (l, r) = match self.aligns[i] {
                    Align::Left => (0, pad),
                    Align::Right => (pad, 0),
                };
                line.push_str(&format!(
                    "| {}{}{} ",
                    " ".repeat(l),
                    cells[i],
                    " ".repeat(r)
                ));
            }
            line.push_str("|\n");
            line
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]).aligns(&[Align::Left, Align::Right]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"));
        assert!(s.contains("| a         |     1 |"));
        assert!(s.contains("| long-name | 12345 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_wrong_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn rowf_displayables() {
        let mut t = Table::new(&["x", "y"]);
        t.rowf(&[&1.5f64, &"s"]);
        assert!(t.render().contains("1.5"));
    }

    #[test]
    fn empty_table_renders_header() {
        let t = Table::new(&["h"]);
        assert!(t.is_empty());
        assert!(t.render().contains("| h |"));
    }
}
