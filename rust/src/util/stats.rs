//! Summary statistics and histograms for experiment harnesses
//! (Monte Carlo sense-margin analysis, bench timing distributions).

/// Running summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_values(values: Vec<f64>) -> Self {
        Summary { values }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (n-1 denominator); `NaN` on an empty
    /// set (like every other statistic here), 0 for a single sample.
    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n == 0 {
            return f64::NAN;
        }
        if n == 1 {
            return 0.0;
        }
        let mean = self.mean();
        let ss: f64 = self.values.iter().map(|v| (v - mean).powi(2)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Smallest sample; `NaN` on an empty set — the fold's `+INFINITY`
    /// seed used to leak out, disagreeing with `mean()`/`percentile()`.
    pub fn min(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; `NaN` on an empty set (see [`Summary::min`]).
    pub fn max(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-bin histogram over `[lo, hi)`; used for the Fig 15 reproduction.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, v: f64) {
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((v - self.lo) / (self.hi - self.lo)
                * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[idx.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Bin center for index `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Render as a horizontal ASCII bar chart (label, width chars).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &count) in self.bins.iter().enumerate() {
            let bar = "#".repeat((count as usize * width) / max as usize);
            out.push_str(&format!(
                "{:>9.4} | {:<width$} {}\n",
                self.center(i),
                bar,
                count,
                width = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_values(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_values((0..=100).map(f64::from).collect());
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(25.0), 25.0);
    }

    #[test]
    fn empty_summary_is_nan_everywhere() {
        // Every statistic of an empty sample set is NaN — min/max used to
        // return ±INFINITY while mean/percentile returned NaN.
        let s = Summary::new();
        assert!(s.is_empty());
        assert!(s.mean().is_nan());
        assert!(s.std().is_nan());
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.median().is_nan());
    }

    #[test]
    fn single_sample_summary() {
        let s = Summary::from_values(vec![3.5]);
        assert_eq!(s.mean(), 3.5);
        assert_eq!(s.min(), 3.5);
        assert_eq!(s.max(), 3.5);
        assert_eq!(s.median(), 3.5);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.bins.iter().all(|&b| b == 1));
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn histogram_centers() {
        let h = Histogram::new(0.0, 1.0, 4);
        assert!((h.center(0) - 0.125).abs() < 1e-12);
        assert!((h.center(3) - 0.875).abs() < 1e-12);
    }

    #[test]
    fn histogram_ascii_renders() {
        let mut h = Histogram::new(0.0, 2.0, 2);
        h.add(0.5);
        h.add(1.5);
        h.add(1.6);
        let s = h.ascii(10);
        assert!(s.contains('#'));
        assert_eq!(s.lines().count(), 2);
    }
}
