//! Deterministic PRNG substrate (the offline registry has no `rand`).
//!
//! `Xoshiro256**` for uniform streams plus a Box–Muller transform for the
//! Gaussians used by the circuit Monte Carlo (Fig 15) and the property-test
//! helper. Deterministic by construction: same seed → same stream on every
//! platform, which the experiment harnesses rely on for reproducibility.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from the last Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so even small seeds give well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln() finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Gaussian with given mean/sigma.
    #[inline]
    pub fn gaussian(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_covers_range() {
        let mut rng = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn int_range_inclusive() {
        let mut rng = Rng::new(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2_000 {
            let v = rng.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gaussian_scaling() {
        let mut rng = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05);
    }
}
