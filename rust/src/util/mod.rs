//! Small self-contained utilities shared across the crate.
//!
//! The offline crate registry has no `serde`/`rand`/`prettytable`, so the
//! JSON parser, RNG and table formatter live here as first-class substrates
//! (DESIGN.md §4, S16–S19).

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// `log2(ceil_pow2(n))`: number of adder-tree levels needed for `n` inputs.
#[inline]
pub fn log2_ceil(n: usize) -> u32 {
    debug_assert!(n > 0);
    usize::BITS - (n - 1).leading_zeros()
}

/// Format a float with engineering-style SI suffix (k, M, G, T).
pub fn si(v: f64) -> String {
    let (div, suffix) = match v.abs() {
        x if x >= 1e12 => (1e12, "T"),
        x if x >= 1e9 => (1e9, "G"),
        x if x >= 1e6 => (1e6, "M"),
        x if x >= 1e3 => (1e3, "k"),
        _ => (1.0, ""),
    };
    format!("{:.3}{}", v / div, suffix)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 1), 1);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(10, 8), 16);
        assert_eq!(round_up(16, 8), 16);
        assert_eq!(round_up(0, 8), 0);
    }

    #[test]
    fn log2_ceil_basics() {
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(4096), 12);
        assert_eq!(log2_ceil(4097), 13);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(1500.0), "1.500k");
        assert_eq!(si(2.5e9), "2.500G");
        assert_eq!(si(12.0), "12.000");
    }
}
