//! Property-testing helper (proptest is unavailable offline).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! retries with a binary-search-style "shrink" over the case index space is
//! not meaningful for seeded generation, so instead it reports the failing
//! seed so the case can be replayed deterministically:
//!
//! ```ignore
//! testutil::check(200, |rng| {
//!     let n = rng.int_range(1, 16) as u32;
//!     let traced = mul_trace_aap_count(n);
//!     prop_assert!(traced > 0);
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Property outcome: `Err(msg)` fails the case and reports the seed.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` deterministic seeds (0..cases), panicking with
/// the first failing seed and message. Each case gets an independent RNG so
/// failures replay exactly via `replay`.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at seed {seed} (replay: testutil::replay({seed}, prop)):\n  {msg}"
            );
        }
    }
}

/// Replay a single failing seed (for debugging).
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed at seed {seed}:\n  {msg}");
    }
}

/// Assert inside a property, returning `Err` instead of panicking so the
/// harness can attach the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!(
                "assertion failed: {} ({}:{})",
                stringify!($cond),
                file!(),
                line!()
            ));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!(
                "assertion failed: {} — {} ({}:{})",
                stringify!($cond),
                format!($($fmt)+),
                file!(),
                line!()
            ));
        }
    };
}

/// `prop_assert_eq!(a, b)` with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "assertion failed: {} == {} (left: {:?}, right: {:?}) ({}:{})",
                stringify!($a),
                stringify!($b),
                a,
                b,
                file!(),
                line!()
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(50, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn failing_property_reports_seed() {
        check(10, |rng| {
            let v = rng.int_range(0, 100);
            prop_assert!(v < 0, "v={v}");
            Ok(())
        });
    }

    #[test]
    fn prop_assert_eq_formats_values() {
        let result: PropResult = (|| {
            prop_assert_eq!(1 + 1, 3);
            Ok(())
        })();
        let msg = result.unwrap_err();
        assert!(msg.contains("left: 2"));
        assert!(msg.contains("right: 3"));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check(5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check(5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
