//! # PIM-DRAM
//!
//! Full-system reproduction of *PIM-DRAM: Accelerating Machine Learning
//! Workloads using Processing in Commodity DRAM* (Roy, Ali, Raghunathan, 2021).
//!
//! The crate is the Layer-3 (coordinator) half of a three-layer stack:
//!
//! * **L1** — Pallas bit-serial matmul kernel (`python/compile/kernels/`),
//!   the functional analogue of the paper's in-subarray multiplication.
//! * **L2** — JAX quantized-CNN graph (`python/compile/model.py`), lowered
//!   once (AOT) to HLO text artifacts.
//! * **L3** — this crate: the DRAM PIM *system* — device/timing model,
//!   in-DRAM compute primitives, circuit-level bitline simulation, bank
//!   peripheral architecture, the paper's mapping algorithm and pipelined
//!   dataflow, a GPU roofline baseline, the device-scoped execution-plan
//!   layer that shards networks across the channel × rank grid
//!   (`plan`), and a multi-device request coordinator that serves batched
//!   traffic from the planned devices (optionally executing the AOT
//!   artifacts via PJRT — `--features pjrt` — while the timing model
//!   prices the same work in DRAM cycles). The versioned `api` layer
//!   (`Spec` → `Job` → report) is the single construction path for all of
//!   it — CLI, TOML configs, benches and serving included.
//!
//! Workloads are authored as typed operator graphs (`ir::Graph` — conv,
//! depthwise conv, linear, matmul, residual adds as ordinary edges) and
//! lowered by the `ir` pass pipeline (shape inference → SFU fusion →
//! bank-op legalization → topological bank-stage scheduling) into the
//! per-bank stage form the rest of the stack prices.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for reproduction results.

// Crate-wide: a reintroduced clone anywhere fails CI (clippy runs with
// -D warnings). Previously scoped to the sim/plan hot paths only.
#![warn(clippy::redundant_clone)]

pub mod analysis;
pub mod api;
pub mod arch;
pub mod bench_harness;
pub mod circuit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod dram;
pub mod energy;
pub mod gpu;
pub mod ir;
pub mod mapopt;
pub mod mapping;
pub mod plan;
pub mod primitives;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod util;
pub mod workloads;
