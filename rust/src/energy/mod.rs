//! Area / power / delay models for the bank peripheral logic (DESIGN.md S9)
//! — reproduces Tables I and II and scales for the ablation studies.
//!
//! The paper synthesizes the RTL with Cadence RTL Compiler to TSMC 65 nm
//! and adds a 21.5 % delay penalty for DRAM-process logic ([17]). Neither
//! tool is available offline, so each component is an analytical model
//! *calibrated to the paper's published totals* (Table I area, Table II
//! power at the 4096-input adder tree design point) and scaled by gate
//! count for other configurations.

pub mod compare;

use crate::util::table::{Align, Table};

/// Delay derate for logic implemented in a DRAM process (§V-B, [17]).
pub const DRAM_PROCESS_DELAY_FACTOR: f64 = 1.215;

/// Peripheral logic clock before DRAM-process derating (GHz).
pub const LOGIC_CLOCK_GHZ: f64 = 0.5;

/// Effective logic cycle time in ns including the 21.5 % derate.
pub fn logic_cycle_ns() -> f64 {
    (1.0 / LOGIC_CLOCK_GHZ) * DRAM_PROCESS_DELAY_FACTOR
}

/// Calibration anchors from Tables I and II (65 nm, 4096-input tree).
pub const PAPER_ADDER_INPUTS: usize = 4096;
pub const PAPER_ADDER_AREA_UM2: f64 = 514_877.0;
pub const PAPER_ADDER_POWER_NW: f64 = 13_200_190.9;
pub const PAPER_ACCUM_AREA_UM2: f64 = 804.0;
pub const PAPER_ACCUM_POWER_NW: f64 = 177_765.864;
pub const PAPER_RELU_AREA_UM2: f64 = 431.0;
pub const PAPER_RELU_POWER_NW: f64 = 109_913.671;
pub const PAPER_MAXPOOL_AREA_UM2: f64 = 983.0;
pub const PAPER_MAXPOOL_POWER_NW: f64 = 127_562.373;
pub const PAPER_BATCHNORM_AREA_UM2: f64 = 506.0;
pub const PAPER_BATCHNORM_POWER_NW: f64 = 120_541.29;
pub const PAPER_QUANTIZE_AREA_UM2: f64 = 91.0;
pub const PAPER_QUANTIZE_POWER_NW: f64 = 28_366.738;
/// §IV-A.6: example 256×8 SRAM transpose unit area.
pub const PAPER_TRANSPOSE_AREA_UM2: f64 = 30_534.894;

/// One peripheral component's modeled area and power.
#[derive(Debug, Clone, PartialEq)]
pub struct Component {
    pub name: &'static str,
    pub area_um2: f64,
    pub power_nw: f64,
}

/// Adder-tree area scaled by unit count ((inputs−1) two-input adders),
/// calibrated at the paper's 4096-input point.
pub fn adder_tree_area_um2(inputs: usize) -> f64 {
    assert!(inputs >= 2);
    PAPER_ADDER_AREA_UM2 * (inputs as f64 - 1.0) / (PAPER_ADDER_INPUTS as f64 - 1.0)
}

/// Adder-tree power scaled the same way.
pub fn adder_tree_power_nw(inputs: usize) -> f64 {
    assert!(inputs >= 2);
    PAPER_ADDER_POWER_NW * (inputs as f64 - 1.0) / (PAPER_ADDER_INPUTS as f64 - 1.0)
}

/// Transpose-unit area scaled by SRAM bit count from the 256×8 anchor.
pub fn transpose_area_um2(rows: usize, bits: usize) -> f64 {
    PAPER_TRANSPOSE_AREA_UM2 * (rows * bits) as f64 / (256.0 * 8.0)
}

/// The Table I / Table II component set for a bank with an `inputs`-wide
/// adder tree (paper order).
pub fn bank_components(inputs: usize) -> Vec<Component> {
    vec![
        Component {
            name: "4096 Adder",
            area_um2: adder_tree_area_um2(inputs),
            power_nw: adder_tree_power_nw(inputs),
        },
        Component {
            name: "Accumulator",
            area_um2: PAPER_ACCUM_AREA_UM2,
            power_nw: PAPER_ACCUM_POWER_NW,
        },
        Component {
            name: "Relu",
            area_um2: PAPER_RELU_AREA_UM2,
            power_nw: PAPER_RELU_POWER_NW,
        },
        Component {
            name: "Maxpool",
            area_um2: PAPER_MAXPOOL_AREA_UM2,
            power_nw: PAPER_MAXPOOL_POWER_NW,
        },
        Component {
            name: "Batchnorm",
            area_um2: PAPER_BATCHNORM_AREA_UM2,
            power_nw: PAPER_BATCHNORM_POWER_NW,
        },
        Component {
            name: "Quantize",
            area_um2: PAPER_QUANTIZE_AREA_UM2,
            power_nw: PAPER_QUANTIZE_POWER_NW,
        },
    ]
}

/// Render the Table I reproduction (area + relative %).
pub fn render_area_table(inputs: usize) -> String {
    let comps = bank_components(inputs);
    let total: f64 = comps.iter().map(|c| c.area_um2).sum();
    let mut t = Table::new(&["Component", "Area(um^2)", "Relative Percentage"])
        .aligns(&[Align::Left, Align::Right, Align::Right]);
    for c in &comps {
        t.row(&[
            c.name.to_string(),
            format!("{:.3}", c.area_um2),
            format!("{:.5}", 100.0 * c.area_um2 / total),
        ]);
    }
    t.render()
}

/// Render the Table II reproduction (power + relative %).
pub fn render_power_table(inputs: usize) -> String {
    let comps = bank_components(inputs);
    let total: f64 = comps.iter().map(|c| c.power_nw).sum();
    let mut t = Table::new(&["Component", "Power(nW)", "Relative Percentage"])
        .aligns(&[Align::Left, Align::Right, Align::Right]);
    for c in &comps {
        t.row(&[
            c.name.to_string(),
            format!("{:.3}", c.power_nw),
            format!("{:.4}", 100.0 * c.power_nw / total),
        ]);
    }
    t.render()
}

/// Total peripheral area per bank (µm²), incl. the transpose unit.
pub fn bank_peripheral_area_um2(inputs: usize) -> f64 {
    bank_components(inputs).iter().map(|c| c.area_um2).sum::<f64>()
        + transpose_area_um2(256, 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_adder_dominates_area() {
        // Paper Table I prints 99.47373 %, but its own absolute numbers
        // give 514877/517692 = 99.456 % — the published percentages are
        // internally inconsistent by ~0.02 % (DESIGN.md §7). We reproduce
        // the absolute areas exactly and accept either percentage.
        let comps = bank_components(4096);
        let total: f64 = comps.iter().map(|c| c.area_um2).sum();
        let adder_pct = 100.0 * comps[0].area_um2 / total;
        assert!((adder_pct - 99.47373).abs() < 0.05, "adder% = {adder_pct}");
    }

    #[test]
    fn table2_adder_dominates_power() {
        // Paper Table II: 95.9014 % of power.
        let comps = bank_components(4096);
        let total: f64 = comps.iter().map(|c| c.power_nw).sum();
        let adder_pct = 100.0 * comps[0].power_nw / total;
        assert!((adder_pct - 95.9014).abs() < 0.01, "adder% = {adder_pct}");
    }

    #[test]
    fn calibration_point_exact() {
        assert_eq!(adder_tree_area_um2(4096), PAPER_ADDER_AREA_UM2);
        assert_eq!(adder_tree_power_nw(4096), PAPER_ADDER_POWER_NW);
        assert_eq!(transpose_area_um2(256, 8), PAPER_TRANSPOSE_AREA_UM2);
    }

    #[test]
    fn adder_scaling_linear_in_units() {
        let half = adder_tree_area_um2(2048);
        // 2047 units vs 4095 units.
        assert!((half / PAPER_ADDER_AREA_UM2 - 2047.0 / 4095.0).abs() < 1e-12);
        assert!(adder_tree_power_nw(8192) > PAPER_ADDER_POWER_NW * 1.9);
    }

    #[test]
    fn derated_logic_clock() {
        // 500 MHz nominal → 2 ns × 1.215 = 2.43 ns per cycle.
        assert!((logic_cycle_ns() - 2.43).abs() < 1e-12);
    }

    #[test]
    fn tables_render_paper_rows() {
        let a = render_area_table(4096);
        assert!(a.contains("514877.000"));
        assert!(a.contains("99.4"));
        let p = render_power_table(4096);
        assert!(p.contains("13200190.9"));
        assert!(p.contains("95.90"));
    }
}
