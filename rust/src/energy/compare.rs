//! Energy comparison: PIM-DRAM vs GPU per inference — the natural
//! extension of the paper's evaluation (it reports performance only; the
//! PIM literature's other headline is energy).
//!
//! GPU energy model: board power × ideal execution time (optimistic for
//! the GPU — idle/static power excluded, matching the "ideal GPU" stance
//! of Fig 16). PIM energy: DRAM command + bus energy from the command
//! stream plus peripheral-logic busy energy from the Table II power model.

use crate::gpu::GpuModel;
use crate::sim::SimResult;
use crate::workloads::Network;

/// Board power of the GPU baseline (Titan Xp TDP, W).
pub const TITAN_XP_TDP_W: f64 = 250.0;

/// Energy-per-image comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyComparison {
    pub net: String,
    /// PIM DRAM-array + bus energy (mJ/image).
    pub pim_dram_mj: f64,
    /// PIM peripheral logic energy (mJ/image).
    pub pim_logic_mj: f64,
    /// GPU energy at TDP × ideal time (mJ/image).
    pub gpu_mj: f64,
}

impl EnergyComparison {
    pub fn pim_total_mj(&self) -> f64 {
        self.pim_dram_mj + self.pim_logic_mj
    }

    /// Energy-efficiency ratio (>1 ⇒ PIM uses less energy).
    pub fn efficiency_ratio(&self) -> f64 {
        self.gpu_mj / self.pim_total_mj()
    }
}

/// Build the comparison from a simulation result.
pub fn compare(result: &SimResult, net: &Network, gpu: &GpuModel) -> EnergyComparison {
    let gpu_s = gpu.network_time_s(net, 4);
    EnergyComparison {
        net: net.name.clone(),
        pim_dram_mj: result.total_dram_energy_nj / 1e6,
        pim_logic_mj: result.logic_energy_nj / 1e6,
        gpu_mj: TITAN_XP_TDP_W * gpu_s * 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, SimConfig};
    use crate::workloads::nets::{alexnet, vgg16};

    #[test]
    fn components_positive() {
        let net = alexnet();
        let r = simulate(&net, &SimConfig::paper_favorable(8)).unwrap();
        let c = compare(&r, &net, &GpuModel::titan_xp());
        assert!(c.pim_dram_mj > 0.0 && c.pim_logic_mj > 0.0 && c.gpu_mj > 0.0);
        assert!(c.efficiency_ratio().is_finite());
    }

    #[test]
    fn gpu_energy_tracks_time() {
        let gpu = GpuModel::titan_xp();
        let (a, v) = (alexnet(), vgg16());
        let ra = simulate(&a, &SimConfig::paper_favorable(8)).unwrap();
        let rv = simulate(&v, &SimConfig::paper_favorable(8)).unwrap();
        let ca = compare(&ra, &a, &gpu);
        let cv = compare(&rv, &v, &gpu);
        // VGG16 is ~6x more GPU time than AlexNet → ~6x the energy.
        let ratio = cv.gpu_mj / ca.gpu_mj;
        let time_ratio = gpu.network_time_s(&v, 4) / gpu.network_time_s(&a, 4);
        assert!((ratio - time_ratio).abs() < 1e-9);
    }

    #[test]
    fn lower_precision_uses_less_pim_energy() {
        let net = alexnet();
        let gpu = GpuModel::titan_xp();
        let e4 = compare(
            &simulate(&net, &SimConfig::paper_favorable(4)).unwrap(),
            &net,
            &gpu,
        );
        let e8 = compare(
            &simulate(&net, &SimConfig::paper_favorable(8)).unwrap(),
            &net,
            &gpu,
        );
        assert!(e4.pim_dram_mj < e8.pim_dram_mj);
    }
}
