//! Majority-based bit-serial in-DRAM ADD (Ali et al. [5], adopted in §II-B):
//!
//!   Cout = MAJ3(A, B, Cin)                     — triple-row activation
//!   Sum  = MAJ5(A, B, Cin, !Cout, !Cout)       — quintuple-row activation
//!
//! Operands are bit-transposed (one row per bit). Per bit: two dual-copies
//! stage the operand bits into (A, A-1) / (B, B-1), a TRA produces the
//! carry (captured into Cout/Cout-1 through the dual-contact cells), and a
//! quintuple activation produces the sum bit. The carry for the next bit is
//! the TRA's own restore value in Cin; the paper notes "Cin is copied to
//! Cin-1 for storing the same value" — [5]'s row decoder folds that refresh
//! into the same AAPs, so the charged total is the published `4n + 1`.

use super::PimSubarray;
use crate::dram::subarray::ActRow;
use crate::dram::Command;

/// Add two n-bit transposed operands: `dst_rows` receives n+1 result bits
/// (LSB first; the final carry lands in `dst_rows[n]`). Charges `4n + 1`
/// AAPs. Rows must all be distinct from the compute rows.
pub fn in_dram_add(
    p: &mut PimSubarray,
    a_rows: &[usize],
    b_rows: &[usize],
    dst_rows: &[usize],
) {
    let n = a_rows.len();
    assert_eq!(b_rows.len(), n, "operand width mismatch");
    assert_eq!(dst_rows.len(), n + 1, "dst must have n+1 rows");
    let l = p.layout;

    // Init: zero the carry rows (dual RowClone from row0) — the "+1".
    p.sa.copy_row(l.row0, l.cin);
    p.sa.copy_row(l.row0, l.cin1);
    p.charge(Command::RowCloneIntra);

    for i in 0..n {
        // Stage operand bits (split decoder writes both copies per AAP).
        p.sa.copy_row(a_rows[i], l.a);
        p.sa.copy_row(a_rows[i], l.a1);
        p.charge(Command::RowCloneIntra);
        p.sa.copy_row(b_rows[i], l.b);
        p.sa.copy_row(b_rows[i], l.b1);
        p.charge(Command::RowCloneIntra);

        // TRA: carry out. Restore overwrites A, B, Cin with MAJ3; the DCC
        // rows capture (Cout, !Cout) in the same AAP; the final bit also
        // drops the carry into dst[n] during the second activation.
        let cout = p.sa.multi_activate(&[
            ActRow::plain(l.a),
            ActRow::plain(l.b),
            ActRow::plain(l.cin),
        ]);
        p.sa.write_row(l.cout, &cout);
        p.sa.write_row(l.cout1, &cout.not());
        if i == n - 1 {
            p.sa.write_row(dst_rows[n], &cout);
        }
        p.charge(Command::Aap { rows: 3 });

        // Quintuple activation: Sum = MAJ5(A-1, B-1, Cin-1, !Cout, !Cout).
        // Both complement terms come from the DCC pair (Cout read negated,
        // Cout-1 read plain).
        let sum = p.sa.multi_activate(&[
            ActRow::plain(l.a1),
            ActRow::plain(l.b1),
            ActRow::plain(l.cin1),
            ActRow::neg(l.cout),
            ActRow::plain(l.cout1),
        ]);
        p.sa.write_row(dst_rows[i], &sum);
        p.charge(Command::Aap { rows: 5 });

        // Carry maintenance folded into the decoder writes: Cin already
        // holds Cout via the TRA restore; refresh Cin-1 to match.
        p.sa.copy_row(l.cin, l.cin1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::primitives::cost::add_aaps;

    /// Write value `v` bit-transposed into rows `rows` at column `col`.
    fn write_val(p: &mut PimSubarray, rows: &[usize], col: usize, v: u64) {
        for (i, &r) in rows.iter().enumerate() {
            p.sa.set_bit(r, col, (v >> i) & 1 == 1);
        }
    }

    fn read_val(p: &PimSubarray, rows: &[usize], col: usize) -> u64 {
        rows.iter()
            .enumerate()
            .map(|(i, &r)| (p.sa.get_bit(r, col) as u64) << i)
            .sum()
    }

    /// Helper: allocate disjoint row groups in the data region.
    fn rows_at(p: &PimSubarray, group: usize, n: usize) -> Vec<usize> {
        let base = p.layout.data_base + group * n.max(1);
        (0..n).map(|i| base + i).collect()
    }

    fn add_case(n: usize, pairs: &[(u64, u64)]) {
        let cols = pairs.len();
        // Generous subarray: 3 groups of up to n+1 rows.
        let mut p = PimSubarray::new(n.min(16), cols, 8);
        let a_rows = rows_at(&p, 0, n);
        let b_rows: Vec<usize> = rows_at(&p, 1, n);
        let dst: Vec<usize> = rows_at(&p, 2, n + 1);
        for (col, &(a, b)) in pairs.iter().enumerate() {
            write_val(&mut p, &a_rows, col, a);
            write_val(&mut p, &b_rows, col, b);
        }
        in_dram_add(&mut p, &a_rows, &b_rows, &dst);
        for (col, &(a, b)) in pairs.iter().enumerate() {
            assert_eq!(
                read_val(&p, &dst, col),
                a + b,
                "col {col}: {a} + {b} (n={n})"
            );
        }
        assert_eq!(p.stats.total_aaps(), add_aaps(n as u64));
    }

    #[test]
    fn exhaustive_4bit() {
        // All 256 (a, b) combinations, packed 16 columns at a time.
        let all: Vec<(u64, u64)> =
            (0..16).flat_map(|a| (0..16).map(move |b| (a, b))).collect();
        for chunk in all.chunks(16) {
            add_case(4, chunk);
        }
    }

    #[test]
    fn exhaustive_1bit() {
        add_case(1, &[(0, 0), (0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn wide_operands() {
        add_case(16, &[(0xFFFF, 0xFFFF), (0x8000, 0x8000), (0x1234, 0x0FED)]);
    }

    #[test]
    fn cost_matches_published_formula() {
        for n in [1usize, 2, 4, 8, 12] {
            let mut p = PimSubarray::new(8, 4, 8);
            let a_rows = rows_at(&p, 0, n);
            let b_rows = rows_at(&p, 1, n);
            let dst = rows_at(&p, 2, n + 1);
            in_dram_add(&mut p, &a_rows, &b_rows, &dst);
            assert_eq!(p.stats.total_aaps(), 4 * n as u64 + 1, "n={n}");
        }
    }

    #[test]
    fn random_additions_property() {
        crate::testutil::check(40, |rng| {
            let n = rng.int_range(1, 16) as usize;
            let cols = rng.int_range(1, 32) as usize;
            let mut p = PimSubarray::new(n.min(16), cols, 8);
            let a_rows = rows_at(&p, 0, n);
            let b_rows = rows_at(&p, 1, n);
            let dst = rows_at(&p, 2, n + 1);
            let mut expect = Vec::new();
            for col in 0..cols {
                let a = rng.int_range(0, (1i64 << n) - 1) as u64;
                let b = rng.int_range(0, (1i64 << n) - 1) as u64;
                write_val(&mut p, &a_rows, col, a);
                write_val(&mut p, &b_rows, col, b);
                expect.push(a + b);
            }
            in_dram_add(&mut p, &a_rows, &b_rows, &dst);
            for (col, &want) in expect.iter().enumerate() {
                prop_assert_eq!(read_val(&p, &dst, col), want);
            }
            Ok(())
        });
    }
}
