//! The paper's core contribution: n-bit column-parallel multiplication in a
//! DRAM subarray (§III-B).
//!
//! Schoolbook decomposition: n² partial products, each an in-subarray AND
//! of one activation bit-plane and one weight bit-plane (rows, so every
//! column multiplies in parallel), accumulated into the product rows
//! P0..P(2n-1) with the majority-based adder:
//!
//!   sum  = a XOR pp  == MAJ5(a, pp, row0, !carry, !carry)
//!   cout = a AND pp  == MAJ3(a, pp, row0)
//!
//! (with row0 ≡ 0, MAJ3 degenerates to AND and MAJ5 to XOR — the same
//! identity the §III-B walkthrough uses when it copies row0 into B/B-1
//! before the final column). The functional result is exact for all
//! operands; the AAP cost charged is the paper's closed form
//! ([`cost::mul_aaps`]), with the derived count available for comparison.

use super::{cost, PimSubarray};
use crate::dram::{BitRow, Command};

/// Multiply the stacked operand pair `pair` in every column simultaneously.
/// Products land in the P rows (read back with
/// [`PimSubarray::read_product`]); original operands are preserved.
pub fn in_dram_mul(p: &mut PimSubarray, pair: usize) {
    let n = p.layout.n;
    let cols = p.sa.cols();
    let zero = BitRow::zeros(cols);

    // Zero the product rows (RowClone from row0; charged in the closed
    // form's initialization term).
    let mut acc: Vec<BitRow> = vec![zero.clone(); 2 * n];

    // Scratch rows reused across all n² partial products — the inner loop
    // is allocation-free (§Perf: 2.4× over the allocating version).
    let mut carry = zero.clone();
    let mut tmp = zero;

    for i in 0..n {
        for j in 0..n {
            // Partial product: AND of activation bit-plane i and weight
            // bit-plane j (the 3-transistor AND-WL, column-parallel).
            p.sa
                .row(p.layout.act_row(pair, i))
                .and_into(p.sa.row(p.layout.wgt_row(pair, j)), &mut carry);

            // Ripple the 1-bit plane into the accumulator rows starting at
            // bit position i+j (majority-adder identities above):
            //   tmp   = slot AND carry   (MAJ3(a, c, 0) — next carry)
            //   slot ^= carry            (MAJ5(a, c, 0, !k, !k) — sum)
            for slot in acc.iter_mut().skip(i + j) {
                if carry.is_zero() {
                    break;
                }
                slot.and_into(&carry, &mut tmp);
                slot.xor_assign(&carry);
                std::mem::swap(&mut carry, &mut tmp);
            }
            debug_assert!(carry.is_zero(), "product overflowed 2n bits");
        }
    }

    // Drive the accumulated planes into the physical product rows.
    for (bit, plane) in acc.iter().enumerate() {
        p.sa.write_row(p.layout.p_row(bit), plane);
    }

    charge_mul(p, n as u64);
}

/// Charge the closed-form AAP cost of one n-bit multiply, split into the
/// command classes it is composed of (3 AAPs per AND = two staging
/// RowClones + the AND-WL activation; the remainder are the adder's
/// TRA/quintuple activations, split evenly for energy accounting).
fn charge_mul(p: &mut PimSubarray, n: u64) {
    let total = cost::mul_aaps(p.cost_model, n);
    let and_ops = cost::mul_and_ops(n);
    for _ in 0..and_ops {
        p.charge(Command::RowCloneIntra);
        p.charge(Command::RowCloneIntra);
        p.charge(Command::Aap { rows: 1 });
    }
    let remaining = total.saturating_sub(and_ops * cost::AND_AAPS);
    for k in 0..remaining {
        p.charge(Command::Aap { rows: if k % 2 == 0 { 3 } else { 5 } });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;
    use crate::primitives::cost::{paper_mul_aaps, CostModel};

    fn mul_case(n: usize, pairs_vals: &[(u64, u64)]) {
        let cols = pairs_vals.len();
        let mut p = PimSubarray::new(n, cols, 1);
        for (col, &(a, w)) in pairs_vals.iter().enumerate() {
            p.write_pair(col, 0, a, w);
        }
        in_dram_mul(&mut p, 0);
        for (col, &(a, w)) in pairs_vals.iter().enumerate() {
            assert_eq!(p.read_product(col), a * w, "col {col}: {a} * {w} (n={n})");
        }
    }

    #[test]
    fn exhaustive_2bit() {
        // The paper's worked example size: all 16 combinations at once.
        let all: Vec<(u64, u64)> =
            (0..4).flat_map(|a| (0..4).map(move |b| (a, b))).collect();
        mul_case(2, &all);
    }

    #[test]
    fn exhaustive_4bit() {
        let all: Vec<(u64, u64)> =
            (0..16).flat_map(|a| (0..16).map(move |b| (a, b))).collect();
        for chunk in all.chunks(64) {
            mul_case(4, chunk);
        }
    }

    #[test]
    fn eight_bit_corners() {
        mul_case(
            8,
            &[
                (0, 0),
                (255, 255),
                (255, 1),
                (1, 255),
                (128, 128),
                (170, 85),
                (0, 255),
                (255, 0),
            ],
        );
    }

    #[test]
    fn one_bit_is_and() {
        mul_case(1, &[(0, 0), (0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn charged_aaps_match_paper_closed_form() {
        for n in [1usize, 2, 3, 4, 8, 12, 16] {
            let mut p = PimSubarray::new(n, 8, 1);
            p.write_pair(0, 0, 1, 1);
            in_dram_mul(&mut p, 0);
            assert_eq!(
                p.stats.total_aaps(),
                paper_mul_aaps(n as u64),
                "n={n}"
            );
        }
    }

    #[test]
    fn derived_cost_model_switch() {
        let mut p = PimSubarray::new(8, 8, 1);
        p.cost_model = CostModel::Derived;
        in_dram_mul(&mut p, 0);
        assert_eq!(
            p.stats.total_aaps(),
            cost::derived_mul_aaps(8),
        );
    }

    #[test]
    fn operands_preserved_after_multiply() {
        let mut p = PimSubarray::new(4, 4, 1);
        p.write_pair(2, 0, 13, 11);
        in_dram_mul(&mut p, 0);
        // Re-run: operands must still be in place (non-destructive compute).
        in_dram_mul(&mut p, 0);
        assert_eq!(p.read_product(2), 143);
    }

    #[test]
    fn stacked_pairs_multiply_independently() {
        let mut p = PimSubarray::new(4, 2, 3);
        p.write_pair(0, 0, 3, 5);
        p.write_pair(0, 1, 7, 7);
        p.write_pair(0, 2, 15, 15);
        in_dram_mul(&mut p, 1);
        assert_eq!(p.read_product(0), 49);
        in_dram_mul(&mut p, 2);
        assert_eq!(p.read_product(0), 225);
        in_dram_mul(&mut p, 0);
        assert_eq!(p.read_product(0), 15);
    }

    #[test]
    fn random_products_property() {
        crate::testutil::check(60, |rng| {
            let n = rng.int_range(1, 12) as usize;
            let cols = rng.int_range(1, 24) as usize;
            let mut p = PimSubarray::new(n, cols, 1);
            let mut expect = Vec::new();
            for col in 0..cols {
                let a = rng.int_range(0, (1i64 << n) - 1) as u64;
                let w = rng.int_range(0, (1i64 << n) - 1) as u64;
                p.write_pair(col, 0, a, w);
                expect.push(a * w);
            }
            in_dram_mul(&mut p, 0);
            for (col, &want) in expect.iter().enumerate() {
                prop_assert_eq!(p.read_product(col), want);
            }
            Ok(())
        });
    }
}
