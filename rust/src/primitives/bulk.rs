//! Ambit-style bulk bitwise operations (§II-B background, Seshadri et al.
//! [14]) — the substrate the paper's AND builds on. Exposed as first-class
//! primitives because ternary/binary networks (the DRISA/DrAcc lineage the
//! paper compares against in §I) run directly on them.
//!
//! Costs follow Ambit's accounting: each op stages its operands into
//! compute rows with dual-write RowClones, performs one triple-row
//! activation, and lands the result via the second activation of the AAP.

use super::PimSubarray;
use crate::dram::subarray::ActRow;
use crate::dram::{BitRow, Command};

/// Bulk AND of two stored rows → `dst` (Ambit: MAJ3(a, b, 0)). 4 AAPs:
/// two dual-copies, zero-init of the control row, one TRA.
pub fn bulk_and(p: &mut PimSubarray, src1: usize, src2: usize, dst: usize) {
    maj3_with_control(p, src1, src2, dst, false)
}

/// Bulk OR of two stored rows → `dst` (Ambit: MAJ3(a, b, 1)). 4 AAPs.
pub fn bulk_or(p: &mut PimSubarray, src1: usize, src2: usize, dst: usize) {
    maj3_with_control(p, src1, src2, dst, true)
}

/// Bulk NOT via the dual-contact cell: read `src` through the DCC's
/// complementary wordline into `dst`. 2 AAPs (copy into the DCC row, AAP
/// out of its negated port).
pub fn bulk_not(p: &mut PimSubarray, src: usize, dst: usize) {
    let l = p.layout;
    p.sa.copy_row(src, l.cout);
    p.charge(Command::RowCloneIntra);
    let neg = p.sa.row(l.cout).not();
    p.sa.write_row(dst, &neg);
    p.charge(Command::Aap { rows: 1 });
}

/// Bulk 3-input majority (the raw TRA) of three stored rows → `dst`.
/// 4 AAPs: three copies (one dual) + the TRA.
pub fn bulk_maj3(
    p: &mut PimSubarray,
    src1: usize,
    src2: usize,
    src3: usize,
    dst: usize,
) {
    let l = p.layout;
    p.sa.copy_row(src1, l.a);
    p.charge(Command::RowCloneIntra);
    p.sa.copy_row(src2, l.b);
    p.charge(Command::RowCloneIntra);
    p.sa.copy_row(src3, l.cin);
    p.charge(Command::RowCloneIntra);
    let sensed = p.sa.multi_activate(&[
        ActRow::plain(l.a),
        ActRow::plain(l.b),
        ActRow::plain(l.cin),
    ]);
    p.sa.write_row(dst, &sensed);
    p.charge(Command::Aap { rows: 3 });
}

fn maj3_with_control(
    p: &mut PimSubarray,
    src1: usize,
    src2: usize,
    dst: usize,
    control: bool,
) {
    let l = p.layout;
    p.sa.copy_row(src1, l.a);
    p.charge(Command::RowCloneIntra);
    p.sa.copy_row(src2, l.b);
    p.charge(Command::RowCloneIntra);
    // Control row: 0 for AND, 1 for OR (row0 or its DCC complement).
    let ctrl = if control {
        BitRow::zeros(p.sa.cols()).not()
    } else {
        BitRow::zeros(p.sa.cols())
    };
    p.sa.write_row(l.cin, &ctrl);
    p.charge(Command::RowCloneIntra);
    let sensed = p.sa.multi_activate(&[
        ActRow::plain(l.a),
        ActRow::plain(l.b),
        ActRow::plain(l.cin),
    ]);
    p.sa.write_row(dst, &sensed);
    p.charge(Command::Aap { rows: 3 });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert_eq;

    fn setup(cols: usize) -> (PimSubarray, usize, usize, usize) {
        let p = PimSubarray::new(2, cols, 4);
        let base = p.layout.data_base;
        (p, base, base + 1, base + 2)
    }

    fn pattern(cols: usize, seed: usize) -> BitRow {
        BitRow::from_fn(cols, |c| (c * 7 + seed * 13) % 3 == 0)
    }

    #[test]
    fn and_or_not_truth() {
        let cols = 130; // crosses word boundaries
        let (mut p, r1, r2, dst) = setup(cols);
        let a = pattern(cols, 1);
        let b = pattern(cols, 2);
        p.sa.write_row(r1, &a);
        p.sa.write_row(r2, &b);

        bulk_and(&mut p, r1, r2, dst);
        assert_eq!(p.sa.row(dst), &a.and(&b));

        bulk_or(&mut p, r1, r2, dst);
        // Sources were re-staged from r1/r2 which survive (copies used).
        assert_eq!(p.sa.row(dst), &a.or(&b));

        bulk_not(&mut p, r1, dst);
        assert_eq!(p.sa.row(dst), &a.not());
    }

    #[test]
    fn sources_preserved() {
        let cols = 64;
        let (mut p, r1, r2, dst) = setup(cols);
        let a = pattern(cols, 3);
        let b = pattern(cols, 4);
        p.sa.write_row(r1, &a);
        p.sa.write_row(r2, &b);
        bulk_and(&mut p, r1, r2, dst);
        assert_eq!(p.sa.row(r1), &a);
        assert_eq!(p.sa.row(r2), &b);
    }

    #[test]
    fn aap_costs() {
        let (mut p, r1, r2, dst) = setup(32);
        bulk_and(&mut p, r1, r2, dst);
        assert_eq!(p.stats.total_aaps(), 4);
        let (mut p2, r1, _, dst) = setup(32);
        bulk_not(&mut p2, r1, dst);
        assert_eq!(p2.stats.total_aaps(), 2);
    }

    #[test]
    fn maj3_ternary_dot_product_property() {
        // The DrAcc-style use: ternary weights via majority votes.
        crate::testutil::check(25, |rng| {
            let cols = 1 + rng.below(100);
            let mut p = PimSubarray::new(2, cols, 6);
            let base = p.layout.data_base;
            let rows: Vec<BitRow> =
                (0..3).map(|s| pattern(cols, rng.below(64) + s)).collect();
            for (i, r) in rows.iter().enumerate() {
                p.sa.write_row(base + i, r);
            }
            bulk_maj3(&mut p, base, base + 1, base + 2, base + 3);
            for c in 0..cols {
                let votes =
                    rows.iter().filter(|r| r.get(c)).count();
                prop_assert_eq!(p.sa.get_bit(base + 3, c), votes >= 2);
            }
            Ok(())
        });
    }
}
