//! In-DRAM compute primitives (DESIGN.md S2–S5): RowClone, the proposed
//! 3-transistor AND, majority-based bit-serial ADD, and the paper's
//! n-bit column-parallel multiplication, all operating on the functional
//! [`crate::dram::Subarray`] with AAP-level cost accounting.
//!
//! Layout of a PIM-enabled subarray (rows, top to bottom):
//!
//! ```text
//! 0            row0 (all zeros)
//! 1..=8        A, A-1, B, B-1, Cin, Cin-1, Cout, Cout-1   (compute rows)
//! 9..9+n-1     I0..In-2 (intermediate ADD results, n > 2)
//! then         P0..P(2n-1)   product rows for the active pair
//! then         operand pairs, bit-transposed: pair p occupies 2n rows
//!              (n activation bits, then n weight bits)
//! ```

pub mod add;
pub mod and_op;
pub mod bulk;
pub mod cost;
pub mod mul;
pub mod rowclone;

pub use cost::{CostModel, add_aaps, mul_aaps, paper_mul_aaps};

use crate::dram::{BitRow, Command, CommandStats, Subarray};

/// Row-index layout for a PIM subarray configured for n-bit operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    pub n: usize,
    pub row0: usize,
    pub a: usize,
    pub a1: usize,
    pub b: usize,
    pub b1: usize,
    pub cin: usize,
    pub cin1: usize,
    pub cout: usize,
    pub cout1: usize,
    /// First intermediate row (I0); n-1 rows follow.
    pub i_base: usize,
    /// First product row (P0); 2n rows follow.
    pub p_base: usize,
    /// First operand data row.
    pub data_base: usize,
}

impl Layout {
    pub fn new(n: usize) -> Self {
        assert!((1..=16).contains(&n), "operand bits {n} out of range");
        let i_base = 9;
        let p_base = i_base + n.saturating_sub(1);
        let data_base = p_base + 2 * n;
        Layout {
            n,
            row0: 0,
            a: 1,
            a1: 2,
            b: 3,
            b1: 4,
            cin: 5,
            cin1: 6,
            cout: 7,
            cout1: 8,
            i_base,
            p_base,
            data_base,
        }
    }

    /// Row of activation bit `bit` of pair `pair`.
    pub fn act_row(&self, pair: usize, bit: usize) -> usize {
        debug_assert!(bit < self.n);
        self.data_base + pair * 2 * self.n + bit
    }

    /// Row of weight bit `bit` of pair `pair`.
    pub fn wgt_row(&self, pair: usize, bit: usize) -> usize {
        debug_assert!(bit < self.n);
        self.data_base + pair * 2 * self.n + self.n + bit
    }

    /// Product row for bit `bit` (0..2n).
    pub fn p_row(&self, bit: usize) -> usize {
        debug_assert!(bit < 2 * self.n);
        self.p_base + bit
    }

    /// Rows needed to hold `pairs` stacked operand pairs.
    pub fn rows_needed(&self, pairs: usize) -> usize {
        self.data_base + pairs * 2 * self.n
    }
}

/// A PIM-enabled subarray: functional array + layout + command accounting.
#[derive(Debug, Clone)]
pub struct PimSubarray {
    pub sa: Subarray,
    pub layout: Layout,
    pub stats: CommandStats,
    pub cost_model: CostModel,
}

impl PimSubarray {
    /// Create with enough rows for `pairs` stacked operand pairs of n bits,
    /// `cols` columns (one multiplication per column).
    pub fn new(n: usize, cols: usize, pairs: usize) -> Self {
        let layout = Layout::new(n);
        let rows = layout.rows_needed(pairs.max(1));
        PimSubarray {
            sa: Subarray::new(rows, cols),
            layout,
            stats: CommandStats::new(),
            cost_model: CostModel::Paper,
        }
    }

    /// Store an (activation, weight) operand pair bit-transposed into
    /// `col` at stack position `pair`. Values must fit in n bits.
    pub fn write_pair(&mut self, col: usize, pair: usize, act: u64, wgt: u64) {
        let n = self.layout.n;
        assert!(act < (1 << n), "activation {act} exceeds {n} bits");
        assert!(wgt < (1 << n), "weight {wgt} exceeds {n} bits");
        for bit in 0..n {
            self.sa
                .set_bit(self.layout.act_row(pair, bit), col, (act >> bit) & 1 == 1);
            self.sa
                .set_bit(self.layout.wgt_row(pair, bit), col, (wgt >> bit) & 1 == 1);
        }
    }

    /// Read back the 2n-bit product of `col` from the product rows.
    pub fn read_product(&self, col: usize) -> u64 {
        let mut v = 0u64;
        for bit in 0..2 * self.layout.n {
            if self.sa.get_bit(self.layout.p_row(bit), col) {
                v |= 1 << bit;
            }
        }
        v
    }

    /// Read product bit-plane `bit` across all columns (what the adder tree
    /// consumes, one bit position at a time — §IV dataflow).
    pub fn product_plane(&self, bit: usize) -> &BitRow {
        self.sa.row(self.layout.p_row(bit))
    }

    pub(crate) fn charge(&mut self, cmd: Command) {
        self.stats.record(cmd);
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_rows_disjoint() {
        for n in [1, 2, 4, 8, 16] {
            let l = Layout::new(n);
            let mut seen = std::collections::HashSet::new();
            let mut rows = vec![
                l.row0, l.a, l.a1, l.b, l.b1, l.cin, l.cin1, l.cout, l.cout1,
            ];
            for i in 0..n.saturating_sub(1) {
                rows.push(l.i_base + i);
            }
            for b in 0..2 * n {
                rows.push(l.p_row(b));
            }
            rows.push(l.act_row(0, 0));
            rows.push(l.wgt_row(0, n - 1));
            for r in rows {
                assert!(seen.insert(r), "duplicate row {r} at n={n}");
            }
        }
    }

    #[test]
    fn pair_rows_stack() {
        let l = Layout::new(8);
        assert_eq!(l.act_row(1, 0) - l.act_row(0, 0), 16);
        assert_eq!(l.wgt_row(0, 0) - l.act_row(0, 0), 8);
        assert_eq!(l.rows_needed(255), l.data_base + 255 * 16);
    }

    #[test]
    fn write_read_pair_roundtrip() {
        let mut p = PimSubarray::new(8, 16, 2);
        p.write_pair(3, 1, 0xAB, 0x5F);
        let n = p.layout.n;
        let mut act = 0u64;
        let mut wgt = 0u64;
        for bit in 0..n {
            if p.sa.get_bit(p.layout.act_row(1, bit), 3) {
                act |= 1 << bit;
            }
            if p.sa.get_bit(p.layout.wgt_row(1, bit), 3) {
                wgt |= 1 << bit;
            }
        }
        assert_eq!(act, 0xAB);
        assert_eq!(wgt, 0x5F);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn write_pair_range_checked() {
        let mut p = PimSubarray::new(4, 8, 1);
        p.write_pair(0, 0, 16, 0);
    }
}
