//! RowClone (Seshadri et al. [15]) — bulk row copy, the data-movement
//! primitive the paper adopts for operand staging and inter-bank transfer.

use crate::dram::{Command, CommandStats, Subarray};

/// Intra-subarray copy: source activation, destination activation while the
/// sense amps still hold the data — one AAP.
pub fn copy_intra(
    sa: &mut Subarray,
    stats: &mut CommandStats,
    src: usize,
    dst: usize,
) {
    sa.copy_row(src, dst);
    stats.record(Command::RowCloneIntra);
}

/// Intra-subarray copy into *two* destination rows in one AAP — the
/// split-row decoder activates both targets (how [5] achieves 4n+1 adds and
/// how operands land in (A, A-1) pairs).
pub fn copy_intra_dual(
    sa: &mut Subarray,
    stats: &mut CommandStats,
    src: usize,
    dst1: usize,
    dst2: usize,
) {
    sa.copy_row(src, dst1);
    sa.copy_row(src, dst2);
    stats.record(Command::RowCloneIntra);
}

/// Inter-bank copy of one row over the internal bus (RowClone PSM): the
/// functional part moves the row between two subarray models; the cost is
/// serialized bus beats plus two row cycles.
pub fn copy_inter_bank(
    src: &Subarray,
    src_row: usize,
    dst: &mut Subarray,
    dst_row: usize,
    stats: &mut CommandStats,
) {
    let data = src.row(src_row).clone();
    let bits = data.cols() as u32;
    dst.write_row(dst_row, &data);
    stats.record(Command::RowCloneInter { row_bits: bits });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::BitRow;

    #[test]
    fn intra_copy_one_aap() {
        let mut sa = Subarray::new(8, 32);
        let mut stats = CommandStats::new();
        sa.write_row(2, &BitRow::from_fn(32, |c| c % 3 == 0));
        copy_intra(&mut sa, &mut stats, 2, 5);
        assert_eq!(sa.row(5), sa.row(2));
        assert_eq!(stats.rowclone_intra, 1);
        assert_eq!(stats.total_aaps(), 1);
    }

    #[test]
    fn dual_copy_one_aap_two_rows() {
        let mut sa = Subarray::new(8, 16);
        let mut stats = CommandStats::new();
        sa.write_row(0, &BitRow::from_fn(16, |c| c < 8));
        copy_intra_dual(&mut sa, &mut stats, 0, 3, 4);
        assert_eq!(sa.row(3), sa.row(0));
        assert_eq!(sa.row(4), sa.row(0));
        assert_eq!(stats.total_aaps(), 1);
    }

    #[test]
    fn inter_bank_copy_moves_data_and_counts_bits() {
        let mut src = Subarray::new(4, 128);
        let mut dst = Subarray::new(4, 128);
        let mut stats = CommandStats::new();
        src.write_row(1, &BitRow::from_fn(128, |c| c % 2 == 1));
        copy_inter_bank(&src, 1, &mut dst, 2, &mut stats);
        assert_eq!(dst.row(2), src.row(1));
        assert_eq!(stats.rowclone_inter, 1);
        assert_eq!(stats.rowclone_inter_bits, 128);
    }
}
