//! The proposed in-subarray AND (§III-A): the paper's new primitive.
//!
//! Three stages, each one AAP:
//!   1. RowClone operand 1 → compute row A
//!   2. RowClone operand 2 → compute row A-1
//!   3. Activate AND-WL: per column, the stored value of A gates which cell
//!      charge-shares with the bitline (NMOS connects A-1 when A=1, PMOS
//!      connects A when A=0), so the sensed value is `A AND A-1`; the
//!      destination row(s) are activated while the sense amps hold it.

use super::PimSubarray;
use crate::dram::Command;

/// Full 3-AAP AND of two stored rows into `dst_rows` (1 or 2 destinations —
/// two via the split decoder, as the multiply uses for (A, A-1) and (B, B-1)
/// writebacks).
pub fn in_dram_and(p: &mut PimSubarray, src1: usize, src2: usize, dst_rows: &[usize]) {
    assert!(!dst_rows.is_empty() && dst_rows.len() <= 2);
    let l = p.layout;
    p.sa.copy_row(src1, l.a);
    p.charge(Command::RowCloneIntra);
    p.sa.copy_row(src2, l.a1);
    p.charge(Command::RowCloneIntra);
    p.sa.and_wl(l.a, l.a1, dst_rows);
    p.charge(Command::Aap { rows: 1 });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::BitRow;

    #[test]
    fn and_truth_table_column_parallel() {
        let mut p = PimSubarray::new(2, 4, 1);
        let (r1, r2) = (p.layout.act_row(0, 0), p.layout.wgt_row(0, 0));
        // columns: (0,0) (0,1) (1,0) (1,1)
        p.sa.write_row(r1, &BitRow::from_fn(4, |c| c >= 2));
        p.sa.write_row(r2, &BitRow::from_fn(4, |c| c % 2 == 1));
        let dst = p.layout.p_row(0);
        in_dram_and(&mut p, r1, r2, &[dst]);
        assert!(!p.sa.get_bit(dst, 0));
        assert!(!p.sa.get_bit(dst, 1));
        assert!(!p.sa.get_bit(dst, 2));
        assert!(p.sa.get_bit(dst, 3));
    }

    #[test]
    fn and_costs_three_aaps() {
        let mut p = PimSubarray::new(2, 8, 1);
        let (r1, r2) = (p.layout.act_row(0, 0), p.layout.wgt_row(0, 0));
        let dst0 = p.layout.p_row(0);
        in_dram_and(&mut p, r1, r2, &[dst0]);
        assert_eq!(p.stats.total_aaps(), super::super::cost::AND_AAPS);
    }

    #[test]
    fn and_preserves_original_operands() {
        // The whole point of the compute-row copies (§III-A): source data
        // must survive the destructive sensing.
        let mut p = PimSubarray::new(2, 4, 1);
        let (r1, r2) = (p.layout.act_row(0, 0), p.layout.wgt_row(0, 0));
        let pat1 = BitRow::from_fn(4, |c| c == 1 || c == 3);
        let pat2 = BitRow::from_fn(4, |c| c >= 1);
        p.sa.write_row(r1, &pat1);
        p.sa.write_row(r2, &pat2);
        let dst0 = p.layout.p_row(0);
        in_dram_and(&mut p, r1, r2, &[dst0]);
        assert_eq!(p.sa.row(r1), &pat1);
        assert_eq!(p.sa.row(r2), &pat2);
    }

    #[test]
    fn and_dual_destination() {
        let mut p = PimSubarray::new(2, 2, 1);
        let (r1, r2) = (p.layout.act_row(0, 0), p.layout.wgt_row(0, 0));
        p.sa.write_row(r1, &BitRow::from_fn(2, |_| true));
        p.sa.write_row(r2, &BitRow::from_fn(2, |c| c == 0));
        let (d1, d2) = (p.layout.b, p.layout.b1);
        in_dram_and(&mut p, r1, r2, &[d1, d2]);
        assert!(p.sa.get_bit(d1, 0) && p.sa.get_bit(d2, 0));
        assert!(!p.sa.get_bit(d1, 1) && !p.sa.get_bit(d2, 1));
        assert_eq!(p.stats.total_aaps(), 3);
    }
}
