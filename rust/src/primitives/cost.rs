//! AAP cost model for the in-DRAM primitives — the paper's closed forms
//! (§III-B) plus an independently-derived count for cross-checking.
//!
//! The paper gives:
//!   * AND: 3 AAPs (copy A, copy B, AND-WL activation) — §III-A.
//!   * n-bit ADD (Ali et al. [5]): `4n + 1` AAPs.
//!   * n-bit MUL, n ≤ 2: `3n² + 3(n-1)² + 4` AAPs.
//!   * n-bit MUL, n > 2: `3n² + 4(n-1)³ + 4(n-1)` AAPs.
//!   * AND ops in a MUL: `(1+2+…+(n-1))·2 + n = n² - n + n = n²`… the paper
//!     writes the sum form; it reduces to `n²` partial products as expected.
//!
//! DESIGN.md §7 records the internal inconsistency between the n ≤ 2 closed
//! form and the §III-B walkthrough (which performs 2 ADDs for n = 2, not
//! (n-1)² = 1). We implement the paper's closed forms verbatim as the
//! default cost model and expose [`derived_mul_aaps`] (a from-first-
//! principles count of the §III-B sequence) behind the
//! [`CostModel::Derived`] switch; EXPERIMENTS.md compares both.

/// Which multiplication cost model the simulator charges.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CostModel {
    /// The paper's closed forms (default — reproduces the paper's numbers).
    #[default]
    Paper,
    /// First-principles op count of the described sequence.
    Derived,
}

/// AAPs for one in-subarray AND (§III-A): copy A + copy A-1 + AND-WL.
pub const AND_AAPS: u64 = 3;

/// AAPs for an n-bit in-subarray ADD (Ali et al. [5]): 4n + 1.
pub fn add_aaps(n: u64) -> u64 {
    4 * n + 1
}

/// Number of AND (partial-product) operations in an n-bit multiply.
/// Paper: `(1+2+…+(n-1))·2 + n`, i.e. one AND per (i, j) pair = n².
pub fn mul_and_ops(n: u64) -> u64 {
    let tri = (n - 1) * n / 2;
    2 * tri + n
}

/// Number of ADD operations in an n-bit multiply.
/// Paper: `(1+2+…+(n-2))·2 + (n-1) + 1` = (n-1)² + 1 for n ≥ 2; 0 for n=1.
pub fn mul_add_ops(n: u64) -> u64 {
    if n < 2 {
        return 0;
    }
    let tri = (n - 2) * (n - 1) / 2;
    2 * tri + (n - 1) + 1
}

/// The paper's closed-form AAP count for an n-bit multiply.
pub fn paper_mul_aaps(n: u64) -> u64 {
    assert!(n >= 1);
    if n <= 2 {
        3 * n * n + 3 * (n - 1) * (n - 1) + 4
    } else {
        3 * n * n + 4 * (n - 1).pow(3) + 4 * (n - 1)
    }
}

/// First-principles count of the §III-B sequence:
///   * n² ANDs at 3 AAPs each;
///   * every partial product except the first of each product column is
///     added into the (n-1)-bit running register at `4(n-1)` AAPs
///     (per-bit copy-copy-TRA-quint, as in [5] §III-B) — that's
///     `n² - (2n - 1) = (n-1)²` adds;
///   * initialization: zeroing Cin/Cin-1 and the n-1 intermediate rows,
///     one RowClone AAP each → `n + 1` AAPs.
pub fn derived_mul_aaps(n: u64) -> u64 {
    assert!(n >= 1);
    let ands = mul_and_ops(n) * AND_AAPS;
    let add_cost = if n <= 2 {
        // Single-bit adds with operands already in compute rows (§III-B:
        // "fewer AAP operations than the add in [5]"): TRA + quint = 2.
        2
    } else {
        4 * (n - 1)
    };
    let adds = (n - 1) * (n - 1) * add_cost;
    let init = n + 1;
    ands + adds + init
}

/// AAPs charged for an n-bit multiply under the chosen model.
pub fn mul_aaps(model: CostModel, n: u64) -> u64 {
    match model {
        CostModel::Paper => paper_mul_aaps(n),
        CostModel::Derived => derived_mul_aaps(n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;

    #[test]
    fn and_op_counts_reduce_to_n_squared() {
        for n in 1..=16 {
            assert_eq!(mul_and_ops(n), n * n, "n={n}");
        }
    }

    #[test]
    fn add_op_counts_closed_form() {
        assert_eq!(mul_add_ops(1), 0);
        assert_eq!(mul_add_ops(2), 2); // §III-B walkthrough: P1 add + final
        for n in 2..=16 {
            assert_eq!(mul_add_ops(n), (n - 1) * (n - 1) + 1, "n={n}");
        }
    }

    #[test]
    fn paper_formula_values() {
        // Spot values straight from the formulas.
        assert_eq!(paper_mul_aaps(1), 3 + 0 + 4);
        assert_eq!(paper_mul_aaps(2), 12 + 3 + 4);
        assert_eq!(paper_mul_aaps(4), 48 + 4 * 27 + 12);
        assert_eq!(paper_mul_aaps(8), 192 + 4 * 343 + 28);
    }

    #[test]
    fn mul_cost_cubic_growth() {
        // Fig 17's shape: runtime grows ~cubically with precision (n>2).
        let r = paper_mul_aaps(16) as f64 / paper_mul_aaps(8) as f64;
        assert!(r > 6.0 && r < 10.0, "16b/8b ratio {r}");
    }

    #[test]
    fn add_formula() {
        assert_eq!(add_aaps(1), 5);
        assert_eq!(add_aaps(8), 33);
        assert_eq!(add_aaps(32), 129);
    }

    #[test]
    fn derived_within_factor_two_of_paper() {
        crate::testutil::check(14, |rng| {
            let n = rng.int_range(2, 15) as u64;
            let p = paper_mul_aaps(n) as f64;
            let d = derived_mul_aaps(n) as f64;
            prop_assert!(d / p < 2.0 && p / d < 2.0, "n={n} paper={p} derived={d}");
            Ok(())
        });
    }

    #[test]
    fn both_models_monotone_in_n() {
        for model in [CostModel::Paper, CostModel::Derived] {
            let mut prev = 0;
            for n in 1..=16 {
                let c = mul_aaps(model, n);
                assert!(c > prev, "{model:?} n={n}");
                prev = c;
            }
        }
    }
}
