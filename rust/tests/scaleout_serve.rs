//! Coordinator end-to-end over *simulated* devices (no artifacts, no
//! PJRT): a pool with one worker per plan replica serves concurrent
//! batched traffic — the acceptance path for multi-device serving.

use std::sync::Arc;
use std::time::Duration;

use pim_dram::coordinator::{MultiDeviceServer, Policy, PoolConfig, SimBackend};
use pim_dram::sim::{simulate, SimConfig};
use pim_dram::workloads::nets::pimnet;

fn start_pool(devices: usize, policy: Policy) -> (MultiDeviceServer, usize) {
    let net = pimnet();
    let r = simulate(&net, &SimConfig::conservative(8)).unwrap();
    assert!(r.replicas() >= 2, "plan must justify a multi-device pool");
    let backend = SimBackend::from_sim(&r, &net, 8);
    let elems = backend.image_elems();
    let server = MultiDeviceServer::start(
        PoolConfig {
            devices,
            policy,
            batch_window: Duration::from_millis(5),
            ..PoolConfig::default()
        },
        move |_| Ok(backend.clone()),
    )
    .unwrap();
    (server, elems)
}

fn image(seed: usize, elems: usize) -> Vec<i32> {
    (0..elems).map(|i| ((seed * 37 + i * 13) % 256) as i32).collect()
}

#[test]
fn two_devices_serve_concurrent_clients() {
    let (server, elems) = start_pool(2, Policy::RoundRobin);
    let server = Arc::new(server);
    let n = 32usize;

    let results: Vec<(usize, usize)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let server = Arc::clone(&server);
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                for i in (t..n).step_by(4) {
                    let resp = server.classify(image(i, elems)).unwrap();
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.latency > Duration::ZERO);
                    out.push((i, resp.class));
                }
                out
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(results.len(), n);

    let m = server.metrics();
    assert_eq!(m.requests, n as u64);
    assert!(m.batches >= 1);
    assert!(m.latency_mean_us > 0.0);
    // Both devices took traffic, and round-robin splits it evenly.
    assert_eq!(m.per_device.len(), 2);
    assert_eq!(m.per_device[0], n as u64 / 2);
    assert_eq!(m.per_device[1], n as u64 / 2);
    assert_eq!(m.per_device.iter().sum::<u64>(), n as u64);

    Arc::try_unwrap(server).ok().expect("all clients done").shutdown();
}

#[test]
fn devices_classify_identically() {
    // The same image must classify the same regardless of which device
    // serves it — replicas are interchangeable.
    let (server, elems) = start_pool(3, Policy::RoundRobin);
    let img = image(7, elems);
    let mut classes = Vec::new();
    let mut devices_seen = Vec::new();
    for _ in 0..6 {
        let resp = server.classify(img.clone()).unwrap();
        classes.push(resp.class);
        devices_seen.push(resp.device);
    }
    devices_seen.sort_unstable();
    devices_seen.dedup();
    assert_eq!(devices_seen, vec![0, 1, 2]);
    assert!(classes.windows(2).all(|w| w[0] == w[1]), "{classes:?}");
    server.shutdown();
}

#[test]
fn least_loaded_and_two_choices_serve() {
    for policy in [Policy::LeastLoaded, Policy::TwoChoices] {
        let (server, elems) = start_pool(2, policy);
        for i in 0..12 {
            server.classify(image(i, elems)).unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.requests, 12);
        assert_eq!(m.per_device.iter().sum::<u64>(), 12);
        server.shutdown();
    }
}

#[test]
fn pool_batches_fill_under_burst() {
    // A burst of exactly batch-size requests to one device coalesces into
    // few executions (padding makes the count exact only when the window
    // aligns, so assert an upper bound).
    let (server, elems) = start_pool(1, Policy::RoundRobin);
    let server = Arc::new(server);
    let batch = server.batch_size();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..batch {
            let server = Arc::clone(&server);
            handles.push(scope.spawn(move || {
                server.classify(image(i, elems)).unwrap()
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert!(resp.class < 10);
        }
    });
    let m = server.metrics();
    assert_eq!(m.requests, batch as u64);
    assert!(
        m.batches <= batch as u64,
        "no batching happened: {} batches",
        m.batches
    );
}
