//! Incremental-vs-fresh equivalence (the correctness bar of the
//! `SimSession` pricing engine, DESIGN.md §8): for every network ×
//! preset × shard policy × grid, the session's two read paths must
//! reproduce `simulate()`'s report **exactly** — bit-for-bit on every
//! f64, not within an epsilon — and fail with the identical error when
//! the fresh path fails.

use pim_dram::plan::ShardPolicy;
use pim_dram::sim::{simulate, SimConfig, SimResult, SimSession};
use pim_dram::workloads::nets::all_networks;
use pim_dram::workloads::Network;

fn presets(bits: usize) -> Vec<(&'static str, SimConfig)> {
    vec![
        ("conservative", SimConfig::conservative(bits)),
        ("paper_favorable", SimConfig::paper_favorable(bits)),
    ]
}

fn grids() -> [(usize, usize); 4] {
    [(1, 4), (2, 2), (2, 4), (4, 4)]
}

fn policies() -> [ShardPolicy; 3] {
    [
        ShardPolicy::Replicate,
        ShardPolicy::LayerSplit,
        ShardPolicy::Hybrid { replicas: 2 },
    ]
}

/// Assert the full-fidelity session result matches the fresh one
/// bit-for-bit on everything the experiments read.
fn assert_full_equiv(ctx: &str, fresh: &SimResult, full: &SimResult) {
    assert_eq!(full.net_name, fresh.net_name, "{ctx}: net_name");
    assert_eq!(full.n_bits, fresh.n_bits, "{ctx}: n_bits");
    assert_eq!(
        full.pipeline.latency_ns.to_bits(),
        fresh.pipeline.latency_ns.to_bits(),
        "{ctx}: latency"
    );
    assert_eq!(
        full.pipeline.cycle_ns.to_bits(),
        fresh.pipeline.cycle_ns.to_bits(),
        "{ctx}: cycle"
    );
    assert_eq!(full.pipeline.bottleneck, fresh.pipeline.bottleneck, "{ctx}: bottleneck");
    assert_eq!(full.pipeline.stages.len(), fresh.pipeline.stages.len(), "{ctx}: stages");
    assert_eq!(full.total_aaps, fresh.total_aaps, "{ctx}: aaps");
    assert_eq!(
        full.total_dram_energy_nj.to_bits(),
        fresh.total_dram_energy_nj.to_bits(),
        "{ctx}: dram energy"
    );
    assert_eq!(
        full.logic_energy_nj.to_bits(),
        fresh.logic_energy_nj.to_bits(),
        "{ctx}: logic energy"
    );
    assert_eq!(
        full.throughput_ips().to_bits(),
        fresh.throughput_ips().to_bits(),
        "{ctx}: throughput"
    );
    assert_eq!(full.replicas(), fresh.replicas(), "{ctx}: replicas");
    assert_eq!(
        full.scale_out.hop_ns_total.to_bits(),
        fresh.scale_out.hop_ns_total.to_bits(),
        "{ctx}: hops"
    );
    assert_eq!(
        full.scale_out.devices.len(),
        fresh.scale_out.devices.len(),
        "{ctx}: devices"
    );
    assert_eq!(full.layers.len(), fresh.layers.len(), "{ctx}: layer count");
    for (a, b) in full.layers.iter().zip(&fresh.layers) {
        assert_eq!(a.name, b.name, "{ctx}: layer name");
        assert_eq!(a.mapping, b.mapping, "{ctx}: {} mapping", a.name);
        for (va, vb, what) in [
            (a.multiply_ns, b.multiply_ns, "multiply"),
            (a.logic_ns, b.logic_ns, "logic"),
            (a.restage_ns, b.restage_ns, "restage"),
            (a.transfer_ns, b.transfer_ns, "transfer"),
            (a.dram_energy_nj, b.dram_energy_nj, "energy"),
        ] {
            assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}: {} {}", a.name, what);
        }
        assert_eq!(a.aaps, b.aaps, "{ctx}: {} aaps", a.name);
    }
}

/// One (network, config) point: fresh vs `simulate_full` vs `report`,
/// errors included. Returns whether the point simulated successfully.
fn check_point(net: &Network, session: &mut SimSession<'_>, ctx: &str, cfg: &SimConfig) -> bool {
    let fresh = simulate(net, cfg);
    let full = session.simulate_full(cfg);
    let rep = session.report(cfg);
    match fresh {
        Err(e) => {
            assert_eq!(full.unwrap_err(), e, "{ctx}: full error");
            assert_eq!(rep.unwrap_err(), e, "{ctx}: report error");
            false
        }
        Ok(fresh) => {
            let full = full.unwrap_or_else(|e| panic!("{ctx}: full failed: {e}"));
            assert_full_equiv(ctx, &fresh, &full);
            let rep = rep.unwrap_or_else(|e| panic!("{ctx}: report failed: {e}"));
            assert_eq!(rep.net_name, fresh.net_name, "{ctx}: rep net");
            assert_eq!(
                rep.latency_ns.to_bits(),
                fresh.latency_ns().to_bits(),
                "{ctx}: rep latency"
            );
            assert_eq!(
                rep.cycle_ns.to_bits(),
                fresh.pipeline.cycle_ns.to_bits(),
                "{ctx}: rep cycle"
            );
            assert_eq!(rep.bottleneck, fresh.pipeline.bottleneck, "{ctx}: rep bottleneck");
            assert_eq!(rep.total_aaps, fresh.total_aaps, "{ctx}: rep aaps");
            assert_eq!(
                rep.total_dram_energy_nj.to_bits(),
                fresh.total_dram_energy_nj.to_bits(),
                "{ctx}: rep dram energy"
            );
            assert_eq!(
                rep.logic_energy_nj.to_bits(),
                fresh.logic_energy_nj.to_bits(),
                "{ctx}: rep logic energy"
            );
            assert_eq!(
                rep.throughput_ips().to_bits(),
                fresh.throughput_ips().to_bits(),
                "{ctx}: rep throughput"
            );
            assert_eq!(rep.replicas, fresh.replicas(), "{ctx}: rep replicas");
            assert_eq!(
                rep.devices_total(),
                fresh.scale_out.devices_total(),
                "{ctx}: rep devices"
            );
            assert_eq!(
                rep.hop_ns_total.to_bits(),
                fresh.scale_out.hop_ns_total.to_bits(),
                "{ctx}: rep hops"
            );
            assert_eq!(
                rep.fully_resident,
                fresh.layers.iter().all(|l| l.mapping.fully_resident()),
                "{ctx}: rep residency"
            );
            true
        }
    }
}

#[test]
fn session_reproduces_simulate_across_the_design_space() {
    let mut points = 0usize;
    let mut simulated = 0usize;
    for net in all_networks() {
        let mut session = SimSession::new(&net);
        for bits in [4usize, 8] {
            for (preset_name, preset) in presets(bits) {
                for (channels, ranks) in grids() {
                    for policy in policies() {
                        let cfg = preset
                            .clone()
                            .with_grid(channels, ranks)
                            .with_shard(policy);
                        let ctx = format!(
                            "{} {preset_name} {bits}b {channels}x{ranks} {policy}",
                            net.name
                        );
                        points += 1;
                        if check_point(&net, &mut session, &ctx, &cfg) {
                            simulated += 1;
                        }
                    }
                }
            }
        }
        let (hits, _) = session.cache_stats();
        assert!(hits > 0, "{}: grid/shard sweep must hit the cache", net.name);
    }
    // The sweep must exercise both successful and failing lowerings.
    assert!(simulated >= points / 2, "{simulated}/{points} points simulated");
    assert!(simulated < points, "expected some plan errors in the grid sweep");
}

#[test]
fn session_reproduces_ks_sweeps() {
    for net in all_networks() {
        let mut session = SimSession::new(&net);
        for k in [1usize, 2, 3, 8] {
            let cfg = SimConfig::paper_favorable(8).with_ks(vec![k]);
            let ctx = format!("{} k={k}", net.name);
            assert!(check_point(&net, &mut session, &ctx, &cfg), "{ctx}");
        }
        // Per-layer vectors too (the optimizer's output shape).
        let ks: Vec<usize> = (0..net.layers.len())
            .map(|i| if i % 2 == 0 { 1 } else { 2 })
            .collect();
        let cfg = SimConfig::conservative(8).with_ks(ks);
        let ctx = format!("{} per-layer ks", net.name);
        assert!(check_point(&net, &mut session, &ctx, &cfg), "{ctx}");
    }
}

#[test]
fn batched_serve_pricing_matches_per_request_job_reports() {
    use pim_dram::api::{Job, Spec};
    use pim_dram::coordinator::SimBackend;

    let base = Spec::builtin("vgg16").with_preset("conservative");
    let variants = vec![
        base.clone(),
        base.clone().with_grid(2, 4).with_shard(ShardPolicy::LayerSplit),
        base.clone().with_grid(4, 4).with_shard(ShardPolicy::Hybrid { replicas: 2 }),
        base.clone().with_ks(vec![2]),
        // 16 layer banks overflow a 1×1 grid — a per-request failure that
        // must poison only its own slot.
        base.clone().with_grid(1, 1),
    ];
    let cfgs: Vec<SimConfig> = variants
        .iter()
        .map(|v| Job::new(v.clone()).unwrap().config().clone())
        .collect();

    let job = Job::new(base).unwrap();
    let mut session = job.session();
    let batched = SimBackend::price_batch(&mut session, &cfgs);
    assert_eq!(batched.len(), variants.len());

    let mut failures = 0usize;
    for (variant, got) in variants.iter().zip(&batched) {
        let ctx = format!("serve batch slot for {variant:?}");
        let want = Job::new(variant.clone()).unwrap().report();
        match (want, got) {
            (Ok(want), Ok(got)) => {
                assert_eq!(&want, got, "{ctx}");
                assert_eq!(
                    want.cycle_ns.to_bits(),
                    got.cycle_ns.to_bits(),
                    "{ctx}: cycle bits"
                );
                assert_eq!(
                    want.latency_ns.to_bits(),
                    got.latency_ns.to_bits(),
                    "{ctx}: latency bits"
                );
                assert_eq!(
                    want.hop_ns_total.to_bits(),
                    got.hop_ns_total.to_bits(),
                    "{ctx}: hop bits"
                );
            }
            (Err(want), Err(got)) => {
                assert_eq!(&want, got, "{ctx}: error");
                failures += 1;
            }
            (want, got) => panic!("{ctx}: mismatch {want:?} vs {got:?}"),
        }
    }
    assert_eq!(failures, 1, "exactly the 1x1 grid slot must fail");

    // The shared pass prices each distinct layer once; the per-request
    // loop above re-priced the network for every variant.
    let (hits, misses) = session.cache_stats();
    assert!(hits > 0, "grid/shard variants must hit the shared cache");
    assert!(
        misses < (job.network().layers.len() * variants.len()) as u64,
        "batched pass must not re-price per request ({misses} misses)"
    );
}

#[test]
fn repeated_calls_are_stable_and_cached() {
    let net = pim_dram::workloads::nets::resnet18();
    let mut session = SimSession::new(&net);
    let cfg = SimConfig::conservative(8).with_grid(2, 4).with_shard(ShardPolicy::LayerSplit);
    let first = session.report(&cfg).unwrap();
    let (_, misses_first) = session.cache_stats();
    let second = session.report(&cfg).unwrap();
    let (_, misses_second) = session.cache_stats();
    assert_eq!(first, second, "report must be deterministic");
    assert_eq!(misses_first, misses_second, "second call must be all hits");
}
